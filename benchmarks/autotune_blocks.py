"""Measured block-size autotune sweep for the Pallas kernel wrappers.

Replaces the hand-guessed ``_DEFAULT_BLOCKS`` numbers with data: for every
(op, problem shape) the serving/training hot path actually hits — the
shape grid comes from ``engine.matmul_shape_grid`` over the model zoo's
bench configs (prefill and one-token decode) — each tile candidate is
registered as an in-process table entry, run through the REAL ``ops``
wrapper (so padding, tile clamping, and the custom-VJP plumbing are all
inside the timed region), and timed best-of-``repeats``. The winner per
(op, bucketed shape, dtype) becomes a ``"source": "measured"`` entry.

Where the entries go:

* always: the ``--out`` report JSON (CI uploads it as an artifact);
* ``REPRO_REGEN_AUTOTUNE=1``: merged over the committed table at
  ``dispatch.table_path()`` (seed entries for shapes the sweep did not
  cover are kept) — this is the workflow for refreshing
  ``src/repro/kernels/autotune_table.json`` in place;
* ``--table PATH``: merged into an arbitrary table file instead.

Block kwargs only reach the Pallas backends — the pure-XLA ``ref``
backend drops them — so sweeping under ``ref`` would measure noise. The
sweep refuses to run there unless ``--backend`` names a Pallas backend
explicitly (CI smoke uses ``pallas-interpret``; real numbers come from
``pallas-tpu`` on the accelerator).

    PYTHONPATH=src:. python benchmarks/autotune_blocks.py \
        --backend pallas-interpret --smoke --out BENCH_autotune.json
    REPRO_REGEN_AUTOTUNE=1 PYTHONPATH=src:. \
        python benchmarks/autotune_blocks.py        # on-TPU refresh
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import projector
from repro.core.quant import quantize_blockwise
from repro.kernels import dispatch, ops, profile
from repro.models import model_zoo
from repro.serve import engine

MODELS = ("llama-60m", "llama-130m")

# Raw tile candidates per op; the wrapper's pick_tile/fit_block clamps
# turn these into the effective tiles, so distinct candidates that clamp
# to the same effective tuple are deduplicated before timing.
CANDIDATES = {
    "int8_matmul": [
        {"bm": bm, "bn": bn, "bk": bk}
        for bm in (64, 128, 256) for bn in (256, 512, 1024)
        for bk in (256, 512, 1024)
    ],
    "int8_matmul_t": [
        {"bm": bm, "bn": bn, "bk": bk}
        for bm in (64, 128, 256) for bn in (256, 512, 1024)
        for bk in (128, 256, 512)
    ],
    "fused_qgalore_update": [{"bm": bm, "bn": 1024}
                             for bm in (128, 256, 512)],
}

SMOKE_CANDIDATES = {
    "int8_matmul": [{"bm": bm, "bn": 256, "bk": 128} for bm in (8, 64)],
    "int8_matmul_t": [{"bm": bm, "bn": 256, "bk": 64} for bm in (8, 64)],
    "fused_qgalore_update": [{"bm": bm, "bn": 256} for bm in (32, 64)],
}


def _bestof(f, args, *, iters: int, repeats: int) -> float:
    """Best-of-``repeats`` mean wall time (us) of ``iters`` calls."""
    jax.block_until_ready(f(*args))            # compile + warm
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = f(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e6


def _effective_i8(op: str, M: int, K: int, n_pad: int, qblock: int,
                  cand: Dict[str, int]) -> Tuple[int, ...]:
    """The tile tuple the wrapper will actually run for a raw candidate
    (dedup key: candidates that clamp identically time identically)."""
    return (dispatch.pick_tile(M, cand["bm"]),
            dispatch.fit_block(n_pad, cand["bn"], qblock),
            dispatch.fit_block(K, cand["bk"]))


def sweep_int8(shapes, backend: str, *, iters: int, repeats: int,
               qblock: int, rows: List[dict]) -> None:
    key = jax.random.PRNGKey(0)
    for (M, K, N) in shapes:
        x = jax.random.normal(key, (M, K), jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), (K, N)) * 0.1
        qt = quantize_blockwise(w, bits=8, block=qblock, symmetric=True)
        n_pad = qt.q.shape[-1]
        g = jax.random.normal(jax.random.fold_in(key, 2),
                              (M, n_pad), jnp.float32)
        for op, run in (
            ("int8_matmul", lambda c: jax.jit(
                lambda a: ops.int8_matmul(a, qt, backend=backend))),
            ("int8_matmul_t", lambda c: jax.jit(
                lambda a: ops._i8t_call(backend, a, qt.q, qt.scale,
                                        qt.block))),
        ):
            operand = x if op == "int8_matmul" else g
            shape_key = (M, K)           # what the wrapper queries with
            seen: Dict[Tuple[int, ...], Dict[str, int]] = {}
            for cand in CANDIDATES[op]:
                eff = _effective_i8(op, M, K, n_pad, qt.block, cand)
                seen.setdefault(eff, cand)
            timings = []
            for cand in seen.values():
                dispatch.register_tuned(op, backend, shape_key, cand,
                                        str(operand.dtype))
                us = _bestof(run(cand), (operand,), iters=iters,
                             repeats=repeats)
                timings.append((us, cand))
            us, best = min(timings, key=lambda t: t[0])
            rows.append(_row(op, backend, shape_key, operand.dtype, best,
                             us, (M, K, N)))
            emit(f"autotune/{op}", us,
                 f"M={M};K={K};N={N};blocks={_fmt(best)};backend={backend}")


def sweep_fused(weight_shapes, backend: str, *, iters: int, repeats: int,
                rank: int, qblock: int, rows: List[dict]) -> None:
    key = jax.random.PRNGKey(3)
    for (m, n) in weight_shapes:
        W = jax.random.normal(key, (m, n)) * 0.02
        qt = quantize_blockwise(W, bits=8, block=qblock, symmetric=True)
        n_pad = qt.q.shape[-1]
        P = jnp.linalg.qr(jax.random.normal(
            jax.random.fold_in(key, 4), (n, rank)))[0]
        qp = projector.quantize_projection(P, 4, 256)
        low = jax.random.normal(jax.random.fold_in(key, 5), (m, rank))
        m32 = jnp.zeros((m, rank))
        v32 = jnp.zeros((m, rank))
        rng = jax.random.PRNGKey(6)
        shape_key = (m, n_pad)           # what the wrapper queries with

        def make(c):
            @jax.jit
            def f(low, m32, v32, rng):
                new_qt, mn, vn = ops.fused_qgalore_update(
                    qt, low, m32, v32, qp, jnp.float32(1), 1e-2, rng,
                    side="right", gscale=0.25, backend=backend)
                return new_qt.q, mn, vn
            return f

        timings = []
        seen = set()
        for cand in CANDIDATES["fused_qgalore_update"]:
            eff = min(cand["bm"], m)
            if eff in seen:
                continue
            seen.add(eff)
            dispatch.register_tuned("fused_qgalore_update", backend,
                                    shape_key, cand)
            us = _bestof(make(cand), (low, m32, v32, rng), iters=iters,
                         repeats=repeats)
            timings.append((us, cand))
        us, best = min(timings, key=lambda t: t[0])
        rows.append(_row("fused_qgalore_update", backend, shape_key,
                         None, best, us, (m, n)))
        emit("autotune/fused_qgalore_update", us,
             f"m={m};n={n};r={rank};blocks={_fmt(best)};backend={backend}")


def _row(op, backend, shape_key, dtype, blocks, us, problem) -> dict:
    return {
        "op": op, "backend": backend,
        "shape": [dispatch._bucket(int(d)) for d in shape_key],
        "dtype": str(dtype) if dtype is not None else "",
        "blocks": dict(blocks), "source": "measured",
        "us": round(us, 1), "problem": list(problem),
    }


def _fmt(blocks: Dict[str, int]) -> str:
    return "/".join(f"{k}{v}" for k, v in sorted(blocks.items()))


def shape_grid(batch: int, prompt: int):
    """Dedup (M, K, N) problems over the zoo's bench models: full-seq and
    half-seq prefill plus one-token decode."""
    shapes = set()
    weights = set()
    for arch in MODELS:
        bundle = model_zoo.build_arch(arch, dtype=jnp.float32)
        for plen in (prompt, max(prompt // 2, 1)):
            shapes.update(engine.matmul_shape_grid(bundle, batch, plen))
        shapes.update(engine.matmul_shape_grid(bundle, batch, prompt,
                                               decode=True))
        weights.update((K, N) for (_, K, N)
                       in engine.matmul_shape_grid(bundle, batch, prompt))
    return sorted(shapes), sorted(weights)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    help="pallas-tpu | pallas-interpret (default: dispatch "
                         "default; refuses ref)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt", type=int, default=128)
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + 2 candidates/op (CI artifact run)")
    ap.add_argument("--out", default="BENCH_autotune.json")
    ap.add_argument("--table", default=None,
                    help="merge measured entries into this table file")
    args = ap.parse_args(argv)

    backend = args.backend or dispatch.default_backend("int8_matmul")
    if backend == "ref":
        print("autotune_blocks: dispatch default is 'ref' — block kwargs "
              "are dropped there, nothing to tune. Pass --backend "
              "pallas-interpret (smoke) or run on TPU.", flush=True)
        with open(args.out, "w") as f:
            json.dump({"meta": {"backend": "ref", "skipped": True},
                       "entries": []}, f, indent=2)
        return None

    if args.smoke:
        CANDIDATES.clear()
        CANDIDATES.update(SMOKE_CANDIDATES)
        shapes = [(8, 64, 128), (16, 128, 96)]
        weights = [(64, 128)]
        qblock, iters, repeats = 64, 1, 1
        rank = 16
    else:
        shapes, weights = shape_grid(args.batch, args.prompt)
        qblock, iters, repeats = 256, args.iters, args.repeats
        rank = args.rank

    rows: List[dict] = []
    with profile.timed("autotune/sweep"):
        sweep_int8(shapes, backend, iters=iters, repeats=repeats,
                   qblock=qblock, rows=rows)
        sweep_fused(weights, backend, iters=iters, repeats=repeats,
                    rank=rank, qblock=qblock, rows=rows)
    dispatch._RUNTIME_TABLE.clear()      # drop sweep candidates

    # keep the best measurement per table key (two problems can bucket
    # to the same entry)
    best: Dict[tuple, dict] = {}
    for r in rows:
        k = (r["op"], r["backend"], tuple(r["shape"]), r["dtype"])
        if k not in best or r["us"] < best[k]["us"]:
            best[k] = r
    entries = [best[k] for k in sorted(best)]

    report = {
        "meta": {"backend": backend, "platform": dispatch.platform(),
                 "smoke": args.smoke, "batch": args.batch,
                 "prompt": args.prompt, "iters": iters,
                 "repeats": repeats, "n_shapes": len(shapes)},
        "entries": entries,
    }
    profile.maybe_attach(report)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out} ({len(entries)} entries)", flush=True)

    table = args.table
    if os.environ.get("REPRO_REGEN_AUTOTUNE", "0") == "1" and not table:
        table = dispatch.table_path()
    if table:
        merged = dispatch.load_table_entries(table) + entries
        dispatch.save_table_entries(merged, table)
        print(f"merged {len(entries)} measured entries into {table}",
              flush=True)
    return report


if __name__ == "__main__":
    main()
