"""Distributed Q-GaLore DP training bench: bytes-on-wire + step time,
compressed (project-before-all-reduce, ``dp_compress``) vs full-rank
GSPMD data parallelism, on a forced 8-device host mesh.

Modes (same init state, same batch, replicated optimizer state so the
wire numbers isolate the DP gradient synchronization):

* ``fullrank``   — the textbook DP-GaLore baseline: ``impl="simple"``
  materializes full-rank dW, GSPMD all-reduces it, the optimizer projects
  AFTER the reduce (what a DDP gradient hook does).
* ``gspmd``      — fused projected backward (grads leave the step
  low-rank) but no manual collectives: GSPMD places the reduction where
  it likes, auto-compressing some leaves and not others.
* ``compressed`` — the production path: fused backward + ``dp_compress``
  shard_map, ONE explicit low-rank pmean.

Measurements per mode: (a) bytes-on-wire — the summed result bytes of
every collective op (all-reduce / reduce-scatter / all-gather /
collective-permute / all-to-all) in the compiled HLO of one step, plus the
analytic payload from the leaf specs; (b) wall-clock step time (median of
``--iters`` post-warmup).

All modes use the GaLore-2-style large-scale DP recipe
``galore_embeddings=True`` (the embedding/unembedding rows otherwise
dominate the wire at these shapes); the analytic section also reports the
paper-default ``galore_embeddings=False`` ratio for honesty.

A ``compressed_zero`` variant re-times the compressed step with the
quantized optimizer state ZeRO-sharded over the DP axes
(``opt_state_sharding(zero_axes=...)``) and reports global vs
max-per-device optimizer bytes — the memory axis of the same subsystem
(its gathers/scatters are GSPMD-inserted at the point of use and show up
in its wire column; they are state traffic, not gradient sync).

A ``tp`` section re-times the compressed step on a 2-D ``(D/tp, tp)``
data x model mesh vs the pure-DP ``(D, 1)`` mesh (ZeRO off, isolating
the model axis) and reports per-device Adam-moment / INT4-projection
bytes: per the shard-dim table in ``core/projector.py`` each 2-D galore
leaf keeps exactly one of {moments, projection} on the model axis, so
``tp_model_sharded_state_reduction_x`` lands ~tp.

    PYTHONPATH=src:. python benchmarks/dist_bench.py --out BENCH_dist.json
    PYTHONPATH=src:. python benchmarks/dist_bench.py --smoke   # CI
"""
from __future__ import annotations

import argparse
import json
import re
import time

from repro.launch.mesh import force_host_device_count

_BYTES = {"f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "s8": 1,
          "u8": 1, "pred": 1, "s64": 8, "u64": 8, "f64": 8}

_COLLECTIVES = ("all-reduce", "reduce-scatter", "all-gather",
                "collective-permute", "all-to-all")


def hlo_collective_bytes(compiled_text: str) -> dict:
    """Sum the result bytes of every collective in a compiled HLO dump.

    Handles both single-result ops (``= f32[8,512]{1,0} all-reduce(...)``)
    and the tuple-result form XLA's combiner passes emit when they merge
    per-leaf reductions (``= (f32[...]{...}, f32[...]{...}) all-reduce``)
    — every tuple element is counted.
    """
    out = {k: 0 for k in _COLLECTIVES}
    pat = re.compile(
        r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s*("
        + "|".join(_COLLECTIVES) + r")\(")
    shape_pat = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    for m in pat.finditer(compiled_text):
        op = m.group(2)
        for dt, dims in shape_pat.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            out[op] += n * _BYTES.get(dt, 4)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def analytic_payload_bytes(specs) -> dict:
    """Per-step DP gradient-reduction payload (f32 words) from leaf specs.
    The compressed number is the canonical ``qgalore.dp_payload_bytes``
    counter (also what the adaptive-rank ablation asserts on), so rank
    overrides flow through here too."""
    import numpy as np
    from repro.core import qgalore
    full = 4 * sum(int(np.prod(s.shape)) for s in specs if not s.frozen)
    comp = qgalore.dp_payload_bytes(specs)
    return {"fullrank_bytes": full, "compressed_bytes": comp,
            "ratio": full / max(comp, 1)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--smoke", action="store_true",
                    help="smoke-config model (CI); full config otherwise")
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--out", default="BENCH_dist.json")
    args = ap.parse_args()

    force_host_device_count(args.devices)
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import QGaLoreConfig, ShapeCell, TrainConfig, replace
    from repro.core.optimizers import preset
    from repro.data.synthetic import batch_for_bundle
    from repro.distributed import sharding as sh
    from repro.models import model_zoo
    from repro.train import step as step_lib

    mesh = jax.make_mesh((args.devices, 1), ("data", "model"))
    bundle = model_zoo.build_arch(args.arch, smoke=args.smoke,
                                  dtype=jnp.float32)
    rank = min(args.rank, 8 if args.smoke else args.rank)
    min_dim = 32 if args.smoke else 128
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       grad_clip=1.0)
    cell = ShapeCell("bench", args.seq, args.batch, "train")
    batch = batch_for_bundle(bundle, cell, 0)

    modes = {
        "fullrank": dict(impl="simple", compress=False, zero=False),
        "gspmd": dict(impl="fused", compress=False, zero=False),
        "compressed": dict(impl="fused", compress=True, zero=False),
        "compressed_zero": dict(impl="fused", compress=True, zero=True),
    }
    report: dict = {
        "arch": args.arch, "smoke": args.smoke, "rank": rank,
        "devices": args.devices, "batch": args.batch, "seq": args.seq,
        "modes": {},
    }

    qcfg = preset("qgalore", QGaLoreConfig(
        rank=rank, min_dim=min_dim, galore_embeddings=True))
    for name, m in modes.items():
        mode_qcfg = replace(qcfg, compress_dp_grads=m["compress"])
        raw, specs = step_lib.build_train_step(
            bundle, mode_qcfg, tcfg, impl=m["impl"],
            param_dtype=jnp.float32, mesh=mesh, dp_compress=m["compress"])
        state = step_lib.init_state(bundle, mode_qcfg,
                                    jax.random.PRNGKey(0), jnp.float32)
        p_sh = sh.param_sharding(state.params, mesh)
        zaxes = sh.zero_axes_for(mesh) if m["zero"] else ()
        o_sh = sh.opt_state_sharding(state.params, state.opt, mode_qcfg,
                                     mesh, zero_axes=zaxes)
        b_sh = sh.data_sharding(
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch),
            mesh)
        rep = sh.replicated(mesh)
        ss = step_lib.TrainState(p_sh, o_sh)
        fn = jax.jit(lambda st, b, lr, rng: raw(
            st, b, lr, rng, refresh_masks=None, refresh=False),
            in_shardings=(ss, b_sh, rep, rep), out_shardings=(ss, None, None))

        with mesh:
            st = jax.device_put(state, ss)
            bt = jax.device_put(batch, b_sh)
            lowered = fn.lower(st, bt, 1e-3, jax.random.PRNGKey(1))
            compiled = lowered.compile()
            wire = hlo_collective_bytes(compiled.as_text())
            # warm + time
            st2, metrics, _ = fn(st, bt, 1e-3, jax.random.PRNGKey(1))
            jax.block_until_ready(st2)
            times = []
            for i in range(args.iters):
                t0 = time.monotonic()
                st2, metrics, _ = fn(st2, bt, 1e-3, jax.random.PRNGKey(i))
                jax.block_until_ready(st2)
                times.append(time.monotonic() - t0)
        opt_leaves = [l for l in jax.tree_util.tree_leaves(st2.opt)
                      if hasattr(l, "addressable_shards")]
        report["modes"][name] = {
            "loss": float(metrics["loss"]),
            "step_time_s_median": float(np.median(times)),
            "step_time_s_all": [round(t, 4) for t in times],
            "hlo_collective_bytes": wire,
            "opt_state_bytes_global": sum(l.nbytes for l in opt_leaves),
            "opt_state_bytes_max_per_device": sum(
                max(s.data.nbytes for s in l.addressable_shards)
                for l in opt_leaves),
        }
        print(f"{name:>16}: loss {report['modes'][name]['loss']:.4f}  "
              f"step {report['modes'][name]['step_time_s_median']:.3f}s  "
              f"wire {wire['total'] / 2**20:.1f} MiB")

    # ------------------------------------------------------------------
    # TP section: the same compressed step on a pure-DP (D,1) mesh vs a
    # 2-D (D/tp, tp) data x model mesh, ZeRO off so the model axis does
    # all the state-sharding work (the compressed_zero mode above covers
    # the DP/ZeRO axis). Per the shard-dim table in core/projector.py
    # every 2-D galore leaf keeps exactly ONE of {Adam moments, INT4
    # projection} on the model axis, so that component's per-device peak
    # drops ~tp-fold; the headline ratio below measures exactly those
    # components under both placements.
    # ------------------------------------------------------------------
    from repro.core import projector, qgalore, quant

    tp = 4 if args.devices % 4 == 0 else 2
    qcfg_tp = replace(qcfg, compress_dp_grads=True)
    tp_runs: dict = {}
    for shape in ((args.devices, 1), (args.devices // tp, tp)):
        dname = f"{shape[0]}x{shape[1]}"
        mesh_t = jax.make_mesh(shape, ("data", "model"))
        raw, specs_t = step_lib.build_train_step(
            bundle, qcfg_tp, tcfg, impl="fused", param_dtype=jnp.float32,
            mesh=mesh_t, dp_compress=True)
        state = step_lib.init_state(bundle, qcfg_tp, jax.random.PRNGKey(0),
                                    jnp.float32)
        p_sh = sh.param_sharding(state.params, mesh_t)
        o_sh = sh.opt_state_sharding(state.params, state.opt, qcfg_tp,
                                     mesh_t)
        b_sh = sh.data_sharding(
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch),
            mesh_t)
        rep = sh.replicated(mesh_t)
        ss = step_lib.TrainState(p_sh, o_sh)
        fn = jax.jit(lambda st, b, lr, rng: raw(
            st, b, lr, rng, refresh_masks=None, refresh=False),
            in_shardings=(ss, b_sh, rep, rep),
            out_shardings=(ss, None, None))
        with mesh_t:
            st = jax.device_put(state, ss)
            bt = jax.device_put(batch, b_sh)
            wire = hlo_collective_bytes(
                fn.lower(st, bt, 1e-3, jax.random.PRNGKey(1))
                .compile().as_text())
            st2, metrics, _ = fn(st, bt, 1e-3, jax.random.PRNGKey(1))
            jax.block_until_ready(st2)
            times = []
            for i in range(args.iters):
                t0 = time.monotonic()
                st2, metrics, _ = fn(st2, bt, 1e-3, jax.random.PRNGKey(i))
                jax.block_until_ready(st2)
                times.append(time.monotonic() - t0)

        def split(tree):
            leaves = [l for l in jax.tree_util.tree_leaves(tree)
                      if hasattr(l, "addressable_shards")]
            return (sum(l.nbytes for l in leaves),
                    sum(max(s.data.nbytes for s in l.addressable_shards)
                        for l in leaves))

        mom_g, mom_d = split(st2.opt.inner)
        prj_g, prj_d = split(st2.opt.proj)
        tp_runs[dname] = {
            "specs": specs_t,
            "inner_flat": jax.tree_util.tree_flatten(
                st2.opt.inner, is_leaf=qgalore._is_inner_leaf)[0],
            "proj_flat": jax.tree_util.tree_flatten(
                st2.opt.proj,
                is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0],
            "summary": {
                "loss": float(metrics["loss"]),
                "step_time_s_median": float(np.median(times)),
                "hlo_collective_bytes": wire,
                "moment_bytes_global": mom_g,
                "moment_bytes_max_per_device": mom_d,
                "projection_bytes_global": prj_g,
                "projection_bytes_max_per_device": prj_d,
            },
        }
        print(f"{dname:>16}: loss {metrics['loss']:.4f}  "
              f"step {float(np.median(times)):.3f}s  "
              f"state/dev {(mom_d + prj_d) / 2**20:.2f} MiB")

    dp_name = f"{args.devices}x1"
    tp_name = f"{args.devices // tp}x{tp}"
    # the model-sharded component of every 2-D galore leaf, measured
    # under BOTH placements (leaf order is mesh-independent)
    specs_2d = tp_runs[tp_name]["specs"]

    def sharded_component_device_bytes(run):
        total = 0
        for i, sp in enumerate(specs_2d):
            if not sp.galore or sp.shard_dim is None:
                continue
            tgt = run["proj_flat"][i] if projector.proj_dim_sharded(
                sp.side, sp.shard_dim) else run["inner_flat"][i]
            total += sum(
                max(s.data.nbytes for s in a.addressable_shards)
                for a in jax.tree_util.tree_leaves(tgt))
        return total

    report["tp"] = {
        "tp_degree": tp,
        "meshes": {k: v["summary"] for k, v in tp_runs.items()},
        "model_sharded_component_device_bytes": {
            k: sharded_component_device_bytes(v)
            for k, v in tp_runs.items()},
    }
    report["tp_model_sharded_state_reduction_x"] = (
        report["tp"]["model_sharded_component_device_bytes"][dp_name]
        / max(report["tp"]["model_sharded_component_device_bytes"][tp_name],
              1))
    report["tp_galore_state_device_reduction_x"] = (
        (tp_runs[dp_name]["summary"]["moment_bytes_max_per_device"]
         + tp_runs[dp_name]["summary"]["projection_bytes_max_per_device"])
        / max(tp_runs[tp_name]["summary"]["moment_bytes_max_per_device"]
              + tp_runs[tp_name]["summary"]
              ["projection_bytes_max_per_device"], 1))

    # analytic payloads for both embedding recipes (no step build needed)
    specs_emb = step_lib._specs_for(bundle, qcfg, jnp.float32)
    specs_noemb = step_lib._specs_for(
        bundle, replace(qcfg, galore_embeddings=False), jnp.float32)
    report["analytic"] = {
        "galore_embeddings": analytic_payload_bytes(specs_emb),
        "paper_default": analytic_payload_bytes(specs_noemb),
    }

    full = report["modes"]["fullrank"]
    comp = report["modes"]["compressed"]
    zero = report["modes"]["compressed_zero"]
    # headline: bytes a DDP-style full-rank gradient sync ships (every
    # grad leaf at full shape — what torch-DDP GaLore all-reduces) over
    # the bytes the compressed step MEASURABLY ships (compiled HLO)
    report["wire_reduction_x_vs_ddp"] = (
        report["analytic"]["galore_embeddings"]["fullrank_bytes"]
        / max(comp["hlo_collective_bytes"]["total"], 1))
    # vs the measured GSPMD baseline, which already auto-compresses some
    # leaves by sinking its all-reduce past projection dots
    report["wire_reduction_x_hlo"] = (
        full["hlo_collective_bytes"]["total"]
        / max(comp["hlo_collective_bytes"]["total"], 1))
    report["wire_reduction_x_analytic"] = \
        report["analytic"]["galore_embeddings"]["ratio"]
    # production TPU recipe: REPRO_BF16_REDUCE=1 reduces the low-rank
    # payload in bf16 (paper §3.1 keeps grads bf16) — half the bytes of
    # the f32 reduction measured above, vs a DDP stack shipping f32
    # master grads. (CPU CI reduces in f32 — see the XLA:CPU note in
    # train/step.py — so this cell is analytic, not HLO-measured.)
    report["wire_reduction_x_bf16_reduce_vs_ddp_f32"] = 2 * \
        report["analytic"]["galore_embeddings"]["ratio"]
    report["steptime_ratio_compressed_over_fullrank"] = (
        comp["step_time_s_median"] / full["step_time_s_median"])
    # the production configuration (launch/train --compress --zero)
    report["steptime_ratio_compressed_zero_over_fullrank"] = (
        zero["step_time_s_median"] / full["step_time_s_median"])
    report["zero_shard_reduction_x"] = (
        zero["opt_state_bytes_global"]
        / max(zero["opt_state_bytes_max_per_device"], 1))
    print(json.dumps({k: v for k, v in report.items()
                      if not isinstance(v, dict)}, indent=2))
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
