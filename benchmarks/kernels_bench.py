"""Kernel microbenchmarks (CPU wall time of the jnp reference paths +
interpret-mode Pallas correctness cost; real-TPU numbers come from the
roofline, not this box) and serving throughput.

The fused-update section times the Q-GaLore per-step weight update both
ways:

* unfused-interpret — the three-op hot path as three separate Pallas
  calls in interpret mode (INT4 projection matmul, jnp Adam, SR requant),
  which is what the per-leaf loop used to run on CPU containers;
* unfused-same-backend — the same three-op composition on the
  dispatch-selected default backend (isolates the fusion benefit from
  the interpreter overhead);
* fused   — ``ops.fused_qgalore_update`` on the dispatch-selected default
  backend (pure-XLA ``ref`` off-TPU, ``pallas-tpu`` on TPU),

and emits both speedup ratios.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import projector, quant
from repro.core.quant import quantize_blockwise
from repro.kernels import dispatch, ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters * 1e6


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 1024), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (1024, 2048))
    qt = quantize_blockwise(w, bits=8, symmetric=True)

    f_deq = jax.jit(lambda a, q: a @ quant.dequantize(q, jnp.float32))
    us = _time(f_deq, x, qt)
    emit("kernels/int8_dense_jnp", us, "M=512;K=1024;N=2048")

    P = jax.random.normal(jax.random.fold_in(key, 2), (1024, 128)) * 0.1
    qp = quantize_blockwise(P, bits=4, block=128, symmetric=False)
    f_proj = jax.jit(lambda g, q: g @ quant.dequantize(q, jnp.float32))
    us = _time(f_proj, x, qp)
    emit("kernels/int4_project_jnp", us, "M=512;K=1024;R=128")

    f_q = jax.jit(lambda a: quantize_blockwise(a, bits=8, symmetric=True).q)
    us = _time(f_q, w)
    emit("kernels/blockwise_quant_jnp", us, "K=1024;N=2048")

    upd = jax.random.normal(jax.random.fold_in(key, 3), w.shape) * 1e-3
    f_sr = jax.jit(lambda q, u, k: quant.requantize_sr(q, u, k).q)
    us = _time(f_sr, qt, upd, jax.random.PRNGKey(9))
    emit("kernels/sr_requant_jnp", us, "K=1024;N=2048")

    # Pallas interpret-mode parity cost (correctness harness, not perf)
    t0 = time.monotonic()
    out = ops.int8_matmul(x[:128, :256], quantize_blockwise(
        w[:256, :512], bits=8, symmetric=True), interpret=True)
    jax.block_until_ready(out)
    emit("kernels/int8_pallas_interpret", (time.monotonic() - t0) * 1e6,
         "M=128;K=256;N=512;mode=interpret")

    quantized_dense_bench(key)
    fused_update_bench(key)


def quantized_dense_bench(key, m=512, k=1024, n=2048, iters=5):
    """quantized_dense fwd + fwd/bwd vs the dequantize-then-einsum baseline
    on the dispatch default backend (the model hot path A/B)."""
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 20), (k, n)) * 0.1
    qt = quantize_blockwise(w, bits=8, symmetric=True)
    backend = dispatch.default_backend("quantized_dense")
    shape = f"M={m};K={k};N={n}"

    f_q = jax.jit(lambda a: ops.quantized_dense(a, qt, dtype=jnp.float32,
                                                backend=backend))
    f_d = jax.jit(lambda a: a @ quant.dequantize(qt, jnp.float32))
    us_q = _time(f_q, x, iters=iters)
    us_d = _time(f_d, x, iters=iters)
    emit("kernels/quantized_dense_fwd", us_q, shape + f";backend={backend}")
    emit("kernels/dequant_dense_fwd", us_d, shape)

    # fwd + bwd (dL/dx and dL/dW) through the custom VJP vs autodiff of
    # the dequant einsum
    wv = quant.virtualize(qt)

    @jax.jit
    def g_q(a, shadow):
        def f(aa, sh):
            out = ops.quantized_dense(
                aa, quant.QVirtual(qt, sh), dtype=jnp.float32,
                backend=backend)
            return jnp.sum(out * out)
        return jax.grad(f, argnums=(0, 1))(a, shadow)

    @jax.jit
    def g_d(a, wfull):
        def f(aa, ww):
            out = aa @ ww
            return jnp.sum(out * out)
        return jax.grad(f, argnums=(0, 1))(a, wfull)

    wd = quant.dequantize(qt, jnp.float32)
    us_qg = _time(g_q, x, wv.shadow, iters=iters)
    us_dg = _time(g_d, x, wd, iters=iters)
    emit("kernels/quantized_dense_fwdbwd", us_qg,
         shape + f";backend={backend}")
    emit("kernels/dequant_dense_fwdbwd", us_dg, shape)
    emit("kernels/quantized_dense_fwd_speedup", us_d / us_q,
         shape + ";unit=x;baseline=dequant-einsum")
    emit("kernels/quantized_dense_fwdbwd_speedup", us_dg / us_qg,
         shape + ";unit=x;baseline=dequant-einsum")


def fused_update_bench(key, m=2048, n=1024, r=128, iters=3):
    """Fused vs unfused Q-GaLore step update (acceptance: >= 1.5x).

    Both variants are jitted end-to-end over a llama-130m-sized layer so
    the comparison measures the update pipeline, not Python dispatch.
    """
    W = jax.random.normal(jax.random.fold_in(key, 10), (m, n)) * 0.02
    qt = quantize_blockwise(W, bits=8, symmetric=True)
    P = jnp.linalg.qr(
        jax.random.normal(jax.random.fold_in(key, 11), (n, r)))[0]
    qp = projector.quantize_projection(P, 4, 256)
    grad = jax.random.normal(jax.random.fold_in(key, 12), (m, n))
    m32 = jnp.zeros((m, r))
    v32 = jnp.zeros((m, r))
    b1, b2, eps, gscale, lr = 0.9, 0.999, 1e-8, 0.25, 1e-2
    rng = jax.random.PRNGKey(5)

    backend = dispatch.default_backend("fused_qgalore_update")

    def make_unfused(op_backend):
        @jax.jit
        def unfused(grad, m32, v32, rng):
            # three separate op calls, as the per-leaf loop ran them:
            # project, Adam (jnp), SR-requant
            low = ops.int4_project(grad, qp, backend=op_backend)
            m_new = b1 * m32 + (1 - b1) * low
            v_new = b2 * v32 + (1 - b2) * low * low
            dirn = (m_new / (1 - b1)) / (jnp.sqrt(v_new / (1 - b2)) + eps)
            upd = gscale * projector.project_back(
                dirn, projector.maybe_dequantize(qp), "right")
            new_qt = ops.sr_requant_update(qt, -lr * upd, rng,
                                           backend=op_backend)
            return new_qt.q, m_new, v_new
        return unfused

    @jax.jit
    def fused(grad, m32, v32, rng):
        low = projector.project(
            grad, projector.maybe_dequantize(qp), "right")
        new_qt, m_new, v_new = ops.fused_qgalore_update(
            qt, low, m32, v32, qp, jnp.float32(1), lr, rng, side="right",
            gscale=gscale, backend=backend)
        return new_qt.q, m_new, v_new

    us_interp = _time(make_unfused("pallas-interpret"), grad, m32, v32,
                      rng, iters=iters)
    us_same = _time(make_unfused(backend), grad, m32, v32, rng,
                    iters=iters)
    us_fused = _time(fused, grad, m32, v32, rng, iters=iters)
    shape = f"M={m};N={n};r={r}"
    emit("kernels/step_update_unfused_interpret", us_interp,
         shape + ";ops=3;mode=interpret")
    emit("kernels/step_update_unfused", us_same,
         shape + f";ops=3;backend={backend}")
    emit("kernels/step_update_fused", us_fused,
         shape + f";backend={backend}")
    # vs the old per-leaf loop on CPU containers (interpret-mode ops)
    emit("kernels/step_update_fused_speedup", us_interp / us_fused,
         shape + ";unit=x;baseline=interpret")
    # vs the same backend unfused — the fusion benefit itself
    emit("kernels/step_update_fusion_speedup", us_same / us_fused,
         shape + ";unit=x;baseline=same-backend")


if __name__ == "__main__":
    main()
