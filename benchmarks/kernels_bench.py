"""Kernel microbenchmarks (CPU wall time of the jnp reference paths +
interpret-mode Pallas correctness cost; real-TPU numbers come from the
roofline, not this box) and the quantized-vs-dequant A/B gate.

Measurement discipline
----------------------
Sequential A/B timing (run all iters of A, then all of B) is what
produced the phantom "quantized prefill regression" this box once
reported: scheduler drift between the two windows shows up as a fake
ratio. Every ratio here is measured with **interleaved paired rounds**
instead — each round times one short burst of every variant
back-to-back (alternating order round to round), the per-round ratios
are trimmed (drop the top/bottom 20%), and the trimmed mean ± standard
error is reported. A real effect survives trimming; a scheduler hiccup
lands in one round and gets dropped.

The ``--gate-out`` mode writes a machine-readable no-regression verdict
for CI: quantized_dense forward must not be slower than the
dequantize-then-einsum baseline. On the ``ref`` backend the two compile
to near-identical XLA programs, so the gate passes when the trimmed
ratio is ≥ 1.0 **or** is within 2 standard errors of 1.0 (a hard ≥ 1.0
on a noisy shared box would flake on a true ratio of exactly 1.0).

The fused-update section times the Q-GaLore per-step weight update both
ways (unfused-interpret / unfused-same-backend / fused) and emits both
speedup ratios.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, paired_ratio, paired_times
from repro.core import projector, quant
from repro.core.quant import quantize_blockwise
from repro.kernels import dispatch, ops, profile


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters * 1e6


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--inner", type=int, default=4)
    ap.add_argument("--gate-out", default=None,
                    help="write the quantized>=dequant gate verdict JSON")
    args = ap.parse_args(argv)

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 1024), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (1024, 2048))
    qt = quantize_blockwise(w, bits=8, symmetric=True)

    f_deq = jax.jit(lambda a, q: a @ quant.dequantize(q, jnp.float32))
    us = _time(f_deq, x, qt)
    emit("kernels/int8_dense_jnp", us, "M=512;K=1024;N=2048")

    P = jax.random.normal(jax.random.fold_in(key, 2), (1024, 128)) * 0.1
    qp = quantize_blockwise(P, bits=4, block=128, symmetric=False)
    f_proj = jax.jit(lambda g, q: g @ quant.dequantize(q, jnp.float32))
    us = _time(f_proj, x, qp)
    emit("kernels/int4_project_jnp", us, "M=512;K=1024;R=128")

    f_q = jax.jit(lambda a: quantize_blockwise(a, bits=8, symmetric=True).q)
    us = _time(f_q, w)
    emit("kernels/blockwise_quant_jnp", us, "K=1024;N=2048")

    upd = jax.random.normal(jax.random.fold_in(key, 3), w.shape) * 1e-3
    f_sr = jax.jit(lambda q, u, k: quant.requantize_sr(q, u, k).q)
    us = _time(f_sr, qt, upd, jax.random.PRNGKey(9))
    emit("kernels/sr_requant_jnp", us, "K=1024;N=2048")

    # Pallas interpret-mode parity cost (correctness harness, not perf)
    t0 = time.monotonic()
    out = ops.int8_matmul(x[:128, :256], quantize_blockwise(
        w[:256, :512], bits=8, symmetric=True), interpret=True)
    jax.block_until_ready(out)
    emit("kernels/int8_pallas_interpret", (time.monotonic() - t0) * 1e6,
         "M=128;K=256;N=512;mode=interpret")

    gate = quantized_dense_bench(key, rounds=args.rounds, inner=args.inner)
    fused_update_bench(key)

    if args.gate_out:
        with open(args.gate_out, "w") as f:
            json.dump(gate, f, indent=2)
        print(f"wrote {args.gate_out} (pass={gate['pass']})", flush=True)
    return gate


# (M, K, N) problems for the quantized-vs-dequant gate: a generic square-
# ish matmul, a 1-row decode shape, and a llama-60m FFN-up prefill slice
# (N=1376 exercises the quant-block column padding / tail scale group).
GATE_SHAPES = ((512, 1024, 2048), (8, 512, 512), (256, 512, 1376))


def quantized_dense_bench(key, *, rounds=12, inner=4) -> dict:
    """quantized_dense fwd + fwd/bwd vs the dequantize-then-einsum
    baseline on the dispatch default backend, measured with interleaved
    paired rounds over GATE_SHAPES. Returns the gate verdict dict."""
    backend = dispatch.default_backend("quantized_dense")
    gate = {"backend": backend, "rounds": rounds, "inner": inner,
            "criterion": "ratio_x >= 1.0 or ratio_x + 2*sem >= 1.0",
            "shapes": [], "pass": True}

    for si, (m, k, n) in enumerate(GATE_SHAPES):
        x = jax.random.normal(jax.random.fold_in(key, si), (m, k),
                              jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 20 + si),
                              (k, n)) * 0.1
        qt = quantize_blockwise(w, bits=8, symmetric=True)
        shape = f"M={m};K={k};N={n}"

        f_q = jax.jit(lambda a=x: ops.quantized_dense(
            a, qt, dtype=jnp.float32, backend=backend))
        f_d = jax.jit(lambda a=x: a @ quant.dequantize(qt, jnp.float32))
        times = paired_times({"dequant": f_d, "quantized": f_q},
                             rounds=rounds, inner=inner)
        stat = paired_ratio(times, "dequant", "quantized")
        us_q = float(np.median(times["quantized"]))
        us_d = float(np.median(times["dequant"]))
        emit("kernels/quantized_dense_fwd", us_q,
             shape + f";backend={backend}")
        emit("kernels/dequant_dense_fwd", us_d, shape)
        emit("kernels/quantized_dense_fwd_speedup", stat["ratio_x"],
             shape + f";unit=x;baseline=dequant-einsum;sem={stat['sem']:.4f}"
             f";rounds={stat['rounds']}")
        ok = (stat["ratio_x"] >= 1.0
              or stat["ratio_x"] + 2.0 * stat["sem"] >= 1.0)
        gate["shapes"].append({"shape": [m, k, n], **stat,
                               "us_quantized": us_q, "us_dequant": us_d,
                               "pass": ok})
        gate["pass"] = gate["pass"] and ok

    # fwd + bwd (dL/dx and dL/dW) through the custom VJP vs autodiff of
    # the dequant einsum — training path, QVirtual weight (shadow dL/dW)
    m, k, n = GATE_SHAPES[0]
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 20), (k, n)) * 0.1
    qt = quantize_blockwise(w, bits=8, symmetric=True)
    shape = f"M={m};K={k};N={n}"
    wv = quant.virtualize(qt)

    @jax.jit
    def g_q(a, shadow):
        def f(aa, sh):
            out = ops.quantized_dense(
                aa, quant.QVirtual(qt, sh), dtype=jnp.float32,
                backend=backend)
            return jnp.sum(out * out)
        return jax.grad(f, argnums=(0, 1))(a, shadow)

    @jax.jit
    def g_d(a, wfull):
        def f(aa, ww):
            out = aa @ ww
            return jnp.sum(out * out)
        return jax.grad(f, argnums=(0, 1))(a, wfull)

    wd = quant.dequantize(qt, jnp.float32)
    times = paired_times(
        {"dequant": lambda: g_d(x, wd),
         "quantized": lambda: g_q(x, wv.shadow)},
        rounds=rounds, inner=max(inner // 2, 1))
    stat = paired_ratio(times, "dequant", "quantized")
    emit("kernels/quantized_dense_fwdbwd",
         float(np.median(times["quantized"])),
         shape + f";backend={backend}")
    emit("kernels/dequant_dense_fwdbwd",
         float(np.median(times["dequant"])), shape)
    emit("kernels/quantized_dense_fwdbwd_speedup", stat["ratio_x"],
         shape + f";unit=x;baseline=dequant-einsum;sem={stat['sem']:.4f}")
    gate["fwdbwd"] = {"shape": [m, k, n], **stat}
    profile.maybe_attach(gate)
    return gate


def fused_update_bench(key, m=2048, n=1024, r=128, iters=3):
    """Fused vs unfused Q-GaLore step update (acceptance: >= 1.5x).

    Both variants are jitted end-to-end over a llama-130m-sized layer so
    the comparison measures the update pipeline, not Python dispatch.
    """
    W = jax.random.normal(jax.random.fold_in(key, 10), (m, n)) * 0.02
    qt = quantize_blockwise(W, bits=8, symmetric=True)
    P = jnp.linalg.qr(
        jax.random.normal(jax.random.fold_in(key, 11), (n, r)))[0]
    qp = projector.quantize_projection(P, 4, 256)
    grad = jax.random.normal(jax.random.fold_in(key, 12), (m, n))
    m32 = jnp.zeros((m, r))
    v32 = jnp.zeros((m, r))
    b1, b2, eps, gscale, lr = 0.9, 0.999, 1e-8, 0.25, 1e-2
    rng = jax.random.PRNGKey(5)

    backend = dispatch.default_backend("fused_qgalore_update")

    def make_unfused(op_backend):
        @jax.jit
        def unfused(grad, m32, v32, rng):
            # three separate op calls, as the per-leaf loop ran them:
            # project, Adam (jnp), SR-requant
            low = ops.int4_project(grad, qp, backend=op_backend)
            m_new = b1 * m32 + (1 - b1) * low
            v_new = b2 * v32 + (1 - b2) * low * low
            dirn = (m_new / (1 - b1)) / (jnp.sqrt(v_new / (1 - b2)) + eps)
            upd = gscale * projector.project_back(
                dirn, projector.maybe_dequantize(qp), "right")
            new_qt = ops.sr_requant_update(qt, -lr * upd, rng,
                                           backend=op_backend)
            return new_qt.q, m_new, v_new
        return unfused

    @jax.jit
    def fused(grad, m32, v32, rng):
        low = projector.project(
            grad, projector.maybe_dequantize(qp), "right")
        new_qt, m_new, v_new = ops.fused_qgalore_update(
            qt, low, m32, v32, qp, jnp.float32(1), lr, rng, side="right",
            gscale=gscale, backend=backend)
        return new_qt.q, m_new, v_new

    us_interp = _time(make_unfused("pallas-interpret"), grad, m32, v32,
                      rng, iters=iters)
    us_same = _time(make_unfused(backend), grad, m32, v32, rng,
                    iters=iters)
    us_fused = _time(fused, grad, m32, v32, rng, iters=iters)
    shape = f"M={m};N={n};r={r}"
    emit("kernels/step_update_unfused_interpret", us_interp,
         shape + ";ops=3;mode=interpret")
    emit("kernels/step_update_unfused", us_same,
         shape + f";ops=3;backend={backend}")
    emit("kernels/step_update_fused", us_fused,
         shape + f";backend={backend}")
    # vs the old per-leaf loop on CPU containers (interpret-mode ops)
    emit("kernels/step_update_fused_speedup", us_interp / us_fused,
         shape + ";unit=x;baseline=interpret")
    # vs the same backend unfused — the fusion benefit itself
    emit("kernels/step_update_fusion_speedup", us_same / us_fused,
         shape + ";unit=x;baseline=same-backend")


if __name__ == "__main__":
    main()
