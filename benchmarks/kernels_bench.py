"""Kernel microbenchmarks (CPU wall time of the jnp reference paths +
interpret-mode Pallas correctness cost; real-TPU numbers come from the
roofline, not this box) and serving throughput."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import quant
from repro.core.quant import quantize_blockwise
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.monotonic()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.monotonic() - t0) / iters * 1e6


def main():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (512, 1024), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (1024, 2048))
    qt = quantize_blockwise(w, bits=8, symmetric=True)

    f_deq = jax.jit(lambda a, q: a @ quant.dequantize(q, jnp.float32))
    us = _time(f_deq, x, qt)
    emit("kernels/int8_dense_jnp", us, "M=512;K=1024;N=2048")

    P = jax.random.normal(jax.random.fold_in(key, 2), (1024, 128)) * 0.1
    qp = quantize_blockwise(P, bits=4, block=128, symmetric=False)
    f_proj = jax.jit(lambda g, q: g @ quant.dequantize(q, jnp.float32))
    us = _time(f_proj, x, qp)
    emit("kernels/int4_project_jnp", us, "M=512;K=1024;R=128")

    f_q = jax.jit(lambda a: quantize_blockwise(a, bits=8, symmetric=True).q)
    us = _time(f_q, w)
    emit("kernels/blockwise_quant_jnp", us, "K=1024;N=2048")

    upd = jax.random.normal(jax.random.fold_in(key, 3), w.shape) * 1e-3
    f_sr = jax.jit(lambda q, u, k: quant.requantize_sr(q, u, k).q)
    us = _time(f_sr, qt, upd, jax.random.PRNGKey(9))
    emit("kernels/sr_requant_jnp", us, "K=1024;N=2048")

    # Pallas interpret-mode parity cost (correctness harness, not perf)
    t0 = time.monotonic()
    out = ops.int8_matmul(x[:128, :256], quantize_blockwise(
        w[:256, :512], bits=8, symmetric=True), interpret=True)
    jax.block_until_ready(out)
    emit("kernels/int8_pallas_interpret", (time.monotonic() - t0) * 1e6,
         "M=128;K=256;N=512;mode=interpret")


if __name__ == "__main__":
    main()
