"""Paper Tables 3-4 (reduced scale): fine-tuning Full vs LoRA vs GaLore vs
QLoRA vs Q-GaLore from a common pre-trained base on a held-out synthetic
task (different token distribution).

Claims under test: Q-GaLore ≈ Full/LoRA/GaLore quality; Q-GaLore beats QLoRA
at the same (lowest) memory tier."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CELL, BENCH_MODEL, bench_qcfg, \
    bench_tcfg, emit
from benchmarks.table1_pretrain import _adapter_train
from repro.config import replace
from repro.core import qgalore, quant
from repro.core.optimizers import lr_at, preset
from repro.data.synthetic import batch_for_bundle
from repro.models import base, lora as lora_lib, model_zoo
from repro.train.trainer import Trainer


def _pretrain_base(steps: int = 40):
    bundle = model_zoo.build(BENCH_MODEL, dtype=jnp.float32)
    tr = Trainer(bundle, bench_tcfg(steps), preset("full"),
                 cell=BENCH_CELL, impl="fused", param_dtype=jnp.float32)
    tr.run()
    return bundle, tr.state.params


def _finetune_opt(bundle, params, method: str, steps: int, seed: int = 101):
    """Fine-tune with an optimizer preset (full / galore / qgalore)."""
    qcfg = preset(method, bench_qcfg())
    from repro.train import step as step_lib
    params = step_lib.prepare_params(params, qcfg, jnp.float32)
    state = qgalore.init(params, qcfg)
    specs = qgalore.leaf_specs(params, qcfg)
    tcfg = replace(bench_tcfg(steps, lr=2e-3), seed=seed)
    from repro.train import stack

    @jax.jit
    def step(p, st, batch, lr, rng):
        (loss, _), grads = stack.fused_value_and_grad(bundle, p, batch, {})
        p, st, _ = qgalore.apply_updates(p, grads, st, qcfg, lr=lr,
                                         rng=rng, specs=specs)
        return p, st, loss

    losses = []
    t0 = time.monotonic()
    for s in range(steps):
        batch = batch_for_bundle(bundle, BENCH_CELL, s, seed)
        params, state, loss = step(params, state, batch, lr_at(s, tcfg),
                                   jax.random.PRNGKey(1000 + s))
        losses.append(float(loss))
    dt = time.monotonic() - t0
    mem = qgalore.memory_report(params, qcfg)["total_gb"]
    return {"final_loss": float(np.mean(losses[-5:])),
            "us_per_call": dt / steps * 1e6, "memory_gb": mem}


def main(steps: int = 40):
    bundle, base_params = _pretrain_base(steps)
    rows = {}
    for method in ("full", "galore", "qgalore"):
        rows[method] = _finetune_opt(bundle, base_params, method, steps)
        emit(f"table34/{method}", rows[method]["us_per_call"],
             f"loss={rows[method]['final_loss']:.3f};"
             f"mem_gb={rows[method]['memory_gb']:.4f}")
    # adapter baselines fine-tune from scratch-init base for memory apples —
    # reuse the pretrain machinery with the trained base:
    import benchmarks.table1_pretrain as t1

    def adapter_from_base(mode, int8):
        params = base_params
        if int8:
            params = quant.tree_quantize(
                params, bits=8, symmetric=True,
                predicate=lambda p, l: l.ndim >= 2 and l.shape[-1] >= 64)
        adapters = lora_lib.init_adapters(params, 16, jax.random.PRNGKey(7))
        qcfg = preset("full")
        state = qgalore.init(adapters, qcfg)
        specs = qgalore.leaf_specs(adapters, qcfg)
        tcfg = replace(bench_tcfg(steps, lr=2e-3), seed=101)

        def loss_fn(ad, b):
            return base.loss_fn(bundle, lora_lib.merge(params, ad), b)

        @jax.jit
        def step(ad, st, b, lr, rng):
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(ad, b)
            ad, st, _ = qgalore.apply_updates(ad, g, st, qcfg, lr=lr,
                                              rng=rng, specs=specs)
            return ad, st, loss

        losses = []
        t0 = time.monotonic()
        for s in range(steps):
            b = batch_for_bundle(bundle, BENCH_CELL, s, 101)
            adapters, state, loss = step(adapters, state, b,
                                         lr_at(s, tcfg),
                                         jax.random.PRNGKey(2000 + s))
            losses.append(float(loss))
        dt = time.monotonic() - t0
        mem = (quant.quantized_nbytes(params)
               + 3 * lora_lib.adapter_nbytes(adapters)) / 2**30
        return {"final_loss": float(np.mean(losses[-5:])),
                "us_per_call": dt / steps * 1e6, "memory_gb": mem}

    rows["lora"] = adapter_from_base("lora", False)
    rows["qlora"] = adapter_from_base("lora", True)
    for m in ("lora", "qlora"):
        emit(f"table34/{m}", rows[m]["us_per_call"],
             f"loss={rows[m]['final_loss']:.3f};"
             f"mem_gb={rows[m]['memory_gb']:.4f}")
    emit("table34/claim_qgalore_vs_qlora", 0.0,
         f"qgalore_loss={rows['qgalore']['final_loss']:.3f};"
         f"qlora_loss={rows['qlora']['final_loss']:.3f};"
         f"qgalore_wins={rows['qgalore']['final_loss'] <= rows['qlora']['final_loss'] + 0.05}")
    return rows


if __name__ == "__main__":
    main()
