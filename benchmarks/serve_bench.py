"""Serving throughput/latency: lockstep vs slot continuous batching vs the
paged runtime (block pool + radix prefix cache + chunked prefill).

Two traffic mixes run through every engine on the SAME quantized-weight
decode path:

* ``uniform`` — the original mix: uniform prompt/output lengths, no
  sharing (the slot scheduler's home turf);
* ``shared_prefix`` — the serving-v2 target: a fraction
  (``--share-ratio``) of requests carry one common system prompt of
  ``--prefix-len`` tokens, and private prompt lengths are heavy-tailed
  (lognormal, clipped to ``--prompt-max``) — long prompts + re-prefilled
  prefixes are exactly what paging fixes.

Engines:

* ``lockstep`` — FIFO groups padded to the group max (pre-scheduler);
* ``slot`` — ``serve.scheduler.Scheduler`` continuous batching;
* ``paged`` — ``serve.paged.PagedScheduler``. Memory-matched to the slot
  pool (same block bytes: ``num_blocks = num_slots·MB + 1``) but with
  ``2×`` the slots — the capacity the block pool buys on mixed-length
  traffic (see ``tests/test_paged.py``).

All engines are verified TOKEN-IDENTICAL on each request set before
timing. Latency metrics add **TTFT** (time-to-first-token) p50/p99 —
the number chunked prefill moves. Timing is best-of-``--rounds`` warm
runs, engines INTERLEAVED per round (machine drift hits all evenly;
compiled programs reused via ``reset()``).

    PYTHONPATH=src:. python benchmarks/serve_bench.py            # full
    PYTHONPATH=src:. python benchmarks/serve_bench.py --smoke    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import QGaLoreConfig
from repro.kernels import dispatch
from repro.models import model_zoo
from repro.serve import engine
from repro.serve.paged import PagedScheduler
from repro.serve.scheduler import Request, Scheduler, _bucket
from repro.train import step as step_lib

MODELS = {"llama_60m": "llama-60m", "llama_130m": "llama-130m"}
PAD = 0


# ---------------------------------------------------------------------------
# Traffic mixes
# ---------------------------------------------------------------------------

def make_requests(n: int, *, prompt_lo: int, prompt_hi: int, out_lo: int,
                  out_hi: int, vocab: int, seed: int = 0):
    """The original uniform mix."""
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        L = int(rng.integers(prompt_lo, prompt_hi + 1))
        N = int(rng.integers(out_lo, out_hi + 1))
        toks = rng.integers(1, vocab, size=L).astype(np.int32)
        reqs.append(Request(rid=rid, tokens=toks, max_new_tokens=N))
    return reqs


def make_shared_prefix_requests(n: int, *, prefix_len: int,
                                share_ratio: float, prompt_lo: int,
                                prompt_hi: int, out_lo: int, out_hi: int,
                                vocab: int, seed: int = 0):
    """Long-prompt + shared-prefix mix: ``share_ratio`` of requests start
    with ONE common prefix; private lengths are heavy-tailed (lognormal
    clipped to [prompt_lo, prompt_hi])."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(1, vocab, size=prefix_len).astype(np.int32)
    reqs = []
    for rid in range(n):
        L = int(np.clip(rng.lognormal(mean=np.log(max(prompt_lo, 2)),
                                      sigma=0.8),
                        prompt_lo, prompt_hi))
        N = int(rng.integers(out_lo, out_hi + 1))
        toks = rng.integers(1, vocab, size=L).astype(np.int32)
        if rng.random() < share_ratio:
            toks = np.concatenate([prefix, toks])
        reqs.append(Request(rid=rid, tokens=toks, max_new_tokens=N))
    return reqs


# ---------------------------------------------------------------------------
# Engine runners — run_once() -> (outputs, wall_s, latencies, ttfts[, stats])
# ---------------------------------------------------------------------------

def make_lockstep_runner(bundle, params, reqs, *, num_slots: int,
                         max_len: int, bucket: int):
    """FIFO groups of ``num_slots``; shares one jitted prefill/decode
    across groups. TTFT for every rid in a group is the group's
    prefill+first-sample completion (all rids in a group stall together —
    the baseline chunked prefill improves on)."""
    prefill = jax.jit(engine.build_prefill(bundle, max_len, pad_id=None))
    decode = jax.jit(engine.build_decode(bundle))

    def run_once():
        outputs, latencies, ttfts = {}, {}, {}
        t0 = time.monotonic()
        for g in range(0, len(reqs), num_slots):
            group = reqs[g: g + num_slots]
            B = len(group)
            Lp = _bucket(max(len(r.tokens) for r in group), bucket)
            toks = np.full((B, Lp), PAD, np.int32)
            for i, r in enumerate(group):
                toks[i, : len(r.tokens)] = r.tokens
            lengths = jnp.asarray([len(r.tokens) for r in group], jnp.int32)
            batch = {"tokens": jnp.asarray(toks), "lengths": lengths}
            steps = max(r.max_new_tokens for r in group)

            logits, state = prefill(params, batch)
            tok = engine.sample(logits, jax.random.PRNGKey(0))
            emitted = [np.asarray(tok)]          # sync: TTFT is real
            t_first = time.monotonic() - t0
            for _ in range(steps - 1):
                logits, state = decode(params, state, tok[:, None])
                tok = engine.sample(logits, jax.random.PRNGKey(0))
                emitted.append(np.asarray(tok))
            out = np.stack(emitted, axis=1)
            t_done = time.monotonic() - t0
            for i, r in enumerate(group):
                outputs[r.rid] = out[i, : r.max_new_tokens].tolist()
                latencies[r.rid] = t_done
                ttfts[r.rid] = t_first
        return outputs, time.monotonic() - t0, latencies, ttfts

    return run_once


def make_sched_runner(sched, reqs, arrivals=None):
    """Runner over a reused scheduler (``reset()`` keeps the compiled
    programs) — works for both the slot and the paged backend."""

    def run_once():
        sched.reset()
        t0 = time.monotonic()
        comps = sched.run(reqs, arrivals=arrivals)
        wall = time.monotonic() - t0
        outputs = {c.rid: list(c.tokens) for c in comps}
        latencies = {c.rid: c.latency for c in comps}
        ttfts = {c.rid: c.ttft for c in comps}
        return outputs, wall, latencies, ttfts, dict(sched.stats)

    return run_once


def _best(old, new):
    return new if old is None or new[1] < old[1] else old


def _metrics(outputs, wall, latencies, ttfts):
    total = sum(len(v) for v in outputs.values())
    lats = np.asarray(sorted(latencies.values()))
    tf = np.asarray(sorted(ttfts.values()))
    return {
        "tokens": total,
        "wall_s": wall,
        "tokens_per_s": total / wall if wall > 0 else float("inf"),
        "p50_latency_ms": float(np.percentile(lats, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(lats, 99) * 1e3),
        "p50_ttft_ms": float(np.percentile(tf, 50) * 1e3),
        "p99_ttft_ms": float(np.percentile(tf, 99) * 1e3),
    }


# ---------------------------------------------------------------------------
# One model × one mix
# ---------------------------------------------------------------------------

def bench_mix(bundle, params, reqs, *, engines, num_slots: int,
              max_len: int, bucket: int, block_size: int,
              prefill_chunk: int, rates, rounds: int) -> dict:
    MB = -(-max_len // block_size)
    runners = {}
    if "lockstep" in engines:
        runners["lockstep"] = make_lockstep_runner(
            bundle, params, reqs, num_slots=num_slots, max_len=max_len,
            bucket=bucket)
    slot_sched = paged_sched = None
    if "slot" in engines:
        slot_sched = Scheduler(bundle, params, num_slots=num_slots,
                               max_len=max_len, pad_id=PAD,
                               prompt_bucket=bucket, dtype=jnp.float32)
        runners["slot"] = make_sched_runner(slot_sched, reqs)
    if "paged" in engines:
        # memory-matched to the slot pool (same block bytes + scratch);
        # the >= 2x concurrency-at-fixed-memory win is asserted separately
        # (tests/test_paged.py) — equal slots here so the comparison
        # isolates paging + radix sharing + chunked prefill
        paged_sched = PagedScheduler(
            bundle, params, num_slots=num_slots, max_len=max_len,
            block_size=block_size, num_blocks=num_slots * MB + 1,
            prefill_chunk=prefill_chunk, pad_id=PAD, dtype=jnp.float32)
        runners["paged"] = make_sched_runner(paged_sched, reqs)

    best = {name: None for name in runners}
    for name in runners:
        runners[name]()                          # compile
    for _ in range(rounds):                      # interleaved rounds
        for name in runners:
            best[name] = _best(best[name], runners[name]())

    # token parity gate before any number is reported
    ref_name = next(iter(best))
    ref_out = best[ref_name][0]
    for name, b in best.items():
        for r in reqs:
            assert b[0][r.rid] == ref_out[r.rid], (
                f"rid {r.rid}: {name} {b[0][r.rid]} != "
                f"{ref_name} {ref_out[r.rid]}")

    result = {"token_parity": True}
    for name, b in best.items():
        m = _metrics(b[0], b[1], b[2], b[3])
        if len(b) > 4:
            m["scheduler_stats"] = b[4]
        result[name] = m
    if "slot" in result and "lockstep" in result:
        result["slot_speedup_x"] = (result["slot"]["tokens_per_s"]
                                    / result["lockstep"]["tokens_per_s"])
    if "paged" in result and "slot" in result:
        result["paged_vs_slot_tokens_per_s_x"] = (
            result["paged"]["tokens_per_s"]
            / result["slot"]["tokens_per_s"])

    # finite offered rates: latency under load (slot + paged)
    result["rates"] = {}
    for rate in rates:
        arrivals = [i / rate for i in range(len(reqs))]
        entry = {}
        for name, sched in (("slot", slot_sched), ("paged", paged_sched)):
            if sched is None:
                continue
            rr = make_sched_runner(sched, reqs, arrivals=arrivals)
            rr()                                 # warm at this schedule
            out_r, wall_r, lat_r, tf_r, _ = rr()
            entry[name] = _metrics(out_r, wall_r, lat_r, tf_r)
        result["rates"][f"{rate:g}_rps"] = entry
    return result


def bench_model(arch_id: str, *, engines, num_slots: int, n_requests: int,
                prompt_lo: int, prompt_hi: int, out_lo: int, out_hi: int,
                prefix_len: int, share_ratio: float, bucket: int,
                block_size: int, prefill_chunk: int, rates, smoke: bool,
                seed: int, rounds: int = 2) -> dict:
    bundle = model_zoo.build_arch(arch_id, smoke=smoke, dtype=jnp.float32)
    # INT8-native weights — the serving format (PR 2)
    params = step_lib.prepare_params(
        bundle.init_params(jax.random.PRNGKey(0)), QGaLoreConfig(),
        jnp.float32)
    V = bundle.cfg.vocab_size

    mixes = {
        "uniform": (
            make_requests(n_requests, prompt_lo=prompt_lo,
                          prompt_hi=prompt_hi, out_lo=out_lo,
                          out_hi=out_hi, vocab=V, seed=seed),
            _bucket(prompt_hi + out_hi + 1, bucket)),
        "shared_prefix": (
            make_shared_prefix_requests(
                n_requests, prefix_len=prefix_len, share_ratio=share_ratio,
                prompt_lo=prompt_lo, prompt_hi=prompt_hi, out_lo=out_lo,
                out_hi=out_hi, vocab=V, seed=seed),
            _bucket(prefix_len + prompt_hi + out_hi + 1, bucket)),
    }
    out = {}
    for mix_name, (reqs, max_len) in mixes.items():
        out[mix_name] = bench_mix(
            bundle, params, reqs, engines=engines, num_slots=num_slots,
            max_len=max_len, bucket=bucket, block_size=block_size,
            prefill_chunk=prefill_chunk, rates=rates, rounds=rounds)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama_60m,llama_130m")
    ap.add_argument("--engines", default="lockstep,slot,paged",
                    help="comma-separated: lockstep,slot,paged")
    ap.add_argument("--paged", action="store_true",
                    help="shortcut: only the slot-vs-paged comparison "
                    "(CI paged-smoke)")
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=48)
    ap.add_argument("--out-min", type=int, default=4)
    ap.add_argument("--out-max", type=int, default=48)
    ap.add_argument("--prefix-len", type=int, default=64,
                    help="shared system-prompt length (shared_prefix mix)")
    ap.add_argument("--share-ratio", type=float, default=0.75,
                    help="fraction of requests carrying the shared prefix")
    ap.add_argument("--bucket", type=int, default=16)
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged pool block size (tokens)")
    ap.add_argument("--prefill-chunk", type=int, default=32,
                    help="paged chunked-prefill width (tokens)")
    ap.add_argument("--rates", default="8",
                    help="comma-separated offered request rates (req/s)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="interleaved timed rounds per engine (best-of)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape-preserving configs (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.paged:
        args.engines = "slot,paged"
    if args.smoke:
        args.num_slots = min(args.num_slots, 4)
        args.requests = min(args.requests, 12)
        args.prompt_min = min(args.prompt_min, 4)
        args.prompt_max = min(args.prompt_max, 16)
        args.out_min = min(args.out_min, 2)
        args.out_max = min(args.out_max, 16)
        args.prefix_len = min(args.prefix_len, 32)
        args.bucket = min(args.bucket, 8)
        args.block_size = min(args.block_size, 8)

    engines = [e.strip() for e in args.engines.split(",") if e.strip()]
    rates = [float(r) for r in args.rates.split(",") if r.strip()]
    report = {
        "meta": {
            "platform": dispatch.platform(),
            "backend": dispatch.default_backend("quantized_dense"),
            "engines": engines,
            "num_slots": args.num_slots, "requests": args.requests,
            "prompt_len": [args.prompt_min, args.prompt_max],
            "out_len": [args.out_min, args.out_max],
            "prefix_len": args.prefix_len, "share_ratio": args.share_ratio,
            "bucket": args.bucket, "block_size": args.block_size,
            "prefill_chunk": args.prefill_chunk, "rates_rps": rates,
            "paged_memory_matched_to_slots": args.num_slots,
            "paged_num_slots": args.num_slots,
            "smoke": args.smoke, "seed": args.seed,
        },
        "results": {},
    }
    for name in args.models.split(","):
        arch = MODELS[name.strip()]
        r = bench_model(arch, engines=engines, num_slots=args.num_slots,
                        n_requests=args.requests,
                        prompt_lo=args.prompt_min, prompt_hi=args.prompt_max,
                        out_lo=args.out_min, out_hi=args.out_max,
                        prefix_len=args.prefix_len,
                        share_ratio=args.share_ratio, bucket=args.bucket,
                        block_size=args.block_size,
                        prefill_chunk=args.prefill_chunk, rates=rates,
                        smoke=args.smoke, seed=args.seed,
                        rounds=args.rounds)
        for mix, rm in r.items():
            for eng in engines:
                if eng not in rm:
                    continue
                m = rm[eng]
                emit(f"serve_bench/{name}_{mix}_{eng}_tokens_per_s",
                     m["wall_s"] * 1e6,
                     f"{m['tokens_per_s']:.1f} tok/s;"
                     f"p99={m['p99_latency_ms']:.0f}ms;"
                     f"ttft_p99={m['p99_ttft_ms']:.0f}ms")
            if "paged_vs_slot_tokens_per_s_x" in rm:
                emit(f"serve_bench/{name}_{mix}_paged_vs_slot",
                     rm["paged"]["wall_s"] * 1e6,
                     f"{rm['paged_vs_slot_tokens_per_s_x']:.2f}x")
        report["results"][name] = r

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}", flush=True)
    return report


if __name__ == "__main__":
    main()
