"""Serving throughput/latency: continuous batching vs the lockstep baseline.

One request set (mixed prompt lengths, mixed output lengths, greedy) runs
through both engines on the SAME quantized-weight decode path:

* ``lockstep`` — ``engine.generate`` semantics: FIFO groups of
  ``num_slots`` requests, each group padded to its longest prompt and
  decoded to its longest output; every request in a group waits for the
  whole group (the pre-scheduler serving model).
* ``continuous`` — ``serve.scheduler.Scheduler``: requests admitted into
  free slots mid-flight, per-slot lengths/EOS tracking, retirement frees
  the slot for the next request.

Both engines are verified TOKEN-IDENTICAL on the request set before
timing (greedy decode is row-independent), so the speedup is
apples-to-apples. Timing is best-of-``--rounds`` warm runs with the two
engines INTERLEAVED per round (machine drift hits both evenly; compile
amortized — the scheduler reuses its compiled programs via ``reset()``).

Emits the repo-standard ``name,us_per_call,derived`` CSV rows and writes
``BENCH_serve.json``: aggregate generated tokens/sec, p50/p99 request
latency, per offered arrival rate (``inf`` = all requests at t=0, plus
finite requests/sec schedules), continuous-vs-lockstep speedup.

    PYTHONPATH=src:. python benchmarks/serve_bench.py            # full
    PYTHONPATH=src:. python benchmarks/serve_bench.py --smoke    # CI smoke
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.config import QGaLoreConfig
from repro.kernels import dispatch
from repro.models import model_zoo
from repro.serve import engine
from repro.serve.scheduler import Request, Scheduler, _bucket
from repro.train import step as step_lib

MODELS = {"llama_60m": "llama-60m", "llama_130m": "llama-130m"}
PAD = 0


def make_requests(n: int, *, prompt_lo: int, prompt_hi: int, out_lo: int,
                  out_hi: int, vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n):
        L = int(rng.integers(prompt_lo, prompt_hi + 1))
        N = int(rng.integers(out_lo, out_hi + 1))
        toks = rng.integers(1, vocab, size=L).astype(np.int32)
        reqs.append(Request(rid=rid, tokens=toks, max_new_tokens=N))
    return reqs


def make_lockstep_runner(bundle, params, reqs, *, num_slots: int,
                         max_len: int, bucket: int):
    """FIFO groups of ``num_slots``; ``run_once() -> (outputs, wall_s,
    latencies)``.

    Shares one jitted prefill/decode across groups (same compiled programs
    the old ``engine.generate`` host loop would build) — a group only pays
    compile for a new padded-prompt bucket, like scheduler admission."""
    prefill = jax.jit(engine.build_prefill(bundle, max_len, pad_id=None))
    decode = jax.jit(engine.build_decode(bundle))

    def run_once():
        outputs, latencies = {}, {}
        t0 = time.monotonic()
        for g in range(0, len(reqs), num_slots):
            group = reqs[g: g + num_slots]
            B = len(group)
            Lp = _bucket(max(len(r.tokens) for r in group), bucket)
            toks = np.full((B, Lp), PAD, np.int32)
            for i, r in enumerate(group):
                toks[i, : len(r.tokens)] = r.tokens
            lengths = jnp.asarray([len(r.tokens) for r in group], jnp.int32)
            batch = {"tokens": jnp.asarray(toks), "lengths": lengths}
            steps = max(r.max_new_tokens for r in group)

            logits, state = prefill(params, batch)
            tok = engine.sample(logits, jax.random.PRNGKey(0))
            emitted = [tok]
            for _ in range(steps - 1):
                logits, state = decode(params, state, tok[:, None])
                tok = engine.sample(logits, jax.random.PRNGKey(0))
                emitted.append(tok)
            out = np.stack([np.asarray(t) for t in emitted], axis=1)
            t_done = time.monotonic() - t0
            for i, r in enumerate(group):
                outputs[r.rid] = out[i, : r.max_new_tokens].tolist()
                latencies[r.rid] = t_done
        return outputs, time.monotonic() - t0, latencies

    return run_once


def make_continuous_runner(bundle, params, reqs, *, num_slots: int,
                           max_len: int, bucket: int, arrivals=None):
    """``run_once() -> (outputs, wall_s, latencies, stats)`` over a reused
    scheduler (``reset()`` keeps the compiled programs)."""
    sched = Scheduler(bundle, params, num_slots=num_slots, max_len=max_len,
                      pad_id=PAD, prompt_bucket=bucket, dtype=jnp.float32)

    def run_once():
        sched.reset()
        t0 = time.monotonic()
        comps = sched.run(reqs, arrivals=arrivals)
        wall = time.monotonic() - t0
        outputs = {c.rid: list(c.tokens) for c in comps}
        latencies = {c.rid: c.latency for c in comps}
        return outputs, wall, latencies, dict(sched.stats)

    return run_once


def _best(old, new):
    return new if old is None or new[1] < old[1] else old


def _metrics(outputs, wall, latencies):
    total = sum(len(v) for v in outputs.values())
    lats = np.asarray(sorted(latencies.values()))
    return {
        "tokens": total,
        "wall_s": wall,
        "tokens_per_s": total / wall if wall > 0 else float("inf"),
        "p50_latency_ms": float(np.percentile(lats, 50) * 1e3),
        "p99_latency_ms": float(np.percentile(lats, 99) * 1e3),
    }


def bench_model(arch_id: str, *, num_slots: int, n_requests: int,
                prompt_lo: int, prompt_hi: int, out_lo: int, out_hi: int,
                bucket: int, rates, smoke: bool, seed: int,
                rounds: int = 2) -> dict:
    bundle = model_zoo.build_arch(arch_id, smoke=smoke, dtype=jnp.float32)
    # INT8-native weights — the serving format (PR 2)
    params = step_lib.prepare_params(
        bundle.init_params(jax.random.PRNGKey(0)), QGaLoreConfig(),
        jnp.float32)
    max_len = _bucket(prompt_hi + out_hi + 1, bucket)
    reqs = make_requests(n_requests, prompt_lo=prompt_lo,
                         prompt_hi=prompt_hi, out_lo=out_lo, out_hi=out_hi,
                         vocab=bundle.cfg.vocab_size, seed=seed)

    lock_run = make_lockstep_runner(
        bundle, params, reqs, num_slots=num_slots, max_len=max_len,
        bucket=bucket)
    cont_run = make_continuous_runner(
        bundle, params, reqs, num_slots=num_slots, max_len=max_len,
        bucket=bucket)
    lock_run(), cont_run()                   # compile
    lock, cont = None, None
    for _ in range(rounds):                  # interleaved: machine drift
        lock = _best(lock, lock_run())       # hits both engines evenly
        cont = _best(cont, cont_run())
    lock_out, lock_wall, lock_lat = lock
    cont_out, cont_wall, cont_lat, stats = cont

    # token parity gate: the speedup must be apples-to-apples
    for r in reqs:
        assert cont_out[r.rid] == lock_out[r.rid], (
            f"{arch_id} rid {r.rid}: continuous {cont_out[r.rid]} != "
            f"lockstep {lock_out[r.rid]}")

    result = {
        "lockstep": _metrics(lock_out, lock_wall, lock_lat),
        "continuous": {**_metrics(cont_out, cont_wall, cont_lat),
                       "scheduler_stats": dict(stats)},
        "token_parity": True,
    }
    result["speedup_x"] = (result["continuous"]["tokens_per_s"]
                           / result["lockstep"]["tokens_per_s"])

    # finite offered rates: latency under load (continuous engine)
    result["rates"] = {}
    for rate in rates:
        arrivals = [i / rate for i in range(len(reqs))]
        rate_run = make_continuous_runner(
            bundle, params, reqs, num_slots=num_slots, max_len=max_len,
            bucket=bucket, arrivals=arrivals)
        rate_run()                           # compile
        out_r, wall_r, lat_r, _ = rate_run()
        result["rates"][f"{rate:g}_rps"] = _metrics(out_r, wall_r, lat_r)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama_60m,llama_130m")
    ap.add_argument("--num-slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=48)
    ap.add_argument("--out-min", type=int, default=4)
    ap.add_argument("--out-max", type=int, default=48)
    ap.add_argument("--bucket", type=int, default=16)
    ap.add_argument("--rates", default="8",
                    help="comma-separated offered request rates (req/s)")
    ap.add_argument("--rounds", type=int, default=2,
                    help="interleaved timed rounds per engine (best-of)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape-preserving configs (CI)")
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.num_slots = min(args.num_slots, 4)
        args.requests = min(args.requests, 12)
        args.prompt_min = min(args.prompt_min, 4)
        args.prompt_max = min(args.prompt_max, 16)
        args.out_min = min(args.out_min, 2)
        args.out_max = min(args.out_max, 32)
        args.bucket = min(args.bucket, 8)

    rates = [float(r) for r in args.rates.split(",") if r.strip()]
    report = {
        "meta": {
            "platform": dispatch.platform(),
            "backend": dispatch.default_backend("quantized_dense"),
            "num_slots": args.num_slots, "requests": args.requests,
            "prompt_len": [args.prompt_min, args.prompt_max],
            "out_len": [args.out_min, args.out_max],
            "bucket": args.bucket, "rates_rps": rates,
            "smoke": args.smoke, "seed": args.seed,
        },
        "results": {},
    }
    for name in args.models.split(","):
        arch = MODELS[name.strip()]
        r = bench_model(arch, num_slots=args.num_slots,
                        n_requests=args.requests,
                        prompt_lo=args.prompt_min, prompt_hi=args.prompt_max,
                        out_lo=args.out_min, out_hi=args.out_max,
                        bucket=args.bucket, rates=rates, smoke=args.smoke,
                        seed=args.seed, rounds=args.rounds)
        for mode in ("lockstep", "continuous"):
            emit(f"serve_bench/{name}_{mode}_tokens_per_s",
                 r[mode]["wall_s"] * 1e6,
                 f"{r[mode]['tokens_per_s']:.1f} tok/s;"
                 f"p50={r[mode]['p50_latency_ms']:.0f}ms;"
                 f"p99={r[mode]['p99_latency_ms']:.0f}ms")
        emit(f"serve_bench/{name}_continuous_speedup",
             r["continuous"]["wall_s"] * 1e6, f"{r['speedup_x']:.2f}x")
        report["results"][name] = r

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}", flush=True)
    return report


if __name__ == "__main__":
    main()
