"""Paper Figures 2/3/6/7 as mechanical ablations (reduced scale).

* fig3  — projection-matrix quantization bits sweep (16/8/4/2): the paper's
          claim is 4-bit P is loss-free, 2-bit degrades.
* fig6  — stochastic rounding ON vs OFF with INT8 weights: SR must win.
* fig7  — SVD-count vs quality trade-off via the adaptive threshold.
* fig2  — layer-wise subspace cosine-similarity dynamics.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_qcfg, emit, run_method
from repro.config import replace


def fig3_proj_bits(steps: int = 60):
    rows = {}
    for bits in (16, 8, 4, 2):
        q = replace(bench_qcfg(), proj_bits=bits, weight_bits=8,
                    adam_bits=8, stochastic_rounding=True)
        r = run_method("raw", steps, qcfg=q)
        # preset() overrides proj_bits — call with raw config instead:
        rows[bits] = r
        emit(f"fig3/proj_bits_{bits}", r["us_per_call"],
             f"loss={r['final_loss']:.3f}")
    ok = rows[4]["final_loss"] < rows[16]["final_loss"] + 0.15
    emit("fig3/claim_4bit_lossless", 0.0, f"int4_within_0.15_of_fp={ok}")
    return rows


def fig6_stochastic_rounding(steps: int = 60):
    # sub-quantum learning rate: round-to-nearest loses the updates entirely
    # (the paper's warm-up-stage observation), SR accumulates them.
    r_sr = run_method("qgalore", steps + 20, lr=1e-3)
    r_rtn = run_method("qgalore_nosr", steps + 20, lr=1e-3)
    emit("fig6/with_sr", r_sr["us_per_call"],
         f"loss={r_sr['final_loss']:.3f}")
    emit("fig6/without_sr", r_rtn["us_per_call"],
         f"loss={r_rtn['final_loss']:.3f}")
    emit("fig6/claim_sr_helps", 0.0,
         f"sr_better={r_sr['final_loss'] < r_rtn['final_loss'] + 0.02};"
         f"gap={r_rtn['final_loss'] - r_sr['final_loss']:.3f}")
    return r_sr, r_rtn


def fig7_svd_counts(steps: int = 80):
    rows = {}
    for name, adaptive, thresh in (("fixed", False, 0.0),
                                   ("adaptive_0.4", True, 0.4),
                                   ("adaptive_0.2", True, 0.2)):
        q = replace(bench_qcfg(), adaptive=adaptive, cos_threshold=thresh,
                    proj_bits=4, weight_bits=8, adam_bits=8,
                    stochastic_rounding=True, update_interval=8,
                    adaptive_k=1)
        r = run_method("raw", steps, qcfg=q)
        ratio = r["svd_used"] / max(r["svd_baseline"], 1)
        rows[name] = (r, ratio)
        emit(f"fig7/{name}", r["us_per_call"],
             f"loss={r['final_loss']:.3f};svd_ratio={ratio:.2f}")
    # the trade-off point: most SVDs saved at ≤0.05 loss gap. (At this
    # micro scale rank-16 subspaces are noisier than the paper's 130M/
    # rank-256 setting, so the operating threshold shifts from the paper's
    # 0.4 to ~0.2 — the CURVE, not the threshold value, is the claim.)
    fixed_loss = rows["fixed"][0]["final_loss"]
    best = min((r for r in rows.values()
                if r[0]["final_loss"] <= fixed_loss + 0.05),
               key=lambda r: r[1])
    emit("fig7/claim_savings_free", 0.0,
         f"svd_saved={1 - best[1]:.0%};loss_gap="
         f"{best[0]['final_loss'] - fixed_loss:.3f}")
    return rows


def fig2_subspace_dynamics(steps: int = 60):
    q = replace(bench_qcfg(), update_interval=6, adaptive=False,
                proj_bits=16)
    r = run_method("raw", steps, qcfg=q)
    ctrl = r["trainer"].controller
    for idx, units in list(ctrl.units.items())[:6]:
        path = ctrl.specs[idx].path.replace("'", "").replace("[", "/") \
            .replace("]", "")
        sims = [np.mean(u.sims[1:]) if len(u.sims) > 1 else float("nan")
                for u in units]
        emit(f"fig2/{path}", 0.0,
             "mean_cos=" + "|".join(f"{s:.2f}" for s in sims))
    return r


def main(steps: int = 60):
    fig3_proj_bits(steps)
    fig6_stochastic_rounding(steps)
    fig7_svd_counts(steps + 20)
    fig2_subspace_dynamics(steps)


if __name__ == "__main__":
    main()
