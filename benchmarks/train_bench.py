"""End-to-end train-step and decode-token throughput: quantized-dense
(INT8-native compute) vs the dequantize-then-einsum baseline.

For each model the SAME quantized parameters run through two traced
variants of the full pipeline (fused projected-backward train step +
Q-GaLore update; serve prefill + per-token decode):

* ``quantized`` — ``layers.QUANTIZED_DENSE = True`` (default): every
  QTensor matmul streams INT8 blocks through the dispatch-registered
  ``quantized_dense`` op; no full-precision weight view exists.
* ``dequant``   — the legacy baseline: materialize (dequantize) each
  weight, einsum in full precision; autodiff saves the dequantized copy,
  and decode re-dequantizes the stacked layer pytree per token.

Both variants are compiled up front and then timed with **interleaved
paired rounds** (see ``benchmarks/common.paired_times``), phase-major:
within each round the two modes of one phase run back-to-back, and the headline
``*_speedup_x`` fields are the trimmed means of the per-round ratios
(with ``*_speedup_sem`` standard errors alongside). The old sequential
A/B (all quantized iters, then all dequant iters) is what manufactured
the phantom 0.76x prefill "regression" on a noisy box — drift between
the two timing windows, not a real kernel gap.

Emits the repo-standard ``name,us_per_call,derived`` CSV rows and writes
``BENCH_train.json`` — the seed of the perf trajectory (CI uploads it per
PR; compare the ``*_speedup_x`` fields across commits).

    PYTHONPATH=src:. python benchmarks/train_bench.py            # full
    PYTHONPATH=src:. python benchmarks/train_bench.py --smoke    # CI smoke
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, paired_ratio
from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
from repro.data.synthetic import batch_for_bundle
from repro.kernels import dispatch, profile
from repro.models import layers, model_zoo
from repro.serve import engine
from repro.train import step as step_lib

MODELS = {"llama_60m": "llama-60m", "llama_130m": "llama-130m"}

PHASES = ("train_step", "prefill", "decode_token")


def build_variant(arch_id: str, mode: str, *, seq: int, batch: int,
                  smoke: bool) -> dict:
    """Compile the full pipeline (train step, prefill, decode) for one
    mode and return zero-arg timed callables. QUANTIZED_DENSE is a
    trace-time global, so compilation happens HERE, while it is set; the
    returned jitted programs keep the mode baked in."""
    qcfg = QGaLoreConfig(rank=32, min_dim=64, update_interval=100_000)
    tcfg = TrainConfig(global_batch=batch, seq_len=seq, steps=2)
    cell = ShapeCell("bench", seq_len=seq, global_batch=batch, kind="train")
    layers.QUANTIZED_DENSE = (mode == "quantized")
    try:
        bundle = model_zoo.build_arch(arch_id, smoke=smoke,
                                      dtype=jnp.float32)
        state = step_lib.init_state(bundle, qcfg, jax.random.PRNGKey(0),
                                    param_dtype=jnp.float32)
        raw_step, _ = step_lib.build_train_step(
            bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32)
        step = jax.jit(functools.partial(raw_step, refresh=False,
                                         refresh_masks=None))
        b = batch_for_bundle(bundle, cell, 0)
        rng = jax.random.PRNGKey(1)

        def step_fn():
            return step(state, b, 1e-3, rng)[0]

        jax.block_until_ready(step_fn())            # compile under mode

        # serving: prefill on the first half, decode token by token
        prompt = {k: (v[:, : seq // 2]
                      if v.ndim >= 2 and v.shape[1] == seq else v)
                  for k, v in b.items()}
        prefill = jax.jit(engine.build_prefill(bundle, max_len=seq + 4))
        decode = jax.jit(engine.build_decode(bundle))

        def prefill_fn():
            return prefill(state.params, prompt)

        logits, dstate = prefill_fn()
        jax.block_until_ready(logits)
        tok = engine.sample(logits, jax.random.PRNGKey(2))

        def decode_fn(st):
            return decode(state.params, st, tok[:, None])

        jax.block_until_ready(decode_fn(dstate)[0])  # compile under mode
        return {"step": step_fn, "prefill": prefill_fn,
                "decode": decode_fn, "dstate": dstate}
    finally:
        layers.QUANTIZED_DENSE = True


def bench_model(arch_id: str, *, seq: int, batch: int, iters: int,
                decode_tokens: int, rounds: int, smoke: bool) -> dict:
    """Paired-rounds A/B of the two modes; returns the per-mode phase
    times (medians), the trimmed-ratio speedups, and their sems."""
    variants = {mode: build_variant(arch_id, mode, seq=seq, batch=batch,
                                    smoke=smoke)
                for mode in ("quantized", "dequant")}

    # Phase-major interleaving: within a round, the two modes of ONE
    # phase run back-to-back before moving on. Mode-major rounds (all
    # three phases of mode A, then all of mode B) separate the paired
    # measurements of each phase by whole train-step bursts, and the
    # allocator/cache wake these leave behind skews the short phases —
    # measured ~8% phantom deficit on llama-130m prefill vs parity when
    # the same programs are timed adjacently.
    times = {p: {m: [] for m in variants} for p in PHASES}
    modes = list(variants)
    for r in range(rounds):
        order = modes if r % 2 == 0 else list(reversed(modes))
        for m in order:
            v = variants[m]
            t0 = time.perf_counter()
            for _ in range(iters):
                out = v["step"]()
            jax.block_until_ready(out)
            times["train_step"][m].append(
                (time.perf_counter() - t0) / iters * 1e6)

        for m in order:
            v = variants[m]
            t0 = time.perf_counter()
            for _ in range(max(iters // 2, 1)):
                logits, _ = v["prefill"]()
            jax.block_until_ready(logits)
            times["prefill"][m].append(
                (time.perf_counter() - t0) / max(iters // 2, 1) * 1e6)

        for m in order:
            v = variants[m]
            st = v["dstate"]
            t0 = time.perf_counter()
            for _ in range(decode_tokens):
                logits, st = v["decode"](st)
            jax.block_until_ready(logits)
            times["decode_token"][m].append(
                (time.perf_counter() - t0) / decode_tokens * 1e6)

    results: dict = {m: {f"{p}_us": float(np.median(times[p][m]))
                         for p in PHASES} for m in variants}
    for p, name in (("train_step", "train"), ("decode_token", "decode"),
                    ("prefill", "prefill")):
        stat = paired_ratio(times[p], "dequant", "quantized")
        results[f"{name}_speedup_x"] = stat["ratio_x"]
        results[f"{name}_speedup_sem"] = stat["sem"]
        results[f"{name}_speedup_median_x"] = stat["median_x"]
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama_60m,llama_130m")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--iters", type=int, default=2,
                    help="calls per variant per round")
    ap.add_argument("--rounds", type=int, default=8,
                    help="interleaved A/B rounds per model")
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape-preserving configs (CI)")
    ap.add_argument("--out", default="BENCH_train.json")
    args = ap.parse_args(argv)

    report = {
        "meta": {
            "platform": dispatch.platform(),
            "backend": dispatch.default_backend("quantized_dense"),
            "seq": args.seq, "batch": args.batch, "iters": args.iters,
            "rounds": args.rounds, "decode_tokens": args.decode_tokens,
            "smoke": args.smoke, "measurement": "interleaved-paired-rounds",
        },
        "results": {},
    }
    for name in args.models.split(","):
        arch = MODELS[name.strip()]
        r = bench_model(arch, seq=args.seq, batch=args.batch,
                        iters=args.iters, decode_tokens=args.decode_tokens,
                        rounds=args.rounds, smoke=args.smoke)
        for mode in ("quantized", "dequant"):
            for k, v in r[mode].items():
                emit(f"train_bench/{name}_{mode}_{k}", v,
                     f"seq={args.seq};batch={args.batch};mode={mode}")
        emit(f"train_bench/{name}_train_speedup", r["train_speedup_x"],
             f"unit=x;baseline=dequant-dense;sem={r['train_speedup_sem']:.4f}")
        emit(f"train_bench/{name}_decode_speedup", r["decode_speedup_x"],
             f"unit=x;baseline=dequant-dense;sem={r['decode_speedup_sem']:.4f}")
        emit(f"train_bench/{name}_prefill_speedup", r["prefill_speedup_x"],
             f"unit=x;baseline=dequant-dense;sem={r['prefill_speedup_sem']:.4f}")
        report["results"][name] = r

    profile.maybe_attach(report)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}", flush=True)
    return report


if __name__ == "__main__":
    main()
