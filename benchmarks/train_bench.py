"""End-to-end train-step and decode-token throughput: quantized-dense
(INT8-native compute) vs the dequantize-then-einsum baseline.

For each model the SAME quantized parameters run through two traced
variants of the full pipeline (fused projected-backward train step +
Q-GaLore update; serve prefill + per-token decode):

* ``quantized`` — ``layers.QUANTIZED_DENSE = True`` (default): every
  QTensor matmul streams INT8 blocks through the dispatch-registered
  ``quantized_dense`` op; no full-precision weight view exists.
* ``dequant``   — the legacy baseline: materialize (dequantize) each
  weight, einsum in full precision; autodiff saves the dequantized copy,
  and decode re-dequantizes the stacked layer pytree per token.

Emits the repo-standard ``name,us_per_call,derived`` CSV rows and writes
``BENCH_train.json`` — the seed of the perf trajectory (CI uploads it per
PR; compare the ``*_speedup_x`` fields across commits).

    PYTHONPATH=src:. python benchmarks/train_bench.py            # full
    PYTHONPATH=src:. python benchmarks/train_bench.py --smoke    # CI smoke
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
from repro.data.synthetic import batch_for_bundle
from repro.kernels import dispatch
from repro.models import layers, model_zoo
from repro.serve import engine
from repro.train import step as step_lib

MODELS = {"llama_60m": "llama-60m", "llama_130m": "llama-130m"}


def _timed(fn, *args, iters=2):
    out = fn(*args)                       # compile + warm
    jax.block_until_ready(out)
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters * 1e6, out


def bench_model(arch_id: str, *, seq: int, batch: int, iters: int,
                decode_tokens: int, smoke: bool) -> dict:
    """{mode: {train_step_us, prefill_us, decode_token_us}} for one arch."""
    qcfg = QGaLoreConfig(rank=32, min_dim=64, update_interval=100_000)
    tcfg = TrainConfig(global_batch=batch, seq_len=seq, steps=iters)
    cell = ShapeCell("bench", seq_len=seq, global_batch=batch, kind="train")
    results: dict = {}
    for mode in ("quantized", "dequant"):
        layers.QUANTIZED_DENSE = (mode == "quantized")
        try:
            bundle = model_zoo.build_arch(arch_id, smoke=smoke,
                                          dtype=jnp.float32)
            state = step_lib.init_state(bundle, qcfg,
                                        jax.random.PRNGKey(0),
                                        param_dtype=jnp.float32)
            raw_step, _ = step_lib.build_train_step(
                bundle, qcfg, tcfg, impl="fused",
                param_dtype=jnp.float32)
            step = jax.jit(functools.partial(raw_step, refresh=False,
                                             refresh_masks=None))
            b = batch_for_bundle(bundle, cell, 0)
            rng = jax.random.PRNGKey(1)
            us_step, _ = _timed(
                lambda s, bb: step(s, bb, 1e-3, rng)[0], state, b,
                iters=iters)

            # serving: prefill on the first half, decode token by token
            prompt = {k: (v[:, : seq // 2]
                          if v.ndim >= 2 and v.shape[1] == seq else v)
                      for k, v in b.items()}
            prefill = jax.jit(engine.build_prefill(bundle, max_len=seq + 4))
            decode = jax.jit(engine.build_decode(bundle))
            us_prefill, (logits, dstate) = _timed(
                prefill, state.params, prompt, iters=max(iters // 2, 1))
            tok = engine.sample(logits, jax.random.PRNGKey(2))

            decode(state.params, dstate, tok[:, None])   # compile
            t0 = time.monotonic()
            st = dstate
            for _ in range(decode_tokens):
                logits, st = decode(state.params, st, tok[:, None])
            jax.block_until_ready(logits)
            us_decode = (time.monotonic() - t0) / decode_tokens * 1e6

            results[mode] = {"train_step_us": us_step,
                             "prefill_us": us_prefill,
                             "decode_token_us": us_decode}
        finally:
            layers.QUANTIZED_DENSE = True
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="llama_60m,llama_130m")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--iters", type=int, default=2)
    ap.add_argument("--decode-tokens", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shape-preserving configs (CI)")
    ap.add_argument("--out", default="BENCH_train.json")
    args = ap.parse_args(argv)

    report = {
        "meta": {
            "platform": dispatch.platform(),
            "backend": dispatch.default_backend("quantized_dense"),
            "seq": args.seq, "batch": args.batch, "iters": args.iters,
            "decode_tokens": args.decode_tokens, "smoke": args.smoke,
        },
        "results": {},
    }
    for name in args.models.split(","):
        arch = MODELS[name.strip()]
        r = bench_model(arch, seq=args.seq, batch=args.batch,
                        iters=args.iters, decode_tokens=args.decode_tokens,
                        smoke=args.smoke)
        for mode, row in r.items():
            for k, v in row.items():
                emit(f"train_bench/{name}_{mode}_{k}", v,
                     f"seq={args.seq};batch={args.batch};mode={mode}")
        r["train_speedup_x"] = (r["dequant"]["train_step_us"]
                                / r["quantized"]["train_step_us"])
        r["decode_speedup_x"] = (r["dequant"]["decode_token_us"]
                                 / r["quantized"]["decode_token_us"])
        r["prefill_speedup_x"] = (r["dequant"]["prefill_us"]
                                  / r["quantized"]["prefill_us"])
        emit(f"train_bench/{name}_train_speedup", r["train_speedup_x"],
             "unit=x;baseline=dequant-dense")
        emit(f"train_bench/{name}_decode_speedup", r["decode_speedup_x"],
             "unit=x;baseline=dequant-dense")
        report["results"][name] = r

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}", flush=True)
    return report


if __name__ == "__main__":
    main()
