"""Paper Table 1 (reduced scale): pre-training loss + weight/optimizer
memory across Full / Low-Rank / LoRA / GaLore / Q-GaLore.

The paper's claim under test: Q-GaLore ≈ GaLore ≈ Full quality at a fraction
of the memory; Low-Rank factorization is notably worse."""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import BENCH_CELL, BENCH_MODEL, bench_qcfg, \
    bench_tcfg, emit, run_method
from repro.core import qgalore, quant
from repro.core.adam8bit import AdamHyper
from repro.core.optimizers import lr_at, preset
from repro.data.synthetic import batch_for_bundle
from repro.models import base, lora as lora_lib, model_zoo


def _adapter_train(mode: str, steps: int, rank: int = 16, lr: float = 5e-3,
                   int8_base: bool = False):
    """LoRA / QLoRA / factorized baseline training loop."""
    bundle = model_zoo.build(BENCH_MODEL, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    if int8_base:
        params = quant.tree_quantize(
            params, bits=8, symmetric=True,
            predicate=lambda p, l: l.ndim >= 2 and l.shape[-1] >= 64)
    adapters = lora_lib.init_adapters(params, rank, jax.random.PRNGKey(1),
                                      mode=mode)
    qcfg = preset("full")
    state = qgalore.init(adapters, qcfg)
    specs = qgalore.leaf_specs(adapters, qcfg)
    tcfg = bench_tcfg(steps, lr)

    def loss_fn(ad, batch):
        virt = lora_lib.merge(params, ad, mode=mode, rank=rank)
        return base.loss_fn(bundle, virt, batch)

    @jax.jit
    def step(ad, st, batch, lr_, rng):
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(ad,
                                                                     batch)
        ad, st, _ = qgalore.apply_updates(ad, grads, st, qcfg, lr=lr_,
                                          rng=rng, specs=specs)
        return ad, st, loss

    losses = []
    t0 = time.monotonic()
    for s in range(steps):
        batch = batch_for_bundle(bundle, BENCH_CELL, s, 0)
        adapters, state, loss = step(adapters, state, batch,
                                     lr_at(s, tcfg),
                                     jax.random.PRNGKey(s))
        losses.append(float(loss))
    dt = time.monotonic() - t0
    base_bytes = quant.quantized_nbytes(params)
    mem = (base_bytes + 3 * lora_lib.adapter_nbytes(adapters)) / 2**30
    return {"final_loss": float(np.mean(losses[-5:])),
            "us_per_call": dt / steps * 1e6, "memory_gb": mem}


def main(steps: int = 60):
    rows = {}
    for method in ("full", "galore", "qgalore"):
        r = run_method(method, steps)
        rows[method] = r
        emit(f"table1/{method}", r["us_per_call"],
             f"loss={r['final_loss']:.3f};mem_gb={r['memory_gb']:.4f}")
    for name, mode, int8 in (("low_rank", "factorized", False),
                             ("lora", "lora", False),
                             ("qlora", "lora", True)):
        r = _adapter_train(mode, steps, int8_base=int8)
        rows[name] = r
        emit(f"table1/{name}", r["us_per_call"],
             f"loss={r['final_loss']:.3f};mem_gb={r['memory_gb']:.4f}")

    # the paper's ordering claims, checked mechanically:
    ok_quality = rows["qgalore"]["final_loss"] < \
        rows["low_rank"]["final_loss"]
    ok_memory = rows["qgalore"]["memory_gb"] < rows["galore"]["memory_gb"] \
        < rows["full"]["memory_gb"]
    emit("table1/claims", 0.0,
         f"qgalore_beats_lowrank={ok_quality};memory_order_ok={ok_memory}")
    return rows


if __name__ == "__main__":
    main()
