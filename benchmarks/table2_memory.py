"""Paper Table 2 / Figure 5: the 7B memory budget (analytic, exact).

Reproduces the memory model on the full LLaMA-7B (and the 60M-1B family of
Table 1) without allocation: weights + optimizer states per method. The
paper's headline: Q-GaLore trains 7B within a 16 GB card; 8-bit GaLore needs
18 GB; 8-bit Adam 26 GB."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.config import QGaLoreConfig, replace
from repro.core import qgalore
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train import step as step_lib

RANKS = {"llama-60m": 128, "llama-130m": 256, "llama-350m": 256,
         "llama-1b": 512, "llama-7b": 1024}

METHODS = ("full", "adam8bit", "galore", "galore8bit", "qgalore")


def method_memory_gb(arch: str, method: str) -> float:
    cfg = model_zoo.get_config(arch)
    bundle = model_zoo.build(cfg)
    qcfg = preset(method, QGaLoreConfig(rank=RANKS[arch]))
    params_abs = jax.eval_shape(
        lambda k: step_lib.prepare_params(bundle.init_params(k), qcfg,
                                          jnp.bfloat16),
        jax.random.PRNGKey(0))
    rep = qgalore.memory_report(params_abs, qcfg)
    return rep["total_gb"]


def main():
    for arch in ("llama-60m", "llama-130m", "llama-350m", "llama-1b"):
        vals = {m: method_memory_gb(arch, m)
                for m in ("full", "galore", "qgalore")}
        emit(f"table2/{arch}", 0.0,
             ";".join(f"{m}={v:.3f}GB" for m, v in vals.items()))
    vals7 = {m: method_memory_gb("llama-7b", m) for m in METHODS}
    for m, v in vals7.items():
        emit(f"table2/llama-7b/{m}", 0.0, f"{v:.2f}GB")
    # headline claim: Q-GaLore 7B weights+optimizer fit a 16GB budget with
    # room for activations/gradient transients (paper: ~15GB end-to-end).
    emit("table2/claim_16gb", 0.0,
         f"qgalore_7b={vals7['qgalore']:.2f}GB;fits_16gb="
         f"{vals7['qgalore'] < 16.0}")
    return vals7


if __name__ == "__main__":
    main()
