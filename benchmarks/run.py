"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``--steps N`` scales the
training-based benchmarks (default 60 ≈ CPU-minutes; the claims are
mechanically checked either way). ``--only <prefix>`` runs a subset.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _roofline(dryrun_dir: str):
    from repro.analysis import roofline
    arts = roofline.load_artifacts(f"{dryrun_dir}/16x16")
    if not arts:
        print("roofline/none,0,run launch.dryrun first", flush=True)
        return
    for key, art in arts.items():
        if not art.get("ok"):
            print(f"roofline/{key},0,FAILED", flush=True)
            continue
        r = roofline.from_artifact(art)
        print(f"roofline/{key},0,dominant={r.dominant};"
              f"compute_s={r.compute_s:.4f};memory_s={r.memory_s:.4f};"
              f"collective_s={r.collective_s:.4f};"
              f"mfu_bound={r.mfu_bound:.3f}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--only", default="")
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    args = ap.parse_args()

    print("name,us_per_call,derived")

    from benchmarks import ablations, kernels_bench, table1_pretrain, \
        table2_memory, table34_finetune
    sections = [
        ("table2", lambda: table2_memory.main()),
        ("kernels", lambda: kernels_bench.main()),
        ("table1", lambda: table1_pretrain.main(args.steps)),
        ("fig3", lambda: ablations.fig3_proj_bits(args.steps)),
        ("fig6", lambda: ablations.fig6_stochastic_rounding(args.steps)),
        ("fig7", lambda: ablations.fig7_svd_counts(args.steps + 20)),
        ("fig2", lambda: ablations.fig2_subspace_dynamics(args.steps)),
        ("table34", lambda: table34_finetune.main(
            max(args.steps * 2 // 3, 20))),
        ("roofline", lambda: _roofline(args.dryrun_dir)),
    ]

    failures = []
    for name, fn in sections:
        if args.only and not name.startswith(args.only):
            continue
        t0 = time.monotonic()
        try:
            fn()
            print(f"section/{name},{(time.monotonic()-t0)*1e6:.0f},ok",
                  flush=True)
        except Exception:                      # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"section/{name},0,FAILED", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
