"""Shared benchmark harness: tiny-but-real training runs + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (the repo-wide
contract) — ``us_per_call`` is the mean step wall time, ``derived`` carries
the benchmark's headline quantity (final loss, memory GB, SVD ratio, …).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, QGaLoreConfig, ShapeCell, TrainConfig, \
    replace
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

# A "130M-family" reduced model that actually trains on CPU in seconds.
BENCH_MODEL = ModelConfig(
    name="llama-bench", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=4, d_ff=344, vocab_size=2048)

BENCH_CELL = ShapeCell("bench", seq_len=64, global_batch=8, kind="train")


def bench_qcfg(**kw) -> QGaLoreConfig:
    base = QGaLoreConfig(rank=16, min_dim=64, update_interval=10,
                         adaptive_k=2, cos_threshold=0.4)
    return replace(base, **kw)


def bench_tcfg(steps: int, lr: float = 5e-3, seed: int = 0) -> TrainConfig:
    return TrainConfig(seed=seed, global_batch=BENCH_CELL.global_batch,
                       seq_len=BENCH_CELL.seq_len, steps=steps,
                       learning_rate=lr, warmup_steps=5, log_every=0)


def run_method(method: str, steps: int, *, qcfg: Optional[QGaLoreConfig] =
               None, model: Optional[ModelConfig] = None,
               seed: int = 0, lr: float = 5e-3) -> Dict:
    """Train BENCH_MODEL with an optimizer preset; returns summary dict."""
    cfg = model or BENCH_MODEL
    bundle = model_zoo.build(cfg, dtype=jnp.float32)
    # method == "raw": take qcfg verbatim (ablations sweep individual knobs)
    q = (qcfg or bench_qcfg()) if method == "raw" \
        else preset(method, qcfg or bench_qcfg())
    tr = Trainer(bundle, bench_tcfg(steps, lr, seed), q, cell=BENCH_CELL,
                 impl="fused", param_dtype=jnp.float32)
    t0 = time.monotonic()
    hist = tr.run()
    dt = time.monotonic() - t0
    from repro.core import qgalore as qg
    mem = qg.memory_report(tr.state.params, q)
    return {
        "losses": [h["loss"] for h in hist],
        "final_loss": float(np.mean([h["loss"] for h in hist[-5:]])),
        "eval_loss": tr.eval_loss(2),
        "us_per_call": dt / max(len(hist), 1) * 1e6,
        "memory_gb": mem["total_gb"],
        "svd_used": tr.controller.total_svd_count(),
        "svd_baseline": tr.controller.baseline_svd_count(steps),
        "trainer": tr,
    }


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
