"""Shared benchmark harness: tiny-but-real training runs + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (the repo-wide
contract) — ``us_per_call`` is the mean step wall time, ``derived`` carries
the benchmark's headline quantity (final loss, memory GB, SVD ratio, …).
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig, QGaLoreConfig, ShapeCell, TrainConfig, \
    replace
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

# A "130M-family" reduced model that actually trains on CPU in seconds.
BENCH_MODEL = ModelConfig(
    name="llama-bench", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=4, d_ff=344, vocab_size=2048)

BENCH_CELL = ShapeCell("bench", seq_len=64, global_batch=8, kind="train")


def bench_qcfg(**kw) -> QGaLoreConfig:
    base = QGaLoreConfig(rank=16, min_dim=64, update_interval=10,
                         adaptive_k=2, cos_threshold=0.4)
    return replace(base, **kw)


def bench_tcfg(steps: int, lr: float = 5e-3, seed: int = 0) -> TrainConfig:
    return TrainConfig(seed=seed, global_batch=BENCH_CELL.global_batch,
                       seq_len=BENCH_CELL.seq_len, steps=steps,
                       learning_rate=lr, warmup_steps=5, log_every=0)


def run_method(method: str, steps: int, *, qcfg: Optional[QGaLoreConfig] =
               None, model: Optional[ModelConfig] = None,
               seed: int = 0, lr: float = 5e-3) -> Dict:
    """Train BENCH_MODEL with an optimizer preset; returns summary dict."""
    cfg = model or BENCH_MODEL
    bundle = model_zoo.build(cfg, dtype=jnp.float32)
    # method == "raw": take qcfg verbatim (ablations sweep individual knobs)
    q = (qcfg or bench_qcfg()) if method == "raw" \
        else preset(method, qcfg or bench_qcfg())
    tr = Trainer(bundle, bench_tcfg(steps, lr, seed), q, cell=BENCH_CELL,
                 impl="fused", param_dtype=jnp.float32)
    t0 = time.monotonic()
    hist = tr.run()
    dt = time.monotonic() - t0
    from repro.core import qgalore as qg
    mem = qg.memory_report(tr.state.params, q)
    return {
        "losses": [h["loss"] for h in hist],
        "final_loss": float(np.mean([h["loss"] for h in hist[-5:]])),
        "eval_loss": tr.eval_loss(2),
        "us_per_call": dt / max(len(hist), 1) * 1e6,
        "memory_gb": mem["total_gb"],
        "svd_used": tr.controller.total_svd_count(),
        "svd_baseline": tr.controller.baseline_svd_count(steps),
        "trainer": tr,
    }


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)


# -- interleaved paired-rounds measurement -----------------------------------
#
# Sequential A/B timing (all iters of A, then all of B) is vulnerable to
# scheduler drift between the two windows — it is what produced the
# phantom "quantized prefill regression" once reported on this box.
# Paired rounds time one short burst of EVERY variant back-to-back per
# round (order reversed on alternate rounds), and ratios are computed
# per-round then trimmed, so a hiccup lands in one round and gets
# dropped instead of skewing one variant's whole budget.

def paired_times(variants, *, rounds: int = 12, inner: int = 4
                 ) -> Dict[str, List[float]]:
    """Per-round us/call for each zero-arg variant in ``variants``
    (a name -> callable dict), measured interleaved."""
    for f in variants.values():                 # compile + warm all first
        jax.block_until_ready(f())
    names = list(variants)
    times: Dict[str, List[float]] = {n: [] for n in names}
    for r in range(rounds):
        order = names if r % 2 == 0 else list(reversed(names))
        for n in order:
            f = variants[n]
            t0 = time.perf_counter()
            for _ in range(inner):
                out = f()
            jax.block_until_ready(out)
            times[n].append((time.perf_counter() - t0) / inner * 1e6)
    return times


def paired_ratio(times: Dict[str, List[float]], base: str, test: str,
                 trim: float = 0.2) -> Dict[str, float]:
    """Trimmed-mean speedup of ``test`` over ``base`` from paired round
    times (ratio_x > 1 ⇔ test is faster): per-round ratios, sorted, with
    the top/bottom ``trim`` fraction dropped; ``sem`` is the standard
    error of the surviving rounds."""
    r = np.asarray(times[base], float) / np.asarray(times[test], float)
    r = np.sort(r)
    k = int(len(r) * trim)
    core = r[k: len(r) - k] if len(r) > 2 * k else r
    sem = float(core.std(ddof=1) / np.sqrt(len(core))) if len(core) > 1 \
        else 0.0
    return {"ratio_x": float(core.mean()), "median_x": float(np.median(r)),
            "sem": sem, "rounds": int(len(r))}
