"""Fused Q-GaLore update kernel: parity vs the unfused three-op path,
backend dispatch, and leaf-batching equivalence."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig, replace
from repro.core import projector, qgalore, quant
from repro.kernels import dispatch, ops

B1, B2, EPS = 0.9, 0.999, 1e-8


def _setup(m, n, r, side, key=0, w_scale=0.02):
    k = jax.random.PRNGKey(key)
    W = jax.random.normal(k, (m, n)) * w_scale
    qt = quant.quantize_blockwise(W, bits=8, symmetric=True)
    d = n if side == "right" else m
    P = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(k, 1), (d, r)))[0]
    qp = projector.quantize_projection(P, 4, 256)
    low_shape = (m, r) if side == "right" else (r, n)
    low = jax.random.normal(jax.random.fold_in(k, 2), low_shape)
    m32 = jax.random.normal(jax.random.fold_in(k, 3), low_shape) * 0.1
    v32 = jnp.abs(jax.random.normal(jax.random.fold_in(k, 4), low_shape)) \
        * 0.01
    return qt, qp, low, m32, v32


def _unfused(qt, qp, low, m32, v32, count, lr, gscale, side, key):
    """The three-op reference composition (Adam → back-project → SR)."""
    m_new = B1 * m32 + (1 - B1) * low
    v_new = B2 * v32 + (1 - B2) * low * low
    c = jnp.float32(count)
    dirn = (m_new / (1 - B1 ** c)) / (
        jnp.sqrt(v_new / (1 - B2 ** c)) + EPS)
    Pd = projector.maybe_dequantize(qp, jnp.float32)
    upd = gscale * projector.project_back(dirn, Pd, side)
    new_qt = quant.requantize_sr(qt, -lr * upd, key)
    return new_qt, m_new, v_new


class TestFusedKernelParity:
    @pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
    @pytest.mark.parametrize("m,n,r,side", [
        (512, 256, 32, "right"),
        (256, 512, 32, "left"),
        (300, 200, 24, "right"),    # non-multiple-of-block rows/cols
        (200, 300, 24, "left"),
    ])
    def test_matches_unfused_within_one_quantum(self, m, n, r, side,
                                                backend):
        qt, qp, low, m32, v32 = _setup(m, n, r, side)
        count, lr, gscale = 3, 1e-2, 0.25
        key = jax.random.PRNGKey(42)
        want, m_ref, v_ref = _unfused(qt, qp, low, m32, v32, count, lr,
                                      gscale, side, key)
        got, m_got, v_got = ops.fused_qgalore_update(
            qt, low, m32, v32, qp, jnp.float32(count), lr, key, side=side,
            gscale=gscale, backend=backend)
        # same SR randoms -> identical up to fp reassociation flipping a
        # value on a floor boundary, i.e. at most one INT8 quantum
        dq_w = np.asarray(quant.dequantize(want, jnp.float32))
        dq_g = np.asarray(quant.dequantize(got, jnp.float32))
        quantum = float(np.asarray(want.scale).max())
        assert float(np.abs(dq_w - dq_g).max()) <= quantum + 1e-6
        # and nearly all codes agree exactly
        frac = (np.asarray(got.q) == np.asarray(want.q))[:, :n].mean()
        assert frac > 0.999
        np.testing.assert_allclose(np.asarray(m_got), np.asarray(m_ref),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(v_got), np.asarray(v_ref),
                                   rtol=1e-5, atol=1e-7)

    def test_mean_error_across_seeds(self):
        """Acceptance: mean deq error vs unfused stays within SR noise
        across >= 3 seeds."""
        qt, qp, low, m32, v32 = _setup(256, 128, 16, "right")
        count, lr, gscale = 2, 5e-3, 0.25
        errs = []
        for seed in range(4):
            key = jax.random.PRNGKey(seed)
            want, _, _ = _unfused(qt, qp, low, m32, v32, count, lr, gscale,
                                  "right", key)
            got, _, _ = ops.fused_qgalore_update(
                qt, low, m32, v32, qp, jnp.float32(count), lr, key,
                side="right", gscale=gscale, backend="ref")
            dq_w = quant.dequantize(want, jnp.float32)
            dq_g = quant.dequantize(got, jnp.float32)
            errs.append(float(jnp.abs(dq_w - dq_g).mean()))
        quantum = float(np.asarray(qt.scale).mean())
        assert np.mean(errs) < 0.05 * quantum

    def test_int4_zero_point_edges(self):
        """Constant / all-zero projection blocks hit the zero-point and
        eps-clamped-scale edge cases of the INT4 dequant."""
        m, n, r = 128, 256, 16
        qt, _, low, m32, v32 = _setup(m, n, r, "right")
        for P in (jnp.zeros((n, r)),                      # scale -> eps
                  jnp.full((n, r), 0.37),                 # zero-range block
                  jnp.concatenate([jnp.zeros((n, r // 2)),
                                   jnp.ones((n, r // 2))], axis=1)):
            qp = projector.quantize_projection(P, 4, 256)
            key = jax.random.PRNGKey(0)
            want, _, _ = _unfused(qt, qp, low, m32, v32, 1, 1e-2, 0.25,
                                  "right", key)
            for backend in ("ref", "pallas-interpret"):
                got, _, _ = ops.fused_qgalore_update(
                    qt, low, m32, v32, qp, jnp.float32(1), 1e-2, key,
                    side="right", gscale=0.25, backend=backend)
                dq_w = np.asarray(quant.dequantize(want, jnp.float32))
                dq_g = np.asarray(quant.dequantize(got, jnp.float32))
                quantum = float(np.asarray(want.scale).max())
                assert float(np.abs(dq_w - dq_g).max()) <= quantum + 1e-6
                assert np.isfinite(dq_g).all()

    def test_weight_decay(self):
        qt, qp, low, m32, v32 = _setup(256, 128, 16, "right")
        key = jax.random.PRNGKey(7)
        wd, lr, gscale = 0.1, 1e-2, 0.25
        m_new = B1 * m32 + (1 - B1) * low
        v_new = B2 * v32 + (1 - B2) * low * low
        dirn = (m_new / (1 - B1)) / (jnp.sqrt(v_new / (1 - B2)) + EPS)
        Pd = projector.maybe_dequantize(qp, jnp.float32)
        upd = gscale * projector.project_back(dirn, Pd, "right") \
            + wd * quant.dequantize(qt, jnp.float32)
        want = quant.requantize_sr(qt, -lr * upd, key)
        got, _, _ = ops.fused_qgalore_update(
            qt, low, m32, v32, qp, jnp.float32(1), lr, key, side="right",
            gscale=gscale, weight_decay=wd, backend="ref")
        dq_w = np.asarray(quant.dequantize(want, jnp.float32))
        dq_g = np.asarray(quant.dequantize(got, jnp.float32))
        quantum = float(np.asarray(want.scale).max())
        assert float(np.abs(dq_w - dq_g).max()) <= quantum + 1e-6


class TestDispatch:
    def test_registry_has_all_backends(self):
        for op in ("int8_matmul", "int4_matmul", "sr_requant",
                   "blockwise_quant", "flash_attention",
                   "fused_qgalore_update"):
            assert set(dispatch.available_backends(op)) == {
                "pallas-tpu", "pallas-interpret", "ref"}

    def test_default_backend_env_override(self, monkeypatch):
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "pallas-interpret")
        assert dispatch.default_backend("anything") == "pallas-interpret"
        monkeypatch.setenv("REPRO_KERNEL_BACKEND", "bogus")
        with pytest.raises(ValueError):
            dispatch.default_backend()

    def test_platform_default_off_tpu(self, monkeypatch):
        monkeypatch.delenv("REPRO_KERNEL_BACKEND", raising=False)
        monkeypatch.delenv("REPRO_PALLAS_COMPILED", raising=False)
        want = "pallas-tpu" if dispatch.platform() == "tpu" else "ref"
        assert dispatch.default_backend("fused_qgalore_update") == want

    def test_fallback_chain(self):
        dispatch.register("_test_only_op", "ref")(lambda: "ref")
        name, fn = dispatch.resolve("_test_only_op", "pallas-tpu")
        assert name == "ref" and fn() == "ref"

    def test_tuned_blocks_bucketing(self):
        b = dispatch.tuned_blocks("fused_qgalore_update", (1000, 900),
                                  backend="pallas-tpu")
        assert b == {"bm": 256, "bn": 1024}     # bucketed to (1024, 1024)
        d = dispatch.tuned_blocks("fused_qgalore_update", (64, 64),
                                  backend="pallas-tpu")
        assert d == {"bm": 256, "bn": 512}      # per-op defaults

    def test_ops_interpret_flag_still_works(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 256))
        qt = quant.quantize_blockwise(
            jax.random.normal(jax.random.PRNGKey(1), (256, 512)),
            bits=8, symmetric=True)
        got_i = ops.int8_matmul(x, qt, interpret=True)
        got_r = ops.int8_matmul(x, qt, backend="ref")
        np.testing.assert_allclose(np.asarray(got_i), np.asarray(got_r),
                                   rtol=2e-2, atol=2e-2)


class TestTileFitting:
    """Tuned tiles must divide the (padded) problem dims — the Pallas
    grids floor-divide and would silently drop the remainder."""

    def test_fit_block(self):
        assert dispatch.fit_block(384, 256) == 192
        assert dispatch.fit_block(768, 512, 256) == 256
        assert dispatch.fit_block(192, 128) == 96
        assert dispatch.fit_block(512, 512) == 512
        assert dispatch.fit_block(256, 1024) == 256
        # awkward dims fall back to one tile, not a grid of 1-wide tiles
        assert dispatch.fit_block(197, 128) == 197
        # ... but a healthy large divisor is still preferred
        assert dispatch.fit_block(394, 256) == 197

    def test_sr_requant_width_not_multiple_of_default_tile(self):
        # C=768: a multiple of the quant block (256) but not of the
        # default bc tile (512) — previously cols 512..767 were never
        # written on the Pallas backends.
        w = jax.random.normal(jax.random.PRNGKey(0), (128, 768)) * 0.02
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        upd = jax.random.normal(jax.random.PRNGKey(1), (128, 768)) * 1e-3
        key = jax.random.PRNGKey(2)
        got = ops.sr_requant_update(qt, upd, key, interpret=True)
        want = ops.sr_requant_update(qt, upd, key, backend="ref")
        np.testing.assert_array_equal(np.asarray(got.q),
                                      np.asarray(want.q))
        np.testing.assert_allclose(np.asarray(got.scale),
                                   np.asarray(want.scale), rtol=1e-6)

    def test_int8_matmul_rows_not_multiple_of_tuned_tile(self):
        # M=384 pads to 384 (multiple of 128) but not of a 256 row tile.
        x = jax.random.normal(jax.random.PRNGKey(3), (384, 256))
        qt = quant.quantize_blockwise(
            jax.random.normal(jax.random.PRNGKey(4), (256, 768)),
            bits=8, symmetric=True)
        got = ops.int8_matmul(x, qt, interpret=True)
        want = ops.int8_matmul(x, qt, backend="ref")
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_flash_attention_seq_not_multiple_of_default_tile(self):
        # S=192 worked pre-dispatch (kernel default bq=min(256,S)); the
        # 128 table default must be fitted down, not crash.
        B, S, H, d = 1, 192, 2, 32
        q = jax.random.normal(jax.random.PRNGKey(5), (B, S, H, d))
        k = jax.random.normal(jax.random.PRNGKey(6), (B, S, H, d))
        v = jax.random.normal(jax.random.PRNGKey(7), (B, S, H, d))
        got = ops.flash_attention(q, k, v, causal=True, interpret=True)
        want = ops.flash_attention(q, k, v, causal=True, backend="ref")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


class TestOptimizerIntegration:
    def _params(self):
        k = jax.random.PRNGKey(3)
        params = {
            "stack": jax.random.normal(k, (2, 128, 96)) * 0.02,
            "a": jax.random.normal(jax.random.fold_in(k, 1),
                                   (128, 96)) * 0.02,
            "b": jax.random.normal(jax.random.fold_in(k, 2),
                                   (128, 96)) * 0.02,
            "c": jax.random.normal(jax.random.fold_in(k, 3),
                                   (96, 160)) * 0.02,
        }
        return quant.tree_quantize(params, bits=8, symmetric=True,
                                   predicate=lambda p, l: l.ndim >= 2)

    def _run(self, cfg):
        params = self._params()
        specs = qgalore.leaf_specs(params, cfg)
        state = qgalore.init(params, cfg)
        grads = quant.tree_dequantize(params, jnp.float32)
        step = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=cfg, specs=specs, refresh=False))
        new_params, new_state, _ = step(params, grads, state, lr=1e-2,
                                        rng=jax.random.PRNGKey(11))
        return quant.tree_dequantize(new_params, jnp.float32), new_state

    def test_batching_is_numerically_transparent(self):
        """Grouped-scan execution == per-leaf loop, exactly (same RNG
        folding per original leaf index)."""
        base = QGaLoreConfig(rank=16, min_dim=64, fused_update=False)
        got, _ = self._run(replace(base, batch_leaves=True))
        want, _ = self._run(replace(base, batch_leaves=False))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            got, want)

    def test_fused_matches_unfused_optimizer_step(self):
        base = QGaLoreConfig(rank=16, min_dim=64, adam_bits=32)
        got, gs = self._run(replace(base, fused_update=True))
        want, ws = self._run(replace(base, fused_update=False))
        flat_g = jax.tree_util.tree_leaves(got)
        flat_w = jax.tree_util.tree_leaves(want)
        for a, b in zip(flat_g, flat_w):
            # same SR draws -> differ by at most one INT8 quantum
            q = float(jnp.abs(jnp.asarray(b)).max()) / 127.0 + 1e-6
            assert float(jnp.abs(a - b).max()) <= q

    def test_fused_with_8bit_moments_descends(self):
        cfg = QGaLoreConfig(rank=16, min_dim=64, adam_bits=8,
                            fused_update=True)
        before = quant.tree_dequantize(self._params(), jnp.float32)
        after, state = self._run(cfg)
        assert int(state.count) == 1
        norm_b = sum(float(jnp.sum(x * x))
                     for x in jax.tree_util.tree_leaves(before))
        norm_a = sum(float(jnp.sum(x * x))
                     for x in jax.tree_util.tree_leaves(after))
        # grads == params, lr>0 -> squared norm must shrink
        assert norm_a < norm_b
        for leaf in jax.tree_util.tree_leaves(after):
            assert np.isfinite(np.asarray(leaf)).all()
