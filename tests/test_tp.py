"""Tensor-parallel Q-GaLore on a 2-D (data x model) mesh.

The tentpole contract (ISSUE 8): every GaLore quantity follows the
weight's TP shard dim —

  side   shard_dim   P (d, r)         low-rank / moments
  right  0 (m)       replicated       sharded on m  (local project)
  right  1 (n)       sliced on d = n  replicated    (psum on low)
  left   0 (m)       sliced on d = m  replicated    (psum on low)
  left   1 (n)       replicated       sharded on n  (local project)

— and the subspace refresh runs on shards over the COMBINED
(data x model) front (train/step.py scatters the layer stack over all
D*t ranks), so no full-rank GaLore tensor is ever gathered: the thing
ColossalAI's distributed_galore does on every refresh. Mesh tests run in
subprocesses (the forced-host-device flag must be set before jax
imports); the pure shard-algebra checks run in-process.
"""
import os
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 600):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


# ---------------------------------------------------------------------------
# Golden parity on the full stack + elastic (2,4) <-> (8,1) restore
# ---------------------------------------------------------------------------

def test_tp_adarank_parity_2x4_vs_1dev_and_elastic_restore():
    """The TP acceptance gate: the FULL distributed stack (compressed-DP
    shard_map + combined-front distributed refresh + ZeRO-sharded state +
    a forced adaptive-rank transition with live state migration) on a
    (2,4) data x model mesh must match the 1-device run — same loss
    trajectory, same transition schedule — and a post-shrink checkpoint
    saved on (2,4) must restore bit-exactly onto an (8,1) mesh (the
    elastic TP <-> DP reshard)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.config import replace as cfg_replace
        from repro.core.optimizers import preset
        from repro.models.model_zoo import build, get_config
        from repro.train.trainer import Trainer

        cfg = cfg_replace(get_config("llama-60m", smoke=True), num_layers=8)
        qcfg = preset("qgalore", QGaLoreConfig(
            rank=8, min_dim=32, update_interval=4, adaptive_k=1,
            cos_threshold=0.3, compress_dp_grads=True,
            galore_embeddings=True, adaptive_rank=True, rank_ladder=(4,),
            explained_ratio_threshold=0.05, rank_patience=1, min_rank=4))
        cell = ShapeCell("t", 32, 8, "train")

        def make(mesh, ckpt_dir=""):
            bundle = build(cfg, dtype=jnp.float32)
            tcfg = TrainConfig(seed=0, global_batch=8, seq_len=32, steps=6,
                               learning_rate=1e-2, warmup_steps=2,
                               grad_clip=1.0, log_every=0,
                               checkpoint_dir=ckpt_dir,
                               async_checkpoint=False)
            return Trainer(bundle, tcfg, qcfg, cell=cell, impl="fused",
                           param_dtype=jnp.float32, mesh=mesh,
                           zero_shard=True)

        d = tempfile.mkdtemp()
        mesh_tp = jax.make_mesh((2, 4), ("data", "model"))
        tr_tp = make(mesh_tp, ckpt_dir=d)
        # the TP annotation really landed on the specs
        ann = {s.path: (s.shard_dim, s.tp) for s in tr_tp.specs
               if s.galore and len(s.mat_shape) == 2}
        assert any(t == 4 for _, t in ann.values()), ann
        hist_tp = tr_tp.run()
        trans_tp = tr_tp.controller.rank_transition_summary()
        assert trans_tp and all(t["step"] == 0 for t in trans_tp), trans_tp
        assert all(t["new"] == 4 for t in trans_tp), trans_tp
        for s in tr_tp.specs:
            if s.galore:
                assert s.rank == 4, s      # live migration really shrank

        mesh_1 = jax.make_mesh((1, 1), ("data", "model"),
                               devices=jax.devices()[:1])
        tr1 = make(mesh_1)
        hist1 = tr1.run()
        assert tr1.controller.rank_transition_summary() == trans_tp
        np.testing.assert_allclose([h["loss"] for h in hist1],
                                   [h["loss"] for h in hist_tp],
                                   rtol=1e-3, atol=1e-3)

        # elastic: the (2,4) ZeRO+TP checkpoint restores onto (8,1)
        mesh_dp = jax.make_mesh((8, 1), ("data", "model"))
        tr_dp = make(mesh_dp, ckpt_dir=d)
        assert tr_dp.mgr.read_meta()["rank_overrides"]
        assert tr_dp.maybe_restore() == 6
        assert {s.path: s.rank for s in tr_dp.specs if s.galore} == \
            {s.path: s.rank for s in tr_tp.specs if s.galore}
        for a, b in zip(
                jax.tree_util.tree_leaves(jax.device_get(tr_tp.state)),
                jax.tree_util.tree_leaves(jax.device_get(tr_dp.state))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK tp adarank", [round(h["loss"], 4) for h in hist_tp])
    """, timeout=900)
    assert "OK tp adarank" in out


# ---------------------------------------------------------------------------
# No full-rank GaLore tensor materializes during a TP refresh
# ---------------------------------------------------------------------------

def test_tp_refresh_no_full_rank_materialization():
    """Compile a refresh step on a (2,4) mesh and scan the HLO: the only
    collectives allowed to touch full-rank stacked-leaf shapes are the
    phase-1 reduce-scatters (each rank RECEIVING its owned layer slice);
    any all-reduce / all-gather producing a full-rank stacked buffer —
    global (L, m, n) or per-front (L/D, m, n) / (L/(D*t), m, n) — means a
    rank gathered gradients it does not own, i.e. the ColossalAI-style
    full-rank refresh the combined-front design exists to avoid. Also
    asserts the structural contract: every stacked galore leaf scatters
    over the combined ('data','model') front of 8 ranks."""
    out = run_py("""
        import re, jax, jax.numpy as jnp, numpy as np
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.config import replace as cfg_replace
        from repro.core.optimizers import preset
        from repro.models.model_zoo import build, get_config
        from repro.train import step as step_lib
        from repro.data.synthetic import batch_for_bundle

        cfg = cfg_replace(get_config("llama-60m", smoke=True), num_layers=8)
        bundle = build(cfg, dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32,
                                               compress_dp_grads=True))
        tcfg = TrainConfig(global_batch=8, seq_len=32, grad_clip=0.0)
        cell = ShapeCell("t", 32, 8, "train")
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        raw, specs = step_lib.build_train_step(
            bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32,
            mesh=mesh, dp_compress=True)
        state = step_lib.init_state(bundle, qcfg, jax.random.PRNGKey(0),
                                    jnp.float32)
        galore = [i for i, s in enumerate(specs) if s.galore]
        masks = {i: jnp.ones((specs[i].nbatch,), bool) for i in galore}

        # structural contract: combined front over all 8 ranks
        assert raw.refresh_axes == ("data", "model"), raw.refresh_axes
        assert raw.refresh_world == 8 and raw.dp_size == 2
        stacked = [i for i in galore if specs[i].batch]
        assert stacked
        mats = set()
        for i in stacked:
            assert raw.dist_front[i] == (("data", "model"), 8), \\
                (i, raw.dist_front[i])
            assert specs[i].nbatch % 8 == 0      # each rank owns L/(D*t)
            assert specs[i].tp == 4 and specs[i].shard_dim in (0, 1)
            mats.add(specs[i].mat_shape)

        fr = jax.jit(lambda st, b, lr, rng, m: raw(
            st, b, lr, rng, refresh_masks=m, refresh=True))
        with mesh:
            batch = batch_for_bundle(bundle, cell, 0)
            txt = fr.lower(state, batch, 1e-2, jax.random.PRNGKey(7),
                           masks).compile().as_text()
            st2, met, om = fr(state, batch, 1e-2, jax.random.PRNGKey(7),
                              masks)
        assert np.isfinite(float(met["loss"]))
        assert len(om.get("sims", {})) == len(galore)

        pat = re.compile(r"=\\s+(\\w+)\\[([\\d,]*)\\][^=]*?"
                         r"\\b(all-gather|all-reduce|reduce-scatter)\\b")
        L = specs[stacked[0]].nbatch               # 8 stacked layers
        forbidden = {",".join(map(str, (lead,) + m))
                     for m in mats for lead in (L, L // 2, L // 8)}
        hits = []
        gathered_lowrank = False
        for m_ in pat.finditer(txt):
            dtype, shape, op = m_.group(1), m_.group(2), m_.group(3)
            if op == "reduce-scatter":
                continue                           # phase-1 reduce: exempt
            if shape in forbidden:
                hits.append((op, dtype, shape))
            dims = tuple(int(x) for x in shape.split(",") if x)
            if len(dims) == 3 and dims[0] == L and dims[-1] <= 8:
                gathered_lowrank = True            # e.g. (8, 64, 8) low
        assert not hits, f"full-rank gather in TP refresh: {hits}"
        assert gathered_lowrank, "no low-rank gather found - wrong scan?"
        print("OK no full-rank", sorted(forbidden))
    """, timeout=900)
    assert "OK no full-rank" in out


# ---------------------------------------------------------------------------
# Per-device optimizer-state bytes shrink ~tp-fold on model-sharded leaves
# ---------------------------------------------------------------------------

def test_tp_per_device_state_bytes():
    """On a (2,4) mesh (ZeRO off, so the model axis does all the work)
    every 2-D galore leaf keeps exactly one of {moments, projection} on
    the model axis per the shard-dim table; that component's max
    per-device bytes must drop ~4x vs the (8,1) mesh where the model axis
    is trivial."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.config import QGaLoreConfig
        from repro.core.optimizers import preset
        from repro.core import projector, qgalore, quant
        from repro.distributed import sharding as sh
        from repro.models import model_zoo
        from repro.train import step as step_lib

        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32))
        state = step_lib.init_state(bundle, qcfg, jax.random.PRNGKey(0),
                                    jnp.float32)
        specs = qgalore.leaf_specs(state.params, qcfg)

        def place(mesh):
            o_sh = sh.opt_state_sharding(state.params, state.opt, qcfg,
                                         mesh)
            with mesh:
                opt = jax.device_put(state.opt, o_sh)
            inner = jax.tree_util.tree_flatten(
                opt.inner, is_leaf=qgalore._is_inner_leaf)[0]
            proj = jax.tree_util.tree_flatten(
                opt.proj,
                is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]
            return inner, proj

        mesh_tp = jax.make_mesh((2, 4), ("data", "model"))
        mesh_dp = jax.make_mesh((8, 1), ("data", "model"))
        specs_tp = sh.annotate_tp(specs, mesh_tp)
        inner_tp, proj_tp = place(mesh_tp)
        inner_dp, proj_dp = place(mesh_dp)

        def nbytes(tree):
            arrs = jax.tree_util.tree_leaves(tree)
            dev = sum(max(s.data.nbytes for s in a.addressable_shards)
                      for a in arrs)
            return dev, sum(a.nbytes for a in arrs)

        checked = 0
        for i, sp in enumerate(specs_tp):
            if not sp.galore or sp.shard_dim is None:
                continue
            if projector.proj_dim_sharded(sp.side, sp.shard_dim):
                tgt_tp, tgt_dp = proj_tp[i], proj_dp[i]      # P sliced on d
            else:
                tgt_tp, tgt_dp = inner_tp[i], inner_dp[i]    # moments
            dev_tp, tot_tp = nbytes(tgt_tp)
            dev_dp, tot_dp = nbytes(tgt_dp)
            assert tot_tp == tot_dp                          # same state
            assert dev_dp == tot_dp, (sp.path, dev_dp, tot_dp)
            # ~tp-fold: INT4/INT8 codes split exactly 4x, per-block
            # scales may stay replicated when they don't divide
            assert dev_tp * 4 <= tot_tp * 1.3, \\
                (sp.path, dev_tp, tot_tp)
            checked += 1
        assert checked >= 6, checked
        print("OK tp bytes", checked)
    """, timeout=600)
    assert "OK tp bytes" in out


# ---------------------------------------------------------------------------
# Sharded subspace math on a real 1-axis mesh
# ---------------------------------------------------------------------------

def test_tp_sharded_subspace_collectives():
    """projector.sharded_subspace / explained_ratio_sharded inside a real
    shard_map over a 4-device axis: both sides x both shard dims, the
    Gram-accumulated subspace must match the SVD subspace (compared via
    subspace similarity — eigen vs SVD differ elementwise at fp32 noise)
    and the sharded explained-variance profile must match the replicated
    one to float tolerance."""
    out = run_py("""
        import functools, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import compat
        from repro.core import projector

        mesh = jax.make_mesh((4,), ("x",))
        psum = functools.partial(jax.lax.psum, axis_name="x")
        G = jax.random.normal(jax.random.PRNGKey(0), (48, 64), jnp.float32)
        rank = 8
        for side in ("right", "left"):
            P_ref = projector.compute_subspace(G, rank, side)
            Pq = projector.quantize_projection(P_ref, bits=4, block=8)
            Pf = projector.maybe_dequantize(Pq)
            ratio_ref = np.asarray(
                projector.explained_ratio(G, Pf, side))
            for shard_dim in (0, 1):
                g_spec = P("x", None) if shard_dim == 0 else P(None, "x")
                sliced = projector.proj_dim_sharded(side, shard_dim)
                p_spec = P("x", None) if sliced else P(None, None)

                f = functools.partial(projector.sharded_subspace,
                                      rank=rank, side=side,
                                      shard_dim=shard_dim, psum=psum)
                P_sh = compat.shard_map(
                    f, mesh=mesh, in_specs=(g_spec,), out_specs=p_spec,
                    check_vma=False)(G)
                sim = float(projector.subspace_similarity(P_ref, P_sh))
                assert sim > 0.99, (side, shard_dim, sim)

                g = functools.partial(projector.explained_ratio_sharded,
                                      side=side, shard_dim=shard_dim,
                                      psum=psum)
                ratio_sh = compat.shard_map(
                    g, mesh=mesh, in_specs=(g_spec, p_spec),
                    out_specs=P(None), check_vma=False)(G, Pf)
                np.testing.assert_allclose(
                    np.asarray(ratio_sh), ratio_ref, rtol=1e-5, atol=1e-6,
                    err_msg=f"{side}/{shard_dim}")
        print("OK sharded subspace")
    """, devices=4, timeout=600)
    assert "OK sharded subspace" in out


# ---------------------------------------------------------------------------
# Host-side shard algebra (no mesh needed)
# ---------------------------------------------------------------------------

def test_projection_shard_reassemble_and_project():
    """Pure shard algebra: slicing an INT4 projection along d commutes
    with reassembly bit-exactly (codes AND scales), and per-shard
    projection recomposes the replicated low-rank product for every
    side x shard-dim combination."""
    from repro.core import projector

    world = 4
    for side, (m, n) in (("right", (64, 32)), ("left", (32, 64))):
        G = jax.random.normal(jax.random.PRNGKey(1), (m, n), jnp.float32)
        P_ = projector.compute_subspace(G, 8, side)
        Pq = projector.quantize_projection(P_, bits=4, block=8)
        Pf = projector.maybe_dequantize(Pq)
        low_full = projector.project(G, Pf, side)
        for shard_dim in (0, 1):
            shards = [projector.shard_projection(Pq, side, shard_dim, k,
                                                 world)
                      for k in range(world)]
            back = projector.reassemble_projection(shards, side, shard_dim)
            for a, b in zip(jax.tree_util.tree_leaves(Pq),
                            jax.tree_util.tree_leaves(back)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

            g_shards = [projector.shard_matrix(G, shard_dim, k, world)
                        for k in range(world)]
            lows = [projector.project_sharded(
                        g_shards[k],
                        projector.shard_projection(Pf, side, shard_dim, k,
                                                   world),
                        side, shard_dim, psum=lambda x: x)
                    for k in range(world)]
            if projector.proj_dim_sharded(side, shard_dim):
                low = sum(lows)                  # contracted dim: reduce
            else:                                # surviving dim: concat
                axis = -2 if side == "right" else -1
                low = jnp.concatenate(lows, axis=axis)
            np.testing.assert_allclose(np.asarray(low),
                                       np.asarray(low_full),
                                       rtol=1e-5, atol=1e-6,
                                       err_msg=f"{side}/{shard_dim}")
