"""Autotune-table machinery: persisted-table roundtrip, runtime entries,
tile clamping (fit_block) and tail-block tile picking (pick_tile)."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import dispatch, ops


class TestPickTile:
    """pick_tile chooses the row tile from the TRUE dim before padding —
    the tail-block fix (a 1-row decode matmul must not pad to 128)."""

    def test_decode_row(self):
        assert dispatch.pick_tile(1, 128) == 8       # one f32 sublane

    def test_non_multiple_prefill(self):
        assert dispatch.pick_tile(100, 128) == 104   # next multiple of 8

    def test_large_dim_capped_by_request(self):
        assert dispatch.pick_tile(256, 128) == 128

    def test_request_below_multiple(self):
        assert dispatch.pick_tile(64, 4) == 8

    def test_exact(self):
        assert dispatch.pick_tile(128, 128) == 128


class TestFitBlock:
    def test_clamps_to_dim(self):
        # a table entry tuned for a big bucket cannot force a small
        # problem to pad up to the entry's tile
        assert dispatch.fit_block(8, 512) == 8

    def test_single_row(self):
        assert dispatch.fit_block(1, 128) == 1

    def test_divisor_with_multiple(self):
        # largest tile <= 1024 dividing 1536 that is a multiple of 256
        assert dispatch.fit_block(1536, 1024, 256) == 768

    def test_exact_fit(self):
        assert dispatch.fit_block(2048, 512) == 512


class TestTableRoundtrip:
    ENTRY = {"op": "int8_matmul", "backend": "pallas-interpret",
             "shape": [256, 512], "dtype": "float32",
             "blocks": {"bm": 64, "bn": 512, "bk": 256},
             "source": "measured"}

    def _with_table(self, tmp_path, monkeypatch, entries):
        p = str(tmp_path / "table.json")
        dispatch.save_table_entries(entries, p)
        monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", p)
        dispatch.reload_table()
        return p

    def test_persist_load_dispatch(self, tmp_path, monkeypatch):
        self._with_table(tmp_path, monkeypatch, [self.ENTRY])
        # the query shape buckets to the stored (256, 512)
        got = dispatch.tuned_blocks("int8_matmul", (200, 500), "float32",
                                    backend="pallas-interpret")
        assert got == {"bm": 64, "bn": 512, "bk": 256}

    def test_any_dtype_fallback(self, tmp_path, monkeypatch):
        e = dict(self.ENTRY, dtype="")
        self._with_table(tmp_path, monkeypatch, [e])
        got = dispatch.tuned_blocks("int8_matmul", (256, 512), "bfloat16",
                                    backend="pallas-interpret")
        assert got == {"bm": 64, "bn": 512, "bk": 256}

    def test_miss_falls_back_to_defaults(self, tmp_path, monkeypatch):
        self._with_table(tmp_path, monkeypatch, [self.ENTRY])
        got = dispatch.tuned_blocks("int8_matmul", (4096, 4096), "float32",
                                    backend="pallas-interpret")
        assert got == dispatch._DEFAULT_BLOCKS["int8_matmul"]

    def test_runtime_registration_wins(self, tmp_path, monkeypatch):
        self._with_table(tmp_path, monkeypatch, [self.ENTRY])
        dispatch.register_tuned("int8_matmul", "pallas-interpret",
                                (256, 512), {"bm": 8, "bn": 256, "bk": 128},
                                "float32")
        try:
            got = dispatch.tuned_blocks("int8_matmul", (256, 512),
                                        "float32",
                                        backend="pallas-interpret")
            assert got == {"bm": 8, "bn": 256, "bk": 128}
        finally:
            dispatch._RUNTIME_TABLE.clear()

    def test_save_dedups_last_wins(self, tmp_path, monkeypatch):
        e2 = dict(self.ENTRY, blocks={"bm": 128, "bn": 256, "bk": 512})
        p = self._with_table(tmp_path, monkeypatch, [self.ENTRY, e2])
        doc = json.load(open(p))
        assert doc["version"] == 1
        assert len(doc["entries"]) == 1
        assert doc["entries"][0]["blocks"] == e2["blocks"]

    def test_merge_keeps_seed_entries(self, tmp_path, monkeypatch):
        seed = dict(self.ENTRY, shape=[4096, 4096], source="seed")
        p = self._with_table(tmp_path, monkeypatch, [seed])
        merged = dispatch.load_table_entries(p) + [self.ENTRY]
        dispatch.save_table_entries(merged, p)
        doc = json.load(open(p))
        assert {tuple(e["shape"]) for e in doc["entries"]} == \
            {(4096, 4096), (256, 512)}

    def test_committed_table_loads(self):
        # the in-repo table parses and serves the seed entries
        entries = dispatch.load_table_entries(dispatch._TABLE_FILE)
        assert entries, "committed autotune_table.json is empty"
        assert all(e["source"] in ("seed", "measured") for e in entries)


class TestTunedBlocksReachKernel:
    def test_wrapper_honors_runtime_entry(self, monkeypatch):
        """A registered entry flows through the ops wrapper into a
        working (and correct) kernel launch at a non-tile-multiple
        shape."""
        M, K, N = 9, 96, 160
        x = jnp.asarray(np.random.default_rng(0).normal(size=(M, K)),
                        jnp.float32)
        w = jnp.asarray(np.random.default_rng(1).normal(size=(K, N)) * 0.1,
                        jnp.float32)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        dispatch.register_tuned("int8_matmul", "pallas-interpret", (M, K),
                                {"bm": 64, "bn": 256, "bk": 32}, "float32")
        try:
            got = ops.int8_matmul(x, qt, backend="pallas-interpret")
        finally:
            dispatch._RUNTIME_TABLE.clear()
        want = x @ quant.dequantize(qt, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)
