"""Fine-tune entrypoint smoke (the paper's Tables 3-4 scenario on the
param-group rules API): frozen groups hold zero optimizer state, per-group
ranks are honored, frozen weights stay bit-identical, and the reported
optimizer+weight memory is <= the QLoRA baseline at matched rank — all
asserted INSIDE ``launch.finetune.run`` and re-checked here on its
report."""
import json
import os

import numpy as np

from repro.launch import finetune


def test_finetune_smoke_memory_vs_qlora(tmp_path):
    out = str(tmp_path / "finetune_memory.json")
    report = finetune.run(arch="llama-60m", smoke=True, steps=6, rank=8,
                          freeze_layers=1, out=out)
    # the comparison JSON is produced (the CI finetune-smoke step asserts
    # this file too)
    assert os.path.exists(out)
    with open(out) as f:
        on_disk = json.load(f)
    assert on_disk["qgalore_leq_qlora"] is True
    assert report["qgalore"]["total_gb"] <= report["qlora"]["total_gb"]
    # frozen base exists and the tuned group got the requested rank
    assert report["frozen_leaves"] > 0 and report["tuned_leaves"] > 0
    assert report["groups"]["frozen_base"] == report["frozen_leaves"]
    assert report["rank"] == 8
    # Q-GaLore actually spent optimizer memory on the tuned group only
    assert 0 < report["qgalore"]["optimizer_gb"] \
        < report["qlora"]["adapter_plus_opt_gb"]
    assert np.isfinite(report["final_loss"])


def test_restore_under_different_rules_fails_loudly(tmp_path):
    """A checkpoint written under frozen-group rules must refuse a restore
    under different rules with the rules-mismatch ValueError — validated
    BEFORE the arrays are touched (not a missing-leaf KeyError), in BOTH
    directions (freeze-more and freeze-less)."""
    import jax.numpy as jnp
    import pytest
    from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
    from repro.core.optimizers import preset
    from repro.models import model_zoo
    from repro.train.trainer import Trainer

    bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                  dtype=jnp.float32, split_layers=1)
    base = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32))
    rules = finetune.build_finetune_rules(
        QGaLoreConfig(rank=8, min_dim=32), rank=8)

    def make(qcfg, d):
        tcfg = TrainConfig(global_batch=2, seq_len=16, steps=2,
                           learning_rate=1e-3, warmup_steps=1, log_every=0,
                           checkpoint_dir=str(d), checkpoint_every=0,
                           async_checkpoint=False)
        return Trainer(bundle, tcfg, qcfg,
                       cell=ShapeCell("t", 16, 2, "train"),
                       param_dtype=jnp.float32)

    tr = make(rules, tmp_path)
    tr.run(steps=1)
    tr.save(0)
    tr.mgr.wait()
    # freeze-less direction: restoring with NO frozen groups wants state
    # arrays the checkpoint never wrote — must be the loud rules error
    with pytest.raises(ValueError, match="param-group rules"):
        make(base, tmp_path).maybe_restore()
    # same rules restore fine
    assert make(rules, tmp_path).maybe_restore() == 1


def test_finetune_rules_shape():
    from repro.config import QGaLoreConfig
    rules = finetune.build_finetune_rules(
        QGaLoreConfig(rank=16, min_dim=32), rank=16)
    names = [g.name for g in rules.groups]
    assert names == ["frozen_base", "qgalore_blocks"]
    assert rules.groups[0].frozen
    assert rules.groups[1].rank == 16
    # first-match-wins: an early-layer leaf hits the frozen group even
    # though no later pattern matches it
    assert rules.resolve("['seg0_dense']['attn']['wq']").name == \
        "frozen_base"
    assert rules.resolve("['seg1_dense']['attn']['wq']").name == \
        "qgalore_blocks"
    assert rules.resolve("['final_norm']").name == "frozen_base"
    # freeze_early=False (unsplit model, blocks live in seg0_): early
    # layers are NOT frozen and the tune pattern matches any segment
    rules0 = finetune.build_finetune_rules(
        QGaLoreConfig(rank=16, min_dim=32), rank=16, freeze_early=False)
    assert rules0.resolve("['seg0_dense']['attn']['wq']").name == \
        "qgalore_blocks"
    assert rules0.resolve("['embedding']").name == "frozen_base"


def test_split_layers_out_of_range_rejected():
    import jax.numpy as jnp
    import pytest
    from repro.models import model_zoo
    cfg = model_zoo.get_config("llama-60m", smoke=True)  # 2 layers
    for bad in (2, 3, -1):
        with pytest.raises(ValueError, match="split_layers"):
            model_zoo.build(cfg, dtype=jnp.float32, split_layers=bad)
    # in-range still builds two segments
    b = model_zoo.build(cfg, dtype=jnp.float32, split_layers=1)
    assert len(b.segments) == 2
