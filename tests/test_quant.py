"""Unit + property tests for the block-wise quantization substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import quant
from repro.core.quant import (
    QTensor, dequantize, pack_int4, quantize_blockwise, requantize_sr,
    stochastic_round, tree_dequantize, tree_quantize, unpack_int4,
)


class TestPacking:
    def test_roundtrip(self):
        u = jnp.arange(16, dtype=jnp.uint8).reshape(2, 8)
        assert (unpack_int4(pack_int4(u)) == u).all()

    def test_shapes(self):
        u = jnp.zeros((3, 5, 256), jnp.uint8)
        p = pack_int4(u)
        assert p.shape == (3, 5, 128)
        assert unpack_int4(p).shape == (3, 5, 256)


class TestQuantize:
    @pytest.mark.parametrize("bits", [4, 8])
    @pytest.mark.parametrize("symmetric", [True, False])
    def test_roundtrip_error_bound(self, bits, symmetric):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (8, 512), jnp.float32)
        qt = quantize_blockwise(x, bits=bits, symmetric=symmetric)
        y = dequantize(qt, jnp.float32)
        assert y.shape == x.shape
        # max error <= scale/2 per element (round-to-nearest)
        scale = np.asarray(qt.scale)
        max_scale = scale.max()
        assert np.abs(np.asarray(y - x)).max() <= max_scale * 0.5 + 1e-6

    def test_padding_last_dim(self):
        x = jnp.ones((4, 300), jnp.float32) * 0.5
        qt = quantize_blockwise(x, bits=8, block=256)
        assert qt.q.shape == (4, 512)
        y = dequantize(qt)
        assert y.shape == (4, 300)
        np.testing.assert_allclose(np.asarray(y, np.float32), 0.5, atol=0.01)

    def test_int4_packed_storage(self):
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 512))
        qt = quantize_blockwise(x, bits=4)
        assert qt.q.dtype == jnp.uint8
        assert qt.q.shape == (4, 256)  # nibble packed

    def test_memory_halving(self):
        x = jnp.zeros((16, 1024), jnp.float32)
        q8 = quantize_blockwise(x, bits=8)
        q4 = quantize_blockwise(x, bits=4)
        assert q4.q.nbytes * 2 == q8.q.nbytes

    def test_pytree_flatten(self):
        x = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
        qt = quantize_blockwise(x, bits=8, symmetric=True)
        leaves, treedef = jax.tree_util.tree_flatten(qt)
        qt2 = jax.tree_util.tree_unflatten(treedef, leaves)
        np.testing.assert_array_equal(np.asarray(qt.q), np.asarray(qt2.q))

    def test_jit_through(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (4, 256))
        qt = quantize_blockwise(x, bits=8)

        @jax.jit
        def f(q):
            return dequantize(q, jnp.float32).sum()

        assert np.isfinite(float(f(qt)))

    def test_zero_tensor(self):
        x = jnp.zeros((2, 256))
        for bits in (4, 8):
            y = dequantize(quantize_blockwise(x, bits=bits))
            np.testing.assert_allclose(np.asarray(y), 0.0, atol=1e-6)


class TestStochasticRounding:
    def test_unbiased(self):
        # E[SR(x)] == x
        x = jnp.full((200_000,), 0.3)
        keys = jax.random.PRNGKey(0)
        r = stochastic_round(x, keys)
        assert abs(float(r.mean()) - 0.3) < 5e-3
        assert set(np.unique(np.asarray(r))) <= {0.0, 1.0}

    def test_integers_fixed(self):
        x = jnp.array([1.0, -2.0, 5.0])
        r = stochastic_round(x, jax.random.PRNGKey(1))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(x))

    @given(frac=st.floats(0.05, 0.95))
    @settings(max_examples=10, deadline=None)
    def test_probability_matches_fraction(self, frac):
        x = jnp.full((100_000,), frac, jnp.float32)
        r = stochastic_round(x, jax.random.PRNGKey(42))
        assert abs(float(r.mean()) - frac) < 2e-2

    def test_sr_requant_accumulates_small_updates(self):
        """The paper's key claim: with SR, sub-quantum updates accumulate;
        with round-to-nearest they vanish."""
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 256)) * 2.0
        qt = quantize_blockwise(x, bits=8, symmetric=True)
        step = float(np.asarray(qt.scale).mean())
        upd = jnp.full(x.shape, 0.05 * step)  # far below one quantum

        # round-to-nearest: re-quantizing with tiny update changes ~nothing
        w = qt
        for i in range(50):
            dq = dequantize(w, jnp.float32) + upd
            w = quantize_blockwise(dq, bits=8, symmetric=True)
        drift_rtn = float((dequantize(w) - dequantize(qt)).mean())

        w = qt
        for i in range(50):
            w = requantize_sr(w, upd, jax.random.PRNGKey(i))
        drift_sr = float((dequantize(w) - dequantize(qt)).mean())

        expected = 50 * 0.05 * step
        # SR captures most of the accumulated update; RTN captures ~none.
        assert drift_sr > 0.5 * expected
        assert abs(drift_rtn) < 0.2 * expected


class TestTreeHelpers:
    def test_tree_quantize_predicate(self):
        tree = {"w": jnp.ones((256, 256)), "b": jnp.ones((256,))}
        qtree = tree_quantize(tree, bits=8,
                              predicate=lambda p, l: l.ndim == 2)
        assert quant.is_qtensor(qtree["w"])
        assert not quant.is_qtensor(qtree["b"])
        deq = tree_dequantize(qtree, jnp.float32)
        np.testing.assert_allclose(np.asarray(deq["w"]), 1.0, atol=0.02)

    def test_quantized_nbytes(self):
        tree = {"w": quantize_blockwise(jnp.ones((256, 256)), bits=8,
                                        symmetric=True)}
        nb = quant.quantized_nbytes(tree)
        assert 256 * 256 <= nb <= 256 * 256 + 4 * 4 * 256  # q + scales
