"""Distributed correctness at container scale: a real (2,2)/(2,4) host-device
mesh in a subprocess (the 512-device flag must be set before jax imports, so
these run out-of-process), exercising sharded train steps, sharded decode,
checkpoint save on one mesh + elastic restore onto another, and the dry-run
entry points."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, devices: int = 8, timeout: int = 600):
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
    """)
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_runs_and_matches_single_device():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.core.optimizers import preset
        from repro.distributed import sharding as sh
        from repro.models import model_zoo
        from repro.train import step as step_lib
        from repro.data.synthetic import batch_for_bundle

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32))
        tcfg = TrainConfig(global_batch=4, seq_len=32, grad_clip=1.0)
        cell = ShapeCell("t", 32, 4, "train")
        raw, specs = step_lib.build_train_step(
            bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32)
        state = step_lib.init_state(bundle, qcfg, jax.random.PRNGKey(0),
                                    jnp.float32)
        batch = batch_for_bundle(bundle, cell, 0)

        p_sh = sh.param_sharding(state.params, mesh)
        o_sh = sh.opt_state_sharding(state.params, state.opt, qcfg, mesh)
        b_sh = sh.data_sharding(
            jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch),
            mesh)
        rep = sh.replicated(mesh)
        fn = jax.jit(lambda st, b, lr, rng: raw(st, b, lr, rng,
                                                refresh_masks=None,
                                                refresh=False),
                     in_shardings=(step_lib.TrainState(p_sh, o_sh),
                                   b_sh, rep, rep))
        st_sharded = jax.device_put(state, step_lib.TrainState(p_sh, o_sh))
        with mesh:
            new_state, metrics, _ = fn(st_sharded, batch, 1e-3,
                                       jax.random.PRNGKey(1))
        loss_sharded = float(metrics["loss"])

        # single-device oracle
        fn1 = jax.jit(lambda st, b, lr, rng: raw(st, b, lr, rng,
                                                 refresh_masks=None,
                                                 refresh=False))
        _, metrics1, _ = fn1(state, batch, 1e-3, jax.random.PRNGKey(1))
        loss1 = float(metrics1["loss"])
        assert abs(loss_sharded - loss1) < 5e-3, (loss_sharded, loss1)
        print("OK", loss_sharded, loss1)
    """)
    assert "OK" in out


def test_elastic_checkpoint_reshard():
    """Save on a (4,2) mesh, restore onto (2,2) with different shardings —
    the elastic-scaling path."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.core.optimizers import preset
        from repro.distributed import sharding as sh
        from repro.models import model_zoo
        from repro.train import step as step_lib
        from repro.train.checkpoint import CheckpointManager

        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32))
        state = step_lib.init_state(bundle, qcfg, jax.random.PRNGKey(0),
                                    jnp.float32)

        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        p_sh_a = sh.param_sharding(state.params, mesh_a)
        o_sh_a = sh.opt_state_sharding(state.params, state.opt, qcfg,
                                       mesh_a)
        st_a = jax.device_put(state, step_lib.TrainState(p_sh_a, o_sh_a))

        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(7, st_a, {"note": "elastic"})

        # restore on a DIFFERENT mesh shape
        mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                               devices=jax.devices()[:4])
        abs_state = step_lib.abstract_state(bundle, qcfg, jnp.float32)
        p_sh_b = sh.param_sharding(abs_state.params, mesh_b)
        o_sh_b = sh.opt_state_sharding(abs_state.params, abs_state.opt,
                                       qcfg, mesh_b)
        restored, meta = mgr.restore(
            None, abs_state, step_lib.TrainState(p_sh_b, o_sh_b))
        assert meta["step"] == 7

        a = jax.tree_util.tree_leaves(jax.device_get(st_a))
        b = jax.tree_util.tree_leaves(jax.device_get(restored))
        assert len(a) == len(b)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("OK elastic reshard", meta)
    """)
    assert "OK elastic reshard" in out


def test_sharded_decode_runs():
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.config import QGaLoreConfig
        from repro.distributed import sharding as sh
        from repro.models import model_zoo
        from repro.serve import engine, shard as sshard
        from repro.train import step as step_lib

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bundle = model_zoo.build_arch("yi-9b", smoke=True,
                                      dtype=jnp.float32)
        params = step_lib.prepare_params(
            bundle.init_params(jax.random.PRNGKey(0)), QGaLoreConfig(),
            jnp.float32)
        B, maxlen = 4, 64
        batch = {"tokens": jnp.zeros((B, 8), jnp.int32)}
        prefill = jax.jit(engine.build_prefill(bundle, maxlen))
        logits, state = prefill(params, batch)

        s_abs = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        s_sh = sshard.decode_state_sharding(
            engine.DecodeState(s_abs.caches, s_abs.lengths, s_abs.extras),
            mesh)
        p_sh = sh.param_sharding(params, mesh)
        decode = jax.jit(engine.build_decode(bundle),
                         in_shardings=(p_sh, s_sh, sh.replicated(mesh)))
        with mesh:
            params_s = jax.device_put(params, p_sh)
            state_s = jax.device_put(state, s_sh)
            lg, state2 = decode(params_s, state_s,
                                jnp.ones((B, 1), jnp.int32))
        import numpy as np
        assert np.isfinite(np.asarray(lg)).all()
        print("OK sharded decode", lg.shape)
    """)
    assert "OK sharded decode" in out


def test_sharded_cache_pool_continuous_decode():
    """Continuous batching on a real mesh: the slot pool sharded via
    pool_sharding (slot axis on data, KV time on model) must produce the
    same tokens as the unsharded scheduler."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import model_zoo
        from repro.serve import shard as sshard
        from repro.serve.scheduler import Request, Scheduler

        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        params = bundle.init_params(jax.random.PRNGKey(0))
        V = bundle.cfg.vocab_size
        rng = np.random.default_rng(0)
        reqs = [Request(rid=r,
                        tokens=rng.integers(1, V, size=int(
                            rng.integers(3, 10))).astype(np.int32),
                        max_new_tokens=int(rng.integers(2, 6)))
                for r in range(6)]

        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             devices=jax.devices()[:4])
        sh = sshard.pool_sharding(bundle, num_slots=4, max_len=32,
                                  mesh=mesh, dtype=jnp.float32)
        with mesh:
            sched = Scheduler(bundle, params, num_slots=4, max_len=32,
                              dtype=jnp.float32, prompt_bucket=8,
                              shardings=sh)
            comps = {c.rid: c.tokens for c in sched.run(list(reqs))}

        plain = Scheduler(bundle, params, num_slots=4, max_len=32,
                          dtype=jnp.float32, prompt_bucket=8)
        ref = {c.rid: c.tokens for c in plain.run(list(reqs))}
        assert comps == ref, (comps, ref)
        print("OK sharded pool", sched.stats)
    """)
    assert "OK sharded pool" in out


def test_sharded_paged_pool_continuous_decode():
    """Paged serving on a real mesh: the block pool sharded via
    paged_pool_sharding (block axis on data, KV time WITHIN blocks on
    model) must produce the same tokens as the unsharded paged scheduler
    AND the slot scheduler — traced-index block gathers/scatters become
    collectives under GSPMD without changing a single token."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import model_zoo
        from repro.serve import shard as sshard
        from repro.serve.paged import PagedScheduler
        from repro.serve.scheduler import Request, Scheduler

        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        params = bundle.init_params(jax.random.PRNGKey(0))
        V = bundle.cfg.vocab_size
        rng = np.random.default_rng(0)
        shared = rng.integers(1, V, size=8)
        def reqs():
            out = []
            for r in range(6):
                p = rng2.integers(1, V, size=int(
                    rng2.integers(3, 10))).astype(np.int32)
                if r % 2 == 0:
                    p = np.concatenate([shared.astype(np.int32), p])
                out.append(Request(rid=r, tokens=p.tolist(),
                                   max_new_tokens=int(
                                       rng2.integers(2, 6))))
            return out

        # num_blocks divisible by the data axis (2) for block sharding
        kw = dict(num_slots=4, max_len=32, block_size=8, num_blocks=18,
                  prefill_chunk=8, dtype=jnp.float32)
        mesh = jax.make_mesh((2, 2), ("data", "model"),
                             devices=jax.devices()[:4])
        sh = sshard.paged_pool_sharding(bundle, kw["num_blocks"],
                                        kw["block_size"], mesh,
                                        dtype=jnp.float32)
        rng2 = np.random.default_rng(1)
        with mesh:
            sched = PagedScheduler(bundle, params, shardings=sh, **kw)
            comps = {c.rid: c.tokens for c in sched.run(reqs())}

        rng2 = np.random.default_rng(1)
        plain = PagedScheduler(bundle, params, **kw)
        ref = {c.rid: c.tokens for c in plain.run(reqs())}
        assert comps == ref, (comps, ref)

        rng2 = np.random.default_rng(1)
        slot = Scheduler(bundle, params, num_slots=4, max_len=32,
                         dtype=jnp.float32, prompt_bucket=8)
        slot_ref = {c.rid: c.tokens for c in slot.run(reqs())}
        assert comps == slot_ref, (comps, slot_ref)
        assert sched.stats["radix_hit_blocks"] > 0
        print("OK sharded paged pool", sched.stats)
    """)
    assert "OK sharded paged pool" in out


@pytest.mark.slow
def test_dryrun_entry_small():
    """The dryrun module itself (512 devices) on the smallest arch/cell."""
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    out_dir = "/tmp/dryrun_test_out"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "xlstm-125m", "--cell", "decode_32k", "--out", out_dir],
        env=env, capture_output=True, text=True, timeout=900, cwd=ROOT)
    assert r.returncode == 0, r.stderr[-2000:]
    with open(os.path.join(out_dir, "16x16",
                           "xlstm-125m__decode_32k.json")) as f:
        art = json.load(f)
    assert art["ok"]
    assert art["cost_analysis"]["flops"] > 0


def test_dp_compress_parity_1dev_vs_8dev():
    """Golden parity case: the SAME distributed mode (dp_compress +
    distributed refresh) on a 1-device vs an 8-device DP mesh must produce
    the same trajectory — the only allowed difference is floating-point
    reduction order (which SR turns into sub-quantum code flips), so the
    loss band is tight. 8 layers so the layer stack divides both worlds
    and refresh-step eligibility is identical."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.core.optimizers import preset
        from repro.models import model_zoo
        from repro.config import replace as cfg_replace
        from repro.models.model_zoo import build, get_config
        from repro.train import step as step_lib
        from repro.data.synthetic import batch_for_bundle

        cfg = cfg_replace(get_config("llama-60m", smoke=True), num_layers=8)
        bundle = build(cfg, dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32))
        tcfg = TrainConfig(global_batch=8, seq_len=32, grad_clip=1.0)
        cell = ShapeCell("t", 32, 8, "train")

        def run(d):
            mesh = jax.make_mesh((d, 1), ("data", "model"),
                                 devices=jax.devices()[:d])
            raw, specs = step_lib.build_train_step(
                bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32,
                mesh=mesh, dp_compress=True)
            state = step_lib.init_state(bundle, qcfg,
                                        jax.random.PRNGKey(0), jnp.float32)
            galore = [i for i, s in enumerate(specs) if s.galore]
            masks = {i: jnp.ones((specs[i].nbatch,), bool) for i in galore}
            fr = jax.jit(lambda st, b, lr, rng, m: raw(
                st, b, lr, rng, refresh_masks=m, refresh=True))
            fn = jax.jit(lambda st, b, lr, rng: raw(
                st, b, lr, rng, refresh_masks=None, refresh=False))
            losses = []
            with mesh:
                for s in range(5):
                    batch = batch_for_bundle(bundle, cell, s)
                    if s % 3 == 0:
                        state, met, _ = fr(state, batch, 1e-2,
                                           jax.random.PRNGKey(s), masks)
                    else:
                        state, met, _ = fn(state, batch, 1e-2,
                                           jax.random.PRNGKey(s))
                    losses.append(float(met["loss"]))
            return losses

        l1, l8 = run(1), run(8)
        np.testing.assert_allclose(l1, l8, rtol=1e-3, atol=1e-3)
        print("OK parity", l1, l8)
    """, timeout=900)
    assert "OK parity" in out


def test_dist_refresh_matches_replicated():
    """The distributed subspace refresh (reduce-scatter + per-owner SVD +
    broadcast) must reproduce the replicated in-optimizer refresh: same
    similarities, same new projections, same next-step loss. grad_clip=0
    so the (documented) low-rank-vs-full-rank clip-norm difference at
    refresh steps doesn't enter."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.core.optimizers import preset
        from repro.core import quant
        from repro.models import model_zoo
        from repro.train import step as step_lib
        from repro.data.synthetic import batch_for_bundle

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        tcfg = TrainConfig(global_batch=8, seq_len=32, grad_clip=0.0)
        cell = ShapeCell("t", 32, 8, "train")

        results = {}
        for dist in (True, False):
            qcfg = preset("qgalore", QGaLoreConfig(
                rank=8, min_dim=32, dist_refresh=dist))
            raw, specs = step_lib.build_train_step(
                bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32,
                mesh=mesh, dp_compress=True)
            state = step_lib.init_state(bundle, qcfg,
                                        jax.random.PRNGKey(0), jnp.float32)
            galore = [i for i, s in enumerate(specs) if s.galore]
            masks = {i: jnp.ones((specs[i].nbatch,), bool) for i in galore}
            fr = jax.jit(lambda st, b, lr, rng, m: raw(
                st, b, lr, rng, refresh_masks=m, refresh=True))
            with mesh:
                batch = batch_for_bundle(bundle, cell, 0)
                state, met, om = fr(state, batch, 1e-2,
                                    jax.random.PRNGKey(7), masks)
                sims = {k: np.asarray(v) for k, v in om["sims"].items()}
                proj = jax.device_get(state.opt.proj)
                results[dist] = (float(met["loss"]), sims, proj)

        l_d, s_d, p_d = results[True]
        l_r, s_r, p_r = results[False]
        assert abs(l_d - l_r) < 1e-4, (l_d, l_r)
        assert set(s_d) == set(s_r)
        for k in s_d:
            np.testing.assert_allclose(s_d[k], s_r[k], atol=1e-3, err_msg=k)
        for a, b in zip(jax.tree_util.tree_leaves(p_d),
                        jax.tree_util.tree_leaves(p_r)):
            a, b = np.asarray(a), np.asarray(b)
            if np.issubdtype(a.dtype, np.integer):
                frac = (a != b).mean()
                assert frac < 0.02, frac     # INT4 codes: rare edge flips
            else:
                np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)
        print("OK dist refresh parity", l_d, l_r)
    """, timeout=900)
    assert "OK dist refresh parity" in out


def test_zero_sharded_state_matches_and_reshards():
    """ZeRO-sharded optimizer state: (a) the sharded step matches the
    replicated-state step, (b) per-device optimizer bytes shrink ~D-fold,
    (c) a ZeRO checkpoint saved on an (8,1) data mesh restores bit-exactly
    onto a (2,2) mesh with different zero axes (elastic reshard)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.core.optimizers import preset
        from repro.distributed import sharding as sh
        from repro.models import model_zoo
        from repro.train import step as step_lib
        from repro.train.checkpoint import CheckpointManager
        from repro.data.synthetic import batch_for_bundle

        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32,
                                               compress_dp_grads=True))
        tcfg = TrainConfig(global_batch=8, seq_len=32, grad_clip=1.0)
        cell = ShapeCell("t", 32, 8, "train")
        mesh = jax.make_mesh((8, 1), ("data", "model"))
        raw, specs = step_lib.build_train_step(
            bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32,
            mesh=mesh, dp_compress=True)
        state = step_lib.init_state(bundle, qcfg, jax.random.PRNGKey(0),
                                    jnp.float32)
        batch = batch_for_bundle(bundle, cell, 0)

        p_sh = sh.param_sharding(state.params, mesh)
        o_rep = sh.opt_state_sharding(state.params, state.opt, qcfg, mesh)
        o_zero = sh.opt_state_sharding(state.params, state.opt, qcfg,
                                       mesh, zero_axes=("data",))
        b_sh = sh.data_sharding(jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), batch), mesh)
        rep = sh.replicated(mesh)

        losses = {}
        states = {}
        for name, o_sh in (("rep", o_rep), ("zero", o_zero)):
            ss = step_lib.TrainState(p_sh, o_sh)
            fn = jax.jit(lambda st, b, lr, rng: raw(
                st, b, lr, rng, refresh_masks=None, refresh=False),
                in_shardings=(ss, b_sh, rep, rep),
                out_shardings=(ss, None, None))
            with mesh:
                st = jax.device_put(state, ss)
                for s in range(2):
                    st, met, _ = fn(st, batch, 1e-3, jax.random.PRNGKey(s))
                losses[name] = float(met["loss"])
            states[name] = st
        # (a) numerics identical up to reduction order
        assert abs(losses["rep"] - losses["zero"]) < 1e-5, losses
        for a, b in zip(jax.tree_util.tree_leaves(
                            jax.device_get(states["rep"])),
                        jax.tree_util.tree_leaves(
                            jax.device_get(states["zero"]))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # (b) per-device bytes of the big moment leaves shrink
        def per_dev(st):
            tot = dev = 0
            for l in jax.tree_util.tree_leaves(st.opt.inner):
                if hasattr(l, "addressable_shards") and l.nbytes > 4096:
                    tot += l.nbytes
                    dev += max(s.data.nbytes for s in l.addressable_shards)
            return tot, dev
        tot, dev = per_dev(states["zero"])
        assert dev * 4 <= tot, (tot, dev)   # >= 4x sharded overall

        # (c) elastic ZeRO reshard through a checkpoint
        d = tempfile.mkdtemp()
        mgr = CheckpointManager(d, async_save=False)
        mgr.save(3, states["zero"], {"note": "zero"})
        mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                               devices=jax.devices()[:4])
        abs_state = step_lib.abstract_state(bundle, qcfg, jnp.float32)
        ss_b = step_lib.TrainState(
            sh.param_sharding(abs_state.params, mesh_b),
            sh.opt_state_sharding(abs_state.params, abs_state.opt, qcfg,
                                  mesh_b, zero_axes=("data",)))
        restored, meta = mgr.restore(None, abs_state, ss_b)
        assert meta["step"] == 3
        for a, b in zip(jax.tree_util.tree_leaves(
                            jax.device_get(states["zero"])),
                        jax.tree_util.tree_leaves(
                            jax.device_get(restored))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK zero shard", losses, tot, dev)
    """, timeout=900)
    assert "OK zero shard" in out


def test_zero2_reduce_scatter_matches_pmean():
    """ZeRO-2 gradient reduce-scatter (ROADMAP item): the steady-state
    low-rank gradients are psum_scattered along each leaf's moment-shard
    dim instead of pmean-replicated. Trajectory must match the pmean path
    (identical psum values, only the layout of the result differs), and
    the scatter dims must align with the ZeRO moment sharding."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.core.optimizers import preset
        from repro.core import qgalore
        from repro.distributed import sharding as sh
        from repro.models import model_zoo
        from repro.train import step as step_lib
        from repro.data.synthetic import batch_for_bundle

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32,
                                               compress_dp_grads=True))
        tcfg = TrainConfig(global_batch=8, seq_len=32, grad_clip=1.0)
        cell = ShapeCell("t", 32, 8, "train")

        abs_state = step_lib.abstract_state(bundle, qcfg, jnp.float32)
        specs = qgalore.leaf_specs(abs_state.params, qcfg)
        o_zero = sh.opt_state_sharding(abs_state.params, abs_state.opt,
                                       qcfg, mesh, zero_axes=("data",))
        dims = sh.zero2_scatter_dims(o_zero, specs, ("data",))
        assert dims, "no ZeRO-2 scatterable leaves found"
        # alignment: the scatter dim carries the data axis in the moment
        # sharding and divides the low-rank shape by the DP world size
        inner_flat = jax.tree_util.tree_flatten(
            o_zero.inner, is_leaf=qgalore._is_inner_leaf)[0]
        for i, d in dims.items():
            m_sh = inner_flat[i].m
            spec_p = (m_sh.q if hasattr(m_sh, 'q') else m_sh).spec
            part = spec_p[d]
            parts = (part,) if isinstance(part, str) else tuple(part)
            assert "data" in parts, (specs[i].path, d, spec_p)
            assert specs[i].low_shape[d] % 8 == 0

        p_sh = sh.param_sharding(abs_state.params, mesh)
        b_abs = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            batch_for_bundle(bundle, cell, 0))
        b_sh = sh.data_sharding(b_abs, mesh)
        rep = sh.replicated(mesh)
        ss = step_lib.TrainState(p_sh, o_zero)

        losses = {}
        for name, z2 in (("pmean", None), ("zero2", dims)):
            raw, _ = step_lib.build_train_step(
                bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32,
                mesh=mesh, dp_compress=True,
                state_shardings=step_lib.TrainState(p_sh, o_zero),
                zero2_dims=z2)
            state = step_lib.init_state(bundle, qcfg,
                                        jax.random.PRNGKey(0), jnp.float32)
            fn = jax.jit(lambda st, b, lr, rng: raw(
                st, b, lr, rng, refresh_masks=None, refresh=False),
                in_shardings=(ss, b_sh, rep, rep),
                out_shardings=(ss, None, None))
            ls = []
            with mesh:
                st = jax.device_put(state, ss)
                for s in range(3):
                    st, met, _ = fn(st, batch_for_bundle(bundle, cell, s),
                                    1e-2, jax.random.PRNGKey(s))
                    ls.append(float(met["loss"]))
            losses[name] = ls
        np.testing.assert_allclose(losses["pmean"], losses["zero2"],
                                   rtol=1e-4, atol=1e-4)
        print("OK zero2 parity", losses)
    """, timeout=900)
    assert "OK zero2 parity" in out


def test_dp_compress_matches_plain():
    """The shard_map-compressed gradient path must produce the same update
    as the plain GSPMD path (same loss trajectory over steps)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.core.optimizers import preset
        from repro.models import model_zoo
        from repro.train import step as step_lib
        from repro.data.synthetic import batch_for_bundle

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32))
        tcfg = TrainConfig(global_batch=8, seq_len=32, grad_clip=1.0)
        cell = ShapeCell("t", 32, 8, "train")

        losses = {}
        for mode in ("plain", "compress"):
            raw, _ = step_lib.build_train_step(
                bundle, qcfg, tcfg, impl="fused", param_dtype=jnp.float32,
                mesh=mesh, dp_compress=(mode == "compress"))
            state = step_lib.init_state(bundle, qcfg, jax.random.PRNGKey(0),
                                        jnp.float32)
            fn = jax.jit(lambda st, b, lr, rng: raw(
                st, b, lr, rng, refresh_masks=None, refresh=False))
            ls = []
            with mesh:
                for s in range(3):
                    batch = batch_for_bundle(bundle, cell, s)
                    state, metrics, _ = fn(state, batch, 1e-3,
                                           jax.random.PRNGKey(s))
                    ls.append(float(metrics["loss"]))
            losses[mode] = ls
        np.testing.assert_allclose(losses["plain"], losses["compress"],
                                   rtol=5e-3, atol=5e-3)
        print("OK dp_compress", losses)
    """, timeout=900)
    assert "OK dp_compress" in out


def test_adarank_zero_compressed_forced_transition():
    """The adarank-smoke CI gate: dynamic rank adaptation under the FULL
    distributed stack — compressed-DP shard_map + distributed refresh (the
    explained-variance profiles are computed on the scattered owners and
    gathered) + ZeRO-sharded optimizer state on an 8-device DP mesh, with
    a forced rank transition at the first refresh. Asserts (a) 1-dev vs
    8-dev parity of the loss trajectory AND the exact transition schedule,
    (b) the migrated (truncated + re-sharded) state keeps stepping, (c) a
    post-shrink ZeRO checkpoint restores bit-identically onto a different
    mesh, adopting the rank overrides meta-first."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
        from repro.config import replace as cfg_replace
        from repro.core.optimizers import preset
        from repro.models.model_zoo import build, get_config
        from repro.train.trainer import Trainer

        cfg = cfg_replace(get_config("llama-60m", smoke=True), num_layers=8)
        qcfg = preset("qgalore", QGaLoreConfig(
            rank=8, min_dim=32, update_interval=4, adaptive_k=1,
            cos_threshold=0.3, compress_dp_grads=True,
            galore_embeddings=True, adaptive_rank=True, rank_ladder=(4,),
            explained_ratio_threshold=0.05, rank_patience=1, min_rank=4))
        cell = ShapeCell("t", 32, 8, "train")

        def make(d, ckpt_dir="", mesh=None):
            bundle = build(cfg, dtype=jnp.float32)
            tcfg = TrainConfig(seed=0, global_batch=8, seq_len=32, steps=6,
                               learning_rate=1e-2, warmup_steps=2,
                               grad_clip=1.0, log_every=0,
                               checkpoint_dir=ckpt_dir,
                               async_checkpoint=False)
            mesh = mesh or jax.make_mesh((d, 1), ("data", "model"),
                                         devices=jax.devices()[:d])
            return Trainer(bundle, tcfg, qcfg, cell=cell, impl="fused",
                           param_dtype=jnp.float32, mesh=mesh,
                           zero_shard=True)

        d8 = tempfile.mkdtemp()
        tr8 = make(8, ckpt_dir=d8)
        hist8 = tr8.run()
        trans8 = tr8.controller.rank_transition_summary()
        assert trans8 and all(t["step"] == 0 for t in trans8), trans8
        assert all(t["new"] == 4 for t in trans8), trans8
        # the live state really shrank: every galore moment's rank dim is 4
        for i, s in enumerate(tr8.specs):
            if s.galore:
                assert s.rank == 4, s

        tr1 = make(1)
        hist1 = tr1.run()
        assert tr1.controller.rank_transition_summary() == trans8
        np.testing.assert_allclose([h["loss"] for h in hist1],
                                   [h["loss"] for h in hist8],
                                   rtol=1e-3, atol=1e-3)

        # (c) elastic post-shrink restore onto a (2,2) mesh
        mesh_b = jax.make_mesh((2, 2), ("data", "model"),
                               devices=jax.devices()[:4])
        trb = make(2, ckpt_dir=d8, mesh=mesh_b)
        assert trb.mgr.read_meta()["rank_overrides"]
        assert trb.maybe_restore() == 6
        assert {s.path: s.rank for s in trb.specs if s.galore} == \
            {s.path: s.rank for s in tr8.specs if s.galore}
        for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(tr8.state)),
                        jax.tree_util.tree_leaves(jax.device_get(trb.state))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print("OK adarank zero", [round(h["loss"], 4) for h in hist8])
    """, timeout=900)
    assert "OK adarank zero" in out
