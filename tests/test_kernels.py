"""Per-kernel allclose vs the ref.py oracles: shape/dtype sweeps +
hypothesis property tests (interpret mode on CPU)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import quant
from repro.kernels import ops, ref
from repro.kernels.blockwise_quant import blockwise_quant
from repro.kernels.flash_attention import flash_attention
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.int8_matmul import int8_matmul
from repro.kernels.sr_requant import sr_requant


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale) \
        .astype(dtype)


class TestInt8Matmul:
    @pytest.mark.parametrize("M,K,N", [(128, 512, 256), (256, 1024, 512),
                                       (128, 256, 768)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, M, K, N, dtype):
        x = _rand(0, (M, K), dtype)
        w = _rand(1, (K, N))
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = int8_matmul(x.astype(jnp.float32), qt.q, qt.scale,
                          block=qt.block, interpret=True)
        want = ref.int8_matmul_ref(x, qt.q, qt.scale, qt.block)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=2e-2, atol=2e-2)

    def test_ops_wrapper_matches_dense(self):
        x = _rand(2, (3, 7, 256))
        w = _rand(3, (256, 512))
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = ops.int8_matmul(x, qt, interpret=True)
        want = x.reshape(-1, 256) @ quant.dequantize(qt, jnp.float32)
        np.testing.assert_allclose(
            np.asarray(got).reshape(-1, 512), np.asarray(want),
            rtol=5e-2, atol=5e-2)


class TestInt4Matmul:
    @pytest.mark.parametrize("M,K,R", [(128, 512, 128), (256, 1024, 64)])
    def test_matches_ref(self, M, K, R):
        g = _rand(4, (M, K))
        P = _rand(5, (K, R), scale=0.1)
        qt = quant.quantize_blockwise(P, bits=4, block=min(128, R),
                                      symmetric=False)
        got = int4_matmul(g, qt.q, qt.scale, qt.zero, block=qt.block,
                          interpret=True)
        want = ref.int4_matmul_ref(g, qt.q, qt.scale, qt.zero, qt.block)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    def test_projection_close_to_fp(self):
        """INT4-projected gradient ≈ FP projection (paper Fig. 3 claim)."""
        g = _rand(6, (256, 512))
        P = jnp.linalg.qr(_rand(7, (512, 128)))[0]
        qt = quant.quantize_blockwise(P, bits=4, block=128, symmetric=False)
        got = ops.int4_project(g, qt, interpret=True)
        want = g @ P
        cos = float(jnp.sum(got * want) /
                    (jnp.linalg.norm(got) * jnp.linalg.norm(want)))
        assert cos > 0.99


class TestSRRequant:
    def test_matches_ref_given_same_randoms(self):
        R, C = 128, 512
        w = _rand(8, (R, C))
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        upd = _rand(9, (R, C), scale=0.01)
        u01 = jax.random.uniform(jax.random.PRNGKey(10), (R, C))
        qn, sn = sr_requant(qt.q, qt.scale, upd, u01, block=256,
                            interpret=True)
        qr, sr_ = ref.sr_requant_ref(qt.q, qt.scale, upd, u01, 256)
        np.testing.assert_array_equal(np.asarray(qn), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(sn), np.asarray(sr_),
                                   rtol=1e-6)

    def test_unbiased_expectation(self):
        """E[deq(SR(W + u))] == deq(W) + u across many keys."""
        R, C = 8, 256
        w = _rand(11, (R, C))
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        upd = jnp.full((R, C), 1e-4)
        outs = []
        for i in range(64):
            new = ops.sr_requant_update(qt, upd, jax.random.PRNGKey(i),
                                        interpret=True)
            outs.append(np.asarray(quant.dequantize(new, jnp.float32)))
        mean = np.mean(outs, axis=0)
        target = np.asarray(quant.dequantize(qt, jnp.float32)) + 1e-4
        scale_typ = float(np.asarray(qt.scale).mean())
        assert np.abs(mean - target).mean() < 0.3 * scale_typ


class TestBlockwiseQuant:
    @pytest.mark.parametrize("R,C", [(128, 512), (64, 256), (256, 1024)])
    def test_matches_ref(self, R, C):
        x = _rand(12, (R, C), scale=3.0)
        q, s = blockwise_quant(x, interpret=True)
        qr, sr_ = ref.blockwise_quant_ref(x, 256)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr_),
                                   rtol=1e-6)

    @given(scale=st.floats(0.01, 100.0))
    @settings(max_examples=8, deadline=None)
    def test_roundtrip_bounded(self, scale):
        x = _rand(13, (32, 256), scale=scale)
        q, s = blockwise_quant(x, interpret=True)
        back = np.asarray(q, np.float32).reshape(32, 1, 256) \
            * np.asarray(s)[..., None]
        err = np.abs(back.reshape(32, 256) - np.asarray(x))
        assert err.max() <= np.asarray(s).max() * 0.5 + 1e-6


class TestFlashAttention:
    @pytest.mark.parametrize("S", [128, 512])
    @pytest.mark.parametrize("causal", [True, False])
    def test_matches_ref(self, S, causal):
        B, H, d = 2, 3, 64
        q = _rand(14, (B, S, H, d))
        k = _rand(15, (B, S, H, d))
        v = _rand(16, (B, S, H, d))
        got = flash_attention(q, k, v, causal=causal, bq=128, bkv=128,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)

    def test_mla_style_dv_differs(self):
        B, S, H, d, dv = 1, 128, 2, 48, 32
        q = _rand(17, (B, S, H, d))
        k = _rand(18, (B, S, H, d))
        v = _rand(19, (B, S, H, dv))
        got = flash_attention(q, k, v, causal=True, bq=64, bkv=64,
                              interpret=True)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-3, atol=2e-3)


class TestFusedEpilogueServingShapes:
    """The scale-in-epilogue kernels at the shapes serving actually hits:
    non-tile-multiple M (1-row decode, ragged 9-row), prime K, and an N
    that is not a quant-block multiple (llama-60m d_ff=1376 → padded
    column tail + partially-real last scale group). Parity is against the
    plain dequantize-then-matmul on BOTH the ref oracle backend and the
    Pallas interpreter."""

    SHAPES = [(1, 512, 1376), (9, 67, 160), (256, 256, 1376)]

    @pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
    @pytest.mark.parametrize("M,K,N", SHAPES)
    def test_forward_matches_dequant(self, backend, M, K, N):
        x = _rand(20, (M, K))
        w = _rand(21, (K, N), scale=0.5)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = ops.quantized_dense(x, qt, dtype=jnp.float32,
                                  backend=backend)
        want = x @ quant.dequantize(qt, jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)

    @pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
    def test_dx_grad_plain_qtensor(self, backend):
        """dL/dx through the no-shadow custom VJP (plain-QTensor serving
        weights) streams the INT8 blocks through the transposed kernel
        and must match autodiff of the dequant einsum."""
        M, K, N = 9, 256, 1376
        x = _rand(22, (M, K))
        w = _rand(23, (K, N), scale=0.5)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)

        def f_q(a):
            out = ops.quantized_dense(a, qt, dtype=jnp.float32,
                                      backend=backend)
            return jnp.sum(out * out)

        wd = quant.dequantize(qt, jnp.float32)

        def f_d(a):
            out = a @ wd
            return jnp.sum(out * out)

        gq = jax.grad(f_q)(x)
        gd = jax.grad(f_d)(x)
        scale = max(float(jnp.abs(gd).max()), 1.0)
        np.testing.assert_allclose(np.asarray(gq) / scale,
                                   np.asarray(gd) / scale,
                                   rtol=2e-2, atol=2e-2)

    @pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
    def test_qtensor_matches_qvirtual_bitwise(self, backend):
        """Serving (plain QTensor, no-shadow core) and training
        (QVirtual, shadow core) must produce bit-identical forwards —
        both route through the same _i8_call."""
        M, K, N = 9, 128, 352
        x = _rand(24, (M, K))
        w = _rand(25, (K, N), scale=0.5)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        out_q = ops.quantized_dense(x, qt, dtype=jnp.float32,
                                    backend=backend)
        out_v = ops.quantized_dense(x, quant.virtualize(qt),
                                    dtype=jnp.float32, backend=backend)
        assert np.array_equal(np.asarray(out_q), np.asarray(out_v))

    @pytest.mark.parametrize("backend", ["ref", "pallas-interpret"])
    def test_transposed_head_matches_dequant(self, backend):
        """quantized_dense_t (tied-embedding head) at a ragged M and a
        vocab that is not a quant-block multiple."""
        M, V, D = 9, 160, 96
        x = _rand(26, (M, D))
        w = _rand(27, (V, D), scale=0.5)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = ops.quantized_dense_t(x, qt, dtype=jnp.float32,
                                    backend=backend)
        want = x @ quant.dequantize(qt, jnp.float32).T
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-2, atol=2e-2)
