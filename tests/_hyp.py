"""Optional-``hypothesis`` shim.

The property-based tests use hypothesis when it is installed (see
requirements-dev.txt); without it, the ``@given`` tests are skipped at
collection time instead of crashing the whole module import. Usage::

    from tests._hyp import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # pragma: no cover - exercised on bare containers
    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed")(fn)
        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _Strategies:
        """Stub: strategy constructors are only evaluated inside @given
        argument lists, which the skip decorator never runs."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _Strategies()
