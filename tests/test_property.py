"""Property-based tests (hypothesis) on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import projector, quant

_settings = settings(max_examples=12, deadline=None)


class TestQuantInvariants:
    @given(bits=st.sampled_from([4, 8]),
           rows=st.integers(1, 8),
           cols=st.sampled_from([64, 256, 300, 512]),
           scale=st.floats(1e-3, 1e3))
    @_settings
    def test_roundtrip_error_bounded_by_half_scale(self, bits, rows, cols,
                                                   scale):
        x = jax.random.normal(jax.random.PRNGKey(rows * cols),
                              (rows, cols)) * scale
        qt = quant.quantize_blockwise(x, bits=bits)
        y = quant.dequantize(qt, jnp.float32)
        max_scale = float(np.asarray(qt.scale).max())
        assert float(jnp.abs(y - x).max()) <= 0.5 * max_scale + 1e-6

    @given(rows=st.integers(1, 4), cols=st.sampled_from([256, 512]))
    @_settings
    def test_quantize_idempotent_on_grid(self, rows, cols):
        # values already on the quantization grid survive a round trip
        x = jax.random.normal(jax.random.PRNGKey(7), (rows, cols))
        qt = quant.quantize_blockwise(x, bits=8, symmetric=True)
        y = quant.dequantize(qt, jnp.float32)
        qt2 = quant.quantize_blockwise(y, bits=8, symmetric=True)
        y2 = quant.dequantize(qt2, jnp.float32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                                   atol=1e-5)

    @given(frac=st.floats(0.1, 0.9), n=st.sampled_from([50_000]))
    @_settings
    def test_sr_unbiased(self, frac, n):
        x = jnp.full((n,), frac)
        r = quant.stochastic_round(x, jax.random.PRNGKey(int(frac * 1e6)))
        assert abs(float(r.mean()) - frac) < 0.02


class TestProjectorInvariants:
    @given(m=st.sampled_from([32, 64, 128]), n=st.sampled_from([32, 96]),
           r=st.sampled_from([4, 8, 16]))
    @_settings
    def test_projection_linearity(self, m, n, r):
        """project(aG1 + bG2) == a·project(G1) + b·project(G2) — the property
        that makes project-before-allreduce gradient compression exact."""
        key = jax.random.PRNGKey(m * n + r)
        G1 = jax.random.normal(key, (m, n))
        G2 = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
        side = projector.galore_side((m, n))
        P = projector.compute_subspace(G1 + G2, r, side)
        a, b = 0.7, -1.3
        lhs = projector.project(a * G1 + b * G2, P, side)
        rhs = a * projector.project(G1, P, side) \
            + b * projector.project(G2, P, side)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-4)

    @given(m=st.sampled_from([64, 128]), r=st.sampled_from([8, 16]))
    @_settings
    def test_project_back_project_is_identity_on_subspace(self, m, r):
        key = jax.random.PRNGKey(m + r)
        G = jax.random.normal(key, (m, 2 * m))
        side = projector.galore_side(G.shape)
        P = projector.compute_subspace(G, r, side)
        low = projector.project(G, P, side)
        back = projector.project_back(low, P, side)
        low2 = projector.project(back, P, side)
        np.testing.assert_allclose(np.asarray(low), np.asarray(low2),
                                   rtol=1e-3, atol=1e-4)

    @given(d=st.sampled_from([32, 64]), r=st.sampled_from([4, 8]))
    @_settings
    def test_similarity_in_unit_interval(self, d, r):
        key = jax.random.PRNGKey(d * r)
        P1 = jnp.linalg.qr(jax.random.normal(key, (d, r)))[0]
        P2 = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                             (d, r)))[0]
        s = float(projector.subspace_similarity(P1, P2))
        assert -1e-5 <= s <= 1.0 + 1e-5


def _rand_orthogonal(key, r):
    return jnp.linalg.qr(jax.random.normal(key, (r, r)))[0]


def _lowrank_plus_noise(key, m, n, r_true, noise):
    """A matrix with a clean rank-r_true spectral gap + small noise."""
    k1, k2, k3 = jax.random.split(key, 3)
    U = jnp.linalg.qr(jax.random.normal(k1, (m, r_true)))[0]
    V = jnp.linalg.qr(jax.random.normal(k2, (n, r_true)))[0]
    s = jnp.linspace(10.0, 5.0, r_true)
    return U @ jnp.diag(s) @ V.T + noise * jax.random.normal(k3, (m, n))


def _check_rotation_sign_perm_invariance(d, r, seed):
    """subspace_similarity is a function of the SUBSPACE: invariant under
    any rotation of the basis, and in particular under the sign flips and
    permutations that make raw singular vectors non-unique."""
    key = jax.random.PRNGKey(seed)
    P = projector.random_orthonormal(key, d, r)
    Q = projector.random_orthonormal(jax.random.fold_in(key, 1), d, r)
    R = _rand_orthogonal(jax.random.fold_in(key, 2), r)
    perm = jax.random.permutation(jax.random.fold_in(key, 3), r)
    signs = jnp.where(
        jax.random.bernoulli(jax.random.fold_in(key, 4), shape=(r,)),
        1.0, -1.0)
    for P2 in (P @ R, P[:, perm] * signs):
        assert abs(float(projector.subspace_similarity(P, P2)) - 1.0) \
            < 1e-4
        np.testing.assert_allclose(
            float(projector.subspace_similarity(Q, P2)),
            float(projector.subspace_similarity(Q, P)), atol=1e-4)


def _check_randomized_matches_svd(m, n, r, seed):
    """On a low-rank-plus-noise matrix the randomized range finder and the
    exact SVD must agree on the dominant subspace (overlap >= 0.95)."""
    key = jax.random.PRNGKey(seed)
    G = _lowrank_plus_noise(key, m, n, r, noise=0.01)
    side = projector.galore_side((m, n))
    P_svd = projector.compute_subspace(G, r, side, "svd")
    P_rnd = projector.compute_subspace(G, r, side, "randomized",
                                       jax.random.fold_in(key, 9))
    overlap = float(projector.subspace_similarity(P_svd, P_rnd))
    assert overlap >= 0.95, overlap


def _check_shape_roundtrip(m, n, r, seed):
    """galore_side / proj_dim / lowrank_shape / project / project_back are
    one consistent shape system."""
    key = jax.random.PRNGKey(seed)
    G = jax.random.normal(key, (m, n))
    side = projector.galore_side((m, n))
    assert side == ("right" if m >= n else "left")
    d = projector.proj_dim((m, n))
    assert d == (n if m >= n else m)
    P = projector.compute_subspace(G, r, side)
    assert P.shape == (d, r)
    low = projector.project(G, P, side)
    assert low.shape == projector.lowrank_shape((m, n), r)
    assert projector.project_back(low, P, side).shape == (m, n)
    # quantized roundtrip keeps the virtual shape
    qP = projector.quantize_projection(P, bits=4, block=256)
    assert tuple(qP.shape) == (d, r)
    assert projector.maybe_dequantize(qP).shape == (d, r)


class TestProjectorSubspaceProperties:
    """Hypothesis sweeps over the projector's subspace invariants (the
    plain ``test_*_once`` variants keep the bodies exercised when
    hypothesis isn't installed)."""

    @given(d=st.sampled_from([32, 64, 96]), r=st.sampled_from([4, 8, 16]),
           seed=st.integers(0, 2**16))
    @_settings
    def test_rotation_sign_perm_invariance(self, d, r, seed):
        _check_rotation_sign_perm_invariance(d, r, seed)

    @given(m=st.sampled_from([48, 64, 128]), n=st.sampled_from([32, 96]),
           r=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
    @_settings
    def test_randomized_matches_svd(self, m, n, r, seed):
        _check_randomized_matches_svd(m, n, r, seed)

    @given(m=st.sampled_from([32, 64, 100]), n=st.sampled_from([32, 80]),
           r=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
    @_settings
    def test_shape_roundtrip(self, m, n, r, seed):
        _check_shape_roundtrip(m, n, r, seed)

    def test_invariance_once(self):
        _check_rotation_sign_perm_invariance(64, 8, 7)

    def test_randomized_once(self):
        _check_randomized_matches_svd(64, 96, 8, 3)

    def test_roundtrip_once(self):
        _check_shape_roundtrip(100, 32, 8, 1)


class TestDataInvariants:
    @given(step=st.integers(0, 10_000))
    @_settings
    def test_batches_deterministic_by_step(self, step):
        from repro.data.synthetic import DataConfig, SyntheticLM
        cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=2, seed=3)
        a = SyntheticLM(cfg).batch_at(step)
        b = SyntheticLM(cfg).batch_at(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        assert int(a["tokens"].max()) < 512


# ---------------------------------------------------------------------------
# Dynamic rank adaptation: explained-variance profile + state migration
# ---------------------------------------------------------------------------

def _check_explained_ratio_spectrum(m, n, r, seed):
    """On an exact-SVD projection the cumulative explained-variance profile
    IS the prefix sum of sigma_i^2 / sum_j sigma_j^2 — and is therefore
    monotone non-decreasing in the rank index, with values in [0, 1]."""
    key = jax.random.PRNGKey(seed)
    G = _lowrank_plus_noise(key, m, n, r, noise=0.05)
    side = projector.galore_side((m, n))
    P = projector.compute_subspace(G, r, side, "svd")
    prof = np.asarray(projector.explained_ratio(G, P, side))
    assert prof.shape == (r,)
    assert np.all(np.diff(prof) >= -1e-6)            # monotone in r
    assert prof[0] >= -1e-6 and prof[-1] <= 1.0 + 1e-5
    s = np.linalg.svd(np.asarray(G), compute_uv=False)
    want = np.cumsum(s[:r] ** 2) / np.sum(s ** 2)
    np.testing.assert_allclose(prof, want, atol=1e-4)
    # truncation consistency: the profile of P[:, :r'] is the profile's
    # prefix — what makes the controller's "ratio at index target-1" read
    # exactly the post-shrink explained variance
    r2 = max(1, r // 2)
    prof2 = np.asarray(projector.explained_ratio(G, P[:, :r2], side))
    np.testing.assert_allclose(prof2, prof[:r2], atol=1e-5)


def _check_explained_ratio_invariance(m, n, r, seed):
    """The FULL-rank entry of the profile depends only on the spanned
    subspace: invariant under any rotation, sign flip, or permutation of
    the P basis. Sign flips leave the whole profile unchanged (each
    column's energy is unchanged); permutations permute the per-column
    energies, preserving the full-rank sum."""
    key = jax.random.PRNGKey(seed)
    G = jax.random.normal(key, (m, n))
    side = projector.galore_side((m, n))
    P = projector.compute_subspace(G, r, side, "svd")
    prof = np.asarray(projector.explained_ratio(G, P, side))
    R = _rand_orthogonal(jax.random.fold_in(key, 2), r)
    perm = jax.random.permutation(jax.random.fold_in(key, 3), r)
    signs = jnp.where(
        jax.random.bernoulli(jax.random.fold_in(key, 4), shape=(r,)),
        1.0, -1.0)
    full_rot = np.asarray(projector.explained_ratio(G, P @ R, side))[-1]
    np.testing.assert_allclose(full_rot, prof[-1], atol=1e-4)
    prof_sign = np.asarray(projector.explained_ratio(G, P * signs, side))
    np.testing.assert_allclose(prof_sign, prof, atol=1e-5)
    full_perm = np.asarray(
        projector.explained_ratio(G, P[:, perm], side))[-1]
    np.testing.assert_allclose(full_perm, prof[-1], atol=1e-4)


def _check_rank_migration_exact(m, n, r, r2, seed):
    """State migration is EXACT: migrating rank-r 8-bit Adam state down to
    r' and stepping equals stepping a fresh rank-r' state packed from the
    same truncated fp32 moments — bit-for-bit, including the repacked
    quantization metadata. Likewise the migrated INT4 projection equals
    quantizing the truncated dequantized columns directly."""
    from repro.config import QGaLoreConfig
    from repro.core import adam8bit, qgalore

    key = jax.random.PRNGKey(seed)
    cfg = QGaLoreConfig(rank=r, min_dim=32)
    specs = qgalore.leaf_specs({"w": jnp.zeros((m, n))}, cfg)
    (spec,) = specs
    assert spec.galore and spec.rank == r
    hyper = adam8bit.AdamHyper.from_config(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    m32 = jax.random.normal(k1, spec.low_shape)
    v32 = jax.random.uniform(k2, spec.low_shape) * 1e-3
    inner = adam8bit.pack_moments(m32, v32, hyper)
    G = jax.random.normal(k3, (m, n))
    P = projector.compute_subspace(G, r, spec.side, "svd")
    qP = projector.quantize_projection(P, cfg.proj_bits, cfg.quant_block)

    inner_mig, P_mig = qgalore.migrate_rank_state(inner, qP, spec, r2)

    mm, vv = adam8bit.moments_fp32(inner)
    inner_ref = adam8bit.pack_moments(
        qgalore.truncate_lowrank(mm, spec.side, r2),
        qgalore.truncate_lowrank(vv, spec.side, r2), hyper)
    P_ref = projector.quantize_projection(
        projector.maybe_dequantize(qP, jnp.float32)[..., :r2],
        cfg.proj_bits, cfg.quant_block)

    g_low = jax.random.normal(
        k4, projector.lowrank_shape((m, n), r2))
    count = jnp.asarray(1, jnp.int32)
    dir_mig, next_mig = adam8bit.update(g_low, inner_mig, count, hyper)
    dir_ref, next_ref = adam8bit.update(g_low, inner_ref, count, hyper)

    for a, b in zip(
            jax.tree_util.tree_leaves((inner_mig, P_mig, dir_mig, next_mig)),
            jax.tree_util.tree_leaves((inner_ref, P_ref, dir_ref, next_ref))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestAdaptiveRankProperties:
    """Hypothesis sweeps over the dynamic-rank-adaptation invariants (the
    ``test_*_once`` variants keep the bodies exercised when hypothesis
    isn't installed)."""

    @given(m=st.sampled_from([32, 64, 96]), n=st.sampled_from([32, 64]),
           r=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
    @_settings
    def test_explained_ratio_spectrum(self, m, n, r, seed):
        _check_explained_ratio_spectrum(m, n, r, seed)

    @given(m=st.sampled_from([32, 64, 96]), n=st.sampled_from([32, 64]),
           r=st.sampled_from([4, 8]), seed=st.integers(0, 2**16))
    @_settings
    def test_explained_ratio_invariance(self, m, n, r, seed):
        _check_explained_ratio_invariance(m, n, r, seed)

    @given(m=st.sampled_from([32, 64]), n=st.sampled_from([32, 64]),
           r=st.sampled_from([8]), r2=st.sampled_from([2, 4]),
           seed=st.integers(0, 2**16))
    @_settings
    def test_rank_migration_exact(self, m, n, r, r2, seed):
        _check_rank_migration_exact(m, n, r, r2, seed)

    def test_spectrum_once(self):
        _check_explained_ratio_spectrum(64, 32, 8, 11)

    def test_invariance_once(self):
        _check_explained_ratio_invariance(64, 32, 8, 5)

    def test_migration_once(self):
        _check_rank_migration_exact(64, 32, 8, 4, 2)


# ---------------------------------------------------------------------------
# Tensor-parallel shard algebra: INT4 slicing commutes with quantization
# ---------------------------------------------------------------------------

def _check_projection_shard_bitexact(m, n, r, world, seed):
    """The invariant TP projection sharding rests on: because
    ``quantize_projection`` blocks along the r axis only, slicing P on its
    d axis COMMUTES BIT-EXACTLY with INT4 quantization — each rank's codes
    AND per-block scales are literal row-slices of the replicated
    quantization (slice-then-quantize == quantize-then-slice), and
    ``reassemble_projection`` is an exact inverse. Surviving-dim shards
    keep P whole by construction. Checked for both sides x both shard
    dims, so every row of the shard-dim table is covered."""
    key = jax.random.PRNGKey(seed)
    G = jax.random.normal(key, (m, n))
    for side in ("right", "left"):
        P = projector.compute_subspace(G, r, side, "svd")
        qP = projector.quantize_projection(P, bits=4, block=r)
        d = P.shape[-2]
        for shard_dim in (0, 1):
            shards = [projector.shard_projection(qP, side, shard_dim, k,
                                                 world)
                      for k in range(world)]
            if projector.proj_dim_sharded(side, shard_dim):
                size = d // world
                for k, s in enumerate(shards):
                    # slice the FLOAT P, quantize the slice: must equal
                    # the slice of the replicated quantization bit-for-bit
                    want = projector.quantize_projection(
                        P[k * size:(k + 1) * size], bits=4, block=r)
                    for a, b in zip(jax.tree_util.tree_leaves(s),
                                    jax.tree_util.tree_leaves(want)):
                        np.testing.assert_array_equal(np.asarray(a),
                                                      np.asarray(b))
            else:
                for s in shards:       # replicated: the full P, untouched
                    for a, b in zip(jax.tree_util.tree_leaves(s),
                                    jax.tree_util.tree_leaves(qP)):
                        np.testing.assert_array_equal(np.asarray(a),
                                                      np.asarray(b))
            back = projector.reassemble_projection(shards, side, shard_dim)
            assert (back.bits, back.block, tuple(back.shape)) == \
                (qP.bits, qP.block, tuple(qP.shape))
            for a, b in zip(jax.tree_util.tree_leaves(back),
                            jax.tree_util.tree_leaves(qP)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestTPShardProperties:
    """Hypothesis sweep over the TP projection-shard invariant (the
    ``_once`` variant keeps the body exercised without hypothesis)."""

    @given(m=st.sampled_from([32, 64, 96]), n=st.sampled_from([32, 64]),
           r=st.sampled_from([4, 8]), world=st.sampled_from([2, 4]),
           seed=st.integers(0, 2**16))
    @_settings
    def test_projection_shard_bitexact(self, m, n, r, world, seed):
        _check_projection_shard_bitexact(m, n, r, world, seed)

    def test_projection_shard_bitexact_once(self):
        _check_projection_shard_bitexact(64, 32, 8, 4, 13)
