"""Property-based tests (hypothesis) on the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import projector, quant

_settings = settings(max_examples=12, deadline=None)


class TestQuantInvariants:
    @given(bits=st.sampled_from([4, 8]),
           rows=st.integers(1, 8),
           cols=st.sampled_from([64, 256, 300, 512]),
           scale=st.floats(1e-3, 1e3))
    @_settings
    def test_roundtrip_error_bounded_by_half_scale(self, bits, rows, cols,
                                                   scale):
        x = jax.random.normal(jax.random.PRNGKey(rows * cols),
                              (rows, cols)) * scale
        qt = quant.quantize_blockwise(x, bits=bits)
        y = quant.dequantize(qt, jnp.float32)
        max_scale = float(np.asarray(qt.scale).max())
        assert float(jnp.abs(y - x).max()) <= 0.5 * max_scale + 1e-6

    @given(rows=st.integers(1, 4), cols=st.sampled_from([256, 512]))
    @_settings
    def test_quantize_idempotent_on_grid(self, rows, cols):
        # values already on the quantization grid survive a round trip
        x = jax.random.normal(jax.random.PRNGKey(7), (rows, cols))
        qt = quant.quantize_blockwise(x, bits=8, symmetric=True)
        y = quant.dequantize(qt, jnp.float32)
        qt2 = quant.quantize_blockwise(y, bits=8, symmetric=True)
        y2 = quant.dequantize(qt2, jnp.float32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y2),
                                   atol=1e-5)

    @given(frac=st.floats(0.1, 0.9), n=st.sampled_from([50_000]))
    @_settings
    def test_sr_unbiased(self, frac, n):
        x = jnp.full((n,), frac)
        r = quant.stochastic_round(x, jax.random.PRNGKey(int(frac * 1e6)))
        assert abs(float(r.mean()) - frac) < 0.02


class TestProjectorInvariants:
    @given(m=st.sampled_from([32, 64, 128]), n=st.sampled_from([32, 96]),
           r=st.sampled_from([4, 8, 16]))
    @_settings
    def test_projection_linearity(self, m, n, r):
        """project(aG1 + bG2) == a·project(G1) + b·project(G2) — the property
        that makes project-before-allreduce gradient compression exact."""
        key = jax.random.PRNGKey(m * n + r)
        G1 = jax.random.normal(key, (m, n))
        G2 = jax.random.normal(jax.random.fold_in(key, 1), (m, n))
        side = projector.galore_side((m, n))
        P = projector.compute_subspace(G1 + G2, r, side)
        a, b = 0.7, -1.3
        lhs = projector.project(a * G1 + b * G2, P, side)
        rhs = a * projector.project(G1, P, side) \
            + b * projector.project(G2, P, side)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-4)

    @given(m=st.sampled_from([64, 128]), r=st.sampled_from([8, 16]))
    @_settings
    def test_project_back_project_is_identity_on_subspace(self, m, r):
        key = jax.random.PRNGKey(m + r)
        G = jax.random.normal(key, (m, 2 * m))
        side = projector.galore_side(G.shape)
        P = projector.compute_subspace(G, r, side)
        low = projector.project(G, P, side)
        back = projector.project_back(low, P, side)
        low2 = projector.project(back, P, side)
        np.testing.assert_allclose(np.asarray(low), np.asarray(low2),
                                   rtol=1e-3, atol=1e-4)

    @given(d=st.sampled_from([32, 64]), r=st.sampled_from([4, 8]))
    @_settings
    def test_similarity_in_unit_interval(self, d, r):
        key = jax.random.PRNGKey(d * r)
        P1 = jnp.linalg.qr(jax.random.normal(key, (d, r)))[0]
        P2 = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                             (d, r)))[0]
        s = float(projector.subspace_similarity(P1, P2))
        assert -1e-5 <= s <= 1.0 + 1e-5


class TestDataInvariants:
    @given(step=st.integers(0, 10_000))
    @_settings
    def test_batches_deterministic_by_step(self, step):
        from repro.data.synthetic import DataConfig, SyntheticLM
        cfg = DataConfig(vocab_size=512, seq_len=16, global_batch=2, seed=3)
        a = SyntheticLM(cfg).batch_at(step)
        b = SyntheticLM(cfg).batch_at(step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))
        assert int(a["tokens"].max()) < 512
