"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and finiteness. The FULL configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeCell
from repro.models import base, model_zoo

ARCHS = [a for a in model_zoo.ARCH_IDS if not a.startswith("llama-")] + \
    ["llama-60m"]

SMOKE_CELL = ShapeCell("smoke", seq_len=32, global_batch=2, kind="train")


def make_batch(bundle, cell=SMOKE_CELL, seed=0):
    specs = bundle.input_specs(cell)
    key = jax.random.PRNGKey(seed)
    batch = {}
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(spec.dtype, jnp.integer):
            batch[name] = jax.random.randint(
                sub, spec.shape, 0, bundle.cfg.vocab_size, spec.dtype)
        else:
            batch[name] = jax.random.normal(sub, spec.shape, jnp.float32) \
                .astype(spec.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss(arch):
    bundle = model_zoo.build_arch(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = make_batch(bundle)
    loss, metrics = jax.jit(
        lambda p, b: base.loss_fn(bundle, p, b))(params, batch)
    loss = float(loss)
    assert np.isfinite(loss), f"{arch}: non-finite loss {loss}"
    # random init ⇒ loss ≈ log(vocab)
    expect = np.log(bundle.cfg.vocab_size)
    assert 0.2 * expect < loss < 3.0 * expect + 1.0, (arch, loss, expect)
    assert float(metrics["accuracy"]) <= 1.0


@pytest.mark.parametrize("arch", ARCHS)
def test_grad_step_finite(arch):
    """One SGD step decreases nothing catastrophically and grads are finite."""
    bundle = model_zoo.build_arch(arch, smoke=True)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = make_batch(bundle)

    def loss_of(p):
        return base.loss_fn(bundle, p, batch)[0]

    loss, grads = jax.jit(jax.value_and_grad(loss_of))(params)
    gleaves = jax.tree_util.tree_leaves(grads)
    assert gleaves
    for g in gleaves:
        assert np.isfinite(np.asarray(g)).all(), arch
    # non-trivial gradient signal somewhere
    gnorm = float(
        jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in gleaves)))
    assert gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive(arch):
    bundle = model_zoo.build_arch(arch, smoke=True)
    n = base.count_params(bundle)
    assert n > 1000


def test_full_config_param_counts():
    """Analytic parameter counts of full configs land in the right ballpark
    (catches config typos without allocating)."""
    expect = {
        "deepseek-v3-671b": (550e9, 750e9),
        "qwen3-moe-30b-a3b": (25e9, 36e9),
        "mistral-nemo-12b": (10e9, 14e9),
        "qwen3-32b": (28e9, 38e9),
        "gemma-7b": (7e9, 10e9),
        "yi-9b": (7.5e9, 10e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "internvl2-2b": (1.5e9, 2.6e9),
        "xlstm-125m": (0.1e9, 0.2e9),
        "seamless-m4t-medium": (0.8e9, 1.6e9),
        "llama-7b": (6e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        cfg = model_zoo.get_config(arch)
        n = model_zoo.count_params_analytic(cfg)
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"


# ---------------------------------------------------------------------------
# Flash-attention routing (REPRO_FLASH_ATTENTION=1)
# ---------------------------------------------------------------------------

def test_flash_attention_route_matches_chunked(monkeypatch):
    """attention.chunked_attention routed through the dispatch-registered
    flash kernel (GQA folded via head repetition) must match the default
    chunked path; ineligible calls (soft-cap, decode offset, non-causal)
    must stay on the chunked path bit-identically with the flag on."""
    from repro.models import attention

    key = jax.random.PRNGKey(0)
    B, S, H, KH, dh = 2, 64, 8, 2, 16
    kq, kk, kv = jax.random.split(key, 3)
    q = jax.random.normal(kq, (B, S, H, dh), jnp.float32)
    k = jax.random.normal(kk, (B, S, KH, dh), jnp.float32)
    v = jax.random.normal(kv, (B, S, KH, dh), jnp.float32)

    monkeypatch.delenv("REPRO_FLASH_ATTENTION", raising=False)
    want = attention.chunked_attention(q, k, v, causal=True)

    monkeypatch.setenv("REPRO_FLASH_ATTENTION", "1")
    assert attention._flash_eligible(q, k, True, 0, 0.0)
    got = attention.chunked_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    # MHA (no GQA fold) also routes
    kf = jnp.repeat(k, H // KH, axis=2)
    vf = jnp.repeat(v, H // KH, axis=2)
    got_mha = attention.chunked_attention(q, kf, vf, causal=True)
    np.testing.assert_allclose(np.asarray(got_mha), np.asarray(want),
                               rtol=2e-5, atol=2e-5)

    # ineligible shapes keep the chunked numerics EXACTLY (flag still on)
    for kwargs in ({"causal": False}, {"softcap": 30.0},
                   {"q_offset": 16}):
        assert not attention._flash_eligible(
            q, k, kwargs.get("causal", True), kwargs.get("q_offset", 0),
            kwargs.get("softcap", 0.0))
        on = attention.chunked_attention(q, k, v, **kwargs)
        monkeypatch.delenv("REPRO_FLASH_ATTENTION")
        off = attention.chunked_attention(q, k, v, **kwargs)
        monkeypatch.setenv("REPRO_FLASH_ATTENTION", "1")
        np.testing.assert_array_equal(np.asarray(on), np.asarray(off))
