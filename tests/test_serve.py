"""Serving correctness: prefill + N decode steps must reproduce the logits
of a full-sequence forward pass (teacher forcing) for every arch family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ShapeCell
from repro.models import base, model_zoo
from repro.serve import engine

from test_models_smoke import make_batch

ARCHS = ["llama-60m", "gemma-7b", "qwen3-moe-30b-a3b", "deepseek-v3-671b",
         "zamba2-2.7b", "xlstm-125m", "seamless-m4t-medium", "yi-9b"]


def _full_logits(bundle, params, batch):
    """Logits at every position from the train-style forward."""
    carry, ctx = bundle.embed(params, batch)
    carry = base.run_segments(bundle, params, carry, ctx)
    # reuse head_logits per position by slicing the last position of
    # incremental prefixes is expensive; instead grab the full logits path:
    return carry


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(arch):
    bundle = model_zoo.build_arch(arch, smoke=True, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    cell = ShapeCell("t", seq_len=16, global_batch=2, kind="train")
    batch = make_batch(bundle, cell)
    tokens = batch["tokens"]
    B, S = tokens.shape
    prompt, rest = 8, S - 8

    # reference: full forward logits at positions prompt-1 .. S-1
    def full_last_logits(upto):
        b = dict(batch)
        b["tokens"] = tokens[:, :upto]
        if "labels" in b:
            b["labels"] = b["labels"][:, :upto]
        carry, ctx = bundle.embed(params, b)
        carry = base.run_segments(bundle, params, carry, ctx)
        return bundle.head_logits(params, carry)[:, -1, :]

    # serve: prefill on the prompt, then teacher-forced decode
    b0 = dict(batch)
    b0["tokens"] = tokens[:, :prompt]
    if "labels" in b0:
        b0["labels"] = b0["labels"][:, :prompt]
    prefill = jax.jit(engine.build_prefill(bundle, max_len=S + 4))
    decode = jax.jit(engine.build_decode(bundle))
    logits, state = prefill(params, b0)

    ref = full_last_logits(prompt)
    got = logits[:, -1, :]
    err = np.abs(np.asarray(got) - np.asarray(ref)).max()
    scale = max(np.abs(np.asarray(ref)).max(), 1.0)
    assert err / scale < 2e-3, f"{arch} prefill mismatch {err/scale}"

    for t in range(prompt, S):
        logits, state = decode(params, state, tokens[:, t: t + 1])
        ref = full_last_logits(t + 1)
        got = logits[:, -1, :]
        err = np.abs(np.asarray(got) - np.asarray(ref)).max()
        scale = max(np.abs(np.asarray(ref)).max(), 1.0)
        assert err / scale < 5e-3, \
            f"{arch} decode step {t} mismatch {err/scale}"


def test_generate_runs():
    bundle = model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    cell = ShapeCell("t", seq_len=8, global_batch=2, kind="train")
    batch = make_batch(bundle, cell)
    toks, state = engine.generate(bundle, params, batch, steps=5,
                                  max_len=16)
    assert toks.shape == (2, 6)
    assert int(state.lengths[0]) == 8 + 5
    assert np.asarray(toks).min() >= 0
    assert np.asarray(toks).max() < bundle.cfg.vocab_size
