"""End-to-end trainer tests: learning, checkpoint/restore determinism,
fault recovery, straggler accounting, adaptive subspace behavior."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig, ShapeCell, TrainConfig, replace
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

CELL = ShapeCell("tiny", seq_len=32, global_batch=4, kind="train")


def make_trainer(tmp_path=None, optimizer="qgalore", steps=12, impl="fused",
                 fault_hook=None, ckpt_every=0, seed=0, lr=1e-2):
    bundle = model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)
    qcfg = preset(optimizer, QGaLoreConfig(
        rank=8, min_dim=32, update_interval=4, adaptive_k=1,
        cos_threshold=0.3))
    tcfg = TrainConfig(
        seed=seed, global_batch=4, seq_len=32, steps=steps,
        learning_rate=lr, warmup_steps=2, grad_clip=1.0,
        checkpoint_dir=str(tmp_path) if tmp_path else "",
        checkpoint_every=ckpt_every, log_every=0,
        async_checkpoint=False)
    return Trainer(bundle, tcfg, qcfg, cell=CELL, impl=impl,
                   param_dtype=jnp.float32, fault_hook=fault_hook)


class TestLearning:
    def test_loss_decreases_qgalore(self):
        tr = make_trainer(steps=55)
        hist = tr.run()
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.15, (first, last)

    def test_loss_decreases_full_baseline(self):
        tr = make_trainer(steps=30, optimizer="full", lr=3e-3)
        hist = tr.run()
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.1

    def test_qgalore_tracks_full_adam(self):
        """Paper Table 1 claim at micro scale: Q-GaLore stays in the same
        loss regime as Full Adam (GaLore's α=0.25 slows the very early
        trajectory; parity at convergence is shown in benchmarks)."""
        losses = {}
        for opt in ("full", "qgalore"):
            tr = make_trainer(steps=55, optimizer=opt)
            hist = tr.run()
            losses[opt] = np.mean([h["loss"] for h in hist[-5:]])
        assert losses["qgalore"] < losses["full"] + 0.8, losses

    def test_svd_calls_saved_by_adaptive(self):
        tr = make_trainer(steps=30)
        tr.run()
        used = tr.controller.total_svd_count()
        base = tr.controller.baseline_svd_count(30)
        assert 0 < used <= base


class TestCheckpointRestore:
    def test_resume_reproduces_trajectory(self, tmp_path):
        # full run
        tr_a = make_trainer(tmp_path=tmp_path / "a", steps=12, ckpt_every=5)
        hist_a = tr_a.run()
        # interrupted run: 0..7, then a fresh trainer resumes from ckpt
        tr_b = make_trainer(tmp_path=tmp_path / "b", steps=12, ckpt_every=5)
        tr_b.run(steps=8)
        tr_c = make_trainer(tmp_path=tmp_path / "b", steps=12, ckpt_every=5)
        resumed_at = tr_c.maybe_restore()
        assert resumed_at > 0
        hist_c = tr_c.run()
        last_a = [h["loss"] for h in hist_a][-3:]
        last_c = [h["loss"] for h in hist_c][-3:]
        np.testing.assert_allclose(last_a, last_c, rtol=2e-3, atol=2e-3)

    def test_resume_bit_identical(self, tmp_path):
        """Save mid-run, restore, continue: the tail must be BIT-identical
        to the uninterrupted run — same losses (exact float equality), same
        final params/optimizer state (exact array equality), same
        SubspaceController intervals and per-layer SVD counts, same SR RNG
        stream (keys are folded from (seed, step), so a restored step N
        draws the randoms step N always draws)."""
        tr_a = make_trainer(tmp_path=tmp_path / "a", steps=14, ckpt_every=5)
        hist_a = tr_a.run()

        tr_b = make_trainer(tmp_path=tmp_path / "b", steps=14, ckpt_every=5)
        tr_b.run(steps=8)                     # interrupted at step 8
        tr_c = make_trainer(tmp_path=tmp_path / "b", steps=14, ckpt_every=5)
        resumed_at = tr_c.maybe_restore()
        assert resumed_at == 8
        hist_c = tr_c.run()

        by_step = {h["step"]: h["loss"] for h in hist_a}
        for h in hist_c:
            assert h["loss"] == by_step[h["step"]], (
                f"step {h['step']}: resumed loss {h['loss']} != "
                f"uninterrupted {by_step[h['step']]}")
        for a, c in zip(jax.tree_util.tree_leaves(jax.device_get(tr_a.state)),
                        jax.tree_util.tree_leaves(jax.device_get(tr_c.state))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        assert tr_a.controller.interval_summary() == \
            tr_c.controller.interval_summary()
        # svd counts differ by bookkeeping before the restore point only in
        # run B's prefix; totals per unit must match the uninterrupted run
        assert tr_a.controller.svd_count_summary() == \
            tr_c.controller.svd_count_summary()

    def test_fault_recovery(self, tmp_path):
        boom = {"armed": True}

        def fault(step):
            if step == 9 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("simulated node failure")

        tr = make_trainer(tmp_path=tmp_path, steps=12, ckpt_every=4,
                          fault_hook=fault)
        hist = tr.run()
        steps_seen = [h["step"] for h in hist]
        assert 11 in steps_seen          # completed despite the failure
        assert not boom["armed"]

    def test_fault_budget_exhausted_raises(self, tmp_path):
        def always_fail(step):
            raise RuntimeError("permafail")

        tr = make_trainer(tmp_path=tmp_path, steps=4, ckpt_every=2,
                          fault_hook=always_fail)
        with pytest.raises(RuntimeError):
            tr.run(max_failures=2)


class TestStraggler:
    def test_straggler_detection(self):
        tr = make_trainer(steps=1)
        for i in range(20):
            tr.stragglers.observe(i, 0.1)
        assert tr.stragglers.observe(20, 1.0)     # 10x median
        assert tr.stragglers.events


class TestImplParity:
    def test_fused_and_simple_same_losses(self):
        h1 = make_trainer(steps=6, impl="fused", seed=3).run()
        h2 = make_trainer(steps=6, impl="simple", seed=3).run()
        l1 = [h["loss"] for h in h1]
        l2 = [h["loss"] for h in h2]
        np.testing.assert_allclose(l1, l2, rtol=5e-3, atol=5e-3)
