"""End-to-end trainer tests: learning, checkpoint/restore determinism,
fault recovery, straggler accounting, adaptive subspace behavior."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig, ShapeCell, TrainConfig, replace
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

CELL = ShapeCell("tiny", seq_len=32, global_batch=4, kind="train")


def make_trainer(tmp_path=None, optimizer="qgalore", steps=12, impl="fused",
                 fault_hook=None, ckpt_every=0, seed=0, lr=1e-2):
    bundle = model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)
    qcfg = preset(optimizer, QGaLoreConfig(
        rank=8, min_dim=32, update_interval=4, adaptive_k=1,
        cos_threshold=0.3))
    tcfg = TrainConfig(
        seed=seed, global_batch=4, seq_len=32, steps=steps,
        learning_rate=lr, warmup_steps=2, grad_clip=1.0,
        checkpoint_dir=str(tmp_path) if tmp_path else "",
        checkpoint_every=ckpt_every, log_every=0,
        async_checkpoint=False)
    return Trainer(bundle, tcfg, qcfg, cell=CELL, impl=impl,
                   param_dtype=jnp.float32, fault_hook=fault_hook)


class TestLearning:
    def test_loss_decreases_qgalore(self):
        tr = make_trainer(steps=55)
        hist = tr.run()
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.15, (first, last)

    def test_loss_decreases_full_baseline(self):
        tr = make_trainer(steps=30, optimizer="full", lr=3e-3)
        hist = tr.run()
        first = np.mean([h["loss"] for h in hist[:5]])
        last = np.mean([h["loss"] for h in hist[-5:]])
        assert last < first - 0.1

    def test_qgalore_tracks_full_adam(self):
        """Paper Table 1 claim at micro scale: Q-GaLore stays in the same
        loss regime as Full Adam (GaLore's α=0.25 slows the very early
        trajectory; parity at convergence is shown in benchmarks)."""
        losses = {}
        for opt in ("full", "qgalore"):
            tr = make_trainer(steps=55, optimizer=opt)
            hist = tr.run()
            losses[opt] = np.mean([h["loss"] for h in hist[-5:]])
        assert losses["qgalore"] < losses["full"] + 0.8, losses

    def test_svd_calls_saved_by_adaptive(self):
        tr = make_trainer(steps=30)
        tr.run()
        used = tr.controller.total_svd_count()
        base = tr.controller.baseline_svd_count(30)
        assert 0 < used <= base


class TestCheckpointRestore:
    def test_resume_reproduces_trajectory(self, tmp_path):
        # full run
        tr_a = make_trainer(tmp_path=tmp_path / "a", steps=12, ckpt_every=5)
        hist_a = tr_a.run()
        # interrupted run: 0..7, then a fresh trainer resumes from ckpt
        tr_b = make_trainer(tmp_path=tmp_path / "b", steps=12, ckpt_every=5)
        tr_b.run(steps=8)
        tr_c = make_trainer(tmp_path=tmp_path / "b", steps=12, ckpt_every=5)
        resumed_at = tr_c.maybe_restore()
        assert resumed_at > 0
        hist_c = tr_c.run()
        last_a = [h["loss"] for h in hist_a][-3:]
        last_c = [h["loss"] for h in hist_c][-3:]
        np.testing.assert_allclose(last_a, last_c, rtol=2e-3, atol=2e-3)

    def test_resume_bit_identical(self, tmp_path):
        """Save mid-run, restore, continue: the tail must be BIT-identical
        to the uninterrupted run — same losses (exact float equality), same
        final params/optimizer state (exact array equality), same
        SubspaceController intervals and per-layer SVD counts, same SR RNG
        stream (keys are folded from (seed, step), so a restored step N
        draws the randoms step N always draws)."""
        tr_a = make_trainer(tmp_path=tmp_path / "a", steps=14, ckpt_every=5)
        hist_a = tr_a.run()

        tr_b = make_trainer(tmp_path=tmp_path / "b", steps=14, ckpt_every=5)
        tr_b.run(steps=8)                     # interrupted at step 8
        tr_c = make_trainer(tmp_path=tmp_path / "b", steps=14, ckpt_every=5)
        resumed_at = tr_c.maybe_restore()
        assert resumed_at == 8
        hist_c = tr_c.run()

        by_step = {h["step"]: h["loss"] for h in hist_a}
        for h in hist_c:
            assert h["loss"] == by_step[h["step"]], (
                f"step {h['step']}: resumed loss {h['loss']} != "
                f"uninterrupted {by_step[h['step']]}")
        for a, c in zip(jax.tree_util.tree_leaves(jax.device_get(tr_a.state)),
                        jax.tree_util.tree_leaves(jax.device_get(tr_c.state))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        assert tr_a.controller.interval_summary() == \
            tr_c.controller.interval_summary()
        # svd counts differ by bookkeeping before the restore point only in
        # run B's prefix; totals per unit must match the uninterrupted run
        assert tr_a.controller.svd_count_summary() == \
            tr_c.controller.svd_count_summary()

    def test_fault_recovery(self, tmp_path):
        boom = {"armed": True}

        def fault(step):
            if step == 9 and boom["armed"]:
                boom["armed"] = False
                raise RuntimeError("simulated node failure")

        tr = make_trainer(tmp_path=tmp_path, steps=12, ckpt_every=4,
                          fault_hook=fault)
        hist = tr.run()
        steps_seen = [h["step"] for h in hist]
        assert 11 in steps_seen          # completed despite the failure
        assert not boom["armed"]

    def test_fault_budget_exhausted_raises(self, tmp_path):
        def always_fail(step):
            raise RuntimeError("permafail")

        tr = make_trainer(tmp_path=tmp_path, steps=4, ckpt_every=2,
                          fault_hook=always_fail)
        with pytest.raises(RuntimeError):
            tr.run(max_failures=2)


class TestStraggler:
    def test_straggler_detection(self):
        tr = make_trainer(steps=1)
        for i in range(20):
            tr.stragglers.observe(i, 0.1)
        assert tr.stragglers.observe(20, 1.0)     # 10x median
        assert tr.stragglers.events


class TestImplParity:
    def test_fused_and_simple_same_losses(self):
        h1 = make_trainer(steps=6, impl="fused", seed=3).run()
        h2 = make_trainer(steps=6, impl="simple", seed=3).run()
        l1 = [h["loss"] for h in h1]
        l2 = [h["loss"] for h in h2]
        np.testing.assert_allclose(l1, l2, rtol=5e-3, atol=5e-3)


def make_adarank_trainer(tmp_path=None, steps=12, ckpt_every=0,
                         adaptive_rank=True):
    """The adarank regression config: base trainer config +
    ``galore_embeddings`` + the adaptive-rank knobs, tuned so a rank-8 → 4
    shrink fires at step 8 (refresh observations at steps 0/4/8, patience
    3) — a 12-step run crosses exactly one transition."""
    bundle = model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)
    qcfg = preset("qgalore", QGaLoreConfig(
        rank=8, min_dim=32, update_interval=4, adaptive_k=1,
        cos_threshold=0.3, galore_embeddings=True,
        adaptive_rank=adaptive_rank, rank_ladder=(4,),
        explained_ratio_threshold=0.45, rank_patience=3, min_rank=4))
    tcfg = TrainConfig(
        seed=0, global_batch=4, seq_len=32, steps=steps,
        learning_rate=1e-2, warmup_steps=2, grad_clip=1.0,
        checkpoint_dir=str(tmp_path) if tmp_path else "",
        checkpoint_every=ckpt_every, log_every=0, async_checkpoint=False)
    return Trainer(bundle, tcfg, qcfg, cell=CELL, impl="fused",
                   param_dtype=jnp.float32)


class TestRankTransitionResume:
    def _assert_states_equal(self, tr_a, tr_b):
        for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(tr_a.state)),
                        jax.tree_util.tree_leaves(jax.device_get(tr_b.state))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_resume_bit_identical_across_rank_transition(self, tmp_path):
        """The rank-transition extension of ``test_resume_bit_identical``:
        (a) a checkpoint saved AFTER a shrink (holding truncated state +
        the rank-override meta) restores into a freshly-built trainer —
        which must adopt the overrides BEFORE touching arrays — and the
        tail is bit-identical; (b) a checkpoint saved BEFORE the shrink
        replays the transition deterministically on resume (migration is
        SR-free round-to-nearest, so replay equals the original)."""
        tr_a = make_adarank_trainer(tmp_path / "a", steps=12, ckpt_every=5)
        hist_a = tr_a.run()
        trans_a = tr_a.controller.rank_transition_summary()
        assert [t["step"] for t in trans_a].count(8) == len(trans_a) > 0, (
            "config drifted: expected all transitions at step 8", trans_a)
        by_step = {h["step"]: h["loss"] for h in hist_a}

        # (a) interrupt after the transition: latest ckpt is step 10
        tr_b = make_adarank_trainer(tmp_path / "b", steps=12, ckpt_every=5)
        tr_b.run(steps=11)
        tr_c = make_adarank_trainer(tmp_path / "b", steps=12, ckpt_every=5)
        meta = tr_c.mgr.read_meta()
        assert meta["rank_overrides"], (
            "post-transition checkpoint must persist the override map")
        assert tr_c.maybe_restore() == 11
        # overrides adopted before array restore: specs already shrunk
        shrunk = {s.path: s.rank for s in tr_c.specs if s.galore}
        assert any(r == 4 for r in shrunk.values()), shrunk
        hist_c = tr_c.run()
        for h in hist_c:
            assert h["loss"] == by_step[h["step"]], h
        self._assert_states_equal(tr_a, tr_c)
        assert tr_c.controller.rank_transition_summary() == trans_a

        # (b) interrupt before the transition (run() saves its last step,
        # 6): resume from step 7 with two streak observations restored,
        # replay the step-8 shrink, land bit-identical
        tr_d = make_adarank_trainer(tmp_path / "d", steps=12, ckpt_every=5)
        tr_d.run(steps=7)
        assert tr_d.controller.rank_transition_summary() == []
        tr_e = make_adarank_trainer(tmp_path / "d", steps=12, ckpt_every=5)
        assert tr_e.maybe_restore() == 7
        assert not tr_e._rank_overrides        # pre-transition ckpt
        hist_e = tr_e.run()
        for h in hist_e:
            assert h["loss"] == by_step[h["step"]], h
        self._assert_states_equal(tr_a, tr_e)
        assert tr_e.controller.rank_transition_summary() == trans_a

    def test_restore_with_adaptive_off_fails_loudly(self, tmp_path):
        """A shrunk checkpoint restored by a run that cannot adapt
        (adaptive_rank off everywhere) must fail META-FIRST with an error
        naming the overridden leaves — not a shape error mid-array-restore."""
        tr = make_adarank_trainer(tmp_path, steps=10, ckpt_every=9)
        tr.run()
        assert tr.controller.current_ranks()
        tr2 = make_adarank_trainer(tmp_path, steps=10, ckpt_every=9,
                                   adaptive_rank=False)
        with pytest.raises(ValueError, match="rank_overrides"):
            tr2.maybe_restore()
