"""The fused projected-backward must match the jax.grad oracle exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import projector, quant
from repro.models import base, model_zoo
from repro.train import stack

from test_models_smoke import make_batch

ARCHS = ["llama-60m", "qwen3-moe-30b-a3b", "zamba2-2.7b", "xlstm-125m",
         "seamless-m4t-medium", "deepseek-v3-671b", "internvl2-2b"]


def _rel_err(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    denom = max(np.abs(b).max(), 1e-6)
    return np.abs(a - b).max() / denom


@pytest.mark.parametrize("arch", ARCHS)
def test_fused_matches_simple_fullrank(arch):
    """No projection: fused manual backward == jax.grad."""
    bundle = model_zoo.build_arch(arch, smoke=True, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = make_batch(bundle)

    (l1, _), g1 = jax.jit(
        lambda p, b: stack.simple_value_and_grad(bundle, p, b))(params, batch)
    (l2, _), g2 = jax.jit(
        lambda p, b: stack.fused_value_and_grad(bundle, p, b, {}))(params,
                                                                  batch)
    assert abs(float(l1) - float(l2)) < 1e-4 * max(abs(float(l1)), 1.0)
    flat1 = jax.tree_util.tree_flatten_with_path(g1)[0]
    flat2 = {jax.tree_util.keystr(p): l
             for p, l in jax.tree_util.tree_flatten_with_path(g2)[0]}
    checked = 0
    for path, leaf in flat1:
        key = jax.tree_util.keystr(path)
        other = flat2[key]
        err = _rel_err(other, leaf)
        assert err < 5e-3, f"{arch} {key}: rel err {err}"
        checked += 1
    assert checked > 3


def test_fused_projected_grads_match_projection_of_full():
    """With P given, fused emits exactly project(full_grad)."""
    bundle = model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    batch = make_batch(bundle)
    seg_key = bundle.seg_key(0)

    # build a projection tree for the segment: P per 2-D (L,m,n) leaf
    rank = 8
    def make_P(leaf):
        if leaf.ndim == 3 and min(leaf.shape[-2:]) >= 16:
            d = projector.proj_dim(leaf.shape[-2:])
            L = leaf.shape[0]
            key = jax.random.PRNGKey(hash(leaf.shape) % 2**31)
            P = jnp.linalg.qr(jax.random.normal(key, (L, d, rank)))[0]
            return P
        return None
    P_tree = jax.tree_util.tree_map(make_P, params[seg_key])

    (_, _), g_full = jax.jit(
        lambda p, b: stack.fused_value_and_grad(bundle, p, b, {}))(params,
                                                                   batch)
    (_, _), g_proj = jax.jit(
        lambda p, b: stack.fused_value_and_grad(
            bundle, p, b, {seg_key: P_tree}))(params, batch)

    flatP = jax.tree_util.tree_flatten_with_path(
        P_tree, is_leaf=lambda x: x is None)[0]
    flat_full = {jax.tree_util.keystr(p): l for p, l in
                 jax.tree_util.tree_flatten_with_path(g_full[seg_key])[0]}
    flat_proj = {jax.tree_util.keystr(p): l for p, l in
                 jax.tree_util.tree_flatten_with_path(g_proj[seg_key])[0]}
    n_proj = 0
    for path, P in flatP:
        key = jax.tree_util.keystr(path)
        if P is None:
            continue
        side = projector.galore_side(flat_full[key].shape)
        expect = projector.project(flat_full[key].astype(jnp.float32),
                                   P, side)
        err = _rel_err(flat_proj[key], expect)
        assert err < 5e-3, f"{key}: {err}"
        assert flat_proj[key].shape != flat_full[key].shape
        n_proj += 1
    assert n_proj >= 4


def test_fused_with_quantized_params_runs():
    """INT8 QTensor params flow through the fused path; grads are virtual-
    shaped and finite."""
    bundle = model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    qparams = quant.tree_quantize(
        params, bits=8, symmetric=True,
        predicate=lambda p, l: l.ndim >= 2 and l.shape[-1] >= 64)
    batch = make_batch(bundle)
    (loss, _), grads = jax.jit(
        lambda p, b: stack.fused_value_and_grad(bundle, p, b, {}))(qparams,
                                                                   batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all()
