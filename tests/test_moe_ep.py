"""Expert-parallel MoE (manual all_to_all inside shard_map) must match the
plain GSPMD-auto MoE exactly (drop-free regime) — run on a real 8-device
mesh in a subprocess."""
import os

from test_distributed import run_py


def test_moe_ep_matches_plain():
    out = run_py("""
        import functools
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.config import MoEConfig, ModelConfig
        from repro.models import moe as moe_lib

        cfg = ModelConfig(
            name="tiny-moe", family="moe", d_model=32, num_heads=4,
            num_kv_heads=4, vocab_size=128,
            moe=MoEConfig(num_experts=8, top_k=2, expert_ff=16,
                          num_shared_experts=1))
        params = moe_lib.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 4, 32)) * 0.5

        ref, aux_ref = moe_lib.moe_apply(params, x, cfg, dtype=jnp.float32)

        mesh = jax.make_mesh((4, 2), ("data", "model"))

        # expert weights sharded on E over data; router/shared replicated —
        # moe_ep_sharded builds the shard_map through the repro.compat shim
        # (old jax.experimental.shard_map vs new jax.shard_map).
        got, aux_got = jax.jit(functools.partial(
            moe_lib.moe_ep_sharded, cfg=cfg, mesh=mesh, ep_axis="data",
            dtype=jnp.float32))(params, x)
        err = np.abs(np.asarray(got) - np.asarray(ref)).max()
        scale = max(np.abs(np.asarray(ref)).max(), 1e-3)
        assert err / scale < 2e-3, err / scale
        # aux: per-shard density estimates differ from global (local top-1
        # histograms) — just require same order of magnitude
        assert np.isfinite(float(aux_got))
        print("OK moe_ep", err / scale)
    """)
    assert "OK moe_ep" in out
