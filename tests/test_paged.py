"""Paged serving runtime (serve/paged.py + serve/radix.py): allocator
property tests, chunked-prefill bit-identity, radix prefix-cache hit
exactness, slot-vs-paged token parity (incl. under eviction, preemption,
and queueing backpressure), and the fixed-memory capacity win."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import model_zoo
from repro.serve import engine
from repro.serve.paged import BlockAllocator, PagedScheduler
from repro.serve.radix import RadixCache
from repro.serve.scheduler import Request, Scheduler, make_scheduler

PAD = 0


@pytest.fixture(scope="module")
def bundle60():
    return model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)


@pytest.fixture(scope="module")
def params60(bundle60):
    return bundle60.init_params(jax.random.PRNGKey(0))


def _reqs(rng, V, n, *, lo=3, hi=20, new_lo=2, new_hi=8, shared=None,
          share_every=2):
    out = []
    for i in range(n):
        p = rng.integers(1, V, size=int(rng.integers(lo, hi))) \
            .astype(np.int32)
        if shared is not None and i % share_every == 0:
            p = np.concatenate([np.asarray(shared, np.int32), p])
        out.append(Request(rid=i, tokens=p.tolist(),
                           max_new_tokens=int(rng.integers(new_lo, new_hi))))
    return out


def _clone(reqs):
    return [Request(r.rid, list(r.tokens), r.max_new_tokens, r.eos_id)
            for r in reqs]


# ---------------------------------------------------------------------------
# Block allocator: property tests
# ---------------------------------------------------------------------------

def test_allocator_random_ops_never_leak_or_double_free():
    """Fuzz alloc/ref/deref against a reference model: after any legal
    sequence, refcounts and the free list partition the pool exactly
    (no leaks, no duplicates), and illegal ops raise."""
    rng = np.random.default_rng(0)
    for trial in range(20):
        a = BlockAllocator(int(rng.integers(2, 40)))
        live = {}                       # phys -> model refcount
        for _ in range(300):
            op = rng.integers(0, 3)
            if op == 0:
                p = a.alloc()
                if p is None:
                    assert not a.free_blocks
                else:
                    assert p not in live and p != 0
                    live[p] = 1
            elif op == 1 and live:
                p = int(rng.choice(list(live)))
                a.ref(p)
                live[p] += 1
            elif op == 2 and live:
                p = int(rng.choice(list(live)))
                a.deref(p)
                live[p] -= 1
                if live[p] == 0:
                    del live[p]
            a.check()
            assert {p: int(a.refcount[p]) for p in live} == live
            assert a.free_blocks == a.usable_blocks - len(live)
        # illegal ops are loud
        with pytest.raises(ValueError):
            a.deref(0)
        p = a.alloc()
        if p is not None:
            a.deref(p)
            with pytest.raises(ValueError):
                a.deref(p)


def test_allocator_accounts_after_random_admit_retire(bundle60, params60):
    """Scheduler-level property: after ANY random admit/retire traffic the
    allocator invariant holds and every non-radix block is back on the
    free list."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(1)
    shared = rng.integers(1, V, size=16)
    sched = PagedScheduler(bundle60, params60, num_slots=4, max_len=48,
                           block_size=8, num_blocks=18, prefill_chunk=8,
                           dtype=jnp.float32)
    for round_ in range(3):
        sched.run(_reqs(rng, V, 7, shared=shared))
        sched.alloc.check()
        held = sum(1 for b in sched.radix.cached_blocks())
        assert sched.alloc.free_blocks == sched.alloc.usable_blocks - held
    # radix blocks are exactly the ones still referenced
    for b in sched.radix.cached_blocks():
        assert int(sched.alloc.refcount[b]) == 1


# ---------------------------------------------------------------------------
# Chunked prefill: bit-identity
# ---------------------------------------------------------------------------

def test_chunked_append_bit_identical_every_chunk_size(bundle60, params60):
    """Appending a length-L prompt in chunks of c must reproduce one-shot
    prefill BIT-identically (logits and cache) for every c — the
    correctness substrate of paged serving."""
    V = bundle60.cfg.vocab_size
    MAX_LEN = 32
    rng = np.random.default_rng(2)
    P = 13
    prompt = rng.integers(1, V, size=P).astype(np.int32)

    prefill = jax.jit(engine.build_prefill(bundle60, MAX_LEN))
    logits_ref, state_ref = prefill(
        params60, {"tokens": jnp.asarray(prompt)[None]})
    append = jax.jit(engine.build_append(bundle60, MAX_LEN))

    def empty():
        ds = engine.abstract_decode_state(bundle60, 1, MAX_LEN, jnp.float32)
        caches = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), ds.caches)
        return engine.DecodeState(caches, jnp.zeros((1,), jnp.int32), {})

    for c in range(1, P + 2):
        st = empty()
        pos = 0
        while pos < P:
            n = min(c, P - pos)
            chunk = np.full((1, c), PAD, np.int32)
            chunk[0, :n] = prompt[pos:pos + n]
            logits, st = append(params60, st, jnp.asarray(chunk),
                                jnp.asarray(n, jnp.int32)[None])
            pos += n
        assert float(jnp.abs(logits - logits_ref).max()) == 0.0, c
        for a, b in zip(jax.tree_util.tree_leaves(st.caches),
                        jax.tree_util.tree_leaves(state_ref.caches)):
            # one-shot prefill only wrote the first P positions; append
            # also only wrote those (masked scatter) — full-leaf compare
            assert float(jnp.abs(a[:, :, :P] - b[:, :, :P]).max()) == 0.0, c
        assert int(st.lengths[0]) == P


def test_append_rejected_for_non_append_bundles():
    """Families that cannot promise chunked==one-shot (recurrent state)
    must refuse build_append loudly, and the paged scheduler must refuse
    them too."""
    bundle = model_zoo.build_arch("xlstm-125m", smoke=True,
                                  dtype=jnp.float32)
    assert not engine.append_ok(bundle)
    with pytest.raises(ValueError, match="chunk-append"):
        engine.build_append(bundle, 32)
    with pytest.raises(ValueError, match="paged serving"):
        PagedScheduler(bundle, None, num_slots=2, max_len=32)
    # make_scheduler auto-falls back to the slot backend
    params = bundle.init_params(jax.random.PRNGKey(0))
    sched = make_scheduler(bundle, params, backend="auto", num_slots=2,
                           max_len=32, dtype=jnp.float32)
    assert type(sched) is Scheduler


# ---------------------------------------------------------------------------
# Token parity: paged vs slot under greedy decode
# ---------------------------------------------------------------------------

def test_paged_token_identical_to_slot(bundle60, params60):
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(3)
    shared = rng.integers(1, V, size=24)
    reqs = _reqs(rng, V, 12, shared=shared)

    slot = Scheduler(bundle60, params60, num_slots=4, max_len=64,
                     dtype=jnp.float32)
    ref = {c.rid: c.tokens for c in slot.run(_clone(reqs))}

    paged = PagedScheduler(bundle60, params60, num_slots=4, max_len=64,
                           block_size=8, prefill_chunk=8,
                           dtype=jnp.float32)
    out = {c.rid: c.tokens for c in paged.run(_clone(reqs))}
    assert out == ref
    assert paged.stats["radix_hit_blocks"] > 0    # sharing actually hit
    assert all(c.t_first >= c.t_admit > 0 for c in paged.completed)


def test_paged_parity_under_eviction_and_preemption(bundle60, params60):
    """A pool too small for the offered concurrency must still produce
    slot-identical tokens — radix eviction and youngest-victim preemption
    only move WHERE blocks live, never what they contain."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(4)
    reqs = [Request(rid=i,
                    tokens=rng.integers(1, V, size=8).astype(np.int32)
                    .tolist(),
                    max_new_tokens=12) for i in range(4)]

    slot = Scheduler(bundle60, params60, num_slots=4, max_len=20,
                     dtype=jnp.float32)
    ref = {c.rid: c.tokens for c in slot.run(_clone(reqs))}

    # 3 concurrent want 15 blocks; pool has 11 usable → preemption
    # (optimistic admission — the default full-window reservation would
    # queue the third request instead of ever preempting)
    paged = PagedScheduler(bundle60, params60, num_slots=3, max_len=20,
                           block_size=4, num_blocks=12, prefill_chunk=4,
                           dtype=jnp.float32, reserve_decode=False)
    out = {c.rid: c.tokens for c in paged.run(_clone(reqs))}
    assert out == ref
    assert paged.stats["preemptions"] > 0
    paged.alloc.check()
    assert paged.alloc.free_blocks == paged.alloc.usable_blocks - \
        len(paged.radix.cached_blocks())


# ---------------------------------------------------------------------------
# Radix prefix cache
# ---------------------------------------------------------------------------

def test_prefix_cache_hit_blocks_bit_identical_to_cold_prefill(
        bundle60, params60):
    """A radix-hit request must read KV blocks BIT-identical to what a
    cold prefill of its full prompt would produce — shared blocks are
    never mutated (the share-only degenerate of copy-on-write)."""
    V = bundle60.cfg.vocab_size
    blk = 8
    rng = np.random.default_rng(5)
    shared = rng.integers(1, V, size=2 * blk).astype(np.int32)
    suffix = rng.integers(1, V, size=5).astype(np.int32)
    prompt_b = np.concatenate([shared, suffix])

    paged = PagedScheduler(bundle60, params60, num_slots=2, max_len=48,
                           block_size=blk, prefill_chunk=8,
                           dtype=jnp.float32)
    # request A seeds the radix cache with the shared blocks
    paged.run([Request(rid=0, tokens=shared.tolist(), max_new_tokens=2)])
    hits0 = paged.stats["radix_hit_blocks"]
    # request B shares the prefix — admission must map A's blocks
    paged.run([Request(rid=1, tokens=prompt_b.tolist(), max_new_tokens=2)])
    assert paged.stats["radix_hit_blocks"] - hits0 == 2

    # the cached blocks must hold KV BIT-identical to a cold one-shot
    # prefill of the cached prefix itself. (A longer prompt's prefill of
    # the same positions can differ by ~1 ulp — XLA tiles matmuls
    # shape-dependently, the same reason width-1 append chunks are padded
    # in engine.build_append — which greedy token parity absorbs; see
    # test_paged_token_identical_to_slot, where radix hits are live.)
    matched = paged.radix.match(prompt_b)
    table = np.zeros((paged.MB,), np.int32)
    table[:len(matched)] = matched
    prefill = jax.jit(engine.build_prefill(bundle60, paged.MB * blk))
    _, cold = prefill(params60, {"tokens": jnp.asarray(shared)[None]})
    for key in cold.caches:
        for shared_leaf, cold_leaf in zip(
                jax.tree_util.tree_leaves(paged.caches[key]),
                jax.tree_util.tree_leaves(cold.caches[key])):
            got = jnp.take(shared_leaf, jnp.asarray(table), axis=1) \
                .reshape(shared_leaf.shape[0], 1, paged.MB * blk,
                         *shared_leaf.shape[3:])
            n = len(matched) * blk      # the shared (cached) positions
            err = jnp.abs(got[:, :, :n] - cold_leaf[:, :, :n]).max()
            assert float(err) == 0.0


def test_radix_lru_evicts_leaves_first():
    r = RadixCache(block_size=2)
    adopted = r.insert([1, 2, 3, 4], [10, 11])    # chain 10 -> 11
    assert adopted == [10, 11]
    r.insert([1, 2, 9, 9], [10, 12])              # branch at depth 1
    assert len(r) == 3
    # internal node 10 is pinned while children live
    assert r.evict(lambda p: p == 10) is None
    # LRU leaf goes first (11 older than 12)
    assert r.evict(lambda p: True) == 11
    assert r.evict(lambda p: True) == 12
    assert r.evict(lambda p: True) == 10          # now a leaf
    assert r.evict(lambda p: True) is None
    assert len(r) == 0


# ---------------------------------------------------------------------------
# Admission backpressure (the submit bugfix)
# ---------------------------------------------------------------------------

def test_submit_queues_when_pool_momentarily_full(bundle60, params60):
    """A request that fits the pool but not RIGHT NOW must queue and
    complete once blocks free up — only can-never-fit requests raise."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(6)
    paged = PagedScheduler(bundle60, params60, num_slots=3, max_len=32,
                           block_size=8, num_blocks=9, prefill_chunk=8,
                           dtype=jnp.float32, use_radix=False)
    # two requests that together hold the whole 8-block pool for a while
    # (4 blocks each once decode crosses position 24) — a slot stays free
    # but no block does, so the latecomer must defer on BLOCKS
    big = [Request(rid=i,
                   tokens=rng.integers(1, V, size=20).astype(np.int32)
                   .tolist(),
                   max_new_tokens=10) for i in range(2)]
    late = Request(rid=9, tokens=rng.integers(1, V, size=10)
                   .astype(np.int32).tolist(), max_new_tokens=4)
    for r in big:
        paged.submit(r)
    # drive until both hold their 4th block (pool saturated), then submit
    for _ in range(40):
        paged.step()
        if paged.alloc.free_blocks == 0:
            break
    assert paged.alloc.free_blocks == 0
    paged.submit(late)          # must NOT raise
    while paged.step():
        pass
    done = {c.rid for c in paged.completed}
    assert done == {0, 1, 9}
    assert paged.stats["admission_blocked"] > 0

    # can never fit: per-request window
    with pytest.raises(ValueError, match="window"):
        paged.submit(Request(rid=10, tokens=[1] * 30, max_new_tokens=10))
    # can never fit: whole pool
    small = PagedScheduler(bundle60, params60, num_slots=1, max_len=32,
                           block_size=8, num_blocks=3, prefill_chunk=8,
                           dtype=jnp.float32)
    with pytest.raises(ValueError, match="never fit"):
        small.submit(Request(rid=11, tokens=[1] * 20, max_new_tokens=10))


# ---------------------------------------------------------------------------
# Capacity at fixed memory
# ---------------------------------------------------------------------------

def test_paged_admits_2x_concurrency_at_fixed_memory(bundle60, params60):
    """Mixed-length traffic: the slot pool burns max_len KV per request;
    the paged pool spends blocks on ACTUAL lengths, so at the same pool
    bytes it runs >= 2x the concurrent requests."""
    V = bundle60.cfg.vocab_size
    MAX_LEN, BLK = 64, 8
    rng = np.random.default_rng(7)
    # short requests: ~2 blocks each vs the slot pool's 8-block reserve
    reqs = _reqs(rng, V, 8, lo=4, hi=10, new_lo=4, new_hi=7)

    slot = Scheduler(bundle60, params60, num_slots=4, max_len=MAX_LEN,
                     dtype=jnp.float32)
    ref = {c.rid: c.tokens for c in slot.run(_clone(reqs))}

    # same block memory as the 4-slot pool (+1 scratch), 8 slots
    paged = PagedScheduler(bundle60, params60, num_slots=8, max_len=MAX_LEN,
                           block_size=BLK,
                           num_blocks=4 * (MAX_LEN // BLK) + 1,
                           prefill_chunk=16, dtype=jnp.float32)
    slot_bytes = sum(
        leaf.size * leaf.dtype.itemsize
        for leaf in jax.tree_util.tree_leaves(slot.pool.caches))
    assert paged.pool_bytes() <= slot_bytes * (1 + 1 / (4 * MAX_LEN // BLK))
    out = {c.rid: c.tokens for c in paged.run(_clone(reqs))}
    assert out == ref
    assert paged.stats["max_concurrent"] >= 2 * slot.num_slots
