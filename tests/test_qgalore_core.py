"""Tests for projector, adam8bit, qgalore optimizer, adaptive controller."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig, replace
from repro.core import adam8bit, adaptive, projector, qgalore, quant
from repro.core.adam8bit import AdamHyper


class TestProjector:
    def test_side_convention(self):
        assert projector.galore_side((512, 128)) == "right"
        assert projector.galore_side((128, 512)) == "left"
        assert projector.proj_dim((512, 128)) == 128
        assert projector.proj_dim((128, 512)) == 128

    @pytest.mark.parametrize("shape,side", [((64, 32), "right"),
                                            ((32, 64), "left")])
    def test_svd_recovers_lowrank(self, shape, side):
        # G exactly rank-4 -> projection with r=4 reconstructs G exactly
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (shape[0], 4))
        b = jax.random.normal(jax.random.fold_in(key, 1), (4, shape[1]))
        G = a @ b
        P = projector.compute_subspace(G, 4, side, method="svd")
        low = projector.project(G, P, side)
        back = projector.project_back(low, P, side)
        np.testing.assert_allclose(np.asarray(back), np.asarray(G),
                                   rtol=1e-3, atol=1e-3)

    def test_randomized_close_to_svd(self):
        key = jax.random.PRNGKey(2)
        G = jax.random.normal(key, (128, 96))
        # make a clear spectral gap
        U, s, Vh = jnp.linalg.svd(G, full_matrices=False)
        s = s.at[8:].multiply(0.01)
        G = U @ jnp.diag(s) @ Vh
        P1 = projector.compute_subspace(G, 8, method="svd")
        P2 = projector.compute_subspace(G, 8, method="randomized",
                                        key=jax.random.PRNGKey(3), iters=3)
        sim = float(projector.subspace_similarity(P1, P2))
        assert sim > 0.98

    def test_similarity_bounds(self):
        key = jax.random.PRNGKey(4)
        P = jnp.linalg.qr(jax.random.normal(key, (64, 8)))[0]
        assert abs(float(projector.subspace_similarity(P, P)) - 1.0) < 1e-5
        # orthogonal complement has ~zero overlap
        Q = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1),
                                            (64, 8)))[0]
        s = float(projector.subspace_similarity(P, Q))
        assert 0.0 <= s < 0.6

    def test_sign_invariance(self):
        key = jax.random.PRNGKey(5)
        P = jnp.linalg.qr(jax.random.normal(key, (64, 8)))[0]
        assert abs(float(projector.subspace_similarity(P, -P)) - 1.0) < 1e-5


class TestAdam8bit:
    def test_matches_fp32_adam_roughly(self):
        # quantized-state Adam should track fp32 Adam directionally
        key = jax.random.PRNGKey(0)
        g = jax.random.normal(key, (4, 512))
        h8 = AdamHyper(bits=8)
        h32 = AdamHyper(bits=32)
        s8 = adam8bit.init_state(g.shape, h8)
        s32 = adam8bit.init_state(g.shape, h32)
        for step in range(1, 6):
            d8, s8 = adam8bit.update(g, s8, jnp.int32(step), h8)
            d32, s32 = adam8bit.update(g, s32, jnp.int32(step), h32)
        cos = float(jnp.sum(d8 * d32) /
                    (jnp.linalg.norm(d8) * jnp.linalg.norm(d32)))
        assert cos > 0.99

    def test_first_step_is_sign_of_grad(self):
        g = jnp.array([[1.0, -2.0, 0.5] + [0.0] * 253])
        h = AdamHyper(bits=32)
        s = adam8bit.init_state(g.shape, h)
        d, _ = adam8bit.update(g, s, jnp.int32(1), h)
        # m_hat/sqrt(v_hat) == sign(g) for the first step (eps tiny)
        np.testing.assert_allclose(np.asarray(d[0, :3]),
                                   np.sign(np.asarray(g[0, :3])), atol=1e-3)


def _toy_params(quantized=True):
    key = jax.random.PRNGKey(0)
    w1 = jax.random.normal(key, (3, 256, 128)) * 0.02     # stacked layers
    w2 = jax.random.normal(jax.random.fold_in(key, 1), (128, 256)) * 0.02
    scale = jnp.ones((128,))
    emb = jax.random.normal(jax.random.fold_in(key, 2), (512, 128)) * 0.02
    params = {"blocks": {"w1": w1, "w2": w2, "norm": scale},
              "embed": emb}
    if quantized:
        params = quant.tree_quantize(
            params, bits=8, symmetric=True,
            predicate=lambda p, l: l.ndim >= 2)
    return params


class TestQGaLoreOptimizer:
    def test_leaf_specs(self):
        cfg = QGaLoreConfig(rank=16, min_dim=64)
        params = _toy_params()
        specs = qgalore.leaf_specs(params, cfg)
        by_path = {s.path: s for s in specs}
        w1 = next(s for p, s in by_path.items() if "w1" in p)
        assert w1.galore and w1.side == "right" and w1.batch == (3,)
        emb = next(s for p, s in by_path.items() if "embed" in p)
        assert not emb.galore  # embeddings excluded by default
        norm = next(s for p, s in by_path.items() if "norm" in p)
        assert not norm.galore

    def test_init_shapes(self):
        cfg = QGaLoreConfig(rank=16, min_dim=64)
        params = _toy_params()
        state = qgalore.init(params, cfg)
        specs = qgalore.leaf_specs(params, cfg)
        proj_leaves = jax.tree_util.tree_flatten(
            state.proj, is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]
        for spec, P in zip(specs, proj_leaves):
            if spec.galore:
                assert P is not None
                assert tuple(P.shape) == spec.proj_shape
            else:
                assert P is None

    @pytest.mark.parametrize("refresh", [False, True])
    def test_step_runs_and_descends(self, refresh):
        cfg = QGaLoreConfig(rank=16, min_dim=64, update_interval=1)
        params = _toy_params()
        state = qgalore.init(params, cfg)
        specs = qgalore.leaf_specs(params, cfg)
        # synthetic full-rank grads = dequantized params (descend towards 0)
        grads = quant.tree_dequantize(params, jnp.float32)
        masks = {i: jnp.ones((s.nbatch,), bool)
                 for i, s in enumerate(specs) if s.galore} if refresh else None
        step = functools.partial(qgalore.apply_updates, cfg=cfg, specs=specs,
                                 refresh=refresh)
        new_params, new_state, metrics = jax.jit(step)(
            params, grads, state, lr=1e-2, rng=jax.random.PRNGKey(7),
            refresh_masks=masks)
        assert int(new_state.count) == 1
        # params changed and are finite
        before = quant.tree_dequantize(params, jnp.float32)
        after = quant.tree_dequantize(new_params, jnp.float32)
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), before, after)
        assert max(jax.tree_util.tree_leaves(diffs)) > 0
        for leaf in jax.tree_util.tree_leaves(after):
            assert np.isfinite(np.asarray(leaf)).all()
        if refresh:
            assert metrics["sims"]  # similarities reported

    def test_lowrank_grads_accepted(self):
        """Fused path: grads already projected."""
        cfg = QGaLoreConfig(rank=16, min_dim=64)
        params = _toy_params()
        specs = qgalore.leaf_specs(params, cfg)
        state = qgalore.init(params, cfg)
        grads = []
        flat, treedef = jax.tree_util.tree_flatten(params,
                                                   is_leaf=quant.is_qtensor)
        for leaf, spec in zip(flat, specs):
            if spec.galore:
                grads.append(jnp.ones(spec.low_shape, jnp.float32))
            else:
                grads.append(jnp.ones(spec.shape, jnp.float32))
        grads = jax.tree_util.tree_unflatten(treedef, grads)
        new_params, _, _ = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=cfg, specs=specs, refresh=False))(
            params, grads, state, lr=1e-3, rng=jax.random.PRNGKey(0))
        for leaf in jax.tree_util.tree_leaves(
                quant.tree_dequantize(new_params)):
            assert np.isfinite(np.asarray(leaf)).all()

    def test_partial_refresh_mask(self):
        """Only masked layers get a new P."""
        cfg = QGaLoreConfig(rank=8, min_dim=64, proj_bits=16)
        key = jax.random.PRNGKey(0)
        params = {"w": jax.random.normal(key, (4, 128, 96)) * 0.02}
        specs = qgalore.leaf_specs(params, cfg)
        state = qgalore.init(params, cfg)
        grads = {"w": jax.random.normal(jax.random.fold_in(key, 9),
                                        (4, 128, 96))}
        mask = jnp.array([True, False, True, False])
        new_params, new_state, metrics = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=cfg, specs=specs, refresh=True))(
            params, grads, state, lr=0.0, rng=key,
            refresh_masks={0: mask})
        P_old = state.proj["w"]
        P_new = new_state.proj["w"]
        changed = np.asarray(jnp.any(P_old != P_new, axis=(1, 2)))
        np.testing.assert_array_equal(changed, np.asarray(mask))
        sims = metrics["sims"][specs[0].path]
        assert float(sims[1]) == -1.0 and float(sims[0]) >= 0.0

    def test_memory_report_qgalore_smaller(self):
        cfg_q = QGaLoreConfig(rank=16, min_dim=64)
        params_q = _toy_params(quantized=True)
        params_f = _toy_params(quantized=False)
        from repro.core.optimizers import preset
        rep_q = qgalore.memory_report(params_q, preset("qgalore", cfg_q))
        rep_f = qgalore.memory_report(params_f, preset("full", cfg_q))
        assert rep_q["total_gb"] < 0.5 * rep_f["total_gb"]


class TestAdaptiveController:
    def _setup(self, cfg):
        params = _toy_params()
        specs = qgalore.leaf_specs(params, cfg)
        return specs, adaptive.SubspaceController(specs, cfg)

    def test_initial_refresh_at_step0(self):
        cfg = QGaLoreConfig(update_interval=10)
        specs, ctrl = self._setup(cfg)
        masks = ctrl.masks_for_step(0)
        assert masks  # everything due at step 0
        for i, m in masks.items():
            assert m.all()

    def test_interval_doubles_on_high_similarity(self):
        cfg = QGaLoreConfig(update_interval=10, adaptive=True,
                            cos_threshold=0.4, adaptive_k=2)
        specs, ctrl = self._setup(cfg)
        gidx = next(i for i, s in enumerate(specs) if s.galore)
        path = specs[gidx].path
        step = 0
        for _ in range(4):
            masks = ctrl.masks_for_step(step)
            sims = {p: np.full((specs[i].nbatch,), 0.9)
                    for i, p in [(i, specs[i].path) for i in masks]}
            ctrl.observe(step, masks, sims)
            step += 10
        intervals = ctrl.interval_summary()[path]
        assert all(iv > cfg.update_interval for iv in intervals)

    def test_interval_stays_on_low_similarity(self):
        cfg = QGaLoreConfig(update_interval=10, adaptive=True,
                            cos_threshold=0.4, adaptive_k=2)
        specs, ctrl = self._setup(cfg)
        step = 0
        for _ in range(4):
            masks = ctrl.masks_for_step(step)
            sims = {specs[i].path: np.full((specs[i].nbatch,), 0.1)
                    for i in masks}
            ctrl.observe(step, masks, sims)
            step += 10
        for ivs in ctrl.interval_summary().values():
            assert all(iv == cfg.update_interval for iv in ivs)

    def test_svd_savings_accounting(self):
        cfg = QGaLoreConfig(update_interval=5, adaptive=True,
                            cos_threshold=0.4, adaptive_k=1)
        specs, ctrl = self._setup(cfg)
        for step in range(100):
            masks = ctrl.masks_for_step(step)
            if masks:
                sims = {specs[i].path: np.full((specs[i].nbatch,), 0.95)
                        for i in masks}
                ctrl.observe(step, masks, sims)
        used = ctrl.total_svd_count()
        base = ctrl.baseline_svd_count(100)
        assert used < 0.5 * base  # >50% SVD savings under stable subspaces

    def test_json_roundtrip(self):
        cfg = QGaLoreConfig(update_interval=10)
        specs, ctrl = self._setup(cfg)
        masks = ctrl.masks_for_step(0)
        sims = {specs[i].path: np.full((specs[i].nbatch,), 0.9)
                for i in masks}
        ctrl.observe(0, masks, sims)
        blob = ctrl.to_json()
        ctrl2 = adaptive.SubspaceController(specs, cfg)
        ctrl2.from_json(blob)
        assert ctrl2.total_svd_count() == ctrl.total_svd_count()
        assert ctrl2.interval_summary() == ctrl.interval_summary()


class TestAdaptiveRankController:
    """Host-side dynamic rank adaptation: shrink decisions from
    explained-variance profiles, strict (de)serialization."""

    CFG = QGaLoreConfig(update_interval=10, rank=16, min_dim=64,
                        adaptive_rank=True, rank_ladder=(8,),
                        explained_ratio_threshold=0.5, rank_patience=2,
                        min_rank=8)

    def _setup(self, cfg):
        params = _toy_params()
        specs = qgalore.leaf_specs(params, cfg)
        return specs, adaptive.SubspaceController(specs, cfg)

    def _observe(self, ctrl, specs, step, ratio_at_target):
        masks = ctrl.masks_for_step(step)
        sims, ratios = {}, {}
        for i in masks:
            sims[specs[i].path] = np.full((specs[i].nbatch,), 0.1)
            prof = np.linspace(0.05, ratio_at_target, ctrl.ranks[i])
            prof[7] = ratio_at_target           # entry read for target 8
            ratios[specs[i].path] = np.tile(prof, (specs[i].nbatch, 1))
        ctrl.observe(step, masks, sims, ratios)
        return masks

    def test_shrink_after_patience_then_floor(self):
        specs, ctrl = self._setup(self.CFG)
        self._observe(ctrl, specs, 0, 0.9)
        assert ctrl.take_rank_decisions() == []        # patience 2
        self._observe(ctrl, specs, 10, 0.9)
        decisions = ctrl.take_rank_decisions()
        galore = [i for i, s in enumerate(specs) if s.galore]
        assert sorted(i for i, _, _ in decisions) == sorted(galore)
        assert all(old == 16 and new == 8 for _, old, new in decisions)
        assert set(ctrl.current_ranks().values()) == {8}
        assert all(t["step"] == 10 for t in
                   ctrl.rank_transition_summary())
        # at the ladder floor no further target exists
        self._observe(ctrl, specs, 20, 0.99)
        self._observe(ctrl, specs, 30, 0.99)
        assert ctrl.take_rank_decisions() == []

    def test_below_threshold_resets_streak(self):
        specs, ctrl = self._setup(self.CFG)
        self._observe(ctrl, specs, 0, 0.9)
        self._observe(ctrl, specs, 10, 0.2)            # resets
        self._observe(ctrl, specs, 20, 0.9)
        assert ctrl.take_rank_decisions() == []        # streak is 1 again
        self._observe(ctrl, specs, 30, 0.9)
        assert ctrl.take_rank_decisions()

    def test_rank_state_json_roundtrip(self):
        specs, ctrl = self._setup(self.CFG)
        self._observe(ctrl, specs, 0, 0.9)
        self._observe(ctrl, specs, 10, 0.9)
        ctrl.take_rank_decisions()
        blob = ctrl.to_json()
        ctrl2 = adaptive.SubspaceController(specs, self.CFG)
        ctrl2.from_json(blob)
        assert ctrl2.ranks == ctrl.ranks
        assert ctrl2.rank_streaks == ctrl.rank_streaks
        assert ctrl2.rank_transition_summary() == \
            ctrl.rank_transition_summary()

    def test_from_json_rejects_mismatched_leaf_set(self):
        """The silent-miss fix: a blob written under different specs must
        raise, not silently resume with desynchronized schedules."""
        specs, ctrl = self._setup(self.CFG)
        blob = ctrl.to_json()
        params_small = {"blocks": {"w2": jax.random.normal(
            jax.random.PRNGKey(0), (128, 256))}}
        specs2 = qgalore.leaf_specs(params_small, self.CFG)
        ctrl2 = adaptive.SubspaceController(specs2, self.CFG)
        with pytest.raises(ValueError, match="does not match"):
            ctrl2.from_json(blob)

    def test_from_json_rejects_unit_count_mismatch(self):
        """Same leaf set, different stacked-layer layout: loud failure."""
        specs, ctrl = self._setup(self.CFG)
        blob = ctrl.to_json()
        key = jax.random.PRNGKey(0)
        params2 = {"blocks": {"w1": jax.random.normal(key, (2, 256, 128)),
                              "w2": jax.random.normal(key, (128, 256)),
                              "norm": jnp.ones((128,))},
                   "embed": jax.random.normal(key, (512, 128))}
        specs2 = qgalore.leaf_specs(params2, self.CFG)
        ctrl2 = adaptive.SubspaceController(specs2, self.CFG)
        with pytest.raises(ValueError, match="serialized units"):
            ctrl2.from_json(blob)

    def test_from_json_accepts_pre_rank_flat_format(self):
        """Checkpoints from before rank adaptation serialize the flat
        {idx: [unit...]} form — they must still restore."""
        import json as _json
        specs, ctrl = self._setup(self.CFG)
        masks = ctrl.masks_for_step(0)
        sims = {specs[i].path: np.full((specs[i].nbatch,), 0.9)
                for i in masks}
        ctrl.observe(0, masks, sims)
        old_blob = _json.dumps(_json.loads(ctrl.to_json())["units"])
        ctrl2 = adaptive.SubspaceController(specs, self.CFG)
        ctrl2.from_json(old_blob)
        assert ctrl2.interval_summary() == ctrl.interval_summary()
        assert ctrl2.svd_count_summary() == ctrl.svd_count_summary()
