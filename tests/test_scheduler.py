"""Continuous-batching serving runtime: ragged-prompt parity, cache-pool
insert, scheduler admit/evict lifecycle, and end-to-end token parity with
the lockstep baseline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig
from repro.models import model_zoo
from repro.serve import engine
from repro.serve.scheduler import Request, Scheduler, init_pool, \
    insert_request, insert_requests
from repro.train import step as step_lib

PAD = 0


@pytest.fixture(scope="module")
def bundle60():
    return model_zoo.build_arch("llama-60m", smoke=True, dtype=jnp.float32)


@pytest.fixture(scope="module")
def params60(bundle60):
    return bundle60.init_params(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def qparams60(bundle60, params60):
    """INT8-quantized weights — the serving-native format."""
    return step_lib.prepare_params(params60, QGaLoreConfig(), jnp.float32)


def _rand_prompt(rng, vocab, lo=3, hi=12):
    return rng.integers(1, vocab, size=int(rng.integers(lo, hi))) \
        .astype(np.int32)


# ---------------------------------------------------------------------------
# Ragged-prompt decode (the build_prefill lengths bugfix)
# ---------------------------------------------------------------------------

def test_ragged_prefill_matches_single_row_quantized(bundle60, qparams60):
    """A right-padded batch row must produce the SAME prefill logits and
    decode trajectory as the same prompt run unpadded on its own —
    on the quantized (INT8-native) weight path."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(0)
    lengths = [12, 7, 4]
    S = max(lengths)
    tokens = np.full((3, S), PAD, np.int32)
    rows = [_rand_prompt(rng, V, L, L + 1) for L in lengths]
    for i, r in enumerate(rows):
        tokens[i, : len(r)] = r

    prefill = jax.jit(engine.build_prefill(bundle60, max_len=24,
                                           pad_id=PAD))
    decode = jax.jit(engine.build_decode(bundle60))
    logits, state = prefill(qparams60, {"tokens": jnp.asarray(tokens)})
    assert np.asarray(state.lengths).tolist() == lengths

    cont = rng.integers(1, V, size=(3, 3)).astype(np.int32)
    lb, sb = logits, state
    for t in range(3):
        lb, sb = decode(qparams60, sb, jnp.asarray(cont[:, t: t + 1]))

    for i, r in enumerate(rows):
        lr, sr = prefill(qparams60, {"tokens": jnp.asarray(r)[None]})
        err = np.abs(np.asarray(lr[0, -1]) - np.asarray(logits[i, -1]))
        assert err.max() == 0.0, f"row {i} prefill mismatch {err.max()}"
        for t in range(3):
            lr, sr = decode(qparams60, sr,
                            jnp.asarray(cont[i: i + 1, t: t + 1]))
        err = np.abs(np.asarray(lr[0, -1]) - np.asarray(lb[i, -1]))
        assert err.max() == 0.0, f"row {i} decode mismatch {err.max()}"


def test_prompt_lengths_trailing_pad_only():
    toks = jnp.asarray([[5, 0, 3, 0, 0],     # pad INSIDE prompt is content
                        [1, 2, 3, 4, 5],
                        [7, 0, 0, 0, 0]], jnp.int32)
    assert engine.prompt_lengths(toks, 0).tolist() == [3, 5, 1]
    assert engine.prompt_lengths(toks, None).tolist() == [5, 5, 5]


# ---------------------------------------------------------------------------
# generate(): EOS retirement (the host-loop bugfix)
# ---------------------------------------------------------------------------

def test_generate_eos_stops_sampling(bundle60, params60):
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(1)
    prompt = _rand_prompt(rng, V, 6, 7)
    batch = {"tokens": jnp.asarray(prompt)[None]}
    ref, _ = engine.generate(bundle60, params60, batch, steps=6,
                             max_len=32)
    ref = np.asarray(ref)[0]
    eos = int(ref[2])

    toks, state = engine.generate(bundle60, params60, batch, steps=6,
                                  max_len=32, eos_id=eos, pad_id=PAD)
    toks = np.asarray(toks)[0]
    assert toks[:3].tolist() == ref[:3].tolist()
    assert (toks[3:] == PAD).all(), f"retired row kept sampling: {toks}"
    # cache length froze at retirement: prompt + 2 decode writes
    assert int(state.lengths[0]) == len(prompt) + 2


# ---------------------------------------------------------------------------
# Cache pool insert
# ---------------------------------------------------------------------------

def test_insert_request_slot_isolation(bundle60, params60):
    """Inserting into slot j overwrites exactly slot j — one compiled
    program serves every slot index (traced slot)."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(2)
    prefill = jax.jit(engine.build_prefill(bundle60, max_len=16))
    pool = init_pool(bundle60, 3, 16, jnp.float32)
    ins = jax.jit(insert_request)

    rows = []
    for i in range(3):
        _, row = prefill(params60,
                         {"tokens": jnp.asarray(
                             _rand_prompt(rng, V, 5, 6))[None]})
        rows.append(row)

    # fill slots 2, 0 (out of order) with one jitted program
    pool = ins(pool, 2, rows[0])
    pool = ins(pool, 0, rows[1])

    def leaf_rows(state, i):
        return [np.asarray(l)[:, i]
                for l in jax.tree_util.tree_leaves(state.caches)]

    for got, want in zip(leaf_rows(pool, 2), leaf_rows(rows[0], 0)):
        np.testing.assert_array_equal(got, want)
    for got, want in zip(leaf_rows(pool, 0), leaf_rows(rows[1], 0)):
        np.testing.assert_array_equal(got, want)
    for leaf in leaf_rows(pool, 1):          # untouched slot stays zero
        assert (leaf == 0).all()
    assert np.asarray(pool.lengths).tolist() == [5, 0, 5]

    # batched scatter insert agrees with two single inserts
    pool2 = insert_requests(init_pool(bundle60, 3, 16, jnp.float32),
                            np.asarray([2, 0], np.int32),
                            jax.tree_util.tree_map(
                                lambda a, b: jnp.concatenate(
                                    [a, b], axis=1 if a.ndim > 1 else 0),
                                rows[0], rows[1]))
    for a, b in zip(jax.tree_util.tree_leaves(pool),
                    jax.tree_util.tree_leaves(pool2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Scheduler lifecycle
# ---------------------------------------------------------------------------

def test_scheduler_admit_evict(bundle60, params60):
    """More requests than slots: every request completes, slots are
    reused, and per-request token counts respect max_new_tokens."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(3)
    reqs = [Request(rid=r, tokens=_rand_prompt(rng, V),
                    max_new_tokens=int(rng.integers(1, 7)))
            for r in range(7)]
    sched = Scheduler(bundle60, params60, num_slots=2, max_len=32,
                      dtype=jnp.float32, prompt_bucket=8)
    comps = sched.run(reqs)

    assert sorted(c.rid for c in comps) == list(range(7))
    assert sched.stats["admitted"] == 7
    assert sched.stats["retired"] == 7
    assert sched.stats["evictions"] >= 5      # 7 requests through 2 slots
    assert all(s.free for s in sched.slots)
    assert not sched.active.any()
    by_rid = {c.rid: c for c in comps}
    for r in reqs:
        assert len(by_rid[r.rid].tokens) == r.max_new_tokens
        assert by_rid[r.rid].prompt_len == len(r.tokens)


def test_scheduler_eos_retires_slot(bundle60, params60):
    """A request whose eos_id matches an emitted token retires early and
    frees its slot for the next admission."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(4)
    prompt = _rand_prompt(rng, V, 6, 7)
    ref, _ = engine.generate(bundle60, params60,
                             {"tokens": jnp.asarray(prompt)[None]},
                             steps=5, max_len=32)
    ref = np.asarray(ref)[0].tolist()
    eos = ref[2]

    reqs = [Request(rid=0, tokens=prompt, max_new_tokens=6, eos_id=eos),
            Request(rid=1, tokens=_rand_prompt(rng, V),
                    max_new_tokens=3)]
    sched = Scheduler(bundle60, params60, num_slots=1, max_len=32,
                      dtype=jnp.float32, prompt_bucket=8)
    comps = {c.rid: c for c in sched.run(reqs)}
    assert comps[0].tokens == ref[:3]         # stopped AT the eos token
    assert len(comps[1].tokens) == 3          # admitted after the eviction
    assert sched.stats["evictions"] == 2


def test_scheduler_rejects_oversized_request(bundle60, params60):
    """Rejection happens at submit() — co-queued requests are unaffected."""
    sched = Scheduler(bundle60, params60, num_slots=1, max_len=8,
                      dtype=jnp.float32)
    with pytest.raises(ValueError, match="exceeds max_len"):
        sched.submit(Request(rid=0, tokens=np.arange(1, 7, dtype=np.int32),
                             max_new_tokens=8))
    assert not sched.pending         # nothing half-queued


def test_scheduler_moe_unpadded_admission():
    """MoE bundles (row-coupled capacity routing → ragged_prefill_ok=False)
    go through exact-length admission and still match per-request
    generate."""
    bundle = model_zoo.build_arch("qwen3-moe-30b-a3b", smoke=True,
                                  dtype=jnp.float32)
    assert not bundle.ragged_prefill_ok
    params = bundle.init_params(jax.random.PRNGKey(0))
    V = bundle.cfg.vocab_size
    rng = np.random.default_rng(7)
    reqs = [Request(rid=r, tokens=_rand_prompt(rng, V, 3, 9),
                    max_new_tokens=int(rng.integers(2, 4)))
            for r in range(3)]
    sched = Scheduler(bundle, params, num_slots=2, max_len=16,
                      dtype=jnp.float32, prompt_bucket=8)
    comps = {c.rid: c for c in sched.run(reqs)}
    for r in reqs:
        out, _ = engine.generate(
            bundle, params, {"tokens": jnp.asarray(r.tokens)[None]},
            steps=r.max_new_tokens - 1, max_len=16)
        assert comps[r.rid].tokens == np.asarray(out)[0].tolist(), \
            f"rid {r.rid}"


def test_prefill_rejects_pad_id_on_unsafe_bundle():
    bundle = model_zoo.build_arch("xlstm-125m", smoke=True,
                                  dtype=jnp.float32)
    with pytest.raises(ValueError, match="ragged_prefill_ok"):
        engine.build_prefill(bundle, max_len=16, pad_id=0)


def test_scheduler_recurrent_family_unpadded_admission():
    """Recurrent-state bundles (ragged_prefill_ok=False) must decode the
    same tokens through the scheduler as per-request lockstep generate —
    admission may not right-pad their prompts."""
    bundle = model_zoo.build_arch("xlstm-125m", smoke=True,
                                  dtype=jnp.float32)
    assert not bundle.ragged_prefill_ok
    params = bundle.init_params(jax.random.PRNGKey(0))
    V = bundle.cfg.vocab_size
    rng = np.random.default_rng(6)
    reqs = [Request(rid=r, tokens=_rand_prompt(rng, V, 3, 9),
                    max_new_tokens=int(rng.integers(2, 5)))
            for r in range(3)]
    sched = Scheduler(bundle, params, num_slots=2, max_len=16,
                      dtype=jnp.float32, prompt_bucket=8)
    comps = {c.rid: c for c in sched.run(reqs)}
    for r in reqs:
        out, _ = engine.generate(
            bundle, params, {"tokens": jnp.asarray(r.tokens)[None]},
            steps=r.max_new_tokens - 1, max_len=16)
        assert comps[r.rid].tokens == np.asarray(out)[0].tolist(), \
            f"rid {r.rid}"


# ---------------------------------------------------------------------------
# Continuous vs lockstep: end-to-end token parity
# ---------------------------------------------------------------------------

def test_continuous_matches_lockstep(bundle60, qparams60):
    """The continuous-batching engine must emit token-identical output to
    the lockstep ``generate`` baseline for the same request set (greedy,
    quantized weights)."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(5)
    reqs = [Request(rid=r, tokens=_rand_prompt(rng, V),
                    max_new_tokens=int(rng.integers(2, 8)))
            for r in range(6)]
    sched = Scheduler(bundle60, qparams60, num_slots=2, max_len=32,
                      dtype=jnp.float32, prompt_bucket=8)
    comps = {c.rid: c for c in sched.run(reqs)}

    # lockstep baseline: one padded batch per pair of requests
    for g in range(0, len(reqs), 2):
        group = reqs[g: g + 2]
        S = max(len(r.tokens) for r in group)
        toks = np.full((len(group), S), PAD, np.int32)
        for i, r in enumerate(group):
            toks[i, : len(r.tokens)] = r.tokens
        steps = max(r.max_new_tokens for r in group)
        out, _ = engine.generate(
            bundle60, qparams60, {"tokens": jnp.asarray(toks)},
            steps=steps - 1, max_len=32, pad_id=PAD)
        out = np.asarray(out)
        for i, r in enumerate(group):
            assert comps[r.rid].tokens == \
                out[i, : r.max_new_tokens].tolist(), f"rid {r.rid}"


# ---------------------------------------------------------------------------
# Empty-row rejection + explicit-lengths ambiguity (build_prefill gather fix)
# ---------------------------------------------------------------------------

def test_prefill_explicit_lengths_pad_id_as_final_token(bundle60,
                                                        qparams60):
    """A prompt whose LAST REAL token equals pad_id is ambiguous to
    trailing-pad detection (it would shorten the row) — explicit
    ``lengths`` must win, taking the head logits at the true final
    position, bit-identical to the unpadded single-row run."""
    row = np.asarray([5, 3, PAD], np.int32)          # real trailing pad_id
    padded = np.full((2, 6), PAD, np.int32)
    padded[0, :3] = row
    padded[1] = np.asarray([7, 2, 9, 4, 6, 8], np.int32)

    prefill = jax.jit(engine.build_prefill(bundle60, max_len=16,
                                           pad_id=PAD))
    logits, state = prefill(
        qparams60, {"tokens": jnp.asarray(padded),
                    "lengths": jnp.asarray([3, 6], jnp.int32)})
    assert np.asarray(state.lengths).tolist() == [3, 6]

    ref, _ = prefill(qparams60, {"tokens": jnp.asarray(row)[None],
                                 "lengths": jnp.asarray([3], jnp.int32)})
    err = np.abs(np.asarray(ref[0, -1]) - np.asarray(logits[0, -1]))
    assert err.max() == 0.0, f"explicit-lengths mismatch {err.max()}"

    # trailing-pad detection on the same batch WOULD have used length 2
    detected = engine.prompt_lengths(jnp.asarray(padded), PAD)
    assert np.asarray(detected).tolist() == [2, 6]


def test_generate_rejects_empty_row(bundle60, qparams60):
    """An all-pad row must fail loudly at the host entry point, not
    silently wrap the last-position gather inside jit."""
    toks = np.asarray([[PAD, PAD, PAD], [5, 3, 2]], np.int32)
    with pytest.raises(ValueError, match="empty prompt row"):
        engine.generate(bundle60, qparams60,
                        {"tokens": jnp.asarray(toks)},
                        steps=2, max_len=16, pad_id=PAD)
    # explicit zero lengths are rejected the same way
    with pytest.raises(ValueError, match="empty prompt row"):
        engine.generate(bundle60, qparams60,
                        {"tokens": jnp.asarray(toks),
                         "lengths": jnp.asarray([0, 3], jnp.int32)},
                        steps=2, max_len=16, pad_id=PAD)


def test_scheduler_rejects_empty_prompt(bundle60, params60):
    sched = Scheduler(bundle60, params60, num_slots=1, max_len=8,
                      dtype=jnp.float32)
    with pytest.raises(ValueError, match="empty prompt"):
        sched.submit(Request(rid=0, tokens=np.zeros((0,), np.int32),
                             max_new_tokens=2))
    assert not sched.pending


# ---------------------------------------------------------------------------
# reset(): warm benchmark rounds must be bit-reproducible under sampling
# ---------------------------------------------------------------------------

def test_scheduler_reset_reproducible_under_temperature(bundle60,
                                                        qparams60):
    """reset() restores the sampling key (and every fold_in input: step
    counter, admission counter), so rerunning the same request set emits
    token-identical completions — the warm-round invariant serve_bench
    relies on."""
    V = bundle60.cfg.vocab_size
    rng = np.random.default_rng(11)
    def reqs():
        return [Request(rid=r, tokens=_rand_prompt(rng, V, 4, 5),
                        max_new_tokens=4) for r in range(4)]
    fixed = reqs()
    sched = Scheduler(bundle60, qparams60, num_slots=2, max_len=32,
                      dtype=jnp.float32, prompt_bucket=8,
                      temperature=0.9, key=jax.random.PRNGKey(7))
    first = {c.rid: c.tokens for c in sched.run(fixed)}
    sched.reset()
    second = {c.rid: c.tokens for c in sched.run(fixed)}
    assert first == second
    # sanity: sampling is actually stochastic (a different key differs
    # somewhere, otherwise this test proves nothing)
    other = Scheduler(bundle60, qparams60, num_slots=2, max_len=32,
                      dtype=jnp.float32, prompt_bucket=8,
                      temperature=0.9, key=jax.random.PRNGKey(8))
    third = {c.rid: c.tokens for c in other.run(fixed)}
    assert first != third
