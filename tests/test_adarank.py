"""Adaptive-rank golden harness + adaptive-vs-static ablation.

Companion to ``tests/test_golden.py``: the same fixed-seed 40-step
llama-60m smoke run, but with dynamic per-layer rank adaptation ON
(``adaptive_rank=True``) and GaLore extended to the embedding/head leaves
so the low-rank state dominates the optimizer bytes. The committed fixture
(``tests/golden/llama60m_adarank_40steps.json``) pins:

* the loss curve (tolerance band, same rtol/atol as the base fixture);
* the EXACT rank-transition schedule — (step, path, old → new) — the
  host-side spectrum-driven shrink decisions are integer state, so any
  change to the explained-variance computation, the controller's
  streak/patience logic, or the refresh numerics that flips a shrink
  decision fails loudly even when the losses stay in band;
* the exact final per-leaf ranks.

Regenerate after an *intentional* numerics change with:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_adarank.py -q

The ablation test pins the paper-motivated payoff: the adaptive run must
end inside a tight loss band of the static-rank run while strictly
shrinking both the optimizer-state bytes and the per-step compressed-DP
gradient payload.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
from repro.core import qgalore
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")
FIXTURE = os.path.join(GOLDEN_DIR, "llama60m_adarank_40steps.json")
STEPS = 40
LOSS_RTOL = 2e-3
LOSS_ATOL = 2e-3
# ablation acceptance: the adaptive run must land within this band of the
# static-rank run's final loss while cutting >= MIN_BYTE_REDUCTION of the
# optimizer-state bytes
ABLATION_LOSS_ATOL = 5e-3
MIN_BYTE_REDUCTION = 0.25


def build_trainer(adaptive_rank: bool = True) -> Trainer:
    """The pinned adarank configuration: the base golden config +
    ``galore_embeddings=True`` (so the embedding/head Adam state is
    low-rank — full-rank embedding state would dominate the byte count
    and mask the rank-shrink effect) + the adaptive-rank knobs. Any change
    here invalidates the fixture — bump the "config" stamp."""
    bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                  dtype=jnp.float32)
    qcfg = preset("qgalore", QGaLoreConfig(
        rank=8, min_dim=32, update_interval=4, adaptive_k=1,
        cos_threshold=0.3, galore_embeddings=True,
        adaptive_rank=adaptive_rank, rank_ladder=(4,),
        explained_ratio_threshold=0.45, rank_patience=3, min_rank=4))
    tcfg = TrainConfig(
        seed=0, global_batch=4, seq_len=32, steps=STEPS,
        learning_rate=1e-2, warmup_steps=2, grad_clip=1.0, log_every=0,
        async_checkpoint=False)
    cell = ShapeCell("golden", 32, 4, "train")
    return Trainer(bundle, tcfg, qcfg, cell=cell, impl="fused",
                   param_dtype=jnp.float32)


def _run(adaptive_rank: bool) -> dict:
    tr = build_trainer(adaptive_rank)
    hist = tr.run()
    return {
        "losses": [float(h["loss"]) for h in hist],
        "transitions": tr.controller.rank_transition_summary(),
        "final_ranks": {tr.specs[i].path: int(r)
                        for i, r in sorted(tr.controller.ranks.items())},
        "opt_bytes": qgalore.optimizer_state_bytes(
            tr.state.params, tr.rules, specs=tr.specs),
        "dp_payload_bytes": qgalore.dp_payload_bytes(tr.specs),
    }


# both tests consume the adaptive run; cache it so the 40-step trajectory
# executes once per pytest session
_CACHE: dict = {}


def _adaptive_run() -> dict:
    if "adaptive" not in _CACHE:
        _CACHE["adaptive"] = _run(adaptive_rank=True)
    return _CACHE["adaptive"]


def test_adarank_golden_trajectory():
    got = dict(_adaptive_run(),
               config="llama-60m smoke / qgalore r8 adarank ladder(4,) "
                      "thresh 0.45 patience 3 / seed 0 / 40 steps")
    if os.environ.get("REPRO_REGEN_GOLDEN") == "1":
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(FIXTURE, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
        pytest.skip(f"regenerated {FIXTURE}")
    assert os.path.exists(FIXTURE), (
        "adarank golden fixture missing — run REPRO_REGEN_GOLDEN=1 pytest "
        "tests/test_adarank.py and commit it")
    with open(FIXTURE) as f:
        want = json.load(f)
    assert got["config"] == want["config"]
    np.testing.assert_allclose(
        got["losses"], want["losses"], rtol=LOSS_RTOL, atol=LOSS_ATOL,
        err_msg="adarank loss trajectory drifted out of the golden band — "
                "if the numerics change is intentional, regenerate the "
                "fixture (see module docstring)")
    assert got["transitions"] == want["transitions"], (
        "the rank-transition schedule changed — the spectrum-driven shrink "
        "decisions (explained-variance profiles, streak/patience logic) "
        "took a different path than the golden run")
    assert got["final_ranks"] == want["final_ranks"]
    assert got["opt_bytes"] == want["opt_bytes"]
    assert got["dp_payload_bytes"] == want["dp_payload_bytes"]


def test_adaptive_vs_static_rank():
    """The ablation the tentpole exists for: dynamic rank adaptation must
    (a) stay within a tight band of the static-rank run's final loss,
    (b) strictly shrink the optimizer-state bytes — by at least 25% —
    (c) strictly shrink the per-step compressed-DP gradient payload."""
    ada = _adaptive_run()
    static = _run(adaptive_rank=False)

    assert static["transitions"] == []          # knob truly off
    assert ada["transitions"], (
        "no rank transitions fired — the adarank config no longer "
        "exercises the adaptive path")

    delta = abs(ada["losses"][-1] - static["losses"][-1])
    assert delta <= ABLATION_LOSS_ATOL, (
        f"adaptive final loss {ada['losses'][-1]} vs static "
        f"{static['losses'][-1]}: delta {delta} > {ABLATION_LOSS_ATOL}")

    red = 1.0 - ada["opt_bytes"] / static["opt_bytes"]
    assert red >= MIN_BYTE_REDUCTION, (
        f"optimizer-state bytes only shrank {red:.1%} "
        f"({static['opt_bytes']} -> {ada['opt_bytes']}), "
        f"need >= {MIN_BYTE_REDUCTION:.0%}")

    assert ada["dp_payload_bytes"] < static["dp_payload_bytes"], (
        "rank shrink must reduce the per-step DP gradient payload")


# ---------------------------------------------------------------------------
# rank_hysteresis: the dead band below the shrink threshold
# ---------------------------------------------------------------------------

def _mini_controller(band: float):
    """One-leaf controller (64x64 galore leaf, rank 8, ladder (4,),
    threshold 0.5, patience 2) driven directly through observe()."""
    from repro.core import adaptive
    from repro.core.rules import as_rules
    qcfg = QGaLoreConfig(rank=8, min_dim=32, adaptive_rank=True,
                         rank_ladder=(4,), explained_ratio_threshold=0.5,
                         rank_hysteresis=band, rank_patience=2, min_rank=4)
    params = {"w": jnp.zeros((64, 64), jnp.float32)}
    specs = qgalore.leaf_specs(params, as_rules(qcfg))
    idx = next(i for i, s in enumerate(specs) if s.galore)
    return (adaptive.SubspaceController(specs, qcfg), idx,
            specs[idx].path)


def _feed(ctrl, idx, path, vals):
    """One observe() per value: the leaf's explained ratio at the target
    rung (rank 4) for each refresh."""
    for step, v in enumerate(vals):
        prof = np.full((1, 8), v, dtype=np.float32)
        ctrl.observe(step, {idx: np.array([True])},
                     {path: np.array([0.9])}, {path: prof})


def test_rank_hysteresis_dead_band_prevents_oscillation():
    """A ratio jittering across the threshold (0.51 / 0.45 / 0.51 around
    threshold 0.5): WITHOUT hysteresis every dip resets the streak, so
    patience 2 is never reached and the schedule oscillates between
    almost-shrinking and starting over. With band 0.1 the dip lands in the
    dead band [0.4, 0.5), the streak HOLDS, and the shrink fires exactly
    once — no repeated reset/refire."""
    jitter = [0.51, 0.45, 0.51]

    ctrl, idx, path = _mini_controller(band=0.0)
    _feed(ctrl, idx, path, jitter)
    assert ctrl.rank_transition_summary() == []
    assert ctrl.ranks[idx] == 8

    ctrl, idx, path = _mini_controller(band=0.1)
    _feed(ctrl, idx, path, jitter)
    trans = ctrl.rank_transition_summary()
    assert [(t["old"], t["new"]) for t in trans] == [(8, 4)]
    assert ctrl.ranks[idx] == 4
    assert ctrl.take_rank_decisions() == [(idx, 8, 4)]
    # at the ladder floor: further observations can't fire again
    _feed(ctrl, idx, path, [0.9, 0.9, 0.9])
    assert len(ctrl.rank_transition_summary()) == 1


def test_rank_hysteresis_clear_drop_still_resets():
    """The band only absorbs jitter: a ratio clearly below
    threshold - band resets the streak even with hysteresis on, and the
    shrink then needs a fresh patience run (with in-band dips holding)."""
    ctrl, idx, path = _mini_controller(band=0.1)
    _feed(ctrl, idx, path, [0.51, 0.30, 0.51])
    assert ctrl.rank_transition_summary() == []       # 0.30 reset progress
    _feed(ctrl, idx, path, [0.45, 0.51])
    trans = ctrl.rank_transition_summary()            # hold, then 2nd hit
    assert [(t["old"], t["new"]) for t in trans] == [(8, 4)]
