"""quantized_dense: forward + gradient parity vs the dequantize-then-einsum
reference across backends, including shapes where M/N/K are not tile
multiples and N is not a multiple of the quant block, plus serve
prefill/decode logits parity on a quantized model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.kernels import dispatch, ops
from repro.models import base, layers, model_zoo
from repro.serve import engine
from repro.train import stack, step as train_step

from test_models_smoke import make_batch

BACKENDS = ["ref", "pallas-interpret"]

# (lead..., K) x (K, N): includes non-tile-multiple M/K and N not a
# multiple of the 256-col quant block (the QTensor pads internally).
SHAPES = [((2, 37), 96, 300),
          ((128,), 512, 256),
          ((5,), 64, 192),
          ((3, 3, 7), 130, 515)]


def _rand(seed, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(jax.random.PRNGKey(seed), shape)
            * scale).astype(dtype)


def _maxerr(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / max(np.abs(b).max(), 1e-6)


class TestForwardParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    @pytest.mark.parametrize("lead,K,N", SHAPES)
    def test_matches_dequant_einsum(self, backend, lead, K, N):
        x = _rand(0, lead + (K,))
        w = _rand(1, (K, N), scale=0.1)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = ops.quantized_dense(x, qt, dtype=jnp.float32, backend=backend)
        want = jnp.einsum("...d,df->...f", x,
                          quant.dequantize(qt, jnp.float32))
        assert got.shape == lead + (N,)
        assert _maxerr(got, want) < 1e-5

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_transposed_matches(self, backend):
        # tied-embedding head orientation: x (..., D) @ W (V, D)^T
        x = _rand(2, (3, 11, 200))
        w = _rand(3, (97, 200), scale=0.1)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = ops.quantized_dense_t(x, qt, dtype=jnp.float32,
                                    backend=backend)
        want = jnp.einsum("...d,vd->...v", x,
                          quant.dequantize(qt, jnp.float32))
        assert got.shape == (3, 11, 97)
        assert _maxerr(got, want) < 1e-5

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_batched_matches(self, backend):
        x = _rand(4, (4, 9, 64))
        w = _rand(5, (4, 64, 300), scale=0.1)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = ops.quantized_dense_batched(x, qt, dtype=jnp.float32,
                                          backend=backend)
        want = jnp.einsum("ecd,edf->ecf", x,
                          quant.dequantize(qt, jnp.float32))
        assert _maxerr(got, want) < 1e-5

    def test_bf16_activations(self):
        x = _rand(6, (32, 128), jnp.bfloat16)
        w = _rand(7, (128, 256), scale=0.1)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        got = ops.quantized_dense(x, qt, dtype=jnp.bfloat16, backend="ref")
        want = jnp.einsum("...d,df->...f", x.astype(jnp.float32),
                          quant.dequantize(qt, jnp.float32))
        assert got.dtype == jnp.bfloat16
        assert _maxerr(got, want) < 1e-2


class TestGradientParity:
    @pytest.mark.parametrize("backend", BACKENDS)
    def test_dx_and_dw_match_reference(self, backend):
        x = _rand(8, (2, 17, 96))
        w = _rand(9, (96, 300), scale=0.1)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        wd = quant.dequantize(qt, jnp.float32)
        g_out = _rand(10, (2, 17, 300))

        def f(shadow, xx):
            wv = quant.QVirtual(qt, shadow)
            out = ops.quantized_dense(xx, wv, dtype=jnp.float32,
                                      backend=backend)
            return jnp.sum(out * g_out)

        wv0 = quant.virtualize(qt)
        dw, dx = jax.grad(f, argnums=(0, 1))(wv0.shadow, x)

        def f_ref(wfull, xx):
            return jnp.sum(jnp.einsum("...d,df->...f", xx, wfull) * g_out)

        dw_ref, dx_ref = jax.grad(f_ref, argnums=(0, 1))(wd, x)
        assert _maxerr(dw, dw_ref) < 1e-5
        assert _maxerr(dx, dx_ref) < 1e-5

    @pytest.mark.parametrize("backend", BACKENDS)
    def test_transposed_grads(self, backend):
        x = _rand(11, (13, 200))
        w = _rand(12, (97, 200), scale=0.1)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        wd = quant.dequantize(qt, jnp.float32)
        g_out = _rand(13, (13, 97))

        def f(shadow, xx):
            wv = quant.QVirtual(qt, shadow)
            out = ops.quantized_dense_t(xx, wv, dtype=jnp.float32,
                                        backend=backend)
            return jnp.sum(out * g_out)

        dw, dx = jax.grad(f, argnums=(0, 1))(quant.virtualize(qt).shadow, x)

        def f_ref(wfull, xx):
            return jnp.sum(jnp.einsum("...d,vd->...v", xx, wfull) * g_out)

        dw_ref, dx_ref = jax.grad(f_ref, argnums=(0, 1))(wd, x)
        assert _maxerr(dw, dw_ref) < 1e-5
        assert _maxerr(dx, dx_ref) < 1e-5

    def test_embed_lookup_grads(self):
        emb = _rand(14, (96, 300), scale=0.1)
        qt = quant.quantize_blockwise(emb, bits=8, symmetric=True)
        tok = jax.random.randint(jax.random.PRNGKey(15), (2, 9), 0, 96)

        def f(shadow):
            out = layers.embed_lookup(quant.QVirtual(qt, shadow), tok,
                                      jnp.float32)
            return jnp.sum(out ** 2)

        got = jax.grad(f)(quant.virtualize(qt).shadow)
        want = jax.grad(
            lambda w: jnp.sum(jnp.take(w, tok, axis=0) ** 2))(
                quant.dequantize(qt, jnp.float32))
        assert _maxerr(got, want) < 1e-6


class TestDispatch:
    def test_registered_all_backends(self):
        assert set(dispatch.available_backends("quantized_dense")) == \
            {"pallas-tpu", "pallas-interpret", "ref"}
        assert set(dispatch.available_backends("int8_matmul_t")) == \
            {"pallas-tpu", "pallas-interpret", "ref"}

    def test_dense_fallback_toggle(self, monkeypatch):
        """QUANTIZED_DENSE=False restores the materialize+einsum path and
        produces the same numbers (the dequant reference)."""
        x = _rand(16, (4, 128))
        w = _rand(17, (128, 256), scale=0.1)
        qt = quant.quantize_blockwise(w, bits=8, symmetric=True)
        fast = layers.dense(x, qt, jnp.float32)
        monkeypatch.setattr(layers, "QUANTIZED_DENSE", False)
        slow = layers.dense(x, qt, jnp.float32)
        assert _maxerr(fast, slow) < 1e-5


def _quantize_params(bundle):
    from repro.config import QGaLoreConfig
    params = bundle.init_params(jax.random.PRNGKey(0))
    return train_step.prepare_params(params, QGaLoreConfig(rank=8,
                                                           min_dim=16))


class TestModelIntegration:
    def test_fused_equals_simple_on_quantized_params(self):
        """Both grad paths consume INT8 natively and must agree."""
        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qparams = _quantize_params(bundle)
        batch = make_batch(bundle)
        (l1, _), g1 = jax.jit(lambda p, b: stack.simple_value_and_grad(
            bundle, p, b))(qparams, batch)
        (l2, _), g2 = jax.jit(lambda p, b: stack.fused_value_and_grad(
            bundle, p, b, {}))(qparams, batch)
        assert abs(float(l1) - float(l2)) < 1e-4 * max(abs(float(l1)), 1.0)
        flat1 = jax.tree_util.tree_flatten_with_path(g1)[0]
        flat2 = {jax.tree_util.keystr(p): l for p, l in
                 jax.tree_util.tree_flatten_with_path(g2)[0]}
        for path, leaf in flat1:
            key = jax.tree_util.keystr(path)
            err = _maxerr(flat2[key], leaf)
            assert err < 5e-3, f"{key}: {err}"

    def test_quantized_grads_match_dequant_reference(self):
        """Grads through quantized_dense == grads of the materialize
        fallback w.r.t. the same virtual weights."""
        bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                      dtype=jnp.float32)
        qparams = _quantize_params(bundle)
        batch = make_batch(bundle)
        (_, _), g_fast = jax.jit(lambda p, b: stack.simple_value_and_grad(
            bundle, p, b))(qparams, batch)
        try:
            layers.QUANTIZED_DENSE = False
            (_, _), g_ref = jax.jit(lambda p, b: stack.simple_value_and_grad(
                bundle, p, b))(qparams, batch)
        finally:
            layers.QUANTIZED_DENSE = True
        flat_ref = {jax.tree_util.keystr(p): l for p, l in
                    jax.tree_util.tree_flatten_with_path(g_ref)[0]}
        for path, leaf in jax.tree_util.tree_flatten_with_path(g_fast)[0]:
            key = jax.tree_util.keystr(path)
            err = _maxerr(leaf, flat_ref[key])
            assert err < 5e-3, f"{key}: {err}"

    @pytest.mark.parametrize("arch", ["zamba2-2.7b", "xlstm-125m",
                                      "qwen3-moe-30b-a3b",
                                      "seamless-m4t-medium",
                                      "deepseek-v3-671b"])
    def test_quantized_families_train_and_serve(self, arch):
        """Every arch family must consume INT8 params natively: stacked
        per-layer vectors (conv_b, dt_bias, A_log, D, gate_bias, norms)
        arrive quantized too — regression for raw-leaf consumption after
        the per-layer tree_dequantize was removed."""
        bundle = model_zoo.build_arch(arch, smoke=True, dtype=jnp.float32)
        qparams = _quantize_params(bundle)
        batch = make_batch(bundle)
        (loss, _), grads = jax.jit(lambda p, b: stack.fused_value_and_grad(
            bundle, p, b, {}))(qparams, batch)
        assert np.isfinite(float(loss))
        for leaf in jax.tree_util.tree_leaves(grads):
            assert np.isfinite(np.asarray(leaf)).all()

        tokens = batch["tokens"]
        prompt = max(tokens.shape[1] // 2, 2)
        b0 = dict(batch)
        b0["tokens"] = tokens[:, :prompt]
        if "labels" in b0:
            b0["labels"] = b0["labels"][:, :prompt]
        prefill = jax.jit(engine.build_prefill(
            bundle, max_len=tokens.shape[1] + 2))
        decode = jax.jit(engine.build_decode(bundle))
        logits, state = prefill(qparams, b0)
        assert np.isfinite(np.asarray(logits)).all()
        logits, _ = decode(qparams, state, tokens[:, prompt: prompt + 1])
        assert np.isfinite(np.asarray(logits)).all()

    @pytest.mark.parametrize("arch", ["llama-60m", "gemma-7b"])
    def test_serve_quantized_logits_parity(self, arch):
        """Prefill + teacher-forced decode on INT8 params reproduces the
        full-forward logits (same quantized params, no per-token dequant);
        gemma-7b covers the tied-embedding head + quantized embed lookup."""
        bundle = model_zoo.build_arch(arch, smoke=True, dtype=jnp.float32)
        qparams = _quantize_params(bundle)
        from repro.config import ShapeCell
        cell = ShapeCell("t", seq_len=12, global_batch=2, kind="train")
        batch = make_batch(bundle, cell)
        tokens = batch["tokens"]
        S = tokens.shape[1]
        prompt = 6

        def full_last_logits(upto):
            b = dict(batch)
            b["tokens"] = tokens[:, :upto]
            if "labels" in b:
                b["labels"] = b["labels"][:, :upto]
            carry, ctx = bundle.embed(qparams, b)
            carry = base.run_segments(bundle, qparams, carry, ctx)
            return bundle.head_logits(qparams, carry)[:, -1, :]

        b0 = dict(batch)
        b0["tokens"] = tokens[:, :prompt]
        if "labels" in b0:
            b0["labels"] = b0["labels"][:, :prompt]
        prefill = jax.jit(engine.build_prefill(bundle, max_len=S + 2))
        decode = jax.jit(engine.build_decode(bundle))
        logits, state = prefill(qparams, b0)
        assert _maxerr(logits[:, -1, :], full_last_logits(prompt)) < 2e-3
        for t in range(prompt, S):
            logits, state = decode(qparams, state, tokens[:, t: t + 1])
            err = _maxerr(logits[:, -1, :], full_last_logits(t + 1))
            assert err < 5e-3, f"{arch} step {t}: {err}"
