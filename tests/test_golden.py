"""Golden-trajectory regression harness.

A fixed-seed 40-step llama-60m (smoke) Q-GaLore run is pinned by a committed
fixture (``tests/golden/llama60m_qgalore_40steps.json``):

* the full loss curve, compared under a tolerance band — kernel or refactor
  PRs cannot silently drift numerics past ``LOSS_RTOL/ATOL`` at any step;
* the per-layer SVD counts and final adaptive intervals, compared EXACTLY —
  the layer-adaptive lazy-update schedule (paper §3.2) is host-side integer
  state, so any change to the similarity computation or controller logic
  that flips a refresh decision fails loudly even when the losses stay in
  band.

Regenerate after an *intentional* numerics change with:

    REPRO_REGEN_GOLDEN=1 PYTHONPATH=src python -m pytest \
        tests/test_golden.py -q

and commit the updated fixture alongside the change that explains it.

The exact 1-device vs N-device ``dp_compress`` parity companion lives in
``tests/test_distributed.py::test_dp_compress_parity_1dev_vs_8dev`` (it
needs a forced multi-device subprocess).
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

GOLDEN_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          "golden")
FIXTURE = os.path.join(GOLDEN_DIR, "llama60m_qgalore_40steps.json")
STEPS = 40
LOSS_RTOL = 2e-3
LOSS_ATOL = 2e-3


def build_trainer() -> Trainer:
    """The pinned configuration. Any change here invalidates the fixture —
    bump the fixture's "config" stamp when you touch it."""
    bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                  dtype=jnp.float32)
    qcfg = preset("qgalore", QGaLoreConfig(
        rank=8, min_dim=32, update_interval=4, adaptive_k=1,
        cos_threshold=0.3))
    tcfg = TrainConfig(
        seed=0, global_batch=4, seq_len=32, steps=STEPS,
        learning_rate=1e-2, warmup_steps=2, grad_clip=1.0, log_every=0,
        async_checkpoint=False)
    cell = ShapeCell("golden", 32, 4, "train")
    return Trainer(bundle, tcfg, qcfg, cell=cell, impl="fused",
                   param_dtype=jnp.float32)


def run_trajectory() -> dict:
    tr = build_trainer()
    hist = tr.run()
    return {
        "config": "llama-60m smoke / qgalore r8 / seed 0 / 40 steps",
        "losses": [float(h["loss"]) for h in hist],
        "svd_counts": tr.controller.svd_count_summary(),
        "intervals": tr.controller.interval_summary(),
        "total_svd": tr.controller.total_svd_count(),
        "baseline_svd": tr.controller.baseline_svd_count(STEPS),
    }


def test_golden_trajectory():
    got = run_trajectory()
    if os.environ.get("REPRO_REGEN_GOLDEN") == "1":
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        with open(FIXTURE, "w") as f:
            json.dump(got, f, indent=2, sort_keys=True)
        pytest.skip(f"regenerated {FIXTURE}")
    assert os.path.exists(FIXTURE), (
        "golden fixture missing — run REPRO_REGEN_GOLDEN=1 pytest "
        "tests/test_golden.py and commit it")
    with open(FIXTURE) as f:
        want = json.load(f)
    assert got["config"] == want["config"]
    np.testing.assert_allclose(
        got["losses"], want["losses"], rtol=LOSS_RTOL, atol=LOSS_ATOL,
        err_msg="loss trajectory drifted out of the golden band — if the "
                "numerics change is intentional, regenerate the fixture "
                "(see module docstring)")
    assert got["svd_counts"] == want["svd_counts"], (
        "per-layer SVD counts changed — the adaptive lazy-update schedule "
        "took different refresh decisions than the golden run")
    assert got["intervals"] == want["intervals"]
    assert got["total_svd"] == want["total_svd"]
    # the adaptive controller must actually have saved work vs fixed-T
    assert got["total_svd"] <= got["baseline_svd"]
