"""Param-group rules + transform-chain tests: resolution ordering, frozen
groups, per-group recipes, bit-parity of the chain vs the fused monolith,
and the group-aware adaptive controller."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import QGaLoreConfig, replace
from repro.core import adaptive, qgalore, quant, transform
from repro.core.optimizers import preset, preset_rules
from repro.core.rules import (DEFAULT_GROUP, ParamGroup, ParamRules,
                              as_rules, normalize_path)


def _toy_params(quantized=True):
    key = jax.random.PRNGKey(0)
    params = {
        "blocks": {
            "w1": jax.random.normal(key, (3, 256, 128)) * 0.02,
            "w2": jax.random.normal(jax.random.fold_in(key, 1),
                                    (128, 256)) * 0.02,
            "norm": jnp.ones((128,)),
        },
        "embed": jax.random.normal(jax.random.fold_in(key, 2),
                                   (512, 128)) * 0.02,
    }
    if quantized:
        params = quant.tree_quantize(
            params, bits=8, symmetric=True,
            predicate=lambda p, l: l.ndim >= 2)
    return params


class TestRulesResolution:
    def test_first_match_wins(self):
        rules = ParamRules(groups=(
            ParamGroup("a", pattern=r"w1"),
            ParamGroup("b", pattern=r"blocks"),   # also matches w1's path
        ))
        assert rules.resolve("['blocks']['w1']").name == "a"
        assert rules.resolve("['blocks']['w2']").name == "b"

    def test_pattern_miss_falls_to_default(self):
        rules = ParamRules(groups=(ParamGroup("a", pattern=r"nomatch"),))
        g = rules.resolve("['blocks']['w1']")
        assert g is DEFAULT_GROUP and g.name == "default"
        assert not g.frozen and g.lr_scale == 1.0

    def test_normalized_path_grammar(self):
        # both the keystr and the /a/b/c grammar match
        assert normalize_path("['seg0_dense']['attn']['wq']") == \
            "/seg0_dense/attn/wq"
        g = ParamGroup("x", pattern=r"/seg0_dense/attn/wq")
        assert g.matches("['seg0_dense']['attn']['wq']")

    def test_overrides_and_inherit(self):
        base = QGaLoreConfig(rank=128, scale=0.25)
        g = ParamGroup("x", rank=16)
        eff = g.apply_to(base)
        assert eff.rank == 16 and eff.scale == 0.25
        # no overrides -> the base object itself (no spurious copies)
        assert ParamGroup("y").apply_to(base) is base

    def test_as_rules_normalization(self):
        cfg = QGaLoreConfig()
        rules = as_rules(cfg)
        assert rules.base is cfg and rules.groups == ()
        assert as_rules(rules) is rules
        with pytest.raises(TypeError):
            as_rules("qgalore")

    def test_fingerprint_tracks_rule_changes(self):
        r1 = ParamRules(groups=(ParamGroup("a", pattern="w1"),))
        r2 = ParamRules(groups=(ParamGroup("a", pattern="w1", rank=4),))
        assert r1.fingerprint() != r2.fingerprint()
        assert r1.fingerprint() == ParamRules(
            groups=(ParamGroup("a", pattern="w1"),)).fingerprint()

    def test_fingerprint_ignores_strategy_and_recipe_knobs(self):
        """Only STATE-STRUCTURAL fields participate: toggling execution
        strategy (fused/batch/compress/dist_refresh) or non-structural
        recipe knobs (scale, intervals, SR, lr_scale) must never refuse a
        checkpoint resume."""
        base = QGaLoreConfig()
        fp = ParamRules(base=base).fingerprint()
        for kw in (dict(fused_update=False), dict(batch_leaves=False),
                   dict(compress_dp_grads=True), dict(dist_refresh=False),
                   dict(scale=0.5), dict(update_interval=7),
                   dict(stochastic_rounding=False), dict(weight_decay=0.1)):
            assert ParamRules(base=replace(base, **kw)).fingerprint() \
                == fp, kw
        # structural changes DO flip it
        for kw in (dict(rank=7), dict(weight_bits=0), dict(adam_bits=32),
                   dict(min_dim=16)):
            assert ParamRules(base=replace(base, **kw)).fingerprint() \
                != fp, kw
        # group lr_scale is non-structural; frozen is structural
        assert ParamRules(groups=(ParamGroup("a", pattern="w1",
                                             lr_scale=0.5),)).fingerprint() \
            == ParamRules(groups=(ParamGroup("a",
                                             pattern="w1"),)).fingerprint()
        assert ParamRules(groups=(ParamGroup("a", pattern="w1",
                                             frozen=True),)).fingerprint() \
            != ParamRules(groups=(ParamGroup("a",
                                             pattern="w1"),)).fingerprint()

    def test_preset_rules_matches_preset(self):
        for name in ("full", "adam8bit", "galore", "qgalore"):
            assert preset_rules(name).base == preset(name)
        assert preset_rules("qgalore").groups == ()


class TestGroupAwareSpecs:
    def test_per_group_rank_and_interval(self):
        rules = ParamRules(
            base=QGaLoreConfig(rank=16, min_dim=64, update_interval=200),
            groups=(ParamGroup("hot", pattern=r"w1", rank=4,
                               update_interval=50),))
        specs = qgalore.leaf_specs(_toy_params(), rules)
        w1 = next(s for s in specs if "w1" in s.path)
        w2 = next(s for s in specs if "w2" in s.path)
        assert w1.rank == 4 and w1.cfg.update_interval == 50
        assert w2.rank == 16 and w2.cfg.update_interval == 200
        assert w1.group == "hot" and w2.group == "default"

    def test_frozen_group_not_galore(self):
        rules = ParamRules(
            base=QGaLoreConfig(rank=16, min_dim=64),
            groups=(ParamGroup("frz", pattern=r"w1", frozen=True),))
        specs = qgalore.leaf_specs(_toy_params(), rules)
        w1 = next(s for s in specs if "w1" in s.path)
        assert w1.frozen and not w1.galore and w1.rank == 0

    def test_group_galore_disable(self):
        rules = ParamRules(
            base=QGaLoreConfig(rank=16, min_dim=64),
            groups=(ParamGroup("plain", pattern=r"w1", enabled=False),))
        specs = qgalore.leaf_specs(_toy_params(), rules)
        w1 = next(s for s in specs if "w1" in s.path)
        assert not w1.galore and not w1.frozen


class TestGroupAwareOptimizer:
    def _setup(self, rules):
        params = _toy_params()
        specs = qgalore.leaf_specs(params, rules)
        state = qgalore.init(params, rules, jax.random.PRNGKey(3))
        grads = quant.tree_dequantize(params, jnp.float32)
        return params, specs, state, grads

    def test_frozen_leaves_zero_state_and_passthrough(self):
        rules = ParamRules(
            base=preset("qgalore", QGaLoreConfig(rank=16, min_dim=64)),
            groups=(ParamGroup("frz", pattern=r"embed|w2", frozen=True),))
        params, specs, state, grads = self._setup(rules)
        inner = jax.tree_util.tree_flatten(
            state.inner, is_leaf=qgalore._is_inner_leaf)[0]
        proj = jax.tree_util.tree_flatten(
            state.proj,
            is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]
        for i, s in enumerate(specs):
            if s.frozen:
                assert inner[i] is None and proj[i] is None
        new_p, new_s, _ = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=rules, specs=specs))(
            params, grads, state, lr=1e-2, rng=jax.random.PRNGKey(0))
        for name in ("embed",):
            a = quant.dequantize(params[name])
            b = quant.dequantize(new_p[name])
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # trainable leaves moved
        w1a = quant.dequantize(params["blocks"]["w1"])
        w1b = quant.dequantize(new_p["blocks"]["w1"])
        assert float(jnp.abs(w1a - w1b).max()) > 0

    def test_default_rules_bit_identical_to_plain_config(self):
        cfg = preset("qgalore", QGaLoreConfig(rank=16, min_dim=64))
        params, specs_c, state_c, grads = self._setup(as_rules(cfg))
        _, specs_r, state_r, _ = self._setup(ParamRules(base=cfg))
        rng = jax.random.PRNGKey(5)
        pa, sa, _ = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=cfg, specs=specs_c))(
            params, grads, state_c, lr=1e-2, rng=rng)
        pb, sb, _ = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=ParamRules(base=cfg),
            specs=specs_r))(params, grads, state_r, lr=1e-2, rng=rng)
        for a, b in zip(jax.tree_util.tree_leaves((pa, sa)),
                        jax.tree_util.tree_leaves((pb, sb))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_lr_scale_group(self):
        # fp weights (galore preset) so a zero effective lr leaves the
        # leaf EXACTLY unchanged (no requantization involved)
        base = preset("galore", QGaLoreConfig(rank=16, min_dim=64))
        r_full = ParamRules(base=base)
        r_slow = ParamRules(base=base, groups=(
            ParamGroup("slow", pattern=r"w1", lr_scale=0.0),))
        params = _toy_params(quantized=False)
        grads = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32),
                                       params)
        rng = jax.random.PRNGKey(9)
        outs = {}
        for name, rules in (("full", r_full), ("slow", r_slow)):
            specs = qgalore.leaf_specs(params, rules)
            state = qgalore.init(params, rules, jax.random.PRNGKey(3))
            outs[name], _, _ = jax.jit(functools.partial(
                qgalore.apply_updates, cfg=rules, specs=specs))(
                params, grads, state, lr=1e-2, rng=rng)
        # lr_scale=0 -> w1 exactly unchanged; unit scale moved it
        np.testing.assert_array_equal(
            np.asarray(outs["slow"]["blocks"]["w1"]),
            np.asarray(params["blocks"]["w1"]))
        assert np.abs(np.asarray(outs["full"]["blocks"]["w1"])
                      - np.asarray(params["blocks"]["w1"])).max() > 0
        # other leaves identical between the two rule-sets
        np.testing.assert_array_equal(
            np.asarray(outs["full"]["blocks"]["w2"]),
            np.asarray(outs["slow"]["blocks"]["w2"]))

    def test_memory_report_frozen_zero_opt_bytes(self):
        params = _toy_params()
        base = preset("qgalore", QGaLoreConfig(rank=16, min_dim=64))
        all_frozen = ParamRules(base=base, groups=(
            ParamGroup("frz", pattern="", frozen=True),))
        rep_all = qgalore.memory_report(params, base)
        rep_frz = qgalore.memory_report(params, all_frozen)
        assert rep_frz["optimizer_gb"] == 0.0
        assert rep_frz["weights_gb"] == rep_all["weights_gb"]
        assert rep_frz["total_gb"] < rep_all["total_gb"]


class TestTransformParity:
    """The stage-by-stage chain is bit-identical to the monolith with the
    fusion/batching strategy flags off — the chain IS the optimizer, the
    monolith is its fused executor."""

    def _cfg(self, **kw):
        return preset("qgalore", QGaLoreConfig(
            rank=8, min_dim=64, fused_update=False, batch_leaves=False,
            **kw))

    @pytest.mark.parametrize("refresh", [False, True])
    def test_reference_chain_matches_monolith(self, refresh):
        cfg = self._cfg()
        params = _toy_params()
        specs = qgalore.leaf_specs(params, cfg)
        grads = quant.tree_dequantize(params, jnp.float32)
        state = qgalore.init(params, cfg, jax.random.PRNGKey(1))
        tx = transform.qgalore_reference_chain(cfg)
        cst = tx.init(params, jax.random.PRNGKey(1))
        masks = {i: jnp.ones((s.nbatch,), bool)
                 for i, s in enumerate(specs) if s.galore} if refresh \
            else None
        rng = jax.random.PRNGKey(7)
        pa, sa, ma = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=cfg, specs=specs,
            refresh=refresh))(params, grads, state, lr=1e-2, rng=rng,
                              refresh_masks=masks)
        pb, sb, mb = jax.jit(functools.partial(
            tx.update, specs=specs, refresh=refresh))(
            grads, cst, params, lr=1e-2, rng=rng, refresh_masks=masks)
        for a, b in zip(jax.tree_util.tree_leaves(pa),
                        jax.tree_util.tree_leaves(pb)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # stage states: project's P == state.proj; adam's == state.inner
        for a, b in zip(jax.tree_util.tree_leaves(sa.proj),
                        jax.tree_util.tree_leaves(sb.stages[0])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(sa.inner),
                        jax.tree_util.tree_leaves(sb.stages[1])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        if refresh:
            assert set(ma["sims"]) == set(mb["sims"])
            for k in ma["sims"]:
                np.testing.assert_array_equal(np.asarray(ma["sims"][k]),
                                              np.asarray(mb["sims"][k]))

    def test_canonical_transform_is_fused_executor(self):
        cfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=64))
        params = _toy_params()
        specs = qgalore.leaf_specs(params, cfg)
        grads = quant.tree_dequantize(params, jnp.float32)
        tx = transform.qgalore_transform(cfg, specs=specs)
        state = tx.init(params, jax.random.PRNGKey(1))
        assert isinstance(state, qgalore.QGaLoreState)
        rng = jax.random.PRNGKey(7)
        pa, sa, _ = jax.jit(functools.partial(
            qgalore.apply_updates, cfg=cfg, specs=specs))(
            params, grads, state, lr=1e-2, rng=rng)
        pb, sb, _ = jax.jit(functools.partial(tx.update, specs=specs))(
            grads, state, params, lr=1e-2, rng=rng)
        for a, b in zip(jax.tree_util.tree_leaves((pa, sa)),
                        jax.tree_util.tree_leaves((pb, sb))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_chain_with_clip_and_weight_decay_stages(self):
        cfg = self._cfg()
        rules = as_rules(cfg)
        params = _toy_params()
        grads = quant.tree_dequantize(params, jnp.float32)
        tx = transform.chain(
            transform.clip_global_norm(1.0),
            transform.project(rules),
            transform.quantized_adam(rules),
            transform.backproject(rules),
            transform.add_weight_decay(0.01),
            transform.sr_requant(rules))
        state = tx.init(params, jax.random.PRNGKey(0))
        new_p, new_s, metrics = jax.jit(tx.update)(
            grads, state, params, lr=1e-3, rng=jax.random.PRNGKey(2))
        assert "grad_norm" in metrics
        for leaf in jax.tree_util.tree_leaves(
                quant.tree_dequantize(new_p)):
            assert np.isfinite(np.asarray(leaf)).all()
        assert int(new_s.count) == 1

    def test_clip_excludes_frozen(self):
        base = preset("qgalore", QGaLoreConfig(rank=8, min_dim=64))
        rules = ParamRules(base=base, groups=(
            ParamGroup("frz", pattern=r"embed", frozen=True),))
        params = _toy_params()
        specs = qgalore.leaf_specs(params, rules)
        grads = quant.tree_dequantize(params, jnp.float32)
        # inflate the frozen leaf's grad: must not affect the clip norm
        grads["embed"] = grads["embed"] + 1e3
        _, norm_f = transform.clip_by_global_norm(grads, 1.0, specs=specs)
        specs_plain = qgalore.leaf_specs(params, base)
        _, norm_p = transform.clip_by_global_norm(grads, 1.0,
                                                  specs=specs_plain)
        assert float(norm_f) < float(norm_p)
        clipped, _ = transform.clip_by_global_norm(grads, 1.0, specs=specs)
        np.testing.assert_array_equal(np.asarray(clipped["embed"]),
                                      np.asarray(grads["embed"]))


class TestPerGroupController:
    def test_per_group_intervals(self):
        params = _toy_params()
        rules = ParamRules(
            base=QGaLoreConfig(rank=16, min_dim=64, update_interval=10,
                               adaptive=False),
            groups=(ParamGroup("hot", pattern=r"w1", update_interval=5),))
        specs = qgalore.leaf_specs(params, rules)
        ctrl = adaptive.SubspaceController(specs, rules)
        hot = next(i for i, s in enumerate(specs) if "w1" in s.path)
        cold = next(i for i, s in enumerate(specs)
                    if s.galore and i != hot)

        refresh_steps = {hot: [], cold: []}
        for step in range(20):
            masks = ctrl.masks_for_step(step)
            if masks:
                sims = {specs[i].path: np.full((specs[i].nbatch,), 0.9)
                        for i in masks}
                for i in masks:
                    refresh_steps[i].append(step)
                ctrl.observe(step, masks, sims)
        assert refresh_steps[hot] == [0, 5, 10, 15]
        assert refresh_steps[cold] == [0, 10]

    def test_per_group_adaptive_doubling(self):
        params = _toy_params()
        rules = ParamRules(
            base=preset("qgalore", QGaLoreConfig(
                rank=16, min_dim=64, update_interval=10, adaptive=True,
                adaptive_k=1, cos_threshold=0.4)),
            groups=(ParamGroup("noadapt", pattern=r"w1", adaptive=False),))
        specs = qgalore.leaf_specs(params, rules)
        ctrl = adaptive.SubspaceController(specs, rules)
        for step in (0, 10, 20):
            masks = ctrl.masks_for_step(step)
            sims = {specs[i].path: np.full((specs[i].nbatch,), 0.95)
                    for i in masks}
            ctrl.observe(step, masks, sims)
        summary = ctrl.interval_summary()
        w1_path = next(s.path for s in specs if "w1" in s.path)
        w2_path = next(s.path for s in specs
                       if s.galore and "w1" not in s.path)
        assert all(iv == 10 for iv in summary[w1_path])     # adaptive off
        assert all(iv > 10 for iv in summary[w2_path])      # doubled

    def test_baseline_svd_count_per_group(self):
        params = _toy_params()
        rules = ParamRules(
            base=preset("qgalore", QGaLoreConfig(
                rank=16, min_dim=64, update_interval=10)),
            groups=(ParamGroup("hot", pattern=r"w1", update_interval=5),))
        specs = qgalore.leaf_specs(params, rules)
        ctrl = adaptive.SubspaceController(specs, rules)
        hot_units = sum(len(us) for i, us in ctrl.units.items()
                        if "w1" in specs[i].path)
        cold_units = sum(len(us) for i, us in ctrl.units.items()
                         if "w1" not in specs[i].path)
        want = hot_units * (1 + 19 // 5) + cold_units * (1 + 19 // 10)
        assert ctrl.baseline_svd_count(20) == want
