"""Pallas TPU kernel: fused stochastic-rounding weight update (paper §3.4).

One HBM pass instead of four: read INT8 weight tile + scales + BF16/F32
update tile, dequantize in VMEM, add, recompute the per-block absmax scale,
stochastically round, write INT8 codes + new scales. The eager-PyTorch
version streams W twice (dequant, requant) plus the update and the randoms;
this kernel streams each exactly once — the op is purely memory-bound so the
fusion IS the speedup (~4× traffic reduction at 1 byte/weight).

The uniform randoms are supplied as an input (generated with jax.random
outside; on real TPU pltpu.prng_random_bits would generate in-kernel and
remove that stream too — kept as an input for interpret-mode parity).

Block layout: rows × 256-column groups, matching the training QTensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, s_ref, upd_ref, u_ref, qo_ref, so_ref, *, block: int):
    q = q_ref[...].astype(jnp.float32)               # (BR, BC)
    s = s_ref[...]                                   # (BR, BC // block)
    BR, BC = q.shape
    nb = BC // block
    w = (q.reshape(BR, nb, block) * s[..., None])
    w = w + upd_ref[...].astype(jnp.float32).reshape(BR, nb, block)
    absmax = jnp.max(jnp.abs(w), axis=-1)            # (BR, nb)
    new_s = jnp.maximum(absmax / 127.0, 1e-12)
    t = w / new_s[..., None]
    codes = jnp.floor(t + u_ref[...].reshape(BR, nb, block))
    codes = jnp.clip(codes, -128, 127)
    qo_ref[...] = codes.reshape(BR, BC).astype(jnp.int8)
    so_ref[...] = new_s


@functools.partial(jax.jit,
                   static_argnames=("block", "br", "bc", "interpret"))
def sr_requant(q, scale, update, u01, *, block: int = 256, br: int = 256,
               bc: int = 512, interpret: bool = True):
    """Fused W' = SR_quant(deq(W) + update).

    q (R,C) int8; scale (R, C/block) f32; update/u01 (R,C).
    Returns (q' int8, scale' f32)."""
    R, C = q.shape
    assert C % block == 0 and bc % block == 0
    br, bc = min(br, R), min(bc, C)
    grid = (R // br, C // bc)
    return pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc // block), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc // block), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((R, C // block), jnp.float32),
        ],
        interpret=interpret,
    )(q, scale, update, u01)
