"""Pallas kernels for the Q-GaLore hot paths, plus backend dispatch.

Modules:
  * ``ops``       — public wrappers (padding, QTensor plumbing, backend
                    selection). Import this, not the kernels directly.
  * ``dispatch``  — backend registry (pallas-tpu / pallas-interpret / ref),
                    platform detection, block-size autotune table.
  * ``ref``       — pure-jnp oracles for every kernel (allclose targets
                    and the fast XLA backend off-TPU).
  * ``fused_update``, ``int4_matmul``, ``int8_matmul``, ``sr_requant``,
    ``blockwise_quant``, ``flash_attention`` — the Pallas kernels.

See docs/kernels.md for each kernel's contract and block-size knobs.
"""
