"""Pallas TPU kernel: the fused Q-GaLore per-step weight update.

One kernel replaces the three-op hot path (INT4 dequant-project →
low-rank Adam → SR requant). Per weight tile it:

1. updates the low-rank Adam moments ``m, v`` from the low-rank gradient
   and forms the bias-corrected direction (paper's 8-bit Adam math, moments
   handled in f32 here — the wrapper (de)quantizes 8-bit moment state),
2. dequantizes the INT4 projection ``P`` in VMEM (nibble unpack on the
   VPU, asymmetric per-block scale/zero — P never exists in HBM above
   4 bits + scales),
3. back-projects the direction to full rank on the MXU,
4. dequantizes the INT8 weight tile, applies ``w - lr * upd`` (plus
   optional weight decay), recomputes per-block absmax scales, and
   stochastically rounds back to INT8.

The full-rank f32 update/weight transients live only in VMEM — they never
round-trip HBM, which is the bulk of the speedup: the op is memory-bound
and the unfused path streams the (m, n) f32 intermediate to HBM twice.
The uniform SR randoms remain a full-rank input stream (generated with
jax.random outside, as in sr_requant.py, for interpret-mode parity; on
real TPU pltpu.prng_random_bits seeded per program would generate them
in-kernel and remove that stream too).

Orientation (GaLore side convention):

* ``side="right"`` (m ≥ n): W (M, N), low-rank L/moments (M, r),
  P (N, r). Grid tiles rows: each program owns a (BM, N) weight stripe,
  its (BM, r) moment rows, and the whole packed P.
* ``side="left"`` (m < n): W (M, N), low-rank L/moments (r, N),
  P (M, r). Grid tiles columns: each program owns a (M, BN) weight
  stripe and its (r, BN) moment columns.

Either way every moment element is owned by exactly one program — no
redundant Adam math, no write races.

``lr`` and ``count`` (the 1-based step, for bias correction) are traced
scalars passed as (1, 1) arrays.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import unpack_int4


def _adam(g, m, v, c, *, beta1, beta2, eps):
    """f32 Adam moment update + bias-corrected direction."""
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * (g * g)
    m_hat = m_new / (1.0 - beta1 ** c)
    v_hat = v_new / (1.0 - beta2 ** c)
    return m_new, v_new, m_hat / (jnp.sqrt(v_hat) + eps)


def _dequant_p(packed, s, z, pblock):
    """(d, r//2) packed nibbles + (d, r//pblock) scale/zero → (d, r) f32.

    ``unpack_int4`` is pure jnp (VPU bitwise ops), so it runs inside the
    kernel body — one source of truth for the nibble convention."""
    u = unpack_int4(packed).astype(jnp.float32) - 8.0   # qmin = -8
    d, r = u.shape
    return ((u.reshape(d, r // pblock, pblock) - z[..., None])
            * s[..., None]).reshape(d, r)


def _sr_requant(w, u, wblock):
    """w (R, C) f32 → (int8 codes (R, C), scales (R, C//wblock))."""
    R, C = w.shape
    nb = C // wblock
    wb = w.reshape(R, nb, wblock)
    absmax = jnp.max(jnp.abs(wb), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    codes = jnp.floor(wb / scale[..., None] + u.reshape(R, nb, wblock))
    return (jnp.clip(codes, -128, 127).reshape(R, C).astype(jnp.int8),
            scale)


def _deq_w(q, s, wblock):
    R, C = q.shape
    return (q.astype(jnp.float32).reshape(R, C // wblock, wblock)
            * s[..., None]).reshape(R, C)


def _kernel_right(g_ref, m_ref, v_ref, p_ref, ps_ref, pz_ref, q_ref, ws_ref,
                  u_ref, c_ref, lr_ref, qo_ref, so_ref, mo_ref, vo_ref, *,
                  pblock: int, wblock: int, beta1: float, beta2: float,
                  eps: float, gscale: float, wd: float):
    c = c_ref[0, 0]
    lr = lr_ref[0, 0]
    m_new, v_new, dirn = _adam(
        g_ref[...].astype(jnp.float32), m_ref[...], v_ref[...], c,
        beta1=beta1, beta2=beta2, eps=eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new

    P = _dequant_p(p_ref[...], ps_ref[...], pz_ref[...], pblock)  # (N, r)
    upd = gscale * jax.lax.dot_general(
        dirn, P, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (BM, N)

    w = _deq_w(q_ref[...], ws_ref[...], wblock)
    if wd:
        upd = upd + wd * w
    codes, scale = _sr_requant(w - lr * upd, u_ref[...], wblock)
    qo_ref[...] = codes
    so_ref[...] = scale


def _kernel_left(g_ref, m_ref, v_ref, p_ref, ps_ref, pz_ref, q_ref, ws_ref,
                 u_ref, c_ref, lr_ref, qo_ref, so_ref, mo_ref, vo_ref, *,
                 pblock: int, wblock: int, beta1: float, beta2: float,
                 eps: float, gscale: float, wd: float):
    c = c_ref[0, 0]
    lr = lr_ref[0, 0]
    m_new, v_new, dirn = _adam(
        g_ref[...].astype(jnp.float32), m_ref[...], v_ref[...], c,
        beta1=beta1, beta2=beta2, eps=eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new

    P = _dequant_p(p_ref[...], ps_ref[...], pz_ref[...], pblock)  # (M, r)
    upd = gscale * jax.lax.dot_general(
        P, dirn, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                       # (M, BN)

    w = _deq_w(q_ref[...], ws_ref[...], wblock)
    if wd:
        upd = upd + wd * w
    codes, scale = _sr_requant(w - lr * upd, u_ref[...], wblock)
    qo_ref[...] = codes
    so_ref[...] = scale


@functools.partial(
    jax.jit,
    static_argnames=("side", "pblock", "wblock", "beta1", "beta2", "eps",
                     "gscale", "wd", "bm", "bn", "interpret"))
def fused_qgalore_update(g, m, v, p_packed, p_scale, p_zero, q, wscale, u01,
                         count, lr, *, side: str, pblock: int, wblock: int,
                         beta1: float = 0.9, beta2: float = 0.999,
                         eps: float = 1e-8, gscale: float = 0.25,
                         wd: float = 0.0, bm: int = 256, bn: int = 512,
                         interpret: bool = True):
    """Fused low-rank-Adam + back-projection + SR weight update.

    All arrays pre-padded to tile boundaries by the caller
    (:func:`repro.kernels.ops.fused_qgalore_update` does this):

    side="right": g/m/v (M, r); P (N, r//2) packed + (N, r//pblock)
    scale/zero; q (M, N) int8 + wscale (M, N//wblock); u01 (M, N);
    M % bm == 0.
    side="left":  g/m/v (r, N); P (M, r//2) packed; q (M, N);
    N % bn == 0 and bn % wblock == 0.

    Returns ``(q', wscale', m', v')``.
    """
    M, N = q.shape
    c2 = jnp.asarray(count, jnp.float32).reshape(1, 1)
    lr2 = jnp.asarray(lr, jnp.float32).reshape(1, 1)
    kw = dict(pblock=pblock, wblock=wblock, beta1=beta1, beta2=beta2,
              eps=eps, gscale=gscale, wd=wd)
    r = g.shape[1] if side == "right" else g.shape[0]
    rh, rp = r // 2, r // pblock
    nb = N // wblock

    if side == "right":
        assert M % bm == 0, (M, bm)
        grid = (M // bm,)
        row = lambda i: (i, 0)
        fixed = lambda i: (0, 0)
        in_specs = [
            pl.BlockSpec((bm, r), row),          # g
            pl.BlockSpec((bm, r), row),          # m
            pl.BlockSpec((bm, r), row),          # v
            pl.BlockSpec((N, rh), fixed),        # packed P
            pl.BlockSpec((N, rp), fixed),        # P scale
            pl.BlockSpec((N, rp), fixed),        # P zero
            pl.BlockSpec((bm, N), row),          # q
            pl.BlockSpec((bm, nb), row),         # wscale
            pl.BlockSpec((bm, N), row),          # u01
            pl.BlockSpec((1, 1), fixed),         # count
            pl.BlockSpec((1, 1), fixed),         # lr
        ]
        out_specs = [
            pl.BlockSpec((bm, N), row),
            pl.BlockSpec((bm, nb), row),
            pl.BlockSpec((bm, r), row),
            pl.BlockSpec((bm, r), row),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M, nb), jnp.float32),
            jax.ShapeDtypeStruct((M, r), jnp.float32),
            jax.ShapeDtypeStruct((M, r), jnp.float32),
        ]
        kernel = functools.partial(_kernel_right, **kw)
    else:
        assert N % bn == 0 and bn % wblock == 0, (N, bn, wblock)
        grid = (N // bn,)
        col = lambda j: (0, j)
        fixed = lambda j: (0, 0)
        in_specs = [
            pl.BlockSpec((r, bn), col),          # g
            pl.BlockSpec((r, bn), col),          # m
            pl.BlockSpec((r, bn), col),          # v
            pl.BlockSpec((M, rh), fixed),        # packed P
            pl.BlockSpec((M, rp), fixed),        # P scale
            pl.BlockSpec((M, rp), fixed),        # P zero
            pl.BlockSpec((M, bn), col),          # q
            pl.BlockSpec((M, bn // wblock), col),
            pl.BlockSpec((M, bn), col),          # u01
            pl.BlockSpec((1, 1), fixed),
            pl.BlockSpec((1, 1), fixed),
        ]
        out_specs = [
            pl.BlockSpec((M, bn), col),
            pl.BlockSpec((M, bn // wblock), col),
            pl.BlockSpec((r, bn), col),
            pl.BlockSpec((r, bn), col),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((M, N), jnp.int8),
            jax.ShapeDtypeStruct((M, nb), jnp.float32),
            jax.ShapeDtypeStruct((r, N), jnp.float32),
            jax.ShapeDtypeStruct((r, N), jnp.float32),
        ]
        kernel = functools.partial(_kernel_left, **kw)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(g, m, v, p_packed, p_scale, p_zero, q, wscale, u01, c2, lr2)
