"""Kernel backend registry, platform detection, and block-size autotuning.

Every compute op in :mod:`repro.kernels` has up to three interchangeable
implementations:

``pallas-tpu``
    The Pallas kernel compiled for the real accelerator
    (``interpret=False``). Fastest path; only valid when
    ``jax.default_backend() == "tpu"``.
``pallas-interpret``
    The same Pallas kernel run through the Pallas interpreter. Bit-faithful
    to the TPU kernel's semantics (used as the correctness harness on CPU
    containers) but orders of magnitude slower than XLA.
``ref``
    The pure-``jnp`` oracle from :mod:`repro.kernels.ref`, jitted by XLA.
    Mathematically identical contract; the fast default off-TPU.

Selection order for :func:`default_backend`:

1. ``REPRO_KERNEL_BACKEND`` env var (one of the names above) — global
   override, useful for A/B benchmarks and CI.
2. ``REPRO_PALLAS_COMPILED=1`` (legacy knob) → ``pallas-tpu``.
3. Platform detection: TPU → ``pallas-tpu``; anything else → ``ref``.

If the requested backend has no registered implementation for an op,
:func:`resolve` walks the fallback chain
``pallas-tpu → pallas-interpret → ref`` so callers never crash on a
partially-implemented op.

Block-size autotune table
-------------------------
:func:`tuned_blocks` returns the block-size kwargs for a (op, shape, dtype,
backend) query. Shapes are bucketed to the next power of two so the table
stays small; exact entries win over bucketed entries, which win over the
per-op defaults.

The table is PERSISTED: entries live in ``autotune_table.json`` next to
this module (override the path with ``REPRO_AUTOTUNE_TABLE``) and are
written by the real sweep in ``benchmarks/autotune_blocks.py`` — run it
with ``REPRO_REGEN_AUTOTUNE=1`` to refresh the committed table in place.
Each entry records its ``source`` ("seed" for the original hand-tuned
values, "measured" for sweep results) so stale guesses are
distinguishable from data. :func:`register_tuned` adds in-process
entries (tests, a live tuner) that win over the file.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

KNOWN_BACKENDS = ("pallas-tpu", "pallas-interpret", "ref")

# op name -> backend name -> callable
_REGISTRY: Dict[str, Dict[str, Callable]] = {}

# Fallback order when the preferred backend is not registered for an op.
_FALLBACK = {
    "pallas-tpu": ("pallas-tpu", "pallas-interpret", "ref"),
    "pallas-interpret": ("pallas-interpret", "ref"),
    "ref": ("ref", "pallas-interpret"),
}


def register(op: str, backend: str):
    """Decorator: register ``fn`` as the ``backend`` implementation of ``op``."""
    assert backend in KNOWN_BACKENDS, backend

    def deco(fn):
        _REGISTRY.setdefault(op, {})[backend] = fn
        return fn

    return deco


@functools.lru_cache(maxsize=None)
def platform() -> str:
    """The JAX default backend platform ("cpu" | "gpu" | "tpu")."""
    return jax.default_backend()


def default_backend(op: Optional[str] = None) -> str:
    """Pick the backend for ``op`` (or globally when ``op`` is None)."""
    env = os.environ.get("REPRO_KERNEL_BACKEND", "")
    if env:
        if env not in KNOWN_BACKENDS:
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r}; expected one of "
                f"{KNOWN_BACKENDS}")
        return env
    if os.environ.get("REPRO_PALLAS_COMPILED", "0") == "1":
        return "pallas-tpu"
    if platform() == "tpu":
        return "pallas-tpu"
    return "ref"


def available_backends(op: str) -> Tuple[str, ...]:
    return tuple(_REGISTRY.get(op, {}))


def resolve(op: str, backend: Optional[str] = None
            ) -> Tuple[str, Callable]:
    """(backend_name, fn) for ``op``, honoring the fallback chain."""
    want = backend or default_backend(op)
    if want not in KNOWN_BACKENDS:
        raise ValueError(
            f"unknown backend {want!r} for op {op!r}; expected one of "
            f"{KNOWN_BACKENDS}")
    impls = _REGISTRY.get(op)
    if not impls:
        raise KeyError(f"no implementations registered for op {op!r}")
    for name in _FALLBACK[want]:
        if name in impls:
            return name, impls[name]
    # last resort: anything registered
    name = next(iter(impls))
    return name, impls[name]


def dispatch(op: str, *args, backend: Optional[str] = None, **kwargs):
    """Call the selected implementation of ``op``."""
    _, fn = resolve(op, backend)
    return fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Block-size autotune table
# ---------------------------------------------------------------------------

def _bucket(n: int) -> int:
    """Round up to the next power of two (shape bucketing key)."""
    b = 1
    while b < n:
        b *= 2
    return b


# Per-op defaults (used when no table entry matches). Values are the
# kwargs forwarded to the Pallas wrapper.
_DEFAULT_BLOCKS: Dict[str, Dict[str, int]] = {
    "int8_matmul": {"bm": 128, "bn": 256, "bk": 512},
    "int8_matmul_t": {"bm": 128, "bn": 512, "bk": 256},
    "int4_matmul": {"bm": 128, "bk": 512},
    "sr_requant": {"br": 256, "bc": 512},
    "blockwise_quant": {"br": 256, "bc": 512},
    "fused_qgalore_update": {"bm": 256, "bn": 512},
    "flash_attention": {"bq": 128, "bkv": 128},
}

# -- persisted autotune table ------------------------------------------------
#
# Entries are keyed (op, backend, bucketed shape, dtype) -> block kwargs
# (dtype "" matches any dtype). They live in autotune_table.json next to
# this module; benchmarks/autotune_blocks.py measures and rewrites it.
# _RUNTIME_TABLE holds in-process registrations (register_tuned) that win
# over the file.

_Key = Tuple[str, str, Tuple[int, ...], str]

_TABLE_ENV = "REPRO_AUTOTUNE_TABLE"
_TABLE_FILE = os.path.join(os.path.dirname(__file__), "autotune_table.json")

_RUNTIME_TABLE: Dict[_Key, Dict[str, int]] = {}


def table_path() -> str:
    return os.environ.get(_TABLE_ENV) or _TABLE_FILE


def _entry_key(e: Dict[str, Any]) -> _Key:
    return (e["op"], e["backend"], tuple(int(d) for d in e["shape"]),
            e.get("dtype", ""))


@functools.lru_cache(maxsize=8)
def _load_table(path: str) -> Dict[_Key, Dict[str, int]]:
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        doc = json.load(f)
    table: Dict[_Key, Dict[str, int]] = {}
    for e in doc.get("entries", ()):
        table[_entry_key(e)] = {k: int(v) for k, v in e["blocks"].items()}
    return table


def reload_table() -> None:
    """Drop the cached file table (after a sweep rewrote it, or a test
    pointed REPRO_AUTOTUNE_TABLE elsewhere)."""
    _load_table.cache_clear()


def load_table_entries(path: Optional[str] = None) -> List[Dict[str, Any]]:
    """The raw entry list from the persisted table (sweep merge source)."""
    p = path or table_path()
    if not os.path.exists(p):
        return []
    with open(p) as f:
        return list(json.load(f).get("entries", ()))


def save_table_entries(entries: List[Dict[str, Any]],
                       path: Optional[str] = None) -> str:
    """Write the table document; deduplicates by key (last entry wins)."""
    p = path or table_path()
    merged: Dict[_Key, Dict[str, Any]] = {}
    for e in entries:
        merged[_entry_key(e)] = e
    doc = {"version": 1,
           "entries": [merged[k] for k in sorted(merged)]}
    with open(p, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    reload_table()
    return p


def register_tuned(op: str, backend: str, shape: Tuple[int, ...],
                   blocks: Dict[str, int], dtype: str = "") -> None:
    """In-process table entry (wins over the persisted file). ``shape`` is
    bucketed here, so callers pass the raw problem shape."""
    key = (op, backend, tuple(_bucket(int(d)) for d in shape), dtype)
    _RUNTIME_TABLE[key] = dict(blocks)


def fit_block(dim: int, request: int, multiple_of: int = 1) -> int:
    """Largest tile ≤ ``request`` that divides ``dim`` (and is a multiple
    of ``multiple_of``), falling back to ``dim`` itself.

    The Pallas kernels floor-divide their grids (``grid = dim // tile``)
    without asserting divisibility, so a table/tuned tile that does not
    divide the (padded) problem dimension would silently drop the
    remainder. Every ``ops`` wrapper clamps its tile kwargs through this
    before forwarding them.

    Awkward dims (e.g. a prime sequence length) whose only small divisors
    are degenerate fall back to ``dim`` itself — one tile over that axis,
    matching the kernels' old ``min(tile, dim)`` clamp — rather than a
    grid of 1-wide tiles.

    The returned tile is never larger than ``dim`` (nor than its
    power-of-two bucket): a table entry tuned for a big bucket cannot
    force a small decode/smoke problem to pad up to the entry's tile.
    """
    request = max(1, min(request, dim, _bucket(dim)))
    best = 1
    for d in range(request, 0, -1):
        if dim % d == 0 and d % multiple_of == 0:
            best = d
            break
    if best * 4 <= request and dim % max(multiple_of, 1) == 0:
        return dim
    return best


def pick_tile(dim: int, request: int, multiple_of: int = 8) -> int:
    """Tile size for a dimension the caller is about to PAD: the smallest
    multiple of ``multiple_of`` covering ``dim``, capped at ``request``.

    :func:`fit_block` fits a tile *into* a fixed (already padded)
    dimension; this is the converse for the wrappers that pad rows up to
    the tile. Picking the tile from the TRUE dimension first fixes the
    tail-block waste on exactly the shapes serving hits: a 1-row decode
    matmul pads to one 8-row tile (the f32 sublane) instead of the old
    hard-coded 128-row boundary, and a 100-row prefill pads to 104 rows
    instead of 128. The caller then pads ``dim`` up to a multiple of the
    returned tile, so the Pallas grid division is exact.
    """
    need = -(-max(dim, 1) // multiple_of) * multiple_of
    return max(multiple_of, min(max(request, multiple_of), need))


def tuned_blocks(op: str, shape: Tuple[int, ...],
                 dtype: str = "", backend: Optional[str] = None
                 ) -> Dict[str, int]:
    """Block-size kwargs for ``op`` on a problem of ``shape``.

    ``shape`` is the op's 2-D problem footprint (e.g. the weight matrix
    (M, N) for the fused update). Lookup order: in-process registrations
    (:func:`register_tuned`) → the persisted table (exact (bucketed
    shape, dtype), then (bucketed shape, any dtype)) → per-op defaults.
    """
    backend = backend or default_backend(op)
    bshape = tuple(_bucket(int(d)) for d in shape)
    table = _load_table(table_path())
    for dt in (dtype, ""):
        key = (op, backend, bshape, dt)
        hit = _RUNTIME_TABLE.get(key)
        if hit is None:
            hit = table.get(key)
        if hit is not None:
            return dict(hit)
    return dict(_DEFAULT_BLOCKS.get(op, {}))
