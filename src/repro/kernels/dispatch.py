"""Kernel backend registry, platform detection, and block-size autotuning.

Every compute op in :mod:`repro.kernels` has up to three interchangeable
implementations:

``pallas-tpu``
    The Pallas kernel compiled for the real accelerator
    (``interpret=False``). Fastest path; only valid when
    ``jax.default_backend() == "tpu"``.
``pallas-interpret``
    The same Pallas kernel run through the Pallas interpreter. Bit-faithful
    to the TPU kernel's semantics (used as the correctness harness on CPU
    containers) but orders of magnitude slower than XLA.
``ref``
    The pure-``jnp`` oracle from :mod:`repro.kernels.ref`, jitted by XLA.
    Mathematically identical contract; the fast default off-TPU.

Selection order for :func:`default_backend`:

1. ``REPRO_KERNEL_BACKEND`` env var (one of the names above) — global
   override, useful for A/B benchmarks and CI.
2. ``REPRO_PALLAS_COMPILED=1`` (legacy knob) → ``pallas-tpu``.
3. Platform detection: TPU → ``pallas-tpu``; anything else → ``ref``.

If the requested backend has no registered implementation for an op,
:func:`resolve` walks the fallback chain
``pallas-tpu → pallas-interpret → ref`` so callers never crash on a
partially-implemented op.

Block-size autotune table
-------------------------
:func:`tuned_blocks` returns the block-size kwargs for a (op, shape, dtype,
backend) query. Shapes are bucketed to the next power of two so the table
stays small; exact entries win over bucketed entries, which win over the
per-op defaults. The table is seeded with hand-tuned values for the fused
update kernel and the matmuls (VMEM-fitting tiles, MXU-aligned); it is a
plain dict so future PRs can extend it from real autotune sweeps.
"""
from __future__ import annotations

import functools
import os
from typing import Any, Callable, Dict, Optional, Tuple

import jax

KNOWN_BACKENDS = ("pallas-tpu", "pallas-interpret", "ref")

# op name -> backend name -> callable
_REGISTRY: Dict[str, Dict[str, Callable]] = {}

# Fallback order when the preferred backend is not registered for an op.
_FALLBACK = {
    "pallas-tpu": ("pallas-tpu", "pallas-interpret", "ref"),
    "pallas-interpret": ("pallas-interpret", "ref"),
    "ref": ("ref", "pallas-interpret"),
}


def register(op: str, backend: str):
    """Decorator: register ``fn`` as the ``backend`` implementation of ``op``."""
    assert backend in KNOWN_BACKENDS, backend

    def deco(fn):
        _REGISTRY.setdefault(op, {})[backend] = fn
        return fn

    return deco


@functools.lru_cache(maxsize=None)
def platform() -> str:
    """The JAX default backend platform ("cpu" | "gpu" | "tpu")."""
    return jax.default_backend()


def default_backend(op: Optional[str] = None) -> str:
    """Pick the backend for ``op`` (or globally when ``op`` is None)."""
    env = os.environ.get("REPRO_KERNEL_BACKEND", "")
    if env:
        if env not in KNOWN_BACKENDS:
            raise ValueError(
                f"REPRO_KERNEL_BACKEND={env!r}; expected one of "
                f"{KNOWN_BACKENDS}")
        return env
    if os.environ.get("REPRO_PALLAS_COMPILED", "0") == "1":
        return "pallas-tpu"
    if platform() == "tpu":
        return "pallas-tpu"
    return "ref"


def available_backends(op: str) -> Tuple[str, ...]:
    return tuple(_REGISTRY.get(op, {}))


def resolve(op: str, backend: Optional[str] = None
            ) -> Tuple[str, Callable]:
    """(backend_name, fn) for ``op``, honoring the fallback chain."""
    want = backend or default_backend(op)
    if want not in KNOWN_BACKENDS:
        raise ValueError(
            f"unknown backend {want!r} for op {op!r}; expected one of "
            f"{KNOWN_BACKENDS}")
    impls = _REGISTRY.get(op)
    if not impls:
        raise KeyError(f"no implementations registered for op {op!r}")
    for name in _FALLBACK[want]:
        if name in impls:
            return name, impls[name]
    # last resort: anything registered
    name = next(iter(impls))
    return name, impls[name]


def dispatch(op: str, *args, backend: Optional[str] = None, **kwargs):
    """Call the selected implementation of ``op``."""
    _, fn = resolve(op, backend)
    return fn(*args, **kwargs)


# ---------------------------------------------------------------------------
# Block-size autotune table
# ---------------------------------------------------------------------------

def _bucket(n: int) -> int:
    """Round up to the next power of two (shape bucketing key)."""
    b = 1
    while b < n:
        b *= 2
    return b


# Per-op defaults (used when no table entry matches). Values are the
# kwargs forwarded to the Pallas wrapper.
_DEFAULT_BLOCKS: Dict[str, Dict[str, int]] = {
    "int8_matmul": {"bm": 128, "bn": 256, "bk": 512},
    "int8_matmul_t": {"bm": 128, "bn": 512, "bk": 256},
    "int4_matmul": {"bm": 128, "bk": 512},
    "sr_requant": {"br": 256, "bc": 512},
    "blockwise_quant": {"br": 256, "bc": 512},
    "fused_qgalore_update": {"bm": 256, "bn": 512},
    "flash_attention": {"bq": 128, "bkv": 128},
}

# (op, backend, bucketed shape, dtype) -> block kwargs. Shape is the
# bucketed problem shape (op-specific meaning, documented in
# docs/kernels.md). dtype "" matches any dtype.
_TABLE: Dict[Tuple[str, str, Tuple[int, ...], str], Dict[str, int]] = {
    # Fused update: small rows → one row-block avoids grid overhead;
    # huge rows → taller tiles amortize the resident P dequant.
    ("fused_qgalore_update", "pallas-tpu", (1024, 1024), ""):
        {"bm": 256, "bn": 1024},
    ("fused_qgalore_update", "pallas-tpu", (4096, 4096), ""):
        {"bm": 512, "bn": 1024},
    ("fused_qgalore_update", "pallas-interpret", (256, 256), ""):
        {"bm": 256, "bn": 256},
    # INT8 matmul: bf16 activations halve VMEM → wider N tiles.
    ("int8_matmul", "pallas-tpu", (4096, 4096), "bfloat16"):
        {"bm": 256, "bn": 512, "bk": 512},
    # Transposed INT8 matmul (dL/dx, tied head): contraction runs along the
    # quant-block axis, so wide bn tiles amortize the scale broadcasts.
    ("int8_matmul_t", "pallas-tpu", (4096, 4096), "bfloat16"):
        {"bm": 256, "bn": 512, "bk": 256},
    ("int4_matmul", "pallas-tpu", (4096, 4096), ""):
        {"bm": 256, "bk": 1024},
}


def fit_block(dim: int, request: int, multiple_of: int = 1) -> int:
    """Largest tile ≤ ``request`` that divides ``dim`` (and is a multiple
    of ``multiple_of``), falling back to ``dim`` itself.

    The Pallas kernels floor-divide their grids (``grid = dim // tile``)
    without asserting divisibility, so a table/tuned tile that does not
    divide the (padded) problem dimension would silently drop the
    remainder. Every ``ops`` wrapper clamps its tile kwargs through this
    before forwarding them.

    Awkward dims (e.g. a prime sequence length) whose only small divisors
    are degenerate fall back to ``dim`` itself — one tile over that axis,
    matching the kernels' old ``min(tile, dim)`` clamp — rather than a
    grid of 1-wide tiles.
    """
    request = max(1, min(request, dim))
    best = 1
    for d in range(request, 0, -1):
        if dim % d == 0 and d % multiple_of == 0:
            best = d
            break
    if best * 4 <= request and dim % max(multiple_of, 1) == 0:
        return dim
    return best


def tuned_blocks(op: str, shape: Tuple[int, ...],
                 dtype: str = "", backend: Optional[str] = None
                 ) -> Dict[str, int]:
    """Block-size kwargs for ``op`` on a problem of ``shape``.

    ``shape`` is the op's 2-D problem footprint (e.g. the weight matrix
    (M, N) for the fused update). Lookup order: exact (bucketed shape,
    dtype) → (bucketed shape, any dtype) → per-op defaults.
    """
    backend = backend or default_backend(op)
    bshape = tuple(_bucket(int(d)) for d in shape)
    for dt in (dtype, ""):
        hit = _TABLE.get((op, backend, bshape, dt))
        if hit is not None:
            return dict(hit)
    return dict(_DEFAULT_BLOCKS.get(op, {}))
