"""Pallas TPU kernel: causal flash attention (framework infra — the 32k
prefill cells need memory-bounded attention; the pure-JAX chunked form in
``models.attention`` is the lowering default, this kernel is the TPU
fast path).

Grid (batch·heads, q_blocks); the kernel loops over KV blocks with the
online-softmax recurrence, keeping running (max, denom, accum) in VMEM.
Causality skips KV blocks strictly above the diagonal.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bkv: int, seq: int,
            causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale        # (bq, d)
    d = q.shape[-1]
    dv = v_ref.shape[-1]

    n_kv = seq // bkv

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(j * bkv, bkv), :].astype(jnp.float32)  # (bkv,d)
        v = v_ref[pl.dslice(j * bkv, bkv), :].astype(jnp.float32)  # (bkv,dv)
        s = q @ k.T                                   # (bq, bkv)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bkv), 0)
            kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32,
                                                      (bq, bkv), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, dv), jnp.float32)
    if causal:
        # only blocks up to (and including) the diagonal contribute
        upper = (qi + 1) * bq
        n_iter = (upper + bkv - 1) // bkv
    else:
        n_iter = n_kv
    m, l, acc = jax.lax.fori_loop(0, n_iter, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bkv", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bkv: int = 256, interpret: bool = True):
    """q,k,v (B,S,H,d) (H == KV heads here; GQA folds beforehand) →
    (B,S,H,dv)."""
    B, S, H, d = q.shape
    dv = v.shape[-1]
    bq, bkv = min(bq, S), min(bkv, S)
    assert S % bq == 0 and S % bkv == 0
    scale = 1.0 / math.sqrt(d)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, d)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, dv)
    out = pl.pallas_call(
        functools.partial(_kernel, bq=bq, bkv=bkv, seq=S, causal=causal,
                          scale=scale),
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, S, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, S, dv), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, dv), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, dv), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, dv).transpose(0, 2, 1, 3)
