"""Lightweight per-op profiling counters behind ``REPRO_PROFILE=1``.

The quantized hot paths run inside ``jit``, so per-call wall time cannot
be observed from Python without defeating the fusion being measured.
What CAN be recorded cheaply and without perturbing the compiled graph:

* **trace-time counters** — every ``ops`` wrapper calls :func:`record`
  while tracing, logging how many times each kernel op is baked into a
  compiled program and the HBM bytes / FLOPs one execution of that call
  moves. Re-traces count again (that is itself a useful signal: an
  unexpected recount means shape churn → recompiles).
* **eager wall timers** — :func:`timed` wraps host-side regions (the
  benches' timing loops, the autotune sweep) with a named wall-clock
  accumulator.

Everything is a no-op unless ``REPRO_PROFILE=1`` at call time, so the
hooks cost one ``os.environ`` dict lookup on the trace path and nothing
at execution time. The benches dump :func:`snapshot` into their JSON
artifacts so the next perf gap is diagnosable from CI output instead of
rerunning A/B sweeps by hand.
"""
from __future__ import annotations

import contextlib
import json
import os
import time
from collections import defaultdict
from typing import Any, Dict, Optional


def enabled() -> bool:
    return os.environ.get("REPRO_PROFILE", "0") == "1"


def _new_row() -> Dict[str, float]:
    return {"trace_calls": 0, "bytes_per_call": 0, "flops_per_call": 0,
            "wall_us": 0.0, "wall_calls": 0}


_COUNTS: Dict[str, Dict[str, float]] = defaultdict(_new_row)


def record(op: str, *, nbytes: int = 0, flops: int = 0,
           meta: Optional[Dict[str, Any]] = None) -> None:
    """Trace-time hook: count one baked-in call of ``op`` and the HBM
    bytes / FLOPs a single execution of it moves. ``meta`` (e.g. the
    problem shape) is kept from the most recent call."""
    if not enabled():
        return
    row = _COUNTS[op]
    row["trace_calls"] += 1
    row["bytes_per_call"] = int(nbytes)
    row["flops_per_call"] = int(flops)
    if meta:
        row["meta"] = dict(meta)


@contextlib.contextmanager
def timed(name: str):
    """Eager wall-clock accumulator for host-side regions."""
    if not enabled():
        yield
        return
    t0 = time.monotonic()
    try:
        yield
    finally:
        row = _COUNTS[name]
        row["wall_us"] += (time.monotonic() - t0) * 1e6
        row["wall_calls"] += 1


def reset() -> None:
    _COUNTS.clear()


def snapshot() -> Dict[str, Dict[str, float]]:
    return {op: dict(row) for op, row in sorted(_COUNTS.items())}


def dump(path: str) -> None:
    with open(path, "w") as f:
        json.dump({"enabled": enabled(), "ops": snapshot()}, f, indent=2)


def maybe_attach(report: Dict[str, Any]) -> None:
    """Attach the current snapshot to a bench report dict (in place) when
    profiling is on; no key is added otherwise."""
    if enabled():
        report["profile"] = snapshot()
