"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def int8_matmul_ref(x, q, scale, block: int):
    """x (M,K) @ dequant(q (K,N) int8, scale (K, N/block)) → (M,N) f32.
    Symmetric (zero-point-free) weights, per-(row, block) scales.

    Mirrors the fused-epilogue kernel's association: the scale (which
    varies along the contraction axis K) folds into the activation per
    quant group — ``out[:, g] = (x * s[:, g]) @ q[:, g]`` — so no
    dequantized W is formed and kernel/oracle share one multiply order.
    """
    K, N = q.shape
    G = N // block
    xf = x.astype(jnp.float32)
    q3 = q.astype(jnp.float32).reshape(K, G, block)
    xs = xf[:, :, None] * scale[None, :, :]            # (M, K, G)
    return jnp.einsum("mkg,kgb->mgb", xs, q3).reshape(x.shape[0], N)


def int8_matmul_t_ref(g, q, scale, block: int):
    """g (M,N) @ dequant(q (K,N) int8, scale (K, N/block))^T → (M,K) f32.
    Same stored blocks as :func:`int8_matmul_ref`, contracted over N.

    Mirrors the transposed kernel's true accumulator epilogue: the
    contraction runs along the quant axis, so raw codes dot first and the
    per-group scale lands once on the (M, K) partial accumulator.
    """
    K, N = q.shape
    G = N // block
    g3 = g.astype(jnp.float32).reshape(g.shape[0], G, block)
    q3 = q.astype(jnp.float32).reshape(K, G, block)
    pdot = jnp.einsum("mgb,kgb->mgk", g3, q3)          # raw-code dots
    return jnp.einsum("mgk,kg->mk", pdot, scale)       # scale epilogue


def int4_matmul_ref(g, packed, scale, zero, block: int):
    """g (M,K) @ dequant_int4(packed (K, R/2), scale/zero (K, R/block))
    → (M,R) f32. Asymmetric nibbles (paper's INT4 projection)."""
    u = quant.unpack_int4(packed).astype(jnp.float32) - 8.0   # qmin = -8
    K, R = u.shape
    w = (u.reshape(K, R // block, block) - zero[..., None]) \
        * scale[..., None]
    return g.astype(jnp.float32) @ w.reshape(K, R)


def sr_requant_ref(q, scale, update, u01, block: int):
    """Fused Q-GaLore weight update oracle: dequant + add + rescale + SR.
    q (R,C) int8 symmetric, scale (R, C/block), update (R,C), u01 uniform
    randoms (R,C). Returns (q', scale')."""
    R, C = q.shape
    w = q.astype(jnp.float32).reshape(R, C // block, block) \
        * scale[..., None]
    w = w.reshape(R, C) + update.astype(jnp.float32)
    wb = w.reshape(R, C // block, block)
    absmax = jnp.max(jnp.abs(wb), axis=-1)
    new_scale = jnp.maximum(absmax / 127.0, 1e-12)
    t = wb / new_scale[..., None]
    codes = jnp.clip(jnp.floor(t + u01.reshape(R, C // block, block)),
                     -128, 127)
    return codes.reshape(R, C).astype(jnp.int8), new_scale


def blockwise_quant_ref(x, block: int):
    """x (R,C) → symmetric int8 codes + per-block scales."""
    R, C = x.shape
    xb = x.astype(jnp.float32).reshape(R, C // block, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale[..., None]), -128, 127)
    return codes.reshape(R, C).astype(jnp.int8), scale


def fused_qgalore_update_ref(g, m, v, p_packed, p_scale, p_zero, q, wscale,
                             u01, count, lr, *, side: str, pblock: int,
                             wblock: int, beta1: float = 0.9,
                             beta2: float = 0.999, eps: float = 1e-8,
                             gscale: float = 0.25, wd: float = 0.0, **_):
    """Oracle for the fused Q-GaLore update (same contract as the kernel).

    side="right": g/m/v (M, r), P packed (N, r/2), q (M, N) int8 symmetric.
    side="left":  g/m/v (r, N), P packed (M, r/2).
    Returns (q', wscale', m', v'). Extra block-size kwargs are ignored so
    this slots into the dispatch registry unchanged.
    """
    c = jnp.asarray(count, jnp.float32)
    lr = jnp.asarray(lr, jnp.float32)
    g = g.astype(jnp.float32)
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * (g * g)
    m_hat = m_new / (1.0 - beta1 ** c)
    v_hat = v_new / (1.0 - beta2 ** c)
    dirn = m_hat / (jnp.sqrt(v_hat) + eps)

    u4 = quant.unpack_int4(p_packed).astype(jnp.float32) - 8.0
    d, r = u4.shape
    P = ((u4.reshape(d, r // pblock, pblock) - p_zero[..., None])
         * p_scale[..., None]).reshape(d, r)
    if side == "right":
        upd = gscale * (dirn @ P.T)               # (M, r) @ (r, N)
    else:
        upd = gscale * (P @ dirn)                 # (M, r) @ (r, N)

    R, C = q.shape
    w = (q.astype(jnp.float32).reshape(R, C // wblock, wblock)
         * wscale[..., None]).reshape(R, C)
    if wd:
        upd = upd + wd * w
    wn = (w - lr * upd).reshape(R, C // wblock, wblock)
    absmax = jnp.max(jnp.abs(wn), axis=-1)
    new_scale = jnp.maximum(absmax / 127.0, 1e-12)
    codes = jnp.floor(wn / new_scale[..., None]
                      + u01.reshape(R, C // wblock, wblock))
    q_new = jnp.clip(codes, -128, 127).reshape(R, C).astype(jnp.int8)
    return q_new, new_scale, m_new, v_new


def flash_attention_ref(q, k, v, causal: bool = True):
    """q,k,v (B,S,H,d) → (B,S,H,d) f32 softmax attention."""
    B, S, H, d = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
