"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import quant


def int8_matmul_ref(x, q, scale, block: int):
    """x (M,K) @ dequant(q (K,N) int8, scale (K, N/block)) → (M,N) f32.
    Symmetric (zero-point-free) weights, per-(row, block) scales."""
    K, N = q.shape
    w = q.astype(jnp.float32).reshape(K, N // block, block) \
        * scale[..., None]
    w = w.reshape(K, N)
    return x.astype(jnp.float32) @ w


def int4_matmul_ref(g, packed, scale, zero, block: int):
    """g (M,K) @ dequant_int4(packed (K, R/2), scale/zero (K, R/block))
    → (M,R) f32. Asymmetric nibbles (paper's INT4 projection)."""
    u = quant.unpack_int4(packed).astype(jnp.float32) - 8.0   # qmin = -8
    K, R = u.shape
    w = (u.reshape(K, R // block, block) - zero[..., None]) \
        * scale[..., None]
    return g.astype(jnp.float32) @ w.reshape(K, R)


def sr_requant_ref(q, scale, update, u01, block: int):
    """Fused Q-GaLore weight update oracle: dequant + add + rescale + SR.
    q (R,C) int8 symmetric, scale (R, C/block), update (R,C), u01 uniform
    randoms (R,C). Returns (q', scale')."""
    R, C = q.shape
    w = q.astype(jnp.float32).reshape(R, C // block, block) \
        * scale[..., None]
    w = w.reshape(R, C) + update.astype(jnp.float32)
    wb = w.reshape(R, C // block, block)
    absmax = jnp.max(jnp.abs(wb), axis=-1)
    new_scale = jnp.maximum(absmax / 127.0, 1e-12)
    t = wb / new_scale[..., None]
    codes = jnp.clip(jnp.floor(t + u01.reshape(R, C // block, block)),
                     -128, 127)
    return codes.reshape(R, C).astype(jnp.int8), new_scale


def blockwise_quant_ref(x, block: int):
    """x (R,C) → symmetric int8 codes + per-block scales."""
    R, C = x.shape
    xb = x.astype(jnp.float32).reshape(R, C // block, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(absmax / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(xb / scale[..., None]), -128, 127)
    return codes.reshape(R, C).astype(jnp.int8), scale


def flash_attention_ref(q, k, v, causal: bool = True):
    """q,k,v (B,S,H,d) → (B,S,H,d) f32 softmax attention."""
    B, S, H, d = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
