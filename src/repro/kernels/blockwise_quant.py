"""Pallas TPU kernel: block-wise symmetric INT8 quantization (paper §3.1).

Single pass: read the float tile, per-256-block absmax reduce (VPU),
round-to-nearest, emit codes + scales. Used when (re)quantizing Adam moments
and fresh weights.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref, *, block: int):
    x = x_ref[...].astype(jnp.float32)
    BR, BC = x.shape
    nb = BC // block
    xb = x.reshape(BR, nb, block)
    absmax = jnp.max(jnp.abs(xb), axis=-1)
    s = jnp.maximum(absmax / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(xb / s[..., None]), -128, 127)
    q_ref[...] = codes.reshape(BR, BC).astype(jnp.int8)
    s_ref[...] = s


@functools.partial(jax.jit,
                   static_argnames=("block", "br", "bc", "interpret"))
def blockwise_quant(x, *, block: int = 256, br: int = 256, bc: int = 512,
                    interpret: bool = True):
    """x (R, C) → (codes int8 (R,C), scales f32 (R, C/block))."""
    R, C = x.shape
    assert C % block == 0 and bc % block == 0
    br, bc = min(br, R), min(bc, C)
    grid = (R // br, C // bc)
    return pl.pallas_call(
        functools.partial(_kernel, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((br, bc // block), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((R, C), jnp.int8),
            jax.ShapeDtypeStruct((R, C // block), jnp.float32),
        ],
        interpret=interpret,
    )(x)
