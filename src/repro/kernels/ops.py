"""Jit'd public wrappers around the Pallas kernels.

Handles padding to tile boundaries, dtype plumbing, and the
interpret-vs-compiled switch (CPU containers run ``interpret=True``; on TPU
set ``REPRO_PALLAS_COMPILED=1`` or pass ``interpret=False``).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.quant import QTensor
from repro.kernels.blockwise_quant import blockwise_quant as _bq
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.int4_matmul import int4_matmul as _i4mm
from repro.kernels.int8_matmul import int8_matmul as _i8mm
from repro.kernels.sr_requant import sr_requant as _srq


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_COMPILED", "0") != "1"


def _pad_to(x, axis: int, mult: int):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def int8_matmul(x, qt: QTensor, *, interpret=None):
    """x (..., K) @ dequant(qt (K, N)) — QTensor must be symmetric INT8."""
    assert qt.bits == 8 and qt.zero is None
    interpret = _interpret_default() if interpret is None else interpret
    lead = x.shape[:-1]
    K = x.shape[-1]
    xf = x.reshape(-1, K)
    xf, M = _pad_to(xf, 0, 128)
    out = _i8mm(xf, qt.q, qt.scale, block=qt.block, interpret=interpret)
    return out[:M, : qt.orig_last].reshape(*lead, qt.orig_last)


def int4_project(g, qt: QTensor, *, interpret=None):
    """GaLore projection g (..., K) @ dequant_int4(qt (K, R))."""
    assert qt.bits == 4 and qt.zero is not None
    interpret = _interpret_default() if interpret is None else interpret
    lead = g.shape[:-1]
    K = g.shape[-1]
    gf = g.reshape(-1, K)
    gf, M = _pad_to(gf, 0, 128)
    out = _i4mm(gf, qt.q, qt.scale, qt.zero, block=qt.block,
                interpret=interpret)
    return out[:M, : qt.orig_last].reshape(*lead, qt.orig_last)


def sr_requant_update(qt: QTensor, update, key, *, interpret=None):
    """Fused SR weight update on a symmetric INT8 QTensor; returns a new
    QTensor (same layout)."""
    assert qt.bits == 8 and qt.zero is None
    interpret = _interpret_default() if interpret is None else interpret
    R = int(jnp.prod(jnp.asarray(qt.q.shape[:-1]))) if qt.q.ndim > 1 else 1
    q2 = qt.q.reshape(R, qt.q.shape[-1])
    s2 = qt.scale.reshape(R, qt.scale.shape[-1])
    upd = update.reshape(R, -1)
    pad = q2.shape[-1] - upd.shape[-1]
    if pad:
        upd = jnp.pad(upd, ((0, 0), (0, pad)))
    u01 = jax.random.uniform(key, q2.shape, jnp.float32)
    q_new, s_new = _srq(q2, s2, upd, u01, block=qt.block,
                        interpret=interpret)
    return QTensor(q_new.reshape(qt.q.shape), s_new.reshape(qt.scale.shape),
                   None, qt.bits, qt.block, qt.orig_last, qt.dtype)


def quantize_int8(x, *, block: int = 256, interpret=None) -> QTensor:
    """Symmetric block-wise INT8 quantization of a 2-D tensor."""
    interpret = _interpret_default() if interpret is None else interpret
    orig_last = x.shape[-1]
    x2 = x.reshape(-1, orig_last)
    x2, R = _pad_to(x2, 0, 1)
    x2, _ = _pad_to(x2, 1, block)
    q, s = _bq(x2, block=block, interpret=interpret)
    q = q.reshape(*x.shape[:-1], x2.shape[-1])
    s = s.reshape(*x.shape[:-1], x2.shape[-1] // block)
    return QTensor(q, s, None, 8, block, orig_last, str(x.dtype))


def flash_attention(q, k, v, *, causal: bool = True, interpret=None):
    """Causal flash attention (B,S,H,d); GQA folded by the caller."""
    interpret = _interpret_default() if interpret is None else interpret
    return _flash(q, k, v, causal=causal, interpret=interpret)
