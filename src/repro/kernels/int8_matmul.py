"""Pallas TPU kernels: BF16/F32 activation × INT8 weight matmuls with the
dequant scale FUSED into the matmul — no dequantized weight tile in VMEM.

TPU adaptation of the paper's INT8 GEMM (bitsandbytes on CUDA): v5e has no
INT8 training GEMM, so the win is HBM traffic — weights stream at 1 byte
instead of 2 and feed the MXU as raw codes. Block layout matches the
training representation: scales per (row, 256-col group), so the kernel
consumes optimizer output with zero relayout.

Two orientations over the SAME stored blocks, with the scale applied on
opposite sides of the dot (the scale axis is the weight's ROW axis K times
the column group, so where it can fuse depends on which axis contracts):

* :func:`int8_matmul` — ``x (M, K) @ deq(W (K, N))`` (forward / serving).
  The contraction runs over K, where the scale VARIES, so a pure
  accumulator epilogue is impossible; instead the per-group scale column
  ``s[:, g]`` (a K-vector) folds into the activation operand:
  ``out[:, g·B:(g+1)·B] += (x * s[:, g]) @ q[:, g·B:(g+1)·B]``.
  One (BM, BK) scaled-activation operand per group replaces the old
  (BK, BN) f32 dequantized weight tile.
* :func:`int8_matmul_t` — ``g (M, N) @ deq(W (K, N))^T`` (backward dL/dx
  and the tied-embedding head). The contraction runs over N — the quant
  axis — so the scale is CONSTANT per (output column k, group g) and a
  true accumulator epilogue applies: the raw-code partial dot
  ``g[:, gg] @ q[:, gg]^T`` lands on the (BM, BK) accumulator scaled once
  by ``s[:, gg]``.

Both associations change only the order of f32 multiplies (exact for the
scale-by-code product; the x·s fold rounds once before the MXU instead of
once after the dequant multiply), so they stay within the existing
parity tolerances of the ref oracles — see kernels/ref.py, which mirrors
the same association order.

``int8_matmul`` grid: (M/BM, N/BN, K/BK), K innermost; f32 accumulator
lives in a VMEM scratch across the K loop. BN is a multiple of the quant
block (256) so each weight tile owns whole scale groups. ``int8_matmul_t``
walks (M/BM, K/BK, N/BN) with N innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, block: int, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (BM, BK)
    q = q_ref[...]                                # (BK, BN) int8
    s = s_ref[...]                                # (BK, BN // block)
    BK, BN = q.shape
    # Scale varies along the contraction axis K → fold it into the
    # activation per quant group instead of materializing deq(W) in VMEM.
    for g in range(BN // block):
        xs = x * s[:, g][None, :]                 # (BM, BK)
        acc_ref[:, g * block:(g + 1) * block] += jax.lax.dot_general(
            xs, q[:, g * block:(g + 1) * block].astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "bm", "bn", "bk", "interpret"))
def int8_matmul(x, q, scale, *, block: int = 256, bm: int = 128,
                bn: int = 256, bk: int = 512, interpret: bool = True):
    """x (M,K) bf16/f32 @ dequant(q (K,N) int8, scale (K, N/block)) → (M,N).

    Shapes must tile evenly (the ops.py wrapper pads); BN % block == 0.
    """
    M, K = x.shape
    Kq, N = q.shape
    assert K == Kq and N % block == 0 and bn % block == 0
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, block=block, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, bn // block), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scale)


def _kernel_t(g_ref, q_ref, s_ref, o_ref, acc_ref, *, block: int, n_n: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)            # (BM, BN)
    q = q_ref[...]                                # (BK, BN) int8
    s = s_ref[...]                                # (BK, BN // block)
    BK, BN = q.shape
    # Contraction runs along N — the quant axis — so the scale applies
    # ONCE per group on the (BM, BK) accumulator: a true epilogue, raw
    # INT8 codes feed the MXU.
    for gg in range(BN // block):
        sl = slice(gg * block, (gg + 1) * block)
        pdot = jax.lax.dot_general(
            g[:, sl], q[:, sl].astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)   # (BM, BK)
        acc_ref[...] += pdot * s[:, gg][None, :]

    @pl.when(pl.program_id(2) == n_n - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "bm", "bn", "bk", "interpret"))
def int8_matmul_t(g, q, scale, *, block: int = 256, bm: int = 128,
                  bn: int = 256, bk: int = 512, interpret: bool = True):
    """g (M,N) bf16/f32 @ dequant(q (K,N) int8, scale (K, N/block))^T → (M,K).

    Streams the SAME int8 blocks as :func:`int8_matmul` (no transposed
    weight copy); the contraction runs over N, the quant-block axis, so
    the scale multiply is a true accumulator epilogue. Shapes must tile
    evenly (the ops.py wrapper pads); BN % block == 0.
    """
    M, N = g.shape
    K, Nq = q.shape
    assert N == Nq and N % block == 0 and bn % block == 0
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    grid = (M // bm, K // bk, N // bn)
    return pl.pallas_call(
        functools.partial(_kernel_t, block=block, n_n=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, k, n: (i, n)),
            pl.BlockSpec((bk, bn), lambda i, k, n: (k, n)),
            pl.BlockSpec((bk, bn // block), lambda i, k, n: (k, n)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, k, n: (i, k)),
        out_shape=jax.ShapeDtypeStruct((M, K), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bk), jnp.float32)],
        interpret=interpret,
    )(g, q, scale)
