"""Pallas TPU kernel: gradient × INT4 projection matmul.

The GaLore projection ``G (m,n) @ P (n,r)`` is the per-step hot-spot the
paper quantizes: P is stored as packed nibbles (two INT4 codes per uint8)
with asymmetric per-block scale/zero. The kernel unpacks nibbles in VMEM
(bitwise ops on the VPU), dequantizes, and feeds the MXU — P never exists in
HBM at more than 4 bits + scales.

r is small (≤ a few hundred), so the grid tiles (M × K) with r resident:
grid (M/BM, K/BK); the packed P tile is (BK, r/2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(g_ref, p_ref, s_ref, z_ref, o_ref, acc_ref, *, block: int,
            n_k: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = g_ref[...].astype(jnp.float32)              # (BM, BK)
    packed = p_ref[...]                             # (BK, R//2) uint8
    lo = (packed & 0xF).astype(jnp.float32) - 8.0
    hi = ((packed >> 4) & 0xF).astype(jnp.float32) - 8.0
    BK = packed.shape[0]
    R = packed.shape[1] * 2
    u = jnp.stack([lo, hi], axis=-1).reshape(BK, R)  # interleaved nibbles
    s = s_ref[...]                                  # (BK, R // block)
    z = z_ref[...]
    w = ((u.reshape(BK, R // block, block) - z[..., None])
         * s[..., None]).reshape(BK, R)
    acc_ref[...] += jax.lax.dot_general(
        g, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block", "bm", "bk", "interpret"))
def int4_matmul(g, packed, scale, zero, *, block: int = 128, bm: int = 128,
                bk: int = 512, interpret: bool = True):
    """g (M,K) @ dequant_int4(packed (K, R/2), scale/zero (K, R/block))
    → (M,R) in g.dtype (f32 accumulation)."""
    M, K = g.shape
    Kp, Rh = packed.shape
    R = Rh * 2
    assert K == Kp and R % block == 0
    bm, bk = min(bm, M), min(bk, K)
    grid = (M // bm, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, block=block, n_k=grid[1]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, k: (i, k)),
            pl.BlockSpec((bk, Rh), lambda i, k: (k, 0)),
            pl.BlockSpec((bk, R // block), lambda i, k: (k, 0)),
            pl.BlockSpec((bk, R // block), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bm, R), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, R), g.dtype),
        scratch_shapes=[pltpu.VMEM((bm, R), jnp.float32)],
        interpret=interpret,
    )(g, packed, scale, zero)
