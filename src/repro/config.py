"""Configuration system for the repro framework.

Dataclass configs are plain-Python (hashable, static) so they can be closed
over by jitted functions. Every assigned architecture provides a module in
``repro.configs`` exposing ``CONFIG`` (full-size) and ``smoke_config()``
(reduced, CPU-runnable).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings (None ⇒ dense FFN)."""
    num_experts: int = 0
    top_k: int = 0
    num_shared_experts: int = 0
    expert_ff: int = 0              # per-expert intermediate size
    router_aux_coef: float = 0.001  # load-balancing auxiliary loss
    # First N layers stay dense (DeepSeek-V3 uses 3 dense layers).
    first_dense_layers: int = 0
    dense_ff: int = 0               # intermediate size of the dense layers


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD settings."""
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    chunk_size: int = 256


@dataclass(frozen=True)
class XLSTMConfig:
    """xLSTM block settings."""
    slstm_every: int = 6        # every Nth block is an sLSTM; others mLSTM
    mlstm_head_dim: int = 0     # 0 ⇒ d_model // num_heads
    proj_factor: float = 2.0    # mLSTM up-projection factor
    chunk_size: int = 256       # chunkwise-parallel training chunk


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: SSM backbone + shared attention block."""
    attn_every: int = 6         # shared transformer block applied every N layers
    shared_lora_rank: int = 64  # per-invocation LoRA on the shared block


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"       # dense | moe | ssm | xlstm | hybrid | encdec | vlm
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12
    num_kv_heads: int = 12
    head_dim: int = 0           # 0 ⇒ d_model // num_heads
    d_ff: int = 3072
    vocab_size: int = 32000
    max_seq_len: int = 8192
    # activation / norm details
    ffn_activation: str = "silu"   # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rmsnorm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention flavor
    attention: str = "gqa"         # gqa | mla
    mla: Optional[MLAConfig] = None
    # family-specific
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # enc-dec
    num_encoder_layers: int = 0
    # vlm / audio frontend stubs: number of prefix embedding positions fed by
    # the (stubbed) modality encoder in train/prefill shapes.
    num_prefix_embeddings: int = 0
    # DeepSeek multi-token prediction depth (0 = off)
    mtp_depth: int = 0
    # logit softcap (gemma2-style, 0=off)
    logit_softcap: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> int:
        """Analytic parameter count (matches models.build for all families)."""
        from repro.models.model_zoo import count_params_analytic
        return count_params_analytic(self)


# ---------------------------------------------------------------------------
# Q-GaLore / optimizer configuration
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class QGaLoreConfig:
    """Everything controlling the paper's technique."""
    enabled: bool = True
    rank: int = 128                 # low-rank dimension r
    scale: float = 0.25             # GaLore alpha
    update_interval: int = 200      # initial SVD interval T
    # adaptive lazy update
    adaptive: bool = True
    cos_threshold: float = 0.4      # paper's 40% threshold
    adaptive_k: int = 3             # consecutive intervals above threshold
    max_interval: int = 3200        # cap on doubled interval
    # quantization
    proj_bits: int = 4              # INT4 projection
    weight_bits: int = 8            # INT8 weights (0 = keep bf16 weights)
    quant_block: int = 256          # paper's block size
    stochastic_rounding: bool = True
    # inner optimizer
    adam_bits: int = 8              # 8-bit Adam states (32 = fp32 states)
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    # dynamic rank adaptation (AdaRankGrad-style, arXiv:2410.17881): shrink
    # a leaf's projection rank at runtime once the measured explained-
    # variance ratio at the next-smaller rank stays above the threshold for
    # `rank_patience` consecutive refreshes. OFF by default — the static-
    # rank pipeline (and the committed golden fixture) is unchanged.
    adaptive_rank: bool = False
    # descending rank rungs, e.g. (128, 64, 32); empty = halve the current
    # rank per transition. `min_rank` floors the ladder either way.
    rank_ladder: Tuple[int, ...] = ()
    explained_ratio_threshold: float = 0.95
    rank_patience: int = 2
    min_rank: int = 8
    # hysteresis half-band around `explained_ratio_threshold`: ratios inside
    # [threshold - band, threshold) neither advance nor reset the shrink
    # streak, so a noisy ratio straddling the threshold cannot oscillate a
    # leaf between ladder rungs (and, once rank growth lands, cannot
    # flip-flop shrink/grow). 0.0 = exact pre-hysteresis behavior.
    rank_hysteresis: float = 0.0
    # subspace method: "svd" (paper-faithful) | "randomized" (TPU-fast)
    subspace_method: str = "svd"
    subspace_iters: int = 2         # power iterations for randomized method
    # fused update path: run Adam + INT4 back-projection + SR requant as
    # ONE kernel per weight (repro.kernels.fused_update) when a leaf is
    # eligible (INT8 symmetric weight, INT4 projection, SR on). Falls back
    # to the unfused composition per-leaf otherwise.
    fused_update: bool = True
    # stack same-shaped leaves and scan ONE update program over them
    # instead of unrolling a Python loop per leaf (smaller HLO, faster
    # compiles, better kernel reuse)
    batch_leaves: bool = True
    # which params get low-rank treatment
    min_dim: int = 128              # both dims must be >= this
    galore_embeddings: bool = False
    # distributed: project before the DP all-reduce (beyond-paper)
    compress_dp_grads: bool = False
    # distributed subspace refresh: at refresh steps, reduce-scatter the
    # full-rank gradient over the DP axes along the layer-stack dim, run
    # each due layer's SVD on its owning shard only, and all-gather the new
    # (small, INT4) P — instead of pmean-replicating the full-rank gradient
    # and repeating every SVD on every device. Only applies to stacked
    # leaves whose layer dim divides the DP world size; others fall back to
    # the replicated refresh. Requires compress_dp_grads + a mesh.
    dist_refresh: bool = True


@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 256
    steps: int = 100
    learning_rate: float = 1e-3
    warmup_steps: int = 10
    lr_schedule: str = "cosine"     # cosine | linear | constant
    min_lr_ratio: float = 0.1
    grad_clip: float = 1.0
    dtype: str = "bfloat16"         # compute dtype
    remat: str = "none"             # none | dots | full
    scan_layers: bool = True
    # checkpointing
    checkpoint_dir: str = ""
    checkpoint_every: int = 0       # 0 = off
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    # optimizer choice: qgalore | galore | adamw | adam8bit | lora | low_rank
    optimizer: str = "qgalore"
    lora_rank: int = 16
    lora_alpha: float = 32.0
    # logging
    log_every: int = 10


# ---------------------------------------------------------------------------
# Input shape cells (assigned grid)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPE_CELLS: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)

# Archs for which long_500k runs (sub-quadratic decode); all others skip it.
LONG_CONTEXT_ARCHS = ("xlstm-125m", "zamba2-2.7b")


def cells_for_arch(arch_name: str):
    """The shape cells that apply to a given architecture."""
    out = []
    for cell in SHAPE_CELLS:
        if cell.name == "long_500k" and arch_name not in LONG_CONTEXT_ARCHS:
            continue
        out.append(cell)
    return tuple(out)


def replace(cfg, **kw):
    """dataclasses.replace that works through our frozen configs."""
    return dataclasses.replace(cfg, **kw)
