"""Param-group rules: ordered, path-pattern overrides of the Q-GaLore recipe.

The optimizer used to be a single global :class:`~repro.config.QGaLoreConfig`
applied uniformly to every leaf; per-layer ranks, frozen groups, and mixed
Q-GaLore/LoRA fine-tuning were inexpressible. This module makes the recipe
*composable*:

* :class:`ParamGroup` — a named override of the recipe for the leaves whose
  path matches its regex ``pattern`` (``re.search`` against both the raw
  ``jax.tree_util.keystr`` form ``['seg0_dense']['attn']['wq']`` and the
  normalized ``/seg0_dense/attn/wq`` form, so either grammar works).
  Overridable knobs: ``rank``, ``update_interval``, ``scale``, ``proj_bits``
  / ``weight_bits`` / ``adam_bits``, the adaptive-controller parameters,
  ``weight_decay`` / ``stochastic_rounding``, a per-group learning-rate
  multiplier ``lr_scale``, and ``frozen=True`` — which drops the leaf from
  the optimizer entirely (no Adam state, no projection, no update).
* :class:`ParamRules` — an ordered tuple of groups over a base config.
  Resolution is **first-match-wins** (like optax ``multi_transform`` masks):
  the first group whose pattern matches the leaf path supplies the
  overrides; unmatched leaves fall through to the base config (the implicit
  default group).

``ParamRules`` is a frozen dataclass of frozen dataclasses — hashable and
static, so (like ``QGaLoreConfig``) it can be closed over by jitted steps.
Every optimizer entry point (``qgalore.leaf_specs/init/apply_updates``,
``transform.qgalore_transform``, ``Trainer``, ``memory_report``,
``opt_state_sharding``) accepts either a plain ``QGaLoreConfig`` or a
``ParamRules``; :func:`as_rules` is the one normalization point. A plain
config is exactly ``ParamRules(base=cfg)`` — single default group, and the
whole pipeline is bit-identical to the pre-rules behavior (the golden
trajectory harness enforces this).

Example — the paper's fine-tuning scenario (see ``repro.launch.finetune``)::

    rules = ParamRules(
        base=preset("qgalore"),
        groups=(
            ParamGroup("frozen_base", pattern=r"embedding|seg0_",
                       frozen=True),
            ParamGroup("late_blocks", pattern=r"seg1_", rank=16,
                       update_interval=100),
        ),
    )
"""
from __future__ import annotations

import hashlib
import json
import re
from dataclasses import dataclass
from typing import Optional, Tuple

from repro.config import QGaLoreConfig, replace

# QGaLoreConfig fields a group may override (None on the group = inherit).
OVERRIDE_FIELDS: Tuple[str, ...] = (
    "enabled", "rank", "scale", "update_interval",
    "adaptive", "cos_threshold", "adaptive_k", "max_interval",
    "proj_bits", "weight_bits", "adam_bits", "stochastic_rounding",
    "weight_decay", "subspace_method", "subspace_iters",
    "min_dim", "galore_embeddings",
    "adaptive_rank", "rank_ladder", "explained_ratio_threshold",
    "rank_patience", "min_rank",
)


@dataclass(frozen=True)
class ParamGroup:
    """One named override rule. ``pattern`` is a regex matched with
    ``re.search`` against the leaf path; an empty pattern matches every
    leaf (useful as an explicit catch-all last group)."""
    name: str
    pattern: str = ""
    frozen: bool = False
    lr_scale: float = 1.0
    # --- QGaLoreConfig overrides (None = inherit from the base config) ---
    enabled: Optional[bool] = None
    rank: Optional[int] = None
    scale: Optional[float] = None
    update_interval: Optional[int] = None
    adaptive: Optional[bool] = None
    cos_threshold: Optional[float] = None
    adaptive_k: Optional[int] = None
    max_interval: Optional[int] = None
    proj_bits: Optional[int] = None
    weight_bits: Optional[int] = None
    adam_bits: Optional[int] = None
    stochastic_rounding: Optional[bool] = None
    weight_decay: Optional[float] = None
    subspace_method: Optional[str] = None
    subspace_iters: Optional[int] = None
    min_dim: Optional[int] = None
    galore_embeddings: Optional[bool] = None
    adaptive_rank: Optional[bool] = None
    rank_ladder: Optional[Tuple[int, ...]] = None
    explained_ratio_threshold: Optional[float] = None
    rank_patience: Optional[int] = None
    min_rank: Optional[int] = None

    def matches(self, path: str) -> bool:
        if not self.pattern:
            return True
        return re.search(self.pattern, path) is not None \
            or re.search(self.pattern, normalize_path(path)) is not None

    def overrides(self) -> dict:
        out = {}
        for f in OVERRIDE_FIELDS:
            v = getattr(self, f)
            if v is not None:
                out[f] = v
        return out

    def apply_to(self, base: QGaLoreConfig) -> QGaLoreConfig:
        ov = self.overrides()
        return replace(base, **ov) if ov else base


# The implicit catch-all: no overrides, trainable, unit lr.
DEFAULT_GROUP = ParamGroup(name="default")


@dataclass(frozen=True)
class ParamRules:
    """Ordered first-match-wins param-group rules over a base recipe."""
    base: QGaLoreConfig = QGaLoreConfig()
    groups: Tuple[ParamGroup, ...] = ()

    def resolve(self, path: str) -> ParamGroup:
        """The first group whose pattern matches ``path`` (the implicit
        default group when none does)."""
        for g in self.groups:
            if g.matches(path):
                return g
        return DEFAULT_GROUP

    def config_for(self, path: str) -> QGaLoreConfig:
        """The effective per-leaf config: base + first-matching overrides."""
        return self.resolve(path).apply_to(self.base)

    def group_names(self) -> Tuple[str, ...]:
        return tuple(g.name for g in self.groups) + (DEFAULT_GROUP.name,)

    def fingerprint(self) -> str:
        """Stable short hash of the rule-set's STATE-STRUCTURAL content —
        persisted in checkpoint metadata so a restore under different
        rules fails loudly instead of silently mis-grouping optimizer
        state. Only fields that change which state arrays exist or their
        shapes/dtypes participate (group membership, frozen, galore
        eligibility, ranks, bit widths, quant block); recipe knobs that
        leave the state layout alone (lr_scale, scale, intervals, adaptive
        thresholds, SR, weight decay) and pure execution-strategy flags
        (fused_update, batch_leaves, compress_dp_grads, dist_refresh) do
        NOT — toggling those must never refuse a resume."""
        blob = json.dumps(_structural_describe(self), sort_keys=True)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def normalize_path(path: str) -> str:
    """``['seg0_dense']['attn']['wq']`` → ``/seg0_dense/attn/wq``."""
    s = re.sub(r"\['([^']*)'\]", r"/\1", path)
    s = s.replace("][", "/").replace("[", "/").replace("]", "")
    return s if s.startswith("/") else "/" + s


def as_rules(cfg_or_rules) -> ParamRules:
    """Normalize: a plain ``QGaLoreConfig`` becomes single-default-group
    rules (bit-identical pipeline); ``ParamRules`` passes through."""
    if isinstance(cfg_or_rules, ParamRules):
        return cfg_or_rules
    if isinstance(cfg_or_rules, QGaLoreConfig):
        return ParamRules(base=cfg_or_rules)
    raise TypeError(
        f"expected QGaLoreConfig or ParamRules, got {type(cfg_or_rules)}")


# QGaLoreConfig fields that determine the optimizer state's STRUCTURE
# (which leaves hold state, array shapes, QTensor-vs-array dtypes). The
# checkpoint fingerprint covers exactly these — see fingerprint().
STRUCTURAL_FIELDS: Tuple[str, ...] = (
    "enabled", "rank", "min_dim", "galore_embeddings",
    "proj_bits", "weight_bits", "adam_bits", "quant_block",
)


def _structural_describe(rules: ParamRules) -> dict:
    def base_dict(cfg):
        return {f: getattr(cfg, f) for f in STRUCTURAL_FIELDS}

    def group_dict(g: ParamGroup):
        d = {f: getattr(g, f) for f in STRUCTURAL_FIELDS
             if getattr(g, f, None) is not None}
        d.update(name=g.name, pattern=g.pattern, frozen=g.frozen)
        return d

    return {
        "base": base_dict(rules.base),
        "groups": [group_dict(g) for g in rules.groups],
    }


def group_assignment(specs) -> dict:
    """{leaf path: group name} for a spec list — the per-leaf group map
    persisted as checkpoint metadata (see ``Trainer.save``)."""
    return {s.path: s.group for s in specs}
