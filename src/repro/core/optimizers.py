"""Learning-rate schedules and baseline-optimizer presets.

All baselines in the paper (Table 1) are expressed as ``QGaLoreConfig``
presets over one implementation, which removes a whole class of
"baseline implemented differently" bugs:

* Full (Adam, BF16)          → galore off, fp32 states, fp weights
* 8-bit Adam                 → galore off, 8-bit states
* GaLore (16-bit Adam)       → galore on, fp32 states, fp weights, fp proj
* 8-bit GaLore               → galore on, 8-bit states, fp weights, fp proj
* Q-GaLore                   → galore on, 8-bit states, INT8 weights + SR,
                               INT4 proj, adaptive lazy update

LoRA / Low-Rank factorization baselines are *model* transforms and live in
``repro.models.lora``.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.config import QGaLoreConfig, TrainConfig, replace


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def lr_at(step: int, cfg: TrainConfig) -> float:
    """Host-side schedule (passed into the jitted step as a scalar)."""
    base = cfg.learning_rate
    warm = max(cfg.warmup_steps, 1)
    if step < cfg.warmup_steps:
        return base * (step + 1) / warm
    if cfg.lr_schedule == "constant":
        return base
    total = max(cfg.steps - cfg.warmup_steps, 1)
    frac = min((step - cfg.warmup_steps) / total, 1.0)
    floor = cfg.min_lr_ratio * base
    if cfg.lr_schedule == "linear":
        return base + (floor - base) * frac
    # cosine
    return floor + 0.5 * (base - floor) * (1 + math.cos(math.pi * frac))


# ---------------------------------------------------------------------------
# Baseline presets (paper Table 1 / Table 2 rows)
# ---------------------------------------------------------------------------

def preset(name: str, base: QGaLoreConfig = QGaLoreConfig()) -> QGaLoreConfig:
    name = name.lower()
    if name in ("full", "adamw", "adam"):
        return replace(base, enabled=False, adam_bits=32, weight_bits=0,
                       stochastic_rounding=False)
    if name == "adam8bit":
        return replace(base, enabled=False, adam_bits=8, weight_bits=0,
                       stochastic_rounding=False)
    if name == "galore":
        return replace(base, enabled=True, adam_bits=32, weight_bits=0,
                       proj_bits=32, stochastic_rounding=False,
                       adaptive=False)
    if name == "galore8bit":
        return replace(base, enabled=True, adam_bits=8, weight_bits=0,
                       proj_bits=32, stochastic_rounding=False,
                       adaptive=False)
    if name == "qgalore":
        return replace(base, enabled=True, adam_bits=8, weight_bits=8,
                       proj_bits=4, stochastic_rounding=True, adaptive=True)
    if name == "qgalore_nosr":
        return replace(base, enabled=True, adam_bits=8, weight_bits=8,
                       proj_bits=4, stochastic_rounding=False, adaptive=True)
    raise ValueError(f"unknown optimizer preset: {name}")
