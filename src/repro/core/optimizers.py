"""Learning-rate schedules and baseline-optimizer presets.

All baselines in the paper (Table 1) are expressed as ``QGaLoreConfig``
presets over one implementation, which removes a whole class of
"baseline implemented differently" bugs:

* Full (Adam, BF16)          → galore off, fp32 states, fp weights
* 8-bit Adam                 → galore off, 8-bit states
* GaLore (16-bit Adam)       → galore on, fp32 states, fp weights, fp proj
* 8-bit GaLore               → galore on, 8-bit states, fp weights, fp proj
* Q-GaLore                   → galore on, 8-bit states, INT8 weights + SR,
                               INT4 proj, adaptive lazy update

LoRA / Low-Rank factorization baselines are *model* transforms and live in
``repro.models.lora``.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.config import QGaLoreConfig, TrainConfig, replace


# ---------------------------------------------------------------------------
# LR schedules
# ---------------------------------------------------------------------------

def lr_at(step: int, cfg: TrainConfig) -> float:
    """Host-side schedule (passed into the jitted step as a scalar)."""
    base = cfg.learning_rate
    warm = max(cfg.warmup_steps, 1)
    if step < cfg.warmup_steps:
        return base * (step + 1) / warm
    if cfg.lr_schedule == "constant":
        return base
    total = max(cfg.steps - cfg.warmup_steps, 1)
    frac = min((step - cfg.warmup_steps) / total, 1.0)
    floor = cfg.min_lr_ratio * base
    if cfg.lr_schedule == "linear":
        return base + (floor - base) * frac
    # cosine
    return floor + 0.5 * (base - floor) * (1 + math.cos(math.pi * frac))


# ---------------------------------------------------------------------------
# Baseline presets (paper Table 1 / Table 2 rows), expressed as rule-sets
# ---------------------------------------------------------------------------

# Each preset is one all-leaves override set — a degenerate rule-set with a
# single (default) group. ``preset_rules`` returns the composable
# ``ParamRules`` form that the new optimizer surface consumes; add groups
# with ``dataclasses.replace(rules, groups=(...))`` or build ``ParamRules``
# directly (see repro.core.rules / docs/optimizer_api.md).
PRESET_OVERRIDES = {
    "full": dict(enabled=False, adam_bits=32, weight_bits=0,
                 stochastic_rounding=False),
    "adamw": dict(enabled=False, adam_bits=32, weight_bits=0,
                  stochastic_rounding=False),
    "adam": dict(enabled=False, adam_bits=32, weight_bits=0,
                 stochastic_rounding=False),
    "adam8bit": dict(enabled=False, adam_bits=8, weight_bits=0,
                     stochastic_rounding=False),
    "galore": dict(enabled=True, adam_bits=32, weight_bits=0,
                   proj_bits=32, stochastic_rounding=False, adaptive=False),
    "galore8bit": dict(enabled=True, adam_bits=8, weight_bits=0,
                       proj_bits=32, stochastic_rounding=False,
                       adaptive=False),
    "qgalore": dict(enabled=True, adam_bits=8, weight_bits=8,
                    proj_bits=4, stochastic_rounding=True, adaptive=True),
    "qgalore_nosr": dict(enabled=True, adam_bits=8, weight_bits=8,
                         proj_bits=4, stochastic_rounding=False,
                         adaptive=True),
}


def preset_rules(name: str, base: QGaLoreConfig = QGaLoreConfig(),
                 groups=()):
    """The preset as a composable rule-set: base config with the preset's
    overrides applied, plus any caller-supplied ``ParamGroup``s (ordered,
    first-match-wins). This is the preferred entry point for the new
    optimizer API."""
    from repro.core.rules import ParamRules
    return ParamRules(base=preset(name, base), groups=tuple(groups))


def preset(name: str, base: QGaLoreConfig = QGaLoreConfig()) -> QGaLoreConfig:
    """Back-compat shim: the preset's base ``QGaLoreConfig``.

    .. deprecated:: PR5
        The optimizer surface is now rule-based — prefer
        :func:`preset_rules` (or building ``repro.core.rules.ParamRules``
        directly), which additionally expresses per-group overrides and
        frozen groups. ``preset`` remains a thin wrapper over the same
        override table (``PRESET_OVERRIDES``) and keeps returning exactly
        the configs it always did, so existing tests / benches / examples
        run unmodified.
    """
    name = name.lower()
    try:
        return replace(base, **PRESET_OVERRIDES[name])
    except KeyError:
        raise ValueError(f"unknown optimizer preset: {name}") from None
