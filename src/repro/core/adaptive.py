"""Lazy layer-wise subspace exploration (paper §3.2).

Host-side controller: per (leaf, layer) it tracks the SVD interval and the
cosine-similarity history of consecutive projection matrices. When the
similarity stays above ``cos_threshold`` for ``adaptive_k`` consecutive
refreshes, the interval doubles (``t → 2t``) up to ``max_interval`` — the
"early bird" layers stop paying for SVDs while drifting layers keep the
original cadence.

The controller lives outside jit (it manipulates Python ints from per-layer
similarity scalars returned by the train step) and is checkpointed as JSON.

Interaction with the compiled step (see ``train/step.py`` /
``core/qgalore.py``):

1. Before each step the trainer asks :meth:`SubspaceController.masks_for_step`
   whether any projection is due; a non-empty answer selects the
   ``refresh=True`` jit variant with the per-layer boolean masks.
2. The refresh step recomputes P only for masked layers (``lax.cond``
   inside the layer scan — unmasked layers skip the SVD entirely) and
   returns the rotation/sign-invariant subspace similarity
   ``‖P_oldᵀ P_new‖_F² / r`` per refreshed layer.
3. :meth:`SubspaceController.observe` folds those similarities back into
   the per-layer intervals.

Memory footprint: the controller holds a few Python ints and a short
similarity history per projection matrix — none of it lives on device, so
the adaptive policy costs zero HBM on top of the paper Table 2 state
budget (INT8 weights, INT4 projections, low-rank INT8 Adam moments).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.config import QGaLoreConfig
from repro.core.rules import as_rules
from repro.core.qgalore import LeafSpec, _eff_cfg


@dataclass
class _Unit:
    """Controller state for one (leaf, batch-entry) projection matrix."""
    interval: int
    next_refresh: int = 0           # step at which the next SVD is due
    streak: int = 0                 # consecutive refreshes above threshold
    sims: List[float] = field(default_factory=list)
    svd_count: int = 0


class SubspaceController:
    """Decides, per training step, which projection matrices to refresh.

    Group-aware: every per-leaf policy knob (initial ``update_interval``,
    ``adaptive`` on/off, ``cos_threshold`` / ``adaptive_k`` /
    ``max_interval``) comes from the leaf's resolved param group
    (``spec.cfg``, see ``repro.core.rules``) — an attention group can
    refresh every 100 steps while an MLP group coasts at 400. ``cfg`` may
    be a plain ``QGaLoreConfig`` (single group, pre-rules behavior) or a
    ``ParamRules``."""

    def __init__(self, specs: List[LeafSpec], cfg):
        self.rules = as_rules(cfg)
        self.cfg = self.rules.base
        self.specs = specs
        self.units: Dict[int, List[_Unit]] = {}
        # dynamic rank adaptation (per-LEAF: a stacked leaf's units share
        # one rank because the state arrays are stacked)
        self.ranks: Dict[int, int] = {}
        self.rank_streaks: Dict[int, int] = {}
        self.transitions: List[dict] = []
        self._pending: List[tuple] = []
        for idx, spec in enumerate(specs):
            if spec.galore:
                eff = _eff_cfg(spec, self.rules)
                self.units[idx] = [
                    _Unit(interval=eff.update_interval)
                    for _ in range(spec.nbatch)
                ]
                self.ranks[idx] = spec.rank
                self.rank_streaks[idx] = 0
        self._orig_ranks = dict(self.ranks)

    def _cfg_for(self, idx: int) -> QGaLoreConfig:
        return _eff_cfg(self.specs[idx], self.rules)

    def update_specs(self, specs: List[LeafSpec]) -> None:
        """Swap in rebuilt (rank-overridden) specs after a migration; the
        leaf set and ordering must be unchanged."""
        if [s.path for s in specs] != [s.path for s in self.specs]:
            raise ValueError("update_specs: leaf set changed")
        self.specs = specs

    # -- scheduling ---------------------------------------------------------
    def masks_for_step(self, step: int) -> Dict[int, np.ndarray]:
        """{leaf_idx: (nbatch,) bool} — empty dict ⇒ no refresh this step."""
        masks: Dict[int, np.ndarray] = {}
        for idx, units in self.units.items():
            m = np.array([step >= u.next_refresh for u in units], dtype=bool)
            if m.any():
                masks[idx] = m
        return masks

    def is_refresh_step(self, step: int) -> bool:
        return bool(self.masks_for_step(step))

    # -- feedback -----------------------------------------------------------
    def observe(self, step: int, masks: Dict[int, np.ndarray],
                sims: Dict[str, np.ndarray],
                ratios: Optional[Dict[str, np.ndarray]] = None) -> None:
        """Consume the per-layer similarities (and, under dynamic rank
        adaptation, the explained-variance profiles) returned by the
        refresh step."""
        path_by_idx = {i: s.path for i, s in enumerate(self.specs)}
        for idx, mask in masks.items():
            sim_arr = sims.get(path_by_idx[idx])
            if sim_arr is None:
                continue
            eff = self._cfg_for(idx)
            sim_arr = np.asarray(sim_arr).reshape(-1)
            for b, unit in enumerate(self.units[idx]):
                if not mask[b]:
                    continue
                unit.svd_count += 1
                s = float(sim_arr[b])
                if s >= 0:
                    unit.sims.append(s)
                    if eff.adaptive and s >= eff.cos_threshold:
                        unit.streak += 1
                        if unit.streak >= eff.adaptive_k:
                            unit.interval = min(unit.interval * 2,
                                                eff.max_interval)
                            unit.streak = 0
                    else:
                        unit.streak = 0
                unit.next_refresh = step + unit.interval
            if eff.adaptive_rank and ratios is not None:
                self._observe_rank(step, idx, mask, eff,
                                   ratios.get(path_by_idx[idx]))

    # -- dynamic rank adaptation --------------------------------------------
    def _next_rank(self, idx: int, eff: QGaLoreConfig) -> Optional[int]:
        """The next rung below the leaf's CURRENT rank: the largest ladder
        value strictly below it, or half of it with an empty ladder; None
        once the floor ``min_rank`` would be crossed."""
        cur = self.ranks[idx]
        if eff.rank_ladder:
            below = [r for r in eff.rank_ladder if r < cur]
            target = max(below) if below else None
        else:
            target = cur // 2
        if target is None or target < max(eff.min_rank, 1):
            return None
        return target

    def _observe_rank(self, step: int, idx: int, mask, eff: QGaLoreConfig,
                      ratio_arr) -> None:
        """One refresh observation of a leaf's explained-variance profile:
        the leaf's streak counts consecutive refreshes where EVERY refreshed
        unit already explains >= threshold of its gradient energy at the
        next-smaller rank; ``rank_patience`` such refreshes trigger a
        shrink decision (picked up by the trainer via
        :meth:`take_rank_decisions`).

        ``rank_hysteresis`` opens a dead band below the threshold:
        observations in ``[threshold - band, threshold)`` HOLD the streak
        instead of resetting it, so a ratio that jitters across the
        threshold between refreshes cannot oscillate the streak (and, with
        rank growth, the rank itself) — a shrink still requires
        ``rank_patience`` observations at/above the full threshold, and
        only a clear drop below the band resets progress."""
        if ratio_arr is None:
            return
        target = self._next_rank(idx, eff)
        if target is None:
            return
        ratio_arr = np.asarray(ratio_arr).reshape(-1, self.ranks[idx])
        vals = [float(ratio_arr[b, target - 1])
                for b in range(ratio_arr.shape[0]) if mask[b]]
        vals = [v for v in vals if v >= 0]
        if not vals:
            return
        if min(vals) >= eff.explained_ratio_threshold - eff.rank_hysteresis \
                and min(vals) < eff.explained_ratio_threshold:
            return                      # dead band: hold the streak
        if min(vals) >= eff.explained_ratio_threshold:
            self.rank_streaks[idx] += 1
            if self.rank_streaks[idx] >= eff.rank_patience:
                old = self.ranks[idx]
                self.ranks[idx] = target
                self.rank_streaks[idx] = 0
                self.transitions.append(
                    {"step": int(step), "path": self.specs[idx].path,
                     "old": int(old), "new": int(target)})
                self._pending.append((idx, old, target))
        else:
            self.rank_streaks[idx] = 0

    def take_rank_decisions(self) -> List[tuple]:
        """Drain pending (leaf_idx, old_rank, new_rank) shrink decisions —
        the trainer migrates state and rebuilds execution for each."""
        out, self._pending = self._pending, []
        return out

    def current_ranks(self) -> Dict[str, int]:
        """{leaf path: rank} for leaves shrunk below their configured rank
        — the override map persisted in checkpoint meta and fed to
        ``qgalore.apply_rank_overrides``."""
        return {self.specs[i].path: r for i, r in self.ranks.items()
                if r != self._orig_ranks[i]}

    def rank_transition_summary(self) -> List[dict]:
        """The exact (step, path, old → new) shrink schedule of the run —
        pinned by the adarank golden fixture."""
        return [dict(t) for t in self.transitions]

    # -- accounting ---------------------------------------------------------
    def total_svd_count(self) -> int:
        return sum(u.svd_count for us in self.units.values() for u in us)

    def baseline_svd_count(self, steps: int) -> int:
        """SVDs a fixed-interval GaLore would have used in `steps` steps
        (per-group initial intervals honored)."""
        if not steps:
            return 0
        total = 0
        for idx, us in self.units.items():
            t = self._cfg_for(idx).update_interval
            total += (1 + (steps - 1) // t) * len(us)
        return total

    def interval_summary(self) -> Dict[str, List[int]]:
        return {self.specs[i].path: [u.interval for u in us]
                for i, us in self.units.items()}

    def svd_count_summary(self) -> Dict[str, List[int]]:
        """{leaf path: per-unit SVD counts} — the layer-adaptive signature of
        a run (golden-trajectory fixtures pin this exactly: a refactor that
        perturbs similarities enough to flip an interval doubling shows up
        here even when the loss curve stays inside its band)."""
        return {self.specs[i].path: [u.svd_count for u in us]
                for i, us in self.units.items()}

    # -- checkpointing ------------------------------------------------------
    def to_json(self) -> str:
        blob = {
            "units": {
                str(i): [
                    {"interval": u.interval,
                     "next_refresh": u.next_refresh,
                     "streak": u.streak, "svd_count": u.svd_count,
                     "sims": u.sims[-16:]}
                    for u in us]
                for i, us in self.units.items()
            },
            "ranks": {str(i): r for i, r in self.ranks.items()},
            "rank_streaks": {str(i): s
                             for i, s in self.rank_streaks.items()},
            "transitions": self.transitions,
        }
        return json.dumps(blob)

    def from_json(self, s: str) -> None:
        """Restore controller state, STRICTLY: the serialized leaf set must
        match this controller's exactly — unknown keys, missing keys, or a
        per-leaf unit-count mismatch mean the checkpoint was written under
        different specs (model/rules drift), and silently dropping entries
        would resume with desynchronized refresh schedules. Accepts the
        pre-rank-adaptation flat format (units only) for old checkpoints."""
        blob = json.loads(s)
        unit_blob = blob["units"] if "units" in blob else blob
        want = {str(i) for i in self.units}
        got = set(unit_blob)
        if got != want:
            raise ValueError(
                "SubspaceController.from_json: serialized leaf set does "
                f"not match the current specs (unknown={sorted(got - want)}"
                f", missing={sorted(want - got)}) — the checkpoint was "
                "written under different model/rules")
        for i_str, dumps in unit_blob.items():
            units = self.units[int(i_str)]
            if len(dumps) != len(units):
                raise ValueError(
                    f"SubspaceController.from_json: leaf {i_str} has "
                    f"{len(dumps)} serialized units, expected "
                    f"{len(units)} (stacked-layer layout changed)")
            for u, d in zip(units, dumps):
                u.interval = d["interval"]
                u.next_refresh = d["next_refresh"]
                u.streak = d["streak"]
                u.svd_count = d["svd_count"]
                u.sims = list(d.get("sims", []))
        if "units" in blob:
            for i_str, r in blob.get("ranks", {}).items():
                if int(i_str) not in self.ranks:
                    raise ValueError(
                        f"SubspaceController.from_json: rank entry for "
                        f"unknown leaf {i_str}")
                self.ranks[int(i_str)] = int(r)
            for i_str, st in blob.get("rank_streaks", {}).items():
                self.rank_streaks[int(i_str)] = int(st)
            self.transitions = [dict(t) for t in blob.get("transitions",
                                                          [])]
