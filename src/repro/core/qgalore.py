"""The Q-GaLore optimizer (paper §3.5) as a composable JAX module.

Combines:
  * low-rank gradient projection (GaLore) with per-leaf left/right sides,
  * INT4 block-wise quantized projection matrices (§3.3),
  * INT8 block-wise quantized weights updated via stochastic rounding (§3.4),
  * 8-bit Adam inner optimizer,
  * in-graph lazy subspace refresh: a per-layer boolean mask (driven by the
    host-side adaptive controller, §3.2) gates an SVD recomputation via
    ``lax.cond`` inside a ``lax.scan`` over the stacked-layer axis, so only
    masked layers pay the SVD cost.

Leaves with stacked leading dims — ``(L, m, n)`` per-layer stacks or
``(L, E, m, n)`` expert stacks — are treated as batches of independent 2-D
GaLore problems (vmapped projection, scanned refresh).

Gradients arriving at :func:`apply_updates` may be **full-rank** (simple
path) or **already low-rank** (fused projected-backward path, see
``repro.train.stack``); refresh steps always require full-rank grads for the
leaves being refreshed.

Hot-path execution (``apply_updates``)
--------------------------------------
Steady-state (non-refresh) steps run through two optimizations, both on by
default and gated by ``QGaLoreConfig``:

* ``cfg.fused_update`` — eligible leaves (symmetric INT8 weight, INT4
  projection, stochastic rounding on) update through ONE fused kernel
  (:func:`repro.kernels.ops.fused_qgalore_update`): low-rank Adam →
  INT4 back-projection → SR INT8 requant, with the full-rank f32 update
  living only in kernel VMEM — it is never written to HBM. Backend
  selection (pallas-tpu / pallas-interpret / pure-XLA ref) comes from
  :mod:`repro.kernels.dispatch`. Given the same RNG key the fused path
  draws the same SR randoms as the unfused composition and matches it to
  within one INT8 quantum (fp reassociation at floor boundaries).
* ``cfg.batch_leaves`` — leaves whose update program is identical
  (same virtual shape, side, rank, quantization layout) are stacked and
  driven by one ``lax.scan`` instead of a per-leaf Python loop, shrinking
  the traced HLO and reusing one compiled kernel across leaves. RNG
  folding is per original leaf index, so grouping does not change
  numerics.

Memory model (paper Table 2): per GaLore leaf ``(m, n)`` the persistent
state is the INT8 weight (codes + f32 block scales), the INT4 projection
``(min(m,n), r)`` (nibbles + scale/zero), and the two low-rank INT8 Adam
moments ``(max(m,n), r)``; on the fused path the transient full-rank f32
update stays in VMEM and the only full-rank f32 stream left in HBM is the
SR randoms input (see ``repro.kernels.ops``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QGaLoreConfig
from repro.core import adam8bit, projector, quant
from repro.core.adam8bit import Adam8bitState, AdamHyper
from repro.core.quant import QTensor
from repro.kernels import ops as kernel_ops


# ---------------------------------------------------------------------------
# Leaf specs (static metadata)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LeafSpec:
    path: str
    shape: Tuple[int, ...]        # virtual (dequantized) shape
    galore: bool
    side: str                     # "left" | "right" | ""
    rank: int
    batch: Tuple[int, ...]        # leading dims (layer stacks / experts)

    @property
    def mat_shape(self) -> Tuple[int, int]:
        return self.shape[-2], self.shape[-1]

    @property
    def nbatch(self) -> int:
        return int(np.prod(self.batch)) if self.batch else 1

    @property
    def low_shape(self) -> Tuple[int, ...]:
        return self.batch + projector.lowrank_shape(self.mat_shape, self.rank)

    @property
    def proj_shape(self) -> Tuple[int, ...]:
        d = projector.proj_dim(self.mat_shape)
        return self.batch + (d, self.rank)


def _leaf_shape(leaf) -> Tuple[int, ...]:
    return tuple(leaf.shape)


def _is_embedding_path(path: str) -> bool:
    p = path.lower()
    return any(k in p for k in ("embed", "lm_head", "unembed", "wte", "wpe"))


def leaf_specs(params, cfg: QGaLoreConfig) -> List[LeafSpec]:
    """One spec per leaf, in tree_flatten order (QTensor = one leaf)."""
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=quant.is_qtensor)[0]
    specs = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shape = _leaf_shape(leaf)
        galore = (
            cfg.enabled
            and len(shape) >= 2
            and shape[-1] >= cfg.min_dim
            and shape[-2] >= cfg.min_dim
            and (cfg.galore_embeddings or not _is_embedding_path(pstr))
        )
        if galore:
            side = projector.galore_side(shape)
            rank = min(cfg.rank, min(shape[-2], shape[-1]))
            specs.append(LeafSpec(pstr, shape, True, side, rank,
                                  tuple(shape[:-2])))
        else:
            specs.append(LeafSpec(pstr, shape, False, "", 0, ()))
    return specs


# ---------------------------------------------------------------------------
# Optimizer state
# ---------------------------------------------------------------------------

class QGaLoreState(NamedTuple):
    inner: Any        # pytree of Adam8bitState (aligned with params leaves)
    proj: Any         # pytree: QTensor P per galore leaf, None otherwise
    count: jax.Array  # int32 scalar


def _hyper(cfg: QGaLoreConfig) -> AdamHyper:
    return AdamHyper(cfg.beta1, cfg.beta2, cfg.eps, cfg.adam_bits,
                     cfg.quant_block)


def _init_projection(spec: LeafSpec, cfg: QGaLoreConfig, key) -> Any:
    """Random-orthonormal init; the controller forces a refresh at step 0."""
    d, r = projector.proj_dim(spec.mat_shape), spec.rank
    q = projector.random_orthonormal(key, d, r, batch=spec.nbatch)
    q = q.reshape(spec.batch + (d, r)) if spec.batch else q[0]
    if cfg.proj_bits >= 16:
        return q.astype(jnp.float32)
    return projector.quantize_projection(q, cfg.proj_bits, cfg.quant_block)


def init(params, cfg: QGaLoreConfig, key=None) -> QGaLoreState:
    key = jax.random.PRNGKey(0) if key is None else key
    specs = leaf_specs(params, cfg)
    flat, treedef = jax.tree_util.tree_flatten(params,
                                               is_leaf=quant.is_qtensor)
    hyper = _hyper(cfg)
    inner, proj = [], []
    for i, (leaf, spec) in enumerate(zip(flat, specs)):
        if spec.galore:
            inner.append(adam8bit.init_state(spec.low_shape, hyper))
            proj.append(_init_projection(spec, cfg, jax.random.fold_in(key, i)))
        else:
            inner.append(adam8bit.init_state(spec.shape, hyper))
            proj.append(None)
    return QGaLoreState(
        inner=jax.tree_util.tree_unflatten(treedef, inner),
        proj=jax.tree_util.tree_unflatten(treedef, proj),
        count=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Subspace refresh (in-graph, mask-gated)
# ---------------------------------------------------------------------------

def refresh_slice(g, P_flat, mask, idx, cfg: QGaLoreConfig, rank: int,
                  side: str, key):
    """Mask-gated subspace refresh over a flat slice of batch entries.

    ``g``: (b, m, n) f32 gradient slices; ``P_flat``: projection with every
    inner leaf carrying leading dim b; ``mask``: (b,) bool; ``idx``: (b,)
    int32 GLOBAL unit indices — per-unit RNG folding uses the global index,
    so a layer-sharded (distributed) refresh draws the same randoms as the
    replicated scan. Returns (P_new_flat, sims (b,)); sims = -1 where not
    refreshed. Only masked entries pay the SVD (``lax.cond`` in the scan).
    """

    def body(carry, inp):
        g_b, P_b, mask_b, i = inp

        def do_refresh(_):
            sub_key = jax.random.fold_in(key, i)
            P_new = projector.compute_subspace(
                g_b, rank, side, cfg.subspace_method, sub_key,
                cfg.subspace_iters)
            sim = projector.subspace_similarity(
                projector.maybe_dequantize(P_b), P_new)
            if cfg.proj_bits >= 16:
                return P_new.astype(jnp.float32), sim
            return (projector.quantize_projection(P_new, cfg.proj_bits,
                                                  cfg.quant_block), sim)

        def keep(_):
            return P_b, jnp.float32(-1.0)

        P_out, sim = jax.lax.cond(mask_b, do_refresh, keep, operand=None)
        return carry, (P_out, sim)

    _, (P_new_flat, sims) = jax.lax.scan(
        body, 0, (g.astype(jnp.float32), P_flat, mask.astype(bool),
                  idx.astype(jnp.int32)))
    return P_new_flat, sims


def _refresh_leaf(grad_full, P_old, mask, spec: LeafSpec,
                  cfg: QGaLoreConfig, key):
    """Recompute P for the masked batch entries of one leaf.

    grad_full: (batch..., m, n); P_old: QTensor/array (batch..., d, r);
    mask: (nbatch,) bool. Returns (P_new, sims (nbatch,)).
    sims = -1 where not refreshed.
    """
    b = spec.nbatch
    m, n = spec.mat_shape
    g = grad_full.reshape(b, m, n)
    # flatten leading batch dims of every inner leaf (q / scale / zero)
    P_flat = jax.tree_util.tree_map(
        lambda x: x.reshape((b,) + x.shape[len(spec.batch):]), P_old)
    P_new_flat, sims = refresh_slice(
        g, P_flat, mask, jnp.arange(b, dtype=jnp.int32), cfg, spec.rank,
        spec.side, key)
    # restore original leading batch dims, leaf-wise (works for QTensor and
    # plain arrays alike — aux metadata is preserved by the scan/cond).
    P_new = jax.tree_util.tree_map(
        lambda new, old: new.reshape(old.shape), P_new_flat, P_old)
    return P_new, sims


# ---------------------------------------------------------------------------
# The update step
# ---------------------------------------------------------------------------

def _grad_is_lowrank(grad, spec: LeafSpec) -> bool:
    return spec.galore and tuple(grad.shape) == spec.low_shape \
        and tuple(grad.shape) != spec.shape


# ---------------------------------------------------------------------------
# Fused update path (one kernel: Adam + back-projection + SR requant)
# ---------------------------------------------------------------------------

def _fused_eligible(param, P, spec: LeafSpec, cfg: QGaLoreConfig) -> bool:
    """The fused kernel covers the paper-default configuration: symmetric
    INT8 weights with stochastic rounding and an INT4 projection. Anything
    else (fp weights, fp projections, round-to-nearest) takes the unfused
    composition."""
    return (
        cfg.fused_update
        and spec.galore
        and cfg.stochastic_rounding
        and quant.is_qtensor(param) and param.bits == 8 and param.symmetric
        and quant.is_qtensor(P) and P.bits == 4
    )


def _update_leaf_fused(param, grad, inner: Adam8bitState, P, spec: LeafSpec,
                       cfg: QGaLoreConfig, lr, count, key):
    """Steady-state update of one GaLore leaf through the fused kernel.

    Draws the same SR randoms as the unfused path (same per-layer key
    folding), so results agree to within one INT8 quantum. Stacked leaves
    scan the kernel over the layer axis so the full-rank transients exist
    for one layer at a time.
    """
    hyper = _hyper(cfg)
    if _grad_is_lowrank(grad, spec):
        low = grad.astype(jnp.float32)
    else:
        P_deq = projector.maybe_dequantize(P, jnp.float32)
        low = projector.project(grad.astype(jnp.float32), P_deq, spec.side)
    m32, v32 = adam8bit.moments_fp32(inner)

    fused = functools.partial(
        kernel_ops.fused_qgalore_update, side=spec.side, gscale=cfg.scale,
        beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
        weight_decay=cfg.weight_decay)

    if spec.batch:
        b = spec.nbatch
        nlead = len(spec.batch)
        flat = lambda t: jax.tree_util.tree_map(
            lambda x: x.reshape((b,) + x.shape[nlead:]), t)
        param_f, P_f = flat(param), flat(P)
        low_f = low.reshape((b,) + low.shape[nlead:])
        m_f = m32.reshape(low_f.shape)
        v_f = v32.reshape(low_f.shape)

        def body(carry, inp):
            p_l, l_l, m_l, v_l, P_l, i = inp
            out = fused(p_l, l_l, m_l, v_l, P_l, count, lr,
                        jax.random.fold_in(key, i))
            return carry, out

        _, (newp_f, mn_f, vn_f) = jax.lax.scan(
            body, 0, (param_f, low_f, m_f, v_f, P_f, jnp.arange(b)))
        new_param = jax.tree_util.tree_map(
            lambda x, ref: x.reshape(ref.shape), newp_f, param)
        m_new = mn_f.reshape(m32.shape)
        v_new = vn_f.reshape(v32.shape)
    else:
        new_param, m_new, v_new = fused(param, low, m32, v32, P, count, lr,
                                        key)
    new_inner = adam8bit.pack_moments(m_new, v_new, hyper)
    return new_param, new_inner, P, None


def _apply_weight_update(param, direction_or_upd, P_deq, spec: LeafSpec,
                         cfg: QGaLoreConfig, lr, key):
    """Back-project (if galore) and apply the update to one (sub-)leaf.
    Shapes here carry NO leading stack dims — the caller scans over them so
    the full-rank f32 transients (project_back output, dequantized weight)
    exist for one layer at a time (this bounded deepseek's optimizer temp
    at 651 GiB/chip → sub-GiB; see EXPERIMENTS.md §Perf)."""
    if P_deq is not None:
        upd = projector.project_back(
            direction_or_upd.astype(jnp.float32), P_deq, spec.side)
        upd = cfg.scale * upd
    else:
        upd = direction_or_upd.astype(jnp.float32)

    if quant.is_qtensor(param):
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * quant.dequantize(param,
                                                            jnp.float32)
        delta = -lr * upd
        if cfg.stochastic_rounding:
            return quant.requantize_sr(param, delta, key)
        w = quant.dequantize(param, jnp.float32) + delta
        return quant.quantize_blockwise(
            w, bits=param.bits, block=param.block,
            symmetric=param.symmetric)
    w = param.astype(jnp.float32)
    if cfg.weight_decay:
        upd = upd + cfg.weight_decay * w
    return (w - lr * upd).astype(param.dtype)


def _update_leaf(param, grad, inner: Adam8bitState, P, spec: LeafSpec,
                 cfg: QGaLoreConfig, lr, count, mask, key, refresh: bool):
    """Returns (new_param, new_inner, new_P, sim_array_or_None)."""
    if not refresh and _fused_eligible(param, P, spec, cfg):
        return _update_leaf_fused(param, grad, inner, P, spec, cfg, lr,
                                  count, key)
    hyper = _hyper(cfg)
    sims = None
    new_P = P
    if spec.galore:
        if refresh:
            if _grad_is_lowrank(grad, spec):
                raise ValueError(
                    f"refresh step needs full-rank grad for {spec.path}")
            new_P, sims = _refresh_leaf(grad, P, mask, spec, cfg, key)
        P_deq_full = projector.maybe_dequantize(new_P, jnp.float32)
        if _grad_is_lowrank(grad, spec):
            low = grad.astype(jnp.float32)
        else:
            low = projector.project(grad.astype(jnp.float32), P_deq_full,
                                    spec.side)
        direction, new_inner = adam8bit.update(low, inner, count, hyper)

        if spec.batch:
            # scan the back-projection + SR requant over the stacked layer
            # axis: per-layer full-rank transients only
            b = spec.nbatch
            flat = lambda t: jax.tree_util.tree_map(
                lambda x: x.reshape((b,) + x.shape[len(spec.batch):]), t)
            param_f = flat(param)
            dir_f = direction.reshape((b,) + direction.shape[len(spec.batch):])
            P_f = flat(new_P)

            def body(carry, inp):
                p_l, d_l, P_l, i = inp
                P_deq = projector.maybe_dequantize(P_l, jnp.float32)
                newp = _apply_weight_update(
                    p_l, d_l, P_deq, spec, cfg, lr,
                    jax.random.fold_in(key, i))
                return carry, newp

            _, new_param_f = jax.lax.scan(
                body, 0, (param_f, dir_f, P_f, jnp.arange(b)))
            new_param = jax.tree_util.tree_map(
                lambda x, ref: x.reshape(ref.shape), new_param_f, param)
        else:
            new_param = _apply_weight_update(param, direction, P_deq_full,
                                             spec, cfg, lr, key)
    else:
        direction, new_inner = adam8bit.update(
            grad.astype(jnp.float32), inner, count, hyper)
        new_param = _apply_weight_update(param, direction, None, spec, cfg,
                                         lr, key)
    return new_param, new_inner, new_P, sims


def _leaf_sig(x):
    """Structural signature of a leaf — two leaves with equal signatures
    run the identical update program and can be stacked + scanned."""
    if x is None:
        return None
    if isinstance(x, Adam8bitState):
        return ("adam", _leaf_sig(x.m), _leaf_sig(x.v))
    if quant.is_qtensor(x):
        return ("qt", tuple(x.q.shape), str(x.q.dtype),
                tuple(x.scale.shape), x.zero is not None, x.bits, x.block,
                x.orig_last, x.dtype)
    return ("arr", tuple(x.shape), str(x.dtype))


def _group_sig(param, grad, inner, P, spec: LeafSpec):
    return (spec.shape, spec.galore, spec.side, spec.rank, spec.batch,
            _leaf_sig(param), _leaf_sig(grad), _leaf_sig(inner),
            _leaf_sig(P))


def _stack_leaves(leaves):
    """Stack a list of same-structure pytrees (QTensor / Adam8bitState /
    array) along a new axis 0, leaf-wise."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *leaves)


def _unstack_leaf(stacked, j):
    return jax.tree_util.tree_map(lambda x: x[j], stacked)


def _run_group(idxs, p_flat, g_flat, i_flat, pr_flat, spec: LeafSpec,
               cfg: QGaLoreConfig, lr, count, rng):
    """Update a group of same-signature leaves with one scanned program.

    Per-leaf RNG keys are folded from the ORIGINAL leaf indices, so the
    result is bit-identical to running the leaves through the Python loop.
    Returns {idx: (new_param, new_inner, new_P)}.
    """
    keys = jnp.stack([jax.random.fold_in(rng, i) for i in idxs])
    p_s = _stack_leaves([p_flat[i] for i in idxs])
    g_s = _stack_leaves([g_flat[i] for i in idxs])
    i_s = _stack_leaves([i_flat[i] for i in idxs])
    has_proj = pr_flat[idxs[0]] is not None
    pr_s = _stack_leaves([pr_flat[i] for i in idxs]) if has_proj else None

    def body(carry, inp):
        if has_proj:
            p, g, inn, P_, k = inp
        else:
            p, g, inn, k = inp
            P_ = None
        np_, ni_, _, _ = _update_leaf(p, g, inn, P_, spec, cfg, lr,
                                      count, None, k, False)
        # P is never refreshed inside a group (refresh leaves run singly)
        # — don't thread it through the scan outputs, which would copy
        # every grouped projection each step.
        return carry, (np_, ni_)

    xs = (p_s, g_s, i_s, pr_s, keys) if has_proj else (p_s, g_s, i_s, keys)
    _, outs = jax.lax.scan(body, 0, xs)
    results = {}
    for j, idx in enumerate(idxs):
        np_ = _unstack_leaf(outs[0], j)
        ni_ = _unstack_leaf(outs[1], j)
        results[idx] = (np_, ni_, pr_flat[idx])
    return results


def apply_updates(
    params,
    grads,
    state: QGaLoreState,
    cfg: QGaLoreConfig,
    lr,
    rng,
    refresh_masks: Optional[Dict[int, jax.Array]] = None,
    refresh: bool = False,
    specs: Optional[List[LeafSpec]] = None,
):
    """One optimizer step (pure; jit with ``refresh`` static).

    ``grads`` leaves may be full-rank or low-rank (see module docstring).
    ``refresh_masks``: {leaf_index: (nbatch,) bool} for galore leaves due for
    subspace refresh (only consulted when ``refresh=True``; unmasked galore
    leaves keep their P).

    Leaves are not updated one-by-one: with ``cfg.batch_leaves`` (default)
    all leaves sharing an update signature (shape / side / rank /
    quantization layout) are stacked and driven by one ``lax.scan``, and
    with ``cfg.fused_update`` (default) each eligible leaf's Adam +
    back-projection + SR requant runs as one fused kernel. Neither changes
    the numbers — per-leaf RNG folding is preserved.

    Returns (new_params, new_state, metrics).
    """
    specs = specs or leaf_specs(params, cfg)
    p_flat, treedef = jax.tree_util.tree_flatten(params,
                                                 is_leaf=quant.is_qtensor)
    g_flat = jax.tree_util.tree_flatten(grads, is_leaf=quant.is_qtensor)[0]
    i_flat = jax.tree_util.tree_flatten(
        state.inner, is_leaf=lambda x: isinstance(x, Adam8bitState))[0]
    pr_flat = jax.tree_util.tree_flatten(
        state.proj, is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]
    count = state.count + 1

    sims_out: Dict[str, jax.Array] = {}
    refresh_masks = refresh_masks or {}
    n_leaves = len(p_flat)

    # Partition: leaves due for refresh (or with grouping off) run singly;
    # the rest are grouped by their update signature.
    groups: Dict[Any, List[int]] = {}
    singles: List[int] = []
    for idx, spec in enumerate(specs):
        do_refresh = refresh and spec.galore and idx in refresh_masks
        if do_refresh or not cfg.batch_leaves:
            singles.append(idx)
        else:
            sig = _group_sig(p_flat[idx], g_flat[idx], i_flat[idx],
                             pr_flat[idx], spec)
            groups.setdefault(sig, []).append(idx)

    results: Dict[int, tuple] = {}
    for sig, idxs in groups.items():
        if len(idxs) == 1:
            singles.append(idxs[0])
            continue
        results.update(_run_group(idxs, p_flat, g_flat, i_flat, pr_flat,
                                  specs[idxs[0]], cfg, lr, count, rng))

    for idx in singles:
        param, grad, inner, P, spec = (p_flat[idx], g_flat[idx],
                                       i_flat[idx], pr_flat[idx],
                                       specs[idx])
        key = jax.random.fold_in(rng, idx)
        do_refresh = refresh and spec.galore and idx in refresh_masks
        mask = refresh_masks.get(idx)
        if do_refresh and mask is None:
            mask = jnp.ones((spec.nbatch,), bool)
        np_, ni_, npr_, sims = _update_leaf(
            param, grad, inner, P, spec, cfg, lr, count, mask, key,
            do_refresh)
        results[idx] = (np_, ni_, npr_)
        if sims is not None:
            sims_out[spec.path] = sims

    new_p = [results[i][0] for i in range(n_leaves)]
    new_i = [results[i][1] for i in range(n_leaves)]
    new_pr = [results[i][2] for i in range(n_leaves)]

    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_state = QGaLoreState(
        inner=jax.tree_util.tree_unflatten(treedef, new_i),
        proj=jax.tree_util.tree_unflatten(treedef, new_pr),
        count=count,
    )
    metrics = {"sims": sims_out}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# Memory model (paper Tables 1/2, Fig. 5)
# ---------------------------------------------------------------------------

def memory_report(params, cfg: QGaLoreConfig,
                  fp_state_bytes: int = 2) -> Dict[str, float]:
    """Analytic bytes for weights + optimizer states (the paper's 'estimated
    memory' columns count exactly these). Non-quantized Adam states are
    counted at BF16 (paper's baseline convention); pass 4 for true FP32."""
    specs = leaf_specs(params, cfg)
    flat = jax.tree_util.tree_flatten(params, is_leaf=quant.is_qtensor)[0]
    w_bytes = opt_bytes = proj_bytes = 0
    for leaf, spec in zip(flat, specs):
        n = int(np.prod(spec.shape))
        if quant.is_qtensor(leaf):
            w_bytes += leaf.nbytes()
        else:
            w_bytes += n * min(leaf.dtype.itemsize, 2)   # bf16 weights
        state_elems = int(np.prod(spec.low_shape)) if spec.galore else n
        bytes_per = 1 if cfg.adam_bits == 8 else fp_state_bytes
        opt_bytes += 2 * state_elems * bytes_per          # m and v
        if cfg.adam_bits == 8:
            opt_bytes += 2 * (state_elems // cfg.quant_block + 1) * 8
        if spec.galore:
            d = projector.proj_dim(spec.mat_shape) * spec.rank * spec.nbatch
            if cfg.proj_bits >= 16:
                proj_bytes += d * 4
            else:
                proj_bytes += d * cfg.proj_bits // 8
    return {
        "weights_gb": w_bytes / 2**30,
        "optimizer_gb": (opt_bytes + proj_bytes) / 2**30,
        "projection_gb": proj_bytes / 2**30,
        "total_gb": (w_bytes + opt_bytes + proj_bytes) / 2**30,
    }
