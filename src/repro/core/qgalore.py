"""The Q-GaLore optimizer (paper §3.5) as a composable JAX module.

Combines:
  * low-rank gradient projection (GaLore) with per-leaf left/right sides,
  * INT4 block-wise quantized projection matrices (§3.3),
  * INT8 block-wise quantized weights updated via stochastic rounding (§3.4),
  * 8-bit Adam inner optimizer,
  * in-graph lazy subspace refresh: a per-layer boolean mask (driven by the
    host-side adaptive controller, §3.2) gates an SVD recomputation via
    ``lax.cond`` inside a ``lax.scan`` over the stacked-layer axis, so only
    masked layers pay the SVD cost.

Leaves with stacked leading dims — ``(L, m, n)`` per-layer stacks or
``(L, E, m, n)`` expert stacks — are treated as batches of independent 2-D
GaLore problems (vmapped projection, scanned refresh).

Gradients arriving at :func:`apply_updates` may be **full-rank** (simple
path) or **already low-rank** (fused projected-backward path, see
``repro.train.stack``); refresh steps always require full-rank grads for the
leaves being refreshed.

Hot-path execution (``apply_updates``)
--------------------------------------
Steady-state (non-refresh) steps run through two optimizations, both on by
default and gated by ``QGaLoreConfig``:

* ``cfg.fused_update`` — eligible leaves (symmetric INT8 weight, INT4
  projection, stochastic rounding on) update through ONE fused kernel
  (:func:`repro.kernels.ops.fused_qgalore_update`): low-rank Adam →
  INT4 back-projection → SR INT8 requant, with the full-rank f32 update
  living only in kernel VMEM — it is never written to HBM. Backend
  selection (pallas-tpu / pallas-interpret / pure-XLA ref) comes from
  :mod:`repro.kernels.dispatch`. Given the same RNG key the fused path
  draws the same SR randoms as the unfused composition and matches it to
  within one INT8 quantum (fp reassociation at floor boundaries).
* ``cfg.batch_leaves`` — leaves whose update program is identical
  (same virtual shape, side, rank, quantization layout) are stacked and
  driven by one ``lax.scan`` instead of a per-leaf Python loop, shrinking
  the traced HLO and reusing one compiled kernel across leaves. RNG
  folding is per original leaf index, so grouping does not change
  numerics.

Memory model (paper Table 2): per GaLore leaf ``(m, n)`` the persistent
state is the INT8 weight (codes + f32 block scales), the INT4 projection
``(min(m,n), r)`` (nibbles + scale/zero), and the two low-rank INT8 Adam
moments ``(max(m,n), r)``; on the fused path the transient full-rank f32
update stays in VMEM and the only full-rank f32 stream left in HBM is the
SR randoms input (see ``repro.kernels.ops``).
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QGaLoreConfig
from repro.core import adam8bit, projector, quant
from repro.core.adam8bit import Adam8bitState, AdamHyper
from repro.core.quant import QTensor
from repro.core.rules import as_rules
from repro.kernels import ops as kernel_ops


# ---------------------------------------------------------------------------
# Leaf specs (static metadata)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LeafSpec:
    path: str
    shape: Tuple[int, ...]        # virtual (dequantized) shape
    galore: bool
    side: str                     # "left" | "right" | ""
    rank: int
    batch: Tuple[int, ...]        # leading dims (layer stacks / experts)
    # --- param-group resolution (repro.core.rules) ---
    frozen: bool = False          # dropped from the optimizer entirely
    lr_scale: float = 1.0         # per-group learning-rate multiplier
    group: str = "default"        # name of the resolved ParamGroup
    # effective per-leaf recipe (base config + group overrides); None only
    # for specs built outside leaf_specs (tests constructing LeafSpec raw)
    cfg: Optional[QGaLoreConfig] = None
    # --- tensor-parallel annotation (distributed.sharding.annotate_tp) ---
    # Which matrix dim the model axis splits (0 = row m, 1 = col n; None =
    # unsharded) and over how many ranks. Project/backproject and the
    # refresh consume shards through these: a surviving-dim shard keeps the
    # low-rank moments sharded with a replicated P, a projected-dim shard
    # keeps P sliced on d with replicated moments (see
    # core.projector.proj_dim_sharded). Defaults describe the DP-only /
    # single-device contract, so un-annotated specs behave exactly as
    # before.
    shard_dim: Optional[int] = None
    tp: int = 1

    @property
    def mat_shape(self) -> Tuple[int, int]:
        return self.shape[-2], self.shape[-1]

    @property
    def proj_sharded(self) -> bool:
        """True when the TP shard slices the projection's d axis."""
        return projector.proj_dim_sharded(self.side, self.shard_dim)

    @property
    def nbatch(self) -> int:
        return int(np.prod(self.batch)) if self.batch else 1

    @property
    def low_shape(self) -> Tuple[int, ...]:
        return self.batch + projector.lowrank_shape(self.mat_shape, self.rank)

    @property
    def proj_shape(self) -> Tuple[int, ...]:
        d = projector.proj_dim(self.mat_shape)
        return self.batch + (d, self.rank)


def _leaf_shape(leaf) -> Tuple[int, ...]:
    return tuple(leaf.shape)


def _is_embedding_path(path: str) -> bool:
    p = path.lower()
    return any(k in p for k in ("embed", "lm_head", "unembed", "wte", "wpe"))


def leaf_specs(params, cfg) -> List[LeafSpec]:
    """One spec per leaf, in tree_flatten order (QTensor = one leaf).

    ``cfg`` may be a plain ``QGaLoreConfig`` (single default group — the
    pre-rules behavior, bit-identical) or a ``ParamRules``: each leaf path
    is resolved to its first-matching group, whose overrides produce the
    per-leaf effective config stored on ``spec.cfg`` and consulted by every
    downstream consumer (init/update, adaptive controller, sharding,
    memory report). Frozen-group leaves get ``frozen=True``, never GaLore,
    and hold no optimizer state.
    """
    rules = as_rules(cfg)
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=quant.is_qtensor)[0]
    specs = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        shape = _leaf_shape(leaf)
        grp = rules.resolve(pstr)
        eff = grp.apply_to(rules.base)
        galore = (
            not grp.frozen
            and eff.enabled
            and len(shape) >= 2
            and shape[-1] >= eff.min_dim
            and shape[-2] >= eff.min_dim
            and (eff.galore_embeddings or not _is_embedding_path(pstr))
        )
        if galore:
            side = projector.galore_side(shape)
            rank = min(eff.rank, min(shape[-2], shape[-1]))
            specs.append(LeafSpec(pstr, shape, True, side, rank,
                                  tuple(shape[:-2]), frozen=False,
                                  lr_scale=grp.lr_scale, group=grp.name,
                                  cfg=eff))
        else:
            specs.append(LeafSpec(pstr, shape, False, "", 0, (),
                                  frozen=grp.frozen,
                                  lr_scale=grp.lr_scale, group=grp.name,
                                  cfg=eff))
    return specs


def _eff_cfg(spec: LeafSpec, cfg) -> QGaLoreConfig:
    """The per-leaf effective config (spec.cfg), falling back to the global
    base for specs constructed without rules resolution."""
    if spec.cfg is not None:
        return spec.cfg
    return as_rules(cfg).base


# ---------------------------------------------------------------------------
# Optimizer state
# ---------------------------------------------------------------------------

class QGaLoreState(NamedTuple):
    inner: Any        # pytree of Adam8bitState (None for frozen leaves)
    proj: Any         # pytree: QTensor P per galore leaf, None otherwise
    count: jax.Array  # int32 scalar


def _is_inner_leaf(x) -> bool:
    """is_leaf for flattening ``state.inner`` — frozen leaves hold None."""
    return isinstance(x, Adam8bitState) or x is None


def _hyper(cfg: QGaLoreConfig) -> AdamHyper:
    return AdamHyper.from_config(cfg)


def _init_projection(spec: LeafSpec, cfg: QGaLoreConfig, key) -> Any:
    """Random-orthonormal init; the controller forces a refresh at step 0."""
    d, r = projector.proj_dim(spec.mat_shape), spec.rank
    q = projector.random_orthonormal(key, d, r, batch=spec.nbatch)
    q = q.reshape(spec.batch + (d, r)) if spec.batch else q[0]
    if cfg.proj_bits >= 16:
        return q.astype(jnp.float32)
    return projector.quantize_projection(q, cfg.proj_bits, cfg.quant_block)


def init(params, cfg, key=None, specs: Optional[List[LeafSpec]] = None
         ) -> QGaLoreState:
    """Build the optimizer state. ``cfg``: QGaLoreConfig or ParamRules.
    Frozen-group leaves hold NO state (None inner, None projection)."""
    key = jax.random.PRNGKey(0) if key is None else key
    specs = specs or leaf_specs(params, cfg)
    flat, treedef = jax.tree_util.tree_flatten(params,
                                               is_leaf=quant.is_qtensor)
    inner, proj = [], []
    for i, (leaf, spec) in enumerate(zip(flat, specs)):
        eff = _eff_cfg(spec, cfg)
        if spec.frozen:
            inner.append(None)
            proj.append(None)
        elif spec.galore:
            inner.append(adam8bit.init_state(spec.low_shape, _hyper(eff)))
            proj.append(_init_projection(spec, eff, jax.random.fold_in(key, i)))
        else:
            inner.append(adam8bit.init_state(spec.shape, _hyper(eff)))
            proj.append(None)
    return QGaLoreState(
        inner=jax.tree_util.tree_unflatten(treedef, inner),
        proj=jax.tree_util.tree_unflatten(treedef, proj),
        count=jnp.zeros((), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Subspace refresh (in-graph, mask-gated)
# ---------------------------------------------------------------------------

def refresh_slice(g, P_flat, mask, idx, cfg: QGaLoreConfig, rank: int,
                  side: str, key):
    """Mask-gated subspace refresh over a flat slice of batch entries.

    ``g``: (b, m, n) f32 gradient slices; ``P_flat``: projection with every
    inner leaf carrying leading dim b; ``mask``: (b,) bool; ``idx``: (b,)
    int32 GLOBAL unit indices — per-unit RNG folding uses the global index,
    so a layer-sharded (distributed) refresh draws the same randoms as the
    replicated scan. Returns (P_new_flat, sims (b,), ratios); sims = -1
    where not refreshed. With ``cfg.adaptive_rank`` on, ``ratios`` is the
    (b, rank) cumulative explained-variance profile of each refreshed
    gradient under its FRESH (pre-quantization) projection (-1 rows where
    not refreshed) — the same SVD pass feeds both signals, no extra
    decomposition. With it off, ``ratios`` is None and the traced graph is
    IDENTICAL to the pre-adaptive-rank one: even a dead extra einsum
    changes XLA fusion enough to drift the similarity values by ulps,
    which flips interval-doubling decisions the golden fixture pins. Only
    masked entries pay the SVD (``lax.cond`` in the scan).
    """
    want_ratios = cfg.adaptive_rank

    def body(carry, inp):
        g_b, P_b, mask_b, i = inp

        def do_refresh(_):
            sub_key = jax.random.fold_in(key, i)
            P_new = projector.compute_subspace(
                g_b, rank, side, cfg.subspace_method, sub_key,
                cfg.subspace_iters)
            sim = projector.subspace_similarity(
                projector.maybe_dequantize(P_b), P_new)
            if cfg.proj_bits >= 16:
                P_out = P_new.astype(jnp.float32)
            else:
                P_out = projector.quantize_projection(P_new, cfg.proj_bits,
                                                      cfg.quant_block)
            if want_ratios:
                return P_out, sim, projector.explained_ratio(g_b, P_new,
                                                             side)
            return P_out, sim

        def keep(_):
            if want_ratios:
                return (P_b, jnp.float32(-1.0),
                        jnp.full((rank,), -1.0, jnp.float32))
            return P_b, jnp.float32(-1.0)

        return carry, jax.lax.cond(mask_b, do_refresh, keep, operand=None)

    _, outs = jax.lax.scan(
        body, 0, (g.astype(jnp.float32), P_flat, mask.astype(bool),
                  idx.astype(jnp.int32)))
    if want_ratios:
        P_new_flat, sims, ratios = outs
    else:
        (P_new_flat, sims), ratios = outs, None
    return P_new_flat, sims, ratios


def _refresh_leaf(grad_full, P_old, mask, spec: LeafSpec,
                  cfg: QGaLoreConfig, key):
    """Recompute P for the masked batch entries of one leaf.

    grad_full: (batch..., m, n); P_old: QTensor/array (batch..., d, r);
    mask: (nbatch,) bool. Returns (P_new, sims (nbatch,), ratios) where
    ratios is (nbatch, r) under ``cfg.adaptive_rank`` and None otherwise;
    sims/ratios = -1 where not refreshed.
    """
    b = spec.nbatch
    m, n = spec.mat_shape
    g = grad_full.reshape(b, m, n)
    # flatten leading batch dims of every inner leaf (q / scale / zero)
    P_flat = jax.tree_util.tree_map(
        lambda x: x.reshape((b,) + x.shape[len(spec.batch):]), P_old)
    P_new_flat, sims, ratios = refresh_slice(
        g, P_flat, mask, jnp.arange(b, dtype=jnp.int32), cfg, spec.rank,
        spec.side, key)
    # restore original leading batch dims, leaf-wise (works for QTensor and
    # plain arrays alike — aux metadata is preserved by the scan/cond).
    P_new = jax.tree_util.tree_map(
        lambda new, old: new.reshape(old.shape), P_new_flat, P_old)
    return P_new, sims, ratios


# ---------------------------------------------------------------------------
# The update step
# ---------------------------------------------------------------------------

def _grad_is_lowrank(grad, spec: LeafSpec) -> bool:
    return spec.galore and tuple(grad.shape) == spec.low_shape \
        and tuple(grad.shape) != spec.shape


# ---------------------------------------------------------------------------
# Fused update path (one kernel: Adam + back-projection + SR requant)
# ---------------------------------------------------------------------------

def _fused_eligible(param, P, spec: LeafSpec, cfg: QGaLoreConfig) -> bool:
    """The fused kernel covers the paper-default configuration: symmetric
    INT8 weights with stochastic rounding and an INT4 projection. Anything
    else (fp weights, fp projections, round-to-nearest) takes the unfused
    composition."""
    return (
        cfg.fused_update
        and spec.galore
        and cfg.stochastic_rounding
        and quant.is_qtensor(param) and param.bits == 8 and param.symmetric
        and quant.is_qtensor(P) and P.bits == 4
    )


def _update_leaf_fused(param, grad, inner: Adam8bitState, P, spec: LeafSpec,
                       cfg: QGaLoreConfig, lr, count, key):
    """Steady-state update of one GaLore leaf through the fused kernel.

    Draws the same SR randoms as the unfused path (same per-layer key
    folding), so results agree to within one INT8 quantum. Stacked leaves
    scan the kernel over the layer axis so the full-rank transients exist
    for one layer at a time.
    """
    hyper = _hyper(cfg)
    if _grad_is_lowrank(grad, spec):
        low = grad.astype(jnp.float32)
    else:
        P_deq = projector.maybe_dequantize(P, jnp.float32)
        low = projector.project(grad.astype(jnp.float32), P_deq, spec.side)
    m32, v32 = adam8bit.moments_fp32(inner)

    fused = functools.partial(
        kernel_ops.fused_qgalore_update, side=spec.side, gscale=cfg.scale,
        beta1=cfg.beta1, beta2=cfg.beta2, eps=cfg.eps,
        weight_decay=cfg.weight_decay)

    if spec.batch:
        b = spec.nbatch
        nlead = len(spec.batch)
        flat = lambda t: jax.tree_util.tree_map(
            lambda x: x.reshape((b,) + x.shape[nlead:]), t)
        param_f, P_f = flat(param), flat(P)
        low_f = low.reshape((b,) + low.shape[nlead:])
        m_f = m32.reshape(low_f.shape)
        v_f = v32.reshape(low_f.shape)

        def body(carry, inp):
            p_l, l_l, m_l, v_l, P_l, i = inp
            out = fused(p_l, l_l, m_l, v_l, P_l, count, lr,
                        jax.random.fold_in(key, i))
            return carry, out

        _, (newp_f, mn_f, vn_f) = jax.lax.scan(
            body, 0, (param_f, low_f, m_f, v_f, P_f, jnp.arange(b)))
        new_param = jax.tree_util.tree_map(
            lambda x, ref: x.reshape(ref.shape), newp_f, param)
        m_new = mn_f.reshape(m32.shape)
        v_new = vn_f.reshape(v32.shape)
    else:
        new_param, m_new, v_new = fused(param, low, m32, v32, P, count, lr,
                                        key)
    new_inner = adam8bit.pack_moments(m_new, v_new, hyper)
    return new_param, new_inner, P, None, None


def _apply_weight_update(param, direction_or_upd, P_deq, spec: LeafSpec,
                         cfg: QGaLoreConfig, lr, key):
    """Back-project (if galore) and apply the update to one (sub-)leaf.
    Shapes here carry NO leading stack dims — the caller scans over them so
    the full-rank f32 transients (project_back output, dequantized weight)
    exist for one layer at a time (this bounded deepseek's optimizer temp
    at 651 GiB/chip → sub-GiB; see EXPERIMENTS.md §Perf)."""
    if P_deq is not None:
        upd = projector.project_back(
            direction_or_upd.astype(jnp.float32), P_deq, spec.side)
        upd = cfg.scale * upd
    else:
        upd = direction_or_upd.astype(jnp.float32)

    if quant.is_qtensor(param):
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * quant.dequantize(param,
                                                            jnp.float32)
        delta = -lr * upd
        if cfg.stochastic_rounding:
            return quant.requantize_sr(param, delta, key)
        w = quant.dequantize(param, jnp.float32) + delta
        return quant.quantize_blockwise(
            w, bits=param.bits, block=param.block,
            symmetric=param.symmetric)
    w = param.astype(jnp.float32)
    if cfg.weight_decay:
        upd = upd + cfg.weight_decay * w
    return (w - lr * upd).astype(param.dtype)


def _update_leaf(param, grad, inner: Adam8bitState, P, spec: LeafSpec,
                 cfg: QGaLoreConfig, lr, count, mask, key, refresh: bool):
    """Returns (new_param, new_inner, new_P, sims_or_None,
    ratios_or_None)."""
    if not refresh and _fused_eligible(param, P, spec, cfg):
        return _update_leaf_fused(param, grad, inner, P, spec, cfg, lr,
                                  count, key)
    hyper = _hyper(cfg)
    sims = ratios = None
    new_P = P
    if spec.galore:
        if refresh:
            if _grad_is_lowrank(grad, spec):
                raise ValueError(
                    f"refresh step needs full-rank grad for {spec.path}")
            new_P, sims, ratios = _refresh_leaf(grad, P, mask, spec, cfg,
                                                key)
        P_deq_full = projector.maybe_dequantize(new_P, jnp.float32)
        if _grad_is_lowrank(grad, spec):
            low = grad.astype(jnp.float32)
        else:
            low = projector.project(grad.astype(jnp.float32), P_deq_full,
                                    spec.side)
        direction, new_inner = adam8bit.update(low, inner, count, hyper)

        if spec.batch:
            # scan the back-projection + SR requant over the stacked layer
            # axis: per-layer full-rank transients only
            b = spec.nbatch
            flat = lambda t: jax.tree_util.tree_map(
                lambda x: x.reshape((b,) + x.shape[len(spec.batch):]), t)
            param_f = flat(param)
            dir_f = direction.reshape((b,) + direction.shape[len(spec.batch):])
            P_f = flat(new_P)

            def body(carry, inp):
                p_l, d_l, P_l, i = inp
                P_deq = projector.maybe_dequantize(P_l, jnp.float32)
                newp = _apply_weight_update(
                    p_l, d_l, P_deq, spec, cfg, lr,
                    jax.random.fold_in(key, i))
                return carry, newp

            _, new_param_f = jax.lax.scan(
                body, 0, (param_f, dir_f, P_f, jnp.arange(b)))
            new_param = jax.tree_util.tree_map(
                lambda x, ref: x.reshape(ref.shape), new_param_f, param)
        else:
            new_param = _apply_weight_update(param, direction, P_deq_full,
                                             spec, cfg, lr, key)
    else:
        direction, new_inner = adam8bit.update(
            grad.astype(jnp.float32), inner, count, hyper)
        new_param = _apply_weight_update(param, direction, None, spec, cfg,
                                         lr, key)
    return new_param, new_inner, new_P, sims, ratios


def _leaf_sig(x):
    """Structural signature of a leaf — two leaves with equal signatures
    run the identical update program and can be stacked + scanned."""
    if x is None:
        return None
    if isinstance(x, Adam8bitState):
        return ("adam", _leaf_sig(x.m), _leaf_sig(x.v))
    if quant.is_qtensor(x):
        return ("qt", tuple(x.q.shape), str(x.q.dtype),
                tuple(x.scale.shape), x.zero is not None, x.bits, x.block,
                x.orig_last, x.dtype)
    return ("arr", tuple(x.shape), str(x.dtype))


def _shard_sig(sh):
    """Hashable signature of a (possibly nested) sharding pytree leaf —
    leaves with different layouts must not share one scanned program, or
    GSPMD rematerializes the whole stack to a common layout (the noisy
    "involuntary full rematerialization" warnings)."""
    if sh is None:
        return None
    return tuple(
        str(getattr(s, "spec", s))
        for s in jax.tree_util.tree_leaves(
            sh, is_leaf=lambda x: x is None))


def _group_sig(param, grad, inner, P, spec: LeafSpec, shard=None):
    # spec.cfg (the per-group effective recipe) and lr_scale are part of
    # the signature: same-signature-same-group leaves still scan as one
    # program, while leaves from different param groups never share one.
    # The TP annotation is part of it too: leaves whose state splits over
    # the model axis on different dims (or not at all) must never share a
    # scanned program even when no explicit shardings are passed.
    return (spec.shape, spec.galore, spec.side, spec.rank, spec.batch,
            spec.shard_dim, spec.tp,
            spec.cfg, spec.lr_scale,
            _leaf_sig(param), _leaf_sig(grad), _leaf_sig(inner),
            _leaf_sig(P), _shard_sig(shard))


def _stack_leaves(leaves):
    """Stack a list of same-structure pytrees (QTensor / Adam8bitState /
    array) along a new axis 0, leaf-wise."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *leaves)


def _unstack_leaf(stacked, j):
    return jax.tree_util.tree_map(lambda x: x[j], stacked)


def _constrain_stacked(tree, shard_tree):
    """Annotate a stacked (leading group axis) pytree with the per-leaf
    sharding extended by a replicated group dim. Enriching the scan xs/ys
    this way keeps GSPMD from involuntarily rematerializing the stacked
    operands to a common layout inside the batched-leaf scan (ZeRO-sharded
    runs; see ROADMAP). No-op outside mesh contexts (``shard_tree=None``)."""
    if shard_tree is None or tree is None:
        return tree

    def one(x, s):
        if x is None or not isinstance(s, jax.sharding.NamedSharding):
            return x
        if len(s.spec) > x.ndim - 1:
            return x
        ext = jax.sharding.NamedSharding(
            s.mesh, jax.sharding.PartitionSpec(None, *s.spec))
        return jax.lax.with_sharding_constraint(x, ext)

    return jax.tree_util.tree_map(one, tree, shard_tree,
                                  is_leaf=lambda x: x is None)


def _run_group(idxs, p_flat, g_flat, i_flat, pr_flat, spec: LeafSpec,
               cfg: QGaLoreConfig, lr, count, rng, shard=None):
    """Update a group of same-signature leaves with one scanned program.

    Per-leaf RNG keys are folded from the ORIGINAL leaf indices, so the
    result is bit-identical to running the leaves through the Python loop.
    ``shard``: optional (param, inner, proj) shardings shared by every leaf
    of the group (the group signature includes the layout) — used to
    annotate the stacked scan operands. Returns
    {idx: (new_param, new_inner, new_P)}.
    """
    keys = jnp.stack([jax.random.fold_in(rng, i) for i in idxs])
    p_s = _stack_leaves([p_flat[i] for i in idxs])
    g_s = _stack_leaves([g_flat[i] for i in idxs])
    i_s = _stack_leaves([i_flat[i] for i in idxs])
    has_proj = pr_flat[idxs[0]] is not None
    pr_s = _stack_leaves([pr_flat[i] for i in idxs]) if has_proj else None
    if shard is not None:
        p_sh, i_sh, pr_sh = shard
        p_s = _constrain_stacked(p_s, p_sh)
        i_s = _constrain_stacked(i_s, i_sh)
        if has_proj:
            pr_s = _constrain_stacked(pr_s, pr_sh)

    def body(carry, inp):
        if has_proj:
            p, g, inn, P_, k = inp
        else:
            p, g, inn, k = inp
            P_ = None
        np_, ni_, _, _, _ = _update_leaf(p, g, inn, P_, spec, cfg, lr,
                                         count, None, k, False)
        # P is never refreshed inside a group (refresh leaves run singly)
        # — don't thread it through the scan outputs, which would copy
        # every grouped projection each step.
        return carry, (np_, ni_)

    xs = (p_s, g_s, i_s, pr_s, keys) if has_proj else (p_s, g_s, i_s, keys)
    _, outs = jax.lax.scan(body, 0, xs)
    if shard is not None:
        outs = (_constrain_stacked(outs[0], p_sh),
                _constrain_stacked(outs[1], i_sh))
    results = {}
    for j, idx in enumerate(idxs):
        np_ = _unstack_leaf(outs[0], j)
        ni_ = _unstack_leaf(outs[1], j)
        results[idx] = (np_, ni_, pr_flat[idx])
    return results


def _lr_for(spec: LeafSpec, lr):
    """Per-group learning rate; the multiply is skipped for the unit scale
    so default single-group rules stay bit-identical."""
    return lr if spec.lr_scale == 1.0 else lr * spec.lr_scale


def apply_updates(
    params,
    grads,
    state: QGaLoreState,
    cfg,
    lr,
    rng,
    refresh_masks: Optional[Dict[int, jax.Array]] = None,
    refresh: bool = False,
    specs: Optional[List[LeafSpec]] = None,
    shardings=None,
):
    """One optimizer step (pure; jit with ``refresh`` static).

    ``cfg`` may be a plain ``QGaLoreConfig`` or a ``ParamRules``: each
    leaf's recipe (rank / bits / scale / lr multiplier) comes from its
    resolved param group (``spec.cfg``); frozen-group leaves pass through
    untouched and hold no state. This function is the fused/batched
    executor of the canonical transform chain
    (``repro.core.transform.qgalore_transform``) — the stage-by-stage
    reference composition lives in ``repro.core.transform``.

    ``grads`` leaves may be full-rank or low-rank (see module docstring).
    ``refresh_masks``: {leaf_index: (nbatch,) bool} for galore leaves due for
    subspace refresh (only consulted when ``refresh=True``; unmasked galore
    leaves keep their P).

    Leaves are not updated one-by-one: with ``cfg.batch_leaves`` (default)
    all leaves sharing an update signature (shape / side / rank /
    quantization layout / param group) are stacked and driven by one
    ``lax.scan``, and with ``cfg.fused_update`` (default) each eligible
    leaf's Adam + back-projection + SR requant runs as one fused kernel.
    Neither changes the numbers — per-leaf RNG folding is preserved.

    ``shardings``: optional ``(param_shardings, QGaLoreState shardings)``
    pair (mesh runs) — layouts join the batching signature and annotate the
    scanned stacks, which quiets GSPMD's involuntary-rematerialization
    warnings in ZeRO-sharded runs.

    Returns (new_params, new_state, metrics).
    """
    rules = as_rules(cfg)
    base = rules.base
    specs = specs or leaf_specs(params, rules)
    p_flat, treedef = jax.tree_util.tree_flatten(params,
                                                 is_leaf=quant.is_qtensor)
    g_flat = jax.tree_util.tree_flatten(grads, is_leaf=quant.is_qtensor)[0]
    i_flat = jax.tree_util.tree_flatten(state.inner,
                                        is_leaf=_is_inner_leaf)[0]
    pr_flat = jax.tree_util.tree_flatten(
        state.proj, is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]
    psh_flat = ish_flat = prsh_flat = None
    if shardings is not None:
        param_sh, opt_sh = shardings
        psh_flat = jax.tree_util.tree_flatten(
            param_sh, is_leaf=quant.is_qtensor)[0]
        ish_flat = jax.tree_util.tree_flatten(
            opt_sh.inner, is_leaf=_is_inner_leaf)[0]
        prsh_flat = jax.tree_util.tree_flatten(
            opt_sh.proj,
            is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]
    count = state.count + 1

    sims_out: Dict[str, jax.Array] = {}
    ratios_out: Dict[str, jax.Array] = {}
    refresh_masks = refresh_masks or {}
    n_leaves = len(p_flat)

    # Partition: frozen leaves pass through; leaves due for refresh (or
    # with grouping off) run singly; the rest are grouped by signature.
    groups: Dict[Any, List[int]] = {}
    singles: List[int] = []
    results: Dict[int, tuple] = {}
    for idx, spec in enumerate(specs):
        if spec.frozen:
            results[idx] = (p_flat[idx], i_flat[idx], pr_flat[idx])
            continue
        do_refresh = refresh and spec.galore and idx in refresh_masks
        if do_refresh or not base.batch_leaves:
            singles.append(idx)
        else:
            sig = _group_sig(p_flat[idx], g_flat[idx], i_flat[idx],
                             pr_flat[idx], spec,
                             None if psh_flat is None else
                             (psh_flat[idx], ish_flat[idx], prsh_flat[idx]))
            groups.setdefault(sig, []).append(idx)

    for sig, idxs in groups.items():
        if len(idxs) == 1:
            singles.append(idxs[0])
            continue
        spec0 = specs[idxs[0]]
        shard = None if psh_flat is None else \
            (psh_flat[idxs[0]], ish_flat[idxs[0]], prsh_flat[idxs[0]])
        results.update(_run_group(idxs, p_flat, g_flat, i_flat, pr_flat,
                                  spec0, _eff_cfg(spec0, rules),
                                  _lr_for(spec0, lr), count, rng,
                                  shard=shard))

    for idx in singles:
        param, grad, inner, P, spec = (p_flat[idx], g_flat[idx],
                                       i_flat[idx], pr_flat[idx],
                                       specs[idx])
        key = jax.random.fold_in(rng, idx)
        do_refresh = refresh and spec.galore and idx in refresh_masks
        mask = refresh_masks.get(idx)
        if do_refresh and mask is None:
            mask = jnp.ones((spec.nbatch,), bool)
        np_, ni_, npr_, sims, ratios = _update_leaf(
            param, grad, inner, P, spec, _eff_cfg(spec, rules),
            _lr_for(spec, lr), count, mask, key, do_refresh)
        results[idx] = (np_, ni_, npr_)
        if sims is not None:
            sims_out[spec.path] = sims
        if ratios is not None:
            ratios_out[spec.path] = ratios

    new_p = [results[i][0] for i in range(n_leaves)]
    new_i = [results[i][1] for i in range(n_leaves)]
    new_pr = [results[i][2] for i in range(n_leaves)]

    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_state = QGaLoreState(
        inner=jax.tree_util.tree_unflatten(treedef, new_i),
        proj=jax.tree_util.tree_unflatten(treedef, new_pr),
        count=count,
    )
    metrics = {"sims": sims_out, "ratios": ratios_out}
    return new_params, new_state, metrics


# ---------------------------------------------------------------------------
# Memory model (paper Tables 1/2, Fig. 5)
# ---------------------------------------------------------------------------

def memory_report(params, cfg, fp_state_bytes: int = 2,
                  specs: Optional[List[LeafSpec]] = None
                  ) -> Dict[str, float]:
    """Analytic bytes for weights + optimizer states (the paper's 'estimated
    memory' columns count exactly these). Non-quantized Adam states are
    counted at BF16 (paper's baseline convention); pass 4 for true FP32.

    Group-aware: per-leaf ranks/bits come from the resolved param group and
    frozen-group leaves contribute their weights but ZERO optimizer bytes —
    this is what the fine-tune entrypoint compares against QLoRA. Pass
    ``specs`` to account for runtime rank overrides (dynamic rank
    adaptation) instead of re-deriving the static specs."""
    rules = as_rules(cfg)
    specs = specs if specs is not None else leaf_specs(params, rules)
    flat = jax.tree_util.tree_flatten(params, is_leaf=quant.is_qtensor)[0]
    w_bytes = opt_bytes = proj_bytes = 0
    for leaf, spec in zip(flat, specs):
        eff = _eff_cfg(spec, rules)
        n = int(np.prod(spec.shape))
        if quant.is_qtensor(leaf):
            w_bytes += leaf.nbytes()
        else:
            w_bytes += n * min(leaf.dtype.itemsize, 2)   # bf16 weights
        if spec.frozen:
            continue                                     # no optimizer state
        state_elems = int(np.prod(spec.low_shape)) if spec.galore else n
        bytes_per = 1 if eff.adam_bits == 8 else fp_state_bytes
        opt_bytes += 2 * state_elems * bytes_per          # m and v
        if eff.adam_bits == 8:
            opt_bytes += 2 * (state_elems // eff.quant_block + 1) * 8
        if spec.galore:
            d = projector.proj_dim(spec.mat_shape) * spec.rank * spec.nbatch
            if eff.proj_bits >= 16:
                proj_bytes += d * 4
            else:
                proj_bytes += d * eff.proj_bits // 8
    return {
        "weights_gb": w_bytes / 2**30,
        "optimizer_gb": (opt_bytes + proj_bytes) / 2**30,
        "projection_gb": proj_bytes / 2**30,
        "total_gb": (w_bytes + opt_bytes + proj_bytes) / 2**30,
    }


def optimizer_state_bytes(params, cfg,
                          specs: Optional[List[LeafSpec]] = None) -> int:
    """Total analytic optimizer-state bytes (moments + projections) —
    the scalar the adaptive-rank ablation tracks step over step."""
    rep = memory_report(params, cfg, specs=specs)
    return int(round(rep["optimizer_gb"] * 2**30))


def dp_payload_bytes(specs: List[LeafSpec]) -> int:
    """Per-step compressed-DP gradient-reduction payload in bytes: galore
    leaves all-reduce their LOW-RANK f32 gradient (project-before-allreduce,
    see ``repro.train.step``), everything else ships full-rank f32. Rank
    overrides from dynamic rank adaptation flow in through ``specs`` —
    shrinking a leaf's rank shrinks its wire bytes proportionally."""
    return 4 * sum(
        int(np.prod(s.low_shape if s.galore else s.shape))
        for s in specs if not s.frozen)


# ---------------------------------------------------------------------------
# Dynamic rank adaptation: spec overrides + low-rank state migration
# ---------------------------------------------------------------------------

def apply_rank_overrides(specs: List[LeafSpec],
                         overrides: Dict[str, int]) -> List[LeafSpec]:
    """Rebuild specs with per-path rank overrides (path → new rank).

    Both ``spec.rank`` and the per-leaf effective config's ``rank`` are
    replaced, so every downstream consumer — ``low_shape`` /
    ``proj_shape``, the ``_group_sig`` batching signature, sharding
    derivation, memory accounting — sees the shrunk rank. Ranks may only
    shrink (truncation keeps the top singular directions; growing would
    need information a smaller state no longer holds)."""
    if not overrides:
        return specs
    unknown = set(overrides) - {s.path for s in specs}
    if unknown:
        raise ValueError(f"rank overrides for unknown leaves: "
                         f"{sorted(unknown)}")
    out = []
    for spec in specs:
        r = overrides.get(spec.path)
        if r is None or r == spec.rank:
            out.append(spec)
            continue
        if not spec.galore:
            raise ValueError(
                f"rank override on non-galore leaf {spec.path}")
        if r > spec.rank:
            raise ValueError(
                f"rank override must shrink: {spec.path} "
                f"{spec.rank} -> {r}")
        cfg2 = spec.cfg if spec.cfg is None else \
            dataclasses.replace(spec.cfg, rank=r)
        out.append(dataclasses.replace(spec, rank=r, cfg=cfg2))
    return out


def truncate_lowrank(x, side: str, new_rank: int):
    """Slice the leading ``new_rank`` directions out of a low-rank array
    ``(batch..., m, r)`` (right) / ``(batch..., r, n)`` (left)."""
    if side == "right":
        return x[..., :new_rank]
    return x[..., :new_rank, :]


def migrate_rank_state(inner: Adam8bitState, P, spec: LeafSpec,
                       new_rank: int, cfg=None):
    """Shrink one galore leaf's optimizer state from ``spec.rank`` to
    ``new_rank``: truncate the INT8 Adam moments and re-quantize the INT4
    projection to the leading-``new_rank`` columns (projection columns are
    singular-value-ordered, so truncation keeps the top directions — the
    AdaRankGrad move). Deterministic (round-to-nearest requantization, no
    SR), so migrate-then-checkpoint equals checkpoint-then-migrate
    bit-for-bit. Returns ``(new_inner, new_P)`` shaped for the
    ``apply_rank_overrides``'d spec.

    TP shards are respected for free: both truncations slice only the r
    axis, never the TP-sharded d / surviving axis, so migrating a
    model-sharded leaf equals the shard-slice of the replicated migration
    (INT4 blocks run along r — requantization of a d-slice is the d-slice
    of the requantization). The trainer re-places the shrunk state under
    the re-derived (2-D mesh + ZeRO) shardings after the rebuild."""
    if not spec.galore:
        raise ValueError(f"cannot migrate non-galore leaf {spec.path}")
    if not 0 < new_rank < spec.rank:
        raise ValueError(
            f"{spec.path}: bad rank transition {spec.rank} -> {new_rank}")
    eff = _eff_cfg(spec, cfg if cfg is not None else spec.cfg)
    m32, v32 = adam8bit.moments_fp32(inner)
    m32 = truncate_lowrank(m32, spec.side, new_rank)
    v32 = truncate_lowrank(v32, spec.side, new_rank)
    new_inner = adam8bit.pack_moments(m32, v32, _hyper(eff))
    P_deq = projector.maybe_dequantize(P, jnp.float32)
    P_trunc = P_deq[..., :new_rank]
    if eff.proj_bits >= 16:
        new_P = P_trunc.astype(jnp.float32)
    else:
        new_P = projector.quantize_projection(P_trunc, eff.proj_bits,
                                              eff.quant_block)
    return new_inner, new_P
