# Q-GaLore core: quantization, projection, adaptive subspace control,
# 8-bit Adam, and the combined optimizer.
from repro.core import adam8bit, adaptive, optimizers, projector, qgalore, quant  # noqa: F401
