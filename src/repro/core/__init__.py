# Q-GaLore core: quantization, projection, adaptive subspace control,
# 8-bit Adam, param-group rules, the transform chain, and the combined
# optimizer (the chain's fused executor).
from repro.core import adam8bit, adaptive, optimizers, projector, qgalore, \
    quant, rules, transform  # noqa: F401
