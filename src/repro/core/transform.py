"""Optax-style gradient-transformation chain over the Q-GaLore recipe.

The optimizer's public surface is a :class:`GradientTransformation` —
``init``/``update`` pair — built by composing named stages::

    tx = chain(
        clip_global_norm(1.0),
        project(rules),          # GaLore: full-rank grad -> rank-r subspace
        quantized_adam(rules),   # 8-bit Adam on the low-rank statistics
        backproject(rules),      # subspace direction -> full-rank update
        sr_requant(rules),       # SR INT8 weight write (+ weight decay)
    )
    state = tx.init(params, key)
    new_params, state, metrics = tx.update(grads, state, params,
                                           lr=1e-3, rng=key)

Unlike optax, ``update`` returns the **new params**, not additive updates:
Q-GaLore's weights are blockwise-INT8 ``QTensor``s whose update IS a
stochastic-rounding requantization — there is no full-precision weight to
add a delta to. Stages communicate through a per-call context (the
projection chosen by ``project`` is what ``backproject`` inverts), so the
chain stays a flat, ordered list like optax's while still expressing the
projected-update sandwich.

Param groups (``repro.core.rules``) thread through every stage: each leaf
uses its resolved per-group recipe (rank / bits / scale / lr multiplier),
and frozen-group leaves pass through all stages untouched with no state.

The canonical pre-built chain is :func:`qgalore_transform` — today's
``qgalore.init`` / ``qgalore.apply_updates`` monolith is its fused/batched
executor (one fused kernel per eligible leaf, same-signature leaves
scanned as one program). Under default single-group rules it is
bit-identical to the pre-redesign optimizer (the golden-trajectory harness
enforces this), and the stage-by-stage composition above reproduces it
exactly with the fusion/batching strategy flags off
(``tests/test_rules.py::TestTransformParity``).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import adam8bit, projector, qgalore, quant
from repro.core.qgalore import LeafSpec, _eff_cfg, _hyper
from repro.core.rules import ParamRules, as_rules


class GradientTransformation(NamedTuple):
    """``init(params, key=None, specs=None) -> state`` and
    ``update(grads, state, params, *, lr, rng, refresh_masks=None,
    refresh=False, specs=None, shardings=None) ->
    (new_params, new_state, metrics)``."""
    init: Callable
    update: Callable


class Stage(NamedTuple):
    """One chain stage. ``init(params_flat, specs, rules, key) -> state``;
    ``apply(ctx, vals, state) -> (vals, new_state)`` where ``vals`` is the
    flat per-leaf value list flowing down the chain (grads -> low-rank
    grads -> Adam directions -> full-rank updates -> new params)."""
    name: str
    rules: Optional[ParamRules]
    init: Callable
    apply: Callable


class ChainState(NamedTuple):
    stages: Tuple[Any, ...]
    count: jax.Array


class _Ctx:
    """Per-update scratch shared by the stages of one chain invocation."""

    def __init__(self, params_flat, specs, rules, lr, rng, count,
                 refresh, refresh_masks, shardings=None):
        self.params_flat = params_flat
        self.specs = specs
        self.rules = rules
        self.lr = lr
        self.rng = rng
        self.count = count
        self.refresh = refresh
        self.refresh_masks = refresh_masks or {}
        self.shardings = shardings           # {path: NamedSharding} hints
        self.metrics: Dict[str, Any] = {}
        self.proj: Optional[List] = None     # written by project()

    def key(self, idx: int):
        # identical folding to the monolith: one key per ORIGINAL leaf
        # index, shared by the refresh SVD and the SR requant draw
        return jax.random.fold_in(self.rng, idx)

    def lr_for(self, spec: LeafSpec):
        return qgalore._lr_for(spec, self.lr)

    def constrain_low(self, idx: int, val):
        """Pin a low-rank per-leaf value to its TP/ZeRO moment layout
        (``distributed.sharding.lowrank_shardings``). No hint for this
        leaf — or no hints at all — is a no-op, so the single-process
        chain stays bit-identical."""
        sh = self.shardings.get(self.specs[idx].path) \
            if isinstance(self.shardings, dict) else None
        return val if sh is None \
            else jax.lax.with_sharding_constraint(val, sh)


def _noop_init(params_flat, specs, rules, key):
    return None


# ---------------------------------------------------------------------------
# Global-norm clipping (also used directly by the train step)
# ---------------------------------------------------------------------------

def global_norm(grads) -> jax.Array:
    leaves = [g for g in jax.tree_util.tree_leaves(grads)
              if hasattr(g, "dtype") and jnp.issubdtype(g.dtype,
                                                        jnp.floating)]
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                        for g in leaves))


def clip_by_global_norm(grads, max_norm,
                        specs: Optional[List[LeafSpec]] = None):
    """Clip to ``max_norm`` (no-op when falsy), returning
    ``(clipped, norm)``. With ``specs``, frozen-group leaves neither enter
    the norm nor get scaled — their gradients are discarded downstream, so
    letting them inflate the norm would silently damp every trainable
    leaf's update."""
    frozen = {i for i, s in enumerate(specs or []) if s.frozen}
    flat, treedef = jax.tree_util.tree_flatten(
        grads, is_leaf=quant.is_qtensor)
    norm = global_norm([g for i, g in enumerate(flat) if i not in frozen])
    if not max_norm:
        return grads, norm
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-6))
    out = [g if i in frozen
           else ((g * scale).astype(g.dtype)
                 if hasattr(g, "dtype")
                 and jnp.issubdtype(g.dtype, jnp.floating) else g)
           for i, g in enumerate(flat)]
    return jax.tree_util.tree_unflatten(treedef, out), norm


def clip_global_norm(max_norm) -> Stage:
    """Stage form of :func:`clip_by_global_norm` (put it first) — one
    implementation, the stage just adapts the flat value list."""

    def apply(ctx: _Ctx, vals, _state):
        out, norm = clip_by_global_norm(list(vals), max_norm,
                                        specs=ctx.specs)
        ctx.metrics["grad_norm"] = norm
        return out, None

    return Stage("clip_global_norm", None, _noop_init, apply)


# ---------------------------------------------------------------------------
# The four core stages
# ---------------------------------------------------------------------------

def project(cfg_or_rules) -> Stage:
    """GaLore projection: owns the per-leaf projection matrices P (INT4
    ``QTensor``s under the paper recipe) and, at refresh steps, the
    mask-gated in-graph SVD. Emits low-rank gradients for galore leaves
    (passthrough for everything else, including grads that already arrive
    low-rank from the fused backward)."""
    rules = as_rules(cfg_or_rules)

    def init(params_flat, specs, rules_, key):
        key = jax.random.PRNGKey(0) if key is None else key
        out = []
        for i, spec in enumerate(specs):
            if spec.galore:
                out.append(qgalore._init_projection(
                    spec, _eff_cfg(spec, rules_),
                    jax.random.fold_in(key, i)))
            else:
                out.append(None)
        return out

    def apply(ctx: _Ctx, vals, P_flat):
        new_P = list(P_flat)
        out = list(vals)
        for idx, spec in enumerate(ctx.specs):
            if spec.frozen or not spec.galore:
                continue
            eff = _eff_cfg(spec, ctx.rules)
            g = vals[idx]
            P = P_flat[idx]
            key = ctx.key(idx)
            if ctx.refresh and idx in ctx.refresh_masks:
                if qgalore._grad_is_lowrank(g, spec):
                    raise ValueError(
                        f"refresh step needs full-rank grad for {spec.path}")
                mask = ctx.refresh_masks[idx]
                if mask is None:
                    mask = jnp.ones((spec.nbatch,), bool)
                P, sims, ratios = qgalore._refresh_leaf(g, P, mask, spec,
                                                        eff, key)
                ctx.metrics.setdefault("sims", {})[spec.path] = sims
                if ratios is not None:
                    ctx.metrics.setdefault("ratios", {})[spec.path] = \
                        ratios
            new_P[idx] = P
            if qgalore._grad_is_lowrank(g, spec):
                out[idx] = ctx.constrain_low(idx, g.astype(jnp.float32))
            else:
                P_deq = projector.maybe_dequantize(P, jnp.float32)
                out[idx] = ctx.constrain_low(idx, projector.project(
                    g.astype(jnp.float32), P_deq, spec.side))
        ctx.proj = new_P
        return out, new_P

    return Stage("project", rules, init, apply)


def quantized_adam(cfg_or_rules) -> Stage:
    """8-bit Adam on the (low-rank, for galore leaves) gradient statistics.
    Owns the blockwise-INT8 moment pairs; emits bias-corrected directions.
    Per-group ``adam_bits`` selects fp32 moments instead."""
    rules = as_rules(cfg_or_rules)

    def init(params_flat, specs, rules_, key):
        out = []
        for spec in specs:
            if spec.frozen:
                out.append(None)
            else:
                shape = spec.low_shape if spec.galore else spec.shape
                out.append(adam8bit.init_state(
                    shape, _hyper(_eff_cfg(spec, rules_))))
        return out

    def apply(ctx: _Ctx, vals, inner_flat):
        out = list(vals)
        new_inner = list(inner_flat)
        for idx, spec in enumerate(ctx.specs):
            if spec.frozen:
                continue
            eff = _eff_cfg(spec, ctx.rules)
            direction, st = adam8bit.update(
                vals[idx].astype(jnp.float32), inner_flat[idx], ctx.count,
                _hyper(eff))
            out[idx] = ctx.constrain_low(idx, direction) \
                if spec.galore else direction
            new_inner[idx] = st
        return out, new_inner

    return Stage("quantized_adam", rules, init, apply)


def backproject(cfg_or_rules) -> Stage:
    """Map subspace directions back to full-rank updates with the SAME P
    the ``project`` stage used this step, scaled by the per-group GaLore
    alpha. Stacked leaves scan the back-projection over the layer axis
    (bounded full-rank transients, mirroring the monolith)."""
    rules = as_rules(cfg_or_rules)

    def apply(ctx: _Ctx, vals, _state):
        if ctx.proj is None:
            raise ValueError("backproject() requires a project() stage "
                             "earlier in the chain")
        out = list(vals)
        for idx, spec in enumerate(ctx.specs):
            if spec.frozen or not spec.galore:
                continue
            eff = _eff_cfg(spec, ctx.rules)
            P = ctx.proj[idx]
            direction = vals[idx]
            if spec.batch:
                b = spec.nbatch
                nlead = len(spec.batch)
                P_f = jax.tree_util.tree_map(
                    lambda x: x.reshape((b,) + x.shape[nlead:]), P)
                d_f = direction.reshape((b,) + direction.shape[nlead:])

                def body(carry, inp, _side=spec.side, _scale=eff.scale):
                    d_l, P_l = inp
                    P_deq = projector.maybe_dequantize(P_l, jnp.float32)
                    upd = _scale * projector.project_back(
                        d_l.astype(jnp.float32), P_deq, _side)
                    return carry, upd

                _, upd_f = jax.lax.scan(body, 0, (d_f, P_f))
                out[idx] = upd_f.reshape(spec.shape)
            else:
                P_deq = projector.maybe_dequantize(P, jnp.float32)
                out[idx] = eff.scale * projector.project_back(
                    direction.astype(jnp.float32), P_deq, spec.side)
        return out, None

    return Stage("backproject", rules, _noop_init, apply)


def sr_requant(cfg_or_rules) -> Stage:
    """Terminal stage: apply ``-lr * update`` to the weights. INT8
    ``QTensor`` weights are rewritten by stochastic-rounding requantization
    (per-group ``stochastic_rounding`` / round-to-nearest); float weights
    get the plain subtraction. Honors the per-group ``weight_decay`` and
    learning-rate multiplier. The chain's value list becomes the new
    params."""
    rules = as_rules(cfg_or_rules)

    def apply(ctx: _Ctx, vals, _state):
        out = []
        for idx, spec in enumerate(ctx.specs):
            param = ctx.params_flat[idx]
            if spec.frozen:
                out.append(param)
                continue
            eff = _eff_cfg(spec, ctx.rules)
            upd = vals[idx]
            lr_eff = ctx.lr_for(spec)
            key = ctx.key(idx)
            if spec.galore and spec.batch:
                # per-layer scan with the monolith's per-layer key folding
                b = spec.nbatch
                nlead = len(spec.batch)
                param_f = jax.tree_util.tree_map(
                    lambda x: x.reshape((b,) + x.shape[nlead:]), param)
                upd_f = upd.reshape((b,) + upd.shape[nlead:])

                def body(carry, inp, _spec=spec, _eff=eff, _lr=lr_eff,
                         _key=key):
                    p_l, u_l, i = inp
                    newp = qgalore._apply_weight_update(
                        p_l, u_l, None, _spec, _eff, _lr,
                        jax.random.fold_in(_key, i))
                    return carry, newp

                _, newp_f = jax.lax.scan(
                    body, 0, (param_f, upd_f, jnp.arange(b)))
                out.append(jax.tree_util.tree_map(
                    lambda x, ref: x.reshape(ref.shape), newp_f, param))
            else:
                out.append(qgalore._apply_weight_update(
                    param, upd, None, spec, eff, lr_eff, key))
        return out, None

    return Stage("sr_requant", rules, _noop_init, apply)


def add_weight_decay(wd: Optional[float] = None) -> Stage:
    """Explicit decoupled weight-decay stage (adds ``wd * W`` to the update
    before ``sr_requant``). NOTE: ``sr_requant`` already honors the
    per-group ``cfg.weight_decay`` — use this stage only for chains whose
    configs keep ``weight_decay=0`` (e.g. to decay just one group, or to
    decay before clipping)."""

    def apply(ctx: _Ctx, vals, _state):
        out = list(vals)
        for idx, spec in enumerate(ctx.specs):
            if spec.frozen:
                continue
            eff = _eff_cfg(spec, ctx.rules)
            decay = eff.weight_decay if wd is None else wd
            if not decay:
                continue
            param = ctx.params_flat[idx]
            w = quant.dequantize(param, jnp.float32) \
                if quant.is_qtensor(param) else param.astype(jnp.float32)
            out[idx] = vals[idx].astype(jnp.float32) + decay * w
        return out, None

    return Stage("add_weight_decay", None, _noop_init, apply)


# ---------------------------------------------------------------------------
# Chain combinator
# ---------------------------------------------------------------------------

def chain(*stages: Stage, rules=None) -> GradientTransformation:
    """Compose stages into one transformation (optax ``chain`` analogue).
    ``rules`` defaults to the first stage that carries one."""
    if rules is None:
        for s in stages:
            if s.rules is not None:
                rules = s.rules
                break
    if rules is None:
        raise ValueError("chain() needs rules — pass rules= or include a "
                         "stage built from a config/rule-set")
    rules = as_rules(rules)

    def init(params, key=None, specs=None):
        specs = specs or qgalore.leaf_specs(params, rules)
        params_flat = jax.tree_util.tree_flatten(
            params, is_leaf=quant.is_qtensor)[0]
        return ChainState(
            stages=tuple(s.init(params_flat, specs, rules, key)
                         for s in stages),
            count=jnp.zeros((), jnp.int32))

    def update(grads, state: ChainState, params, *, lr, rng,
               refresh_masks=None, refresh: bool = False, specs=None,
               shardings=None):
        # ``shardings``: either the fused executor's TrainState-of-
        # shardings (ignored here) or a ``{path: NamedSharding}`` dict of
        # low-rank layout hints (``sharding.lowrank_shardings``) pinned
        # between stages on a 2-D mesh.
        specs = specs or qgalore.leaf_specs(params, rules)
        params_flat, treedef = jax.tree_util.tree_flatten(
            params, is_leaf=quant.is_qtensor)
        vals = jax.tree_util.tree_flatten(
            grads, is_leaf=quant.is_qtensor)[0]
        count = state.count + 1
        ctx = _Ctx(params_flat, specs, rules, lr, rng, count, refresh,
                   refresh_masks,
                   shardings=shardings
                   if isinstance(shardings, dict) else None)
        new_states = []
        for s, st in zip(stages, state.stages):
            vals, st = s.apply(ctx, vals, st)
            new_states.append(st)
        new_params = jax.tree_util.tree_unflatten(treedef, vals)
        return new_params, ChainState(tuple(new_states), count), ctx.metrics

    return GradientTransformation(init, update)


# ---------------------------------------------------------------------------
# The canonical pre-built chain
# ---------------------------------------------------------------------------

def qgalore_reference_chain(cfg_or_rules) -> GradientTransformation:
    """The canonical four stages composed literally — the unfused,
    per-leaf reference. Matches the fused executor bit-for-bit when the
    strategy flags (``fused_update`` / ``batch_leaves``) are off, and to
    within one INT8 quantum otherwise."""
    rules = as_rules(cfg_or_rules)
    return chain(project(rules), quantized_adam(rules), backproject(rules),
                 sr_requant(rules), rules=rules)


def qgalore_transform(cfg_or_rules, specs=None) -> GradientTransformation:
    """The canonical Q-GaLore transformation: semantically the
    ``project → quantized_adam → backproject → sr_requant`` chain, executed
    by the fused/batched monolith (``qgalore.apply_updates``) — eligible
    leaves run Adam + INT4 back-projection + SR requant as ONE kernel and
    same-signature leaves scan as one program. State is a plain
    ``QGaLoreState`` (checkpoint / ZeRO-sharding compatible). This is what
    the production train step uses."""
    rules = as_rules(cfg_or_rules)
    _specs = specs

    def init(params, key=None, specs=None):
        return qgalore.init(params, rules, key, specs=specs or _specs)

    def update(grads, state, params, *, lr, rng, refresh_masks=None,
               refresh: bool = False, specs=None, shardings=None):
        return qgalore.apply_updates(
            params, grads, state, rules, lr=lr, rng=rng,
            refresh_masks=refresh_masks, refresh=refresh,
            specs=specs or _specs, shardings=shardings)

    return GradientTransformation(init, update)
