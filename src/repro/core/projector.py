"""Gradient-subspace computation and projection (GaLore core, paper §3.2-3.3).

For a gradient ``G (m, n)`` GaLore projects into a rank-``r`` subspace:

* ``m >= n`` → "right": ``P = V_r (n, r)``; low-rank ``G @ P`` is ``(m, r)``;
  back-projection ``L @ P^T``.
* ``m < n``  → "left":  ``P = U_r (m, r)``; low-rank ``P^T @ G`` is ``(r, n)``;
  back-projection ``P @ L``.

Two subspace methods:

* ``svd`` — exact ``jnp.linalg.svd`` (paper-faithful).
* ``randomized`` — Halko-style randomized range finder with ``q`` power
  iterations: ``O(mnr)`` instead of ``O(mn^2)``; the TPU-native default for
  large layers (full SVD lowers to slow QR iteration on TPU).

Subspace similarity uses the rotation/sign-invariant overlap
``||P_old^T P_new||_F^2 / r`` (mean squared canonical correlation), which
equals 1 for identical subspaces — naive flattened cosine is corrupted by the
sign/permutation ambiguity of singular vectors.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QTensor


def galore_side(shape: Tuple[int, ...]) -> str:
    """'right' when m >= n else 'left' (GaLore convention)."""
    m, n = shape[-2], shape[-1]
    return "right" if m >= n else "left"


def proj_dim(shape: Tuple[int, ...]) -> int:
    """The dimension the projection matrix lives on (rows of P)."""
    m, n = shape[-2], shape[-1]
    return n if m >= n else m


def lowrank_shape(shape: Tuple[int, ...], rank: int) -> Tuple[int, ...]:
    m, n = shape[-2], shape[-1]
    lead = tuple(shape[:-2])
    if m >= n:
        return lead + (m, rank)
    return lead + (rank, n)


# ---------------------------------------------------------------------------
# Subspace computation
# ---------------------------------------------------------------------------

def random_orthonormal(key: jax.Array, d: int, r: int,
                       batch: int = 0) -> jax.Array:
    """Random orthonormal frame(s) ``(batch?, d, r)`` — the cold-start
    projection (the controller forces a real refresh at step 0) and the
    rotation generator for subspace-invariance property tests."""
    b = max(batch, 1)
    q = jnp.linalg.qr(jax.random.normal(key, (b, d, r), jnp.float32))[0]
    return q if batch else q[0]


def _topr_svd(G: jax.Array, rank: int, side: str) -> jax.Array:
    """Exact top-r singular vectors. G: (m, n) float32."""
    U, _, Vh = jnp.linalg.svd(G, full_matrices=False)
    if side == "right":
        return Vh[:rank, :].T          # (n, r)
    return U[:, :rank]                 # (m, r)


def _topr_randomized(G: jax.Array, rank: int, side: str, key: jax.Array,
                     iters: int = 2, oversample: int = 8) -> jax.Array:
    """Randomized range finder for the top-r left/right singular subspace."""
    A = G if side == "left" else G.T           # want range(A): (d, k)
    d, k = A.shape
    p = min(rank + oversample, k)
    omega = jax.random.normal(key, (k, p), dtype=A.dtype)
    Y = A @ omega                               # (d, p)
    for _ in range(iters):
        Y = jnp.linalg.qr(Y)[0]
        Y = A @ (A.T @ Y)
    Q = jnp.linalg.qr(Y)[0]                     # (d, p) orthonormal
    # Rayleigh-Ritz refinement to order directions by singular value.
    B = Q.T @ A                                 # (p, k)
    Ub, _, _ = jnp.linalg.svd(B, full_matrices=False)
    return (Q @ Ub)[:, :rank]                   # (d, r)


def compute_subspace(
    G: jax.Array,
    rank: int,
    side: Optional[str] = None,
    method: str = "svd",
    key: Optional[jax.Array] = None,
    iters: int = 2,
) -> jax.Array:
    """Top-r subspace of a single gradient matrix ``G (m, n)`` → P."""
    side = side or galore_side(G.shape)
    Gf = G.astype(jnp.float32)
    rank = min(rank, min(G.shape[-2], G.shape[-1]))
    if method == "randomized":
        if key is None:
            key = jax.random.PRNGKey(0)
        return _topr_randomized(Gf, rank, side, key, iters)
    return _topr_svd(Gf, rank, side)


# ---------------------------------------------------------------------------
# Projection apply / back-project (batched over leading dims)
# ---------------------------------------------------------------------------

def project(G: jax.Array, P: jax.Array, side: str) -> jax.Array:
    """Full-rank grad → low-rank. Batched over leading dims of both."""
    if side == "right":
        return jnp.einsum("...mn,...nr->...mr", G, P)
    return jnp.einsum("...mr,...mn->...rn", P, G)


def project_back(L: jax.Array, P: jax.Array, side: str) -> jax.Array:
    """Low-rank update → full-rank."""
    if side == "right":
        return jnp.einsum("...mr,...nr->...mn", L, P)
    return jnp.einsum("...mr,...rn->...mn", P, L)


def project_activation(x: jax.Array, P: jax.Array) -> jax.Array:
    """x (..., m) @ P (m, r) — used by the fused projected-backward path so
    the DP all-reduce happens on the (r, n) payload, not (m, n)."""
    return jnp.einsum("...m,mr->...r", x, P)


# ---------------------------------------------------------------------------
# Subspace similarity (adaptive lazy update signal)
# ---------------------------------------------------------------------------

def subspace_similarity(P_old: jax.Array, P_new: jax.Array) -> jax.Array:
    """||P_old^T P_new||_F^2 / r ∈ [0, 1]; 1 ⇔ identical subspaces.

    Works on (possibly dequantized) projection matrices with orthonormal-ish
    columns; batched over leading dims.
    """
    M = jnp.einsum("...dr,...ds->...rs",
                   P_old.astype(jnp.float32), P_new.astype(jnp.float32))
    r = P_new.shape[-1]
    return jnp.sum(M * M, axis=(-2, -1)) / r


# ---------------------------------------------------------------------------
# Explained-variance ratio (dynamic rank adaptation signal)
# ---------------------------------------------------------------------------

def explained_ratio(G: jax.Array, P: jax.Array, side: str) -> jax.Array:
    """Cumulative explained-variance profile of ``G`` under ``P``: entry
    ``k`` is ``||proj of G onto the first k+1 columns of P||_F^2 /
    ||G||_F^2`` — for an exact-SVD ``P`` this is the prefix sum of
    ``sigma_i^2 / sum_j sigma_j^2``, i.e. the top-(k+1) singular energy
    over total. Shape ``(..., r)``; monotone non-decreasing in k, values in
    ``[0, 1]``. The full-rank entry ``[..., -1]`` is invariant under any
    rotation / sign flip / permutation of the P basis (it only depends on
    the subspace); per-prefix entries assume singular-value-ordered columns
    (what :func:`compute_subspace` produces), which is also what makes
    rank-truncation ``P[..., :r']`` keep the TOP directions.
    """
    Gf = G.astype(jnp.float32)
    Pf = P.astype(jnp.float32)
    low = project(Gf, Pf, side)
    # per-direction energies: column k of P owns axis -1 (right) / -2 (left)
    axis = -2 if side == "right" else -1
    energies = jnp.sum(low * low, axis=axis)          # (..., r)
    total = jnp.sum(Gf * Gf, axis=(-2, -1))           # (...,)
    cum = jnp.cumsum(energies, axis=-1)
    return cum / jnp.maximum(total, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Quantized projection helpers
# ---------------------------------------------------------------------------

def quantize_projection(P: jax.Array, bits: int, block: int) -> QTensor:
    """Quantize P (d, r) to INT4 along the r axis (block ≤ r, no padding
    waste for the common r=128 case)."""
    eff_block = min(block, max(2, P.shape[-1]))
    # keep nibble packing happy: even block
    if eff_block % 2:
        eff_block += 1
    return quant.quantize_blockwise(P, bits=bits, block=eff_block,
                                    symmetric=False)


def maybe_dequantize(P, dtype=jnp.float32):
    if isinstance(P, QTensor):
        return quant.dequantize(P, dtype)
    return P.astype(dtype)
