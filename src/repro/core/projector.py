"""Gradient-subspace computation and projection (GaLore core, paper §3.2-3.3).

For a gradient ``G (m, n)`` GaLore projects into a rank-``r`` subspace:

* ``m >= n`` → "right": ``P = V_r (n, r)``; low-rank ``G @ P`` is ``(m, r)``;
  back-projection ``L @ P^T``.
* ``m < n``  → "left":  ``P = U_r (m, r)``; low-rank ``P^T @ G`` is ``(r, n)``;
  back-projection ``P @ L``.

Two subspace methods:

* ``svd`` — exact ``jnp.linalg.svd`` (paper-faithful).
* ``randomized`` — Halko-style randomized range finder with ``q`` power
  iterations: ``O(mnr)`` instead of ``O(mn^2)``; the TPU-native default for
  large layers (full SVD lowers to slow QR iteration on TPU).

Subspace similarity uses the rotation/sign-invariant overlap
``||P_old^T P_new||_F^2 / r`` (mean squared canonical correlation), which
equals 1 for identical subspaces — naive flattened cosine is corrupted by the
sign/permutation ambiguity of singular vectors.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QTensor


def galore_side(shape: Tuple[int, ...]) -> str:
    """'right' when m >= n else 'left' (GaLore convention)."""
    m, n = shape[-2], shape[-1]
    return "right" if m >= n else "left"


def proj_dim(shape: Tuple[int, ...]) -> int:
    """The dimension the projection matrix lives on (rows of P)."""
    m, n = shape[-2], shape[-1]
    return n if m >= n else m


def lowrank_shape(shape: Tuple[int, ...], rank: int) -> Tuple[int, ...]:
    m, n = shape[-2], shape[-1]
    lead = tuple(shape[:-2])
    if m >= n:
        return lead + (m, rank)
    return lead + (rank, n)


# ---------------------------------------------------------------------------
# Subspace computation
# ---------------------------------------------------------------------------

def random_orthonormal(key: jax.Array, d: int, r: int,
                       batch: int = 0) -> jax.Array:
    """Random orthonormal frame(s) ``(batch?, d, r)`` — the cold-start
    projection (the controller forces a real refresh at step 0) and the
    rotation generator for subspace-invariance property tests."""
    b = max(batch, 1)
    q = jnp.linalg.qr(jax.random.normal(key, (b, d, r), jnp.float32))[0]
    return q if batch else q[0]


def _topr_svd(G: jax.Array, rank: int, side: str) -> jax.Array:
    """Exact top-r singular vectors. G: (m, n) float32."""
    U, _, Vh = jnp.linalg.svd(G, full_matrices=False)
    if side == "right":
        return Vh[:rank, :].T          # (n, r)
    return U[:, :rank]                 # (m, r)


def _topr_randomized(G: jax.Array, rank: int, side: str, key: jax.Array,
                     iters: int = 2, oversample: int = 8) -> jax.Array:
    """Randomized range finder for the top-r left/right singular subspace."""
    A = G if side == "left" else G.T           # want range(A): (d, k)
    d, k = A.shape
    p = min(rank + oversample, k)
    omega = jax.random.normal(key, (k, p), dtype=A.dtype)
    Y = A @ omega                               # (d, p)
    for _ in range(iters):
        Y = jnp.linalg.qr(Y)[0]
        Y = A @ (A.T @ Y)
    Q = jnp.linalg.qr(Y)[0]                     # (d, p) orthonormal
    # Rayleigh-Ritz refinement to order directions by singular value.
    B = Q.T @ A                                 # (p, k)
    Ub, _, _ = jnp.linalg.svd(B, full_matrices=False)
    return (Q @ Ub)[:, :rank]                   # (d, r)


def compute_subspace(
    G: jax.Array,
    rank: int,
    side: Optional[str] = None,
    method: str = "svd",
    key: Optional[jax.Array] = None,
    iters: int = 2,
) -> jax.Array:
    """Top-r subspace of a single gradient matrix ``G (m, n)`` → P."""
    side = side or galore_side(G.shape)
    Gf = G.astype(jnp.float32)
    rank = min(rank, min(G.shape[-2], G.shape[-1]))
    if method == "randomized":
        if key is None:
            key = jax.random.PRNGKey(0)
        return _topr_randomized(Gf, rank, side, key, iters)
    return _topr_svd(Gf, rank, side)


# ---------------------------------------------------------------------------
# Projection apply / back-project (batched over leading dims)
# ---------------------------------------------------------------------------

def project(G: jax.Array, P: jax.Array, side: str) -> jax.Array:
    """Full-rank grad → low-rank. Batched over leading dims of both."""
    if side == "right":
        return jnp.einsum("...mn,...nr->...mr", G, P)
    return jnp.einsum("...mr,...mn->...rn", P, G)


def project_back(L: jax.Array, P: jax.Array, side: str) -> jax.Array:
    """Low-rank update → full-rank."""
    if side == "right":
        return jnp.einsum("...mr,...nr->...mn", L, P)
    return jnp.einsum("...mr,...rn->...mn", P, L)


def project_activation(x: jax.Array, P: jax.Array) -> jax.Array:
    """x (..., m) @ P (m, r) — used by the fused projected-backward path so
    the DP all-reduce happens on the (r, n) payload, not (m, n)."""
    return jnp.einsum("...m,mr->...r", x, P)


# ---------------------------------------------------------------------------
# Subspace similarity (adaptive lazy update signal)
# ---------------------------------------------------------------------------

def subspace_similarity(P_old: jax.Array, P_new: jax.Array) -> jax.Array:
    """||P_old^T P_new||_F^2 / r ∈ [0, 1]; 1 ⇔ identical subspaces.

    Works on (possibly dequantized) projection matrices with orthonormal-ish
    columns; batched over leading dims.
    """
    M = jnp.einsum("...dr,...ds->...rs",
                   P_old.astype(jnp.float32), P_new.astype(jnp.float32))
    r = P_new.shape[-1]
    return jnp.sum(M * M, axis=(-2, -1)) / r


# ---------------------------------------------------------------------------
# Explained-variance ratio (dynamic rank adaptation signal)
# ---------------------------------------------------------------------------

def explained_ratio(G: jax.Array, P: jax.Array, side: str) -> jax.Array:
    """Cumulative explained-variance profile of ``G`` under ``P``: entry
    ``k`` is ``||proj of G onto the first k+1 columns of P||_F^2 /
    ||G||_F^2`` — for an exact-SVD ``P`` this is the prefix sum of
    ``sigma_i^2 / sum_j sigma_j^2``, i.e. the top-(k+1) singular energy
    over total. Shape ``(..., r)``; monotone non-decreasing in k, values in
    ``[0, 1]``. The full-rank entry ``[..., -1]`` is invariant under any
    rotation / sign flip / permutation of the P basis (it only depends on
    the subspace); per-prefix entries assume singular-value-ordered columns
    (what :func:`compute_subspace` produces), which is also what makes
    rank-truncation ``P[..., :r']`` keep the TOP directions.
    """
    Gf = G.astype(jnp.float32)
    Pf = P.astype(jnp.float32)
    low = project(Gf, Pf, side)
    # per-direction energies: column k of P owns axis -1 (right) / -2 (left)
    axis = -2 if side == "right" else -1
    energies = jnp.sum(low * low, axis=axis)          # (..., r)
    total = jnp.sum(Gf * Gf, axis=(-2, -1))           # (...,)
    cum = jnp.cumsum(energies, axis=-1)
    return cum / jnp.maximum(total, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Tensor-parallel shard helpers
#
# A TP-sharded weight splits one of its two matrix dims over the model axis
# (``shard_dim``: 0 = row m, 1 = col n). Which side of the GaLore state that
# shard lands on follows from the side convention:
#
#   side   shard_dim   P (d, r)             low-rank / moments
#   right  0 (m)       replicated           sharded on m  (local project)
#   right  1 (n)       sliced on d = n      replicated    (psum on low)
#   left   0 (m)       sliced on d = m      replicated    (psum on low)
#   left   1 (n)       replicated           sharded on n  (local project)
#
# ``quantize_projection`` blocks along the r axis only, so slicing P on its
# d axis commutes BIT-EXACTLY with INT4 quantization — per-shard codes and
# scales are literal row-slices of the replicated quantization (the property
# tests/test_property.py pins).
# ---------------------------------------------------------------------------

def proj_dim_sharded(side: str, shard_dim: Optional[int]) -> bool:
    """True when a weight shard on matrix dim ``shard_dim`` lands on the
    projection dim d (rows of P): the projected-away dim is n for "right"
    and m for "left". False → the shard lands on the low-rank moments'
    surviving dim and P stays replicated over the model axis."""
    if shard_dim is None:
        return False
    return (side == "right") == (shard_dim == 1)


def shard_matrix(G: jax.Array, shard_dim: int, index: int,
                 world: int) -> jax.Array:
    """The TP rank-``index`` slice of a (batch..., m, n) weight/gradient."""
    axis = G.ndim - 2 + shard_dim
    size = G.shape[axis] // world
    return jax.lax.slice_in_dim(G, index * size, (index + 1) * size,
                                axis=axis)


def shard_projection(P, side: str, shard_dim: Optional[int], index: int,
                     world: int):
    """Rank-``index``'s slice of a projection consistent with the weight's
    TP shard dim. When the shard lands on the surviving dim
    (``not proj_dim_sharded``) P is replicated and returned whole;
    otherwise the d axis (dim -2 of P, codes AND per-block scales) is
    sliced — bit-exact against the replicated quantization because INT4
    blocks run along r only."""
    if not proj_dim_sharded(side, shard_dim):
        return P

    def slice_d(x):
        size = x.shape[-2] // world
        return jax.lax.slice_in_dim(x, index * size, (index + 1) * size,
                                    axis=x.ndim - 2)

    if isinstance(P, QTensor):
        return QTensor(slice_d(P.q), slice_d(P.scale),
                       None if P.zero is None else slice_d(P.zero),
                       P.bits, P.block, P.orig_last, P.dtype)
    return slice_d(P)


def reassemble_projection(shards, side: str, shard_dim: Optional[int]):
    """Inverse of :func:`shard_projection`: concatenate per-rank slices back
    to the replicated P (codes and scales concatenated on d). With a
    surviving-dim shard every entry is the full P already."""
    if not proj_dim_sharded(side, shard_dim):
        return shards[0]
    cat = lambda xs: jnp.concatenate(xs, axis=xs[0].ndim - 2)
    if isinstance(shards[0], QTensor):
        p0 = shards[0]
        return QTensor(cat([s.q for s in shards]),
                       cat([s.scale for s in shards]),
                       None if p0.zero is None
                       else cat([s.zero for s in shards]),
                       p0.bits, p0.block, p0.orig_last, p0.dtype)
    return cat(list(shards))


def project_sharded(G, P, side: str, shard_dim: Optional[int], psum):
    """Low-rank projection from per-rank shards: local einsum plus — only
    when the shard dim is the CONTRACTED (projected-away) dim — one ``psum``
    of the low-rank product. ``psum`` is any reducer over the model front
    (``jax.lax.psum`` bound to the axis inside a shard_map, or ``sum`` over
    a host-side list in tests). Never touches a full-rank tensor."""
    low = project(G.astype(jnp.float32), maybe_dequantize(P), side)
    if proj_dim_sharded(side, shard_dim):
        return psum(low)
    return low


def explained_ratio_sharded(G, P, side: str, shard_dim: Optional[int],
                            psum) -> jax.Array:
    """:func:`explained_ratio` of the FULL gradient computed from per-rank
    shards. Contracted-dim shard: psum the (low-rank) projection before
    squaring; surviving-dim shard: per-direction energies are sums of
    squares over the sharded axis, so the partials psum directly. The
    total Frobenius mass psums in both cases. Wire payload is (r,)-sized
    (+ the low-rank product in the contracted case) — no full-rank tensor
    ever crosses the model front."""
    Gf = G.astype(jnp.float32)
    low = project(Gf, maybe_dequantize(P), side)
    axis = -2 if side == "right" else -1
    total = psum(jnp.sum(Gf * Gf, axis=(-2, -1)))
    if proj_dim_sharded(side, shard_dim):
        energies = jnp.sum(jnp.square(psum(low)), axis=axis)
    else:
        energies = psum(jnp.sum(low * low, axis=axis))
    cum = jnp.cumsum(energies, axis=-1)
    return cum / jnp.maximum(total, 1e-30)[..., None]


def _canonical_signs(W: jax.Array) -> jax.Array:
    """Deterministic per-column sign: the largest-|entry| coordinate is made
    positive (ties broken by lowest index via argmax)."""
    pick = jnp.take_along_axis(
        W, jnp.argmax(jnp.abs(W), axis=-2, keepdims=True), axis=-2)
    return W * jnp.where(pick >= 0, 1.0, -1.0)


def sharded_subspace(G_shard: jax.Array, rank: int, side: str,
                     shard_dim: int, psum, eps: float = 1e-12):
    """Exact top-``rank`` subspace of the full gradient from per-rank
    shards, without gathering it: accumulate the Gram matrix over the
    UNSHARDED matrix dim (one psum of a (d, d) block, d = that dim),
    eigendecompose it (replicated, deterministic — every rank computes the
    same factors from the same psum'd Gram, so no cross-rank sign
    divergence), and return this rank's piece of P:

    * surviving-dim shard → the Gram dim IS the projection dim; the
      (sign-canonicalized) top-``rank`` eigenvectors are the full,
      replicated P.
    * contracted-dim shard → the Gram dim is the surviving dim; the local
      P slice is recovered as ``G_shard^T U_r / sigma_r`` (right) /
      ``G_shard V_r / sigma_r`` (left) — each rank materializes only its
      (d_loc, r) slice.

    Eigen-vs-SVD numerics differ at fp32 noise level (compare subspaces via
    :func:`subspace_similarity`, not elementwise). The production
    distributed refresh (train/step.py) instead re-scatters stacked leaves
    over the layer dim and runs the replicated-bit-identical per-layer SVD;
    this routine is the per-matrix alternative for leaves with no layer dim
    to scatter."""
    Gf = G_shard.astype(jnp.float32)
    sliced = proj_dim_sharded(side, shard_dim)
    # Gram over the unsharded dim: (d, d) with d the un-sharded matrix dim
    if (side == "right") == (not sliced):
        C = psum(jnp.einsum("...mn,...mk->...nk", Gf, Gf))   # G^T G (n, n)
    else:
        C = psum(jnp.einsum("...mn,...kn->...mk", Gf, Gf))   # G G^T (m, m)
    lam, W = jnp.linalg.eigh(C)                 # ascending eigenvalues
    lam = lam[..., ::-1][..., :rank]
    W = _canonical_signs(W[..., ::-1][..., :rank])
    if not sliced:
        return W                                # full replicated P
    inv_sigma = jax.lax.rsqrt(jnp.maximum(lam, eps))
    if side == "right":                          # V_loc = G_loc^T U / sigma
        return jnp.einsum("...mn,...mr->...nr", Gf, W) * inv_sigma[..., None, :]
    return jnp.einsum("...mn,...nr->...mr", Gf, W) * inv_sigma[..., None, :]


# ---------------------------------------------------------------------------
# Quantized projection helpers
# ---------------------------------------------------------------------------

def quantize_projection(P: jax.Array, bits: int, block: int) -> QTensor:
    """Quantize P (d, r) to INT4 along the r axis (block ≤ r, no padding
    waste for the common r=128 case)."""
    eff_block = min(block, max(2, P.shape[-1]))
    # keep nibble packing happy: even block
    if eff_block % 2:
        eff_block += 1
    return quant.quantize_blockwise(P, bits=bits, block=eff_block,
                                    symmetric=False)


def maybe_dequantize(P, dtype=jnp.float32):
    if isinstance(P, QTensor):
        return quant.dequantize(P, dtype)
    return P.astype(dtype)
