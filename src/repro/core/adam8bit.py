"""8-bit Adam (Dettmers et al.) — block-wise quantized first/second moments.

The paper uses 8-bit Adam as the inner optimizer for the low-rank gradient
statistics. Moments are stored as block-wise INT8 ``QTensor``s (block 256):
``m`` symmetric (signed), ``v`` asymmetric (non-negative). With
``bits == 32`` the states stay float32 (used for baselines/tests).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QTensor


class Adam8bitState(NamedTuple):
    m: Any          # QTensor | jax.Array
    v: Any          # QTensor | jax.Array


@dataclass(frozen=True)
class AdamHyper:
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    bits: int = 8
    block: int = 256

    @classmethod
    def from_config(cls, cfg) -> "AdamHyper":
        """Derive from a (per-group effective) ``QGaLoreConfig`` — with
        param-group rules every leaf can carry its own ``adam_bits``, so
        the hyper pair is derived per leaf (see repro.core.rules)."""
        return cls(cfg.beta1, cfg.beta2, cfg.eps, cfg.adam_bits,
                   cfg.quant_block)


def _eff_block(shape, hyper: AdamHyper) -> int:
    return quant.auto_block(shape[-1], hyper.block)


def init_state(shape, hyper: AdamHyper) -> Adam8bitState:
    z = jnp.zeros(shape, jnp.float32)
    if hyper.bits == 32:
        return Adam8bitState(z, z)
    blk = _eff_block(shape, hyper)
    m = quant.quantize_blockwise(z, bits=8, block=blk, symmetric=True)
    v = quant.quantize_blockwise(z, bits=8, block=blk, symmetric=False)
    return Adam8bitState(m, v)


def _deq(x) -> jax.Array:
    if isinstance(x, QTensor):
        return quant.dequantize(x, jnp.float32)
    return x.astype(jnp.float32)


def _deq_v(x) -> jax.Array:
    """v is stored as sqrt(v) to halve its dynamic range — a linear INT8
    code on v directly loses small-magnitude elements (bitsandbytes solves
    this with a non-linear dynamic code; sqrt-domain storage achieves the
    same effect with the uniform block-wise quantizer)."""
    s = _deq(x)
    return s * s


def _quant_v(v: jax.Array, hyper: AdamHyper):
    return quant.quantize_blockwise(jnp.sqrt(v), bits=8,
                                    block=_eff_block(v.shape, hyper),
                                    symmetric=False)


def moments_fp32(state: Adam8bitState) -> tuple[jax.Array, jax.Array]:
    """Dequantize the moment pair to f32 (``v`` leaves the sqrt domain).

    Used by the fused update path: the kernel does its Adam math on f32
    moments in VMEM; this is the HBM→f32 load it starts from. The traffic
    is low-rank (``max(m,n) * r`` per moment), a ``r/min(m,n)`` fraction
    of the weight stream.
    """
    is_q = isinstance(state.v, QTensor)
    m = _deq(state.m)
    v = _deq_v(state.v) if is_q else _deq(state.v)
    return m, v


def pack_moments(m: jax.Array, v: jax.Array,
                 hyper: AdamHyper) -> Adam8bitState:
    """Re-quantize updated f32 moments into the stored representation
    (INT8 block-wise for ``bits == 8``, ``v`` back into sqrt domain)."""
    if hyper.bits == 32:
        return Adam8bitState(m, v)
    return Adam8bitState(
        quant.quantize_blockwise(m, bits=8,
                                 block=_eff_block(m.shape, hyper),
                                 symmetric=True),
        _quant_v(v, hyper),
    )


def update(
    grad: jax.Array,
    state: Adam8bitState,
    count: jax.Array,          # step count *after* this update (1-based)
    hyper: AdamHyper,
) -> tuple[jax.Array, Adam8bitState]:
    """One Adam step on (possibly low-rank) ``grad``.

    Returns the bias-corrected direction ``m̂ / (sqrt(v̂) + eps)`` (the caller
    applies learning rate / GaLore scale) and the new state.
    """
    g = grad.astype(jnp.float32)
    m_prev, v_prev = moments_fp32(state)
    m = hyper.beta1 * m_prev + (1.0 - hyper.beta1) * g
    v = hyper.beta2 * v_prev + (1.0 - hyper.beta2) * (g * g)
    c = count.astype(jnp.float32)
    m_hat = m / (1.0 - hyper.beta1 ** c)
    v_hat = v / (1.0 - hyper.beta2 ** c)
    direction = m_hat / (jnp.sqrt(v_hat) + hyper.eps)
    return direction.astype(grad.dtype), pack_moments(m, v, hyper)


def state_nbytes(state: Adam8bitState) -> int:
    return quant.quantized_nbytes(state._asdict())
