"""Block-wise uniform quantization substrate (paper §3.1, §3.4).

Implements the paper's quantizer::

    W_q = clamp(round(W / s) + z, -2^{n-1}, 2^{n-1} - 1)

with per-block scale ``s`` and zero-point ``z`` computed over blocks of 256
elements along the last axis (lane dimension — this vectorizes on the TPU VPU
and lets Pallas kernels broadcast scales from SMEM).

``QTensor`` is a registered pytree so quantized weights flow through jit /
pjit / grad transparently. INT4 values are nibble-packed two-per-uint8.

Stochastic rounding (paper §3.4)::

    SR(x) = floor(x) + Bernoulli(x - floor(x))

is implemented as ``floor(x + u)``, ``u ~ U[0,1)`` which is the same
distribution and fuses into a single VPU pass.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

DEFAULT_BLOCK = 256
_EPS = 1e-12


def _qrange(bits: int) -> Tuple[int, int]:
    return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1


def auto_block(last_dim: int, block: int = DEFAULT_BLOCK) -> int:
    """Largest sensible block ≤ last_dim (avoids 2× padding waste when
    quantizing tensors whose last dim is smaller than the block, e.g. the
    rank-128 low-rank Adam moments)."""
    if last_dim >= block:
        return block
    b = 2
    while b * 2 <= last_dim:
        b *= 2
    return b


# ---------------------------------------------------------------------------
# QTensor pytree
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class QTensor:
    """A block-wise quantized tensor.

    ``q``      int8 codes (bits==8) or uint8 nibble-packed codes (bits==4),
               shape (..., padded_last) or (..., padded_last // 2) if packed.
    ``scale``  float32 per-block scales, shape (..., padded_last // block).
    ``zero``   float32 per-block zero points (None when symmetric).
    """
    q: jax.Array
    scale: jax.Array
    zero: Optional[jax.Array]
    bits: int
    block: int
    orig_last: int          # unpadded size of the last axis
    dtype: str              # dequantization dtype, e.g. "bfloat16"

    # -- pytree protocol ----------------------------------------------------
    def tree_flatten(self):
        return (self.q, self.scale, self.zero), (
            self.bits, self.block, self.orig_last, self.dtype)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale, zero = children
        bits, block, orig_last, dtype = aux
        return cls(q, scale, zero, bits, block, orig_last, dtype)

    # -- convenience --------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, ...]:
        lead = self.q.shape[:-1]
        return tuple(lead) + (self.orig_last,)

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def symmetric(self) -> bool:
        return self.zero is None

    def dequantize(self, dtype=None) -> jax.Array:
        return dequantize(self, dtype)

    def nbytes(self) -> int:
        n = int(np.prod(self.q.shape)) * self.q.dtype.itemsize
        n += int(np.prod(self.scale.shape)) * self.scale.dtype.itemsize
        if self.zero is not None:
            n += int(np.prod(self.zero.shape)) * self.zero.dtype.itemsize
        return n


def is_qtensor(x) -> bool:
    return isinstance(x, QTensor)


# ---------------------------------------------------------------------------
# QVirtual: the training-path view of a quantized weight
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class QVirtual:
    """A quantized weight paired with a gradient slot for its virtual value.

    The INT8 representation stays the compute format — ``repro.kernels.ops.
    quantized_dense`` streams ``qt``'s blocks directly — while ``shadow``
    (a zeros array of the virtual, dequantized shape) is the float primal
    that ``jax.vjp`` differentiates. The custom VJPs route ``dL/dW`` into
    the shadow's cotangent, so gradients keep the repo-wide "one virtual
    full-rank leaf per QTensor" contract without the forward ever
    materializing ``W`` (the shadow itself is never read and is dead-code
    eliminated by XLA).
    """
    qt: QTensor
    shadow: jax.Array

    def tree_flatten(self):
        return (self.qt, self.shadow), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def shape(self):
        return self.qt.shape

    @property
    def ndim(self):
        return self.qt.ndim


def is_qvirtual(x) -> bool:
    return isinstance(x, QVirtual)


def virtualize(qt: QTensor) -> QVirtual:
    """Pair a QTensor with a zeros gradient slot of its virtual shape."""
    return QVirtual(qt, jnp.zeros(qt.shape, jnp.dtype(qt.dtype)))


def tree_virtualize(tree):
    """QTensor leaves → QVirtual (the differentiable training view)."""
    return jax.tree_util.tree_map(
        lambda l: virtualize(l) if is_qtensor(l) else l,
        tree, is_leaf=is_qtensor)


def tree_devirtualize_grads(tree):
    """Collapse QVirtual-structured cotangents to the shadow (= dL/dW)
    leaf, restoring the plain "one array per QTensor" gradient tree. Also
    drops the float0 cotangents of the integer code arrays, which must not
    escape scan bodies."""
    return jax.tree_util.tree_map(
        lambda l: l.shadow if is_qvirtual(l) else l,
        tree, is_leaf=is_qvirtual)


def _zero_cotangent(x: jax.Array):
    if jnp.issubdtype(x.dtype, jnp.floating):
        return jnp.zeros_like(x)
    return np.zeros(x.shape, jax.dtypes.float0)


def zero_qtensor_cotangent(qt: QTensor):
    """Cotangent for a QTensor primal: float0 for codes, zeros for scales."""
    return QTensor(_zero_cotangent(qt.q), _zero_cotangent(qt.scale),
                   None if qt.zero is None else _zero_cotangent(qt.zero),
                   qt.bits, qt.block, qt.orig_last, qt.dtype)


@jax.custom_vjp
def virtual_dequantize(shadow: jax.Array, qt: QTensor) -> jax.Array:
    """``dequantize(qt)`` whose gradient flows to ``shadow``.

    Fallback for QVirtual consumers that genuinely need the materialized
    weight (embedding gathers, MLA's absorbed decode matmul, expert
    oracles); matmuls should use ``ops.quantized_dense`` instead, which
    never materializes.
    """
    return dequantize(qt, shadow.dtype)


def _vdeq_fwd(shadow, qt):
    return virtual_dequantize(shadow, qt), (shadow, qt)


def _vdeq_bwd(res, g):
    shadow, qt = res
    return g.astype(shadow.dtype), zero_qtensor_cotangent(qt)


virtual_dequantize.defvjp(_vdeq_fwd, _vdeq_bwd)


def gather_rows(qt: QTensor, idx: jax.Array) -> QTensor:
    """Row-gather of a 2-D QTensor (e.g. embedding rows for a token batch)
    without dequantizing the full table: codes and scales are gathered,
    the result dequantizes to ``(*idx.shape, orig_last)``."""
    assert qt.ndim == 2, qt.shape
    return QTensor(jnp.take(qt.q, idx, axis=0),
                   jnp.take(qt.scale, idx, axis=0),
                   None if qt.zero is None else jnp.take(qt.zero, idx,
                                                         axis=0),
                   qt.bits, qt.block, qt.orig_last, qt.dtype)


# ---------------------------------------------------------------------------
# Packing helpers (INT4)
# ---------------------------------------------------------------------------

def pack_int4(u: jax.Array) -> jax.Array:
    """Pack unsigned nibbles (values 0..15, uint8) pairs into uint8.

    Last axis must be even; out last axis is halved.
    """
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4` — interleaves nibbles back."""
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*p.shape[:-1], p.shape[-1] * 2)


# ---------------------------------------------------------------------------
# Quantize / dequantize
# ---------------------------------------------------------------------------

def _pad_last(x: jax.Array, block: int) -> jax.Array:
    last = x.shape[-1]
    pad = (-last) % block
    if pad:
        widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
        x = jnp.pad(x, widths)
    return x


def _block_view(x: jax.Array, block: int) -> jax.Array:
    return x.reshape(*x.shape[:-1], x.shape[-1] // block, block)


def quantize_blockwise(
    x: jax.Array,
    bits: int = 8,
    block: int = DEFAULT_BLOCK,
    symmetric: bool = False,
    stochastic_key: Optional[jax.Array] = None,
) -> QTensor:
    """Block-wise uniform quantization along the last axis.

    With ``stochastic_key`` the rounding is stochastic (unbiased); otherwise
    round-to-nearest. Scales/zeros are float32.
    """
    assert bits in (2, 4, 8), bits
    orig_last = x.shape[-1]
    dtype = str(x.dtype)
    xf = _pad_last(x.astype(jnp.float32), block)
    xb = _block_view(xf, block)
    qmin, qmax = _qrange(bits)

    if symmetric:
        absmax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
        scale = jnp.maximum(absmax / qmax, _EPS)
        zero = None
        t = xb / scale
    else:
        mx = jnp.max(xb, axis=-1, keepdims=True)
        mn = jnp.min(xb, axis=-1, keepdims=True)
        scale = jnp.maximum((mx - mn) / (qmax - qmin), _EPS)
        zero = qmin - mn / scale           # float zero-point
        t = xb / scale + zero

    if stochastic_key is not None:
        u = jax.random.uniform(stochastic_key, t.shape, dtype=jnp.float32)
        codes = jnp.floor(t + u)
    else:
        codes = jnp.round(t)
    codes = jnp.clip(codes, qmin, qmax)

    flat_codes = codes.reshape(*xf.shape)
    scale_out = scale[..., 0]
    zero_out = None if zero is None else zero[..., 0]

    if bits == 8:
        q = flat_codes.astype(jnp.int8)
    else:
        u8 = (flat_codes - qmin).astype(jnp.uint8)   # 0 .. 2^bits-1
        q = pack_int4(u8) if bits == 4 else u8
    return QTensor(q, scale_out, zero_out, bits, block, orig_last, dtype)


def dequantize(qt: QTensor, dtype=None) -> jax.Array:
    """Inverse transform; returns (q - z) * s cropped to the original shape."""
    out_dtype = dtype or jnp.dtype(qt.dtype)
    qmin, _ = _qrange(qt.bits)
    if qt.bits == 8:
        codes = qt.q.astype(jnp.float32)
    elif qt.bits == 4:
        codes = unpack_int4(qt.q).astype(jnp.float32) + qmin
    else:
        codes = qt.q.astype(jnp.float32) + qmin
    cb = _block_view(codes, qt.block)
    if qt.zero is None:
        xb = cb * qt.scale[..., None]
    else:
        xb = (cb - qt.zero[..., None]) * qt.scale[..., None]
    x = xb.reshape(*codes.shape)
    if x.shape[-1] != qt.orig_last:
        x = x[..., : qt.orig_last]
    return x.astype(out_dtype)


def requantize_sr(
    qt: QTensor, update: jax.Array, key: jax.Array,
    symmetric: Optional[bool] = None,
) -> QTensor:
    """The Q-GaLore weight update: W' = SR_quant(dequant(W) + update).

    Recomputes per-block scales from the updated values (the weight
    distribution drifts over training) and requantizes with stochastic
    rounding so sub-quantum gradient contributions survive in expectation.
    """
    w = dequantize(qt, jnp.float32) + update.astype(jnp.float32)
    sym = qt.symmetric if symmetric is None else symmetric
    return quantize_blockwise(
        w, bits=qt.bits, block=qt.block, symmetric=sym, stochastic_key=key)


# ---------------------------------------------------------------------------
# Plain stochastic rounding (used for bf16 casts and tests)
# ---------------------------------------------------------------------------

def stochastic_round(x: jax.Array, key: jax.Array) -> jax.Array:
    """SR to integers: floor(x + u)."""
    u = jax.random.uniform(key, x.shape, dtype=jnp.float32)
    return jnp.floor(x.astype(jnp.float32) + u)


# ---------------------------------------------------------------------------
# Pytree helpers
# ---------------------------------------------------------------------------

def tree_quantize(tree, bits=8, block=DEFAULT_BLOCK, symmetric=True,
                  predicate=None):
    """Quantize every array leaf for which ``predicate(path, leaf)`` holds."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)
    leaves = []
    for path, leaf in flat:
        if predicate is None or predicate(path, leaf):
            leaves.append(quantize_blockwise(leaf, bits, block, symmetric))
        else:
            leaves.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def tree_dequantize(tree, dtype=None):
    return jax.tree_util.tree_map(
        lambda l: dequantize(l, dtype) if is_qtensor(l) else l,
        tree, is_leaf=is_qtensor)


def quantized_nbytes(tree) -> int:
    """Total bytes of a (possibly mixed) params tree."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree, is_leaf=is_qtensor):
        if is_qtensor(leaf):
            total += leaf.nbytes()
        else:
            total += int(np.prod(leaf.shape)) * leaf.dtype.itemsize
    return total
