"""Production training launcher: any --arch on any mesh.

On a real TPU slice this is the per-host entry point (jax.distributed
initializes from the TPU environment); on the CPU container pass
``--devices N --mesh dxm`` to emulate a small mesh, or nothing for
single-device smoke runs.

    PYTHONPATH=src python -m repro.launch.train --arch llama-60m --smoke \
        --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch yi-9b \
        --mesh 16x16 --batch 256 --seq 4096 --compress --zero  # on hardware
    PYTHONPATH=src python -m repro.launch.train --arch llama-60m --smoke \
        --devices 8 --mesh 8x1 --compress --zero   # distributed mode on CPU
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rank", type=int, default=128)
    ap.add_argument("--adaptive-rank", action="store_true",
                    help="dynamic per-layer rank adaptation: shrink a "
                         "leaf's rank down the --rank-ladder when its "
                         "explained-variance ratio holds above "
                         "--rank-threshold for --rank-patience refreshes")
    ap.add_argument("--rank-ladder", default="",
                    help="comma-separated shrink rungs, e.g. 64,32 "
                         "(empty = halve)")
    ap.add_argument("--rank-threshold", type=float, default=0.95)
    ap.add_argument("--rank-patience", type=int, default=2)
    ap.add_argument("--min-rank", type=int, default=8)
    ap.add_argument("--optimizer", default="qgalore")
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress", action="store_true",
                    help="DP low-rank gradient compression + distributed "
                         "subspace refresh (shard_map)")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO-shard the quantized optimizer state over "
                         "the DP axes (combined with --compress this also "
                         "turns on the ZeRO-2 gradient reduce-scatter; "
                         "see --zero2)")
    ap.add_argument("--zero2", type=int, default=-1, choices=(-1, 0, 1),
                    help="force the ZeRO-2 low-rank-gradient "
                         "reduce-scatter on (1) or off (0); default -1 "
                         "follows --zero")
    ap.add_argument("--mesh", default="",
                    help="dxm, e.g. 4x2 (data x model); empty = single dev")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel degree: builds a (devices/tp, "
                         "tp) (data x model) mesh. Mutually exclusive "
                         "with --mesh; must divide the device count")
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU emulation)")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--multihost", action="store_true",
                    help="initialize jax.distributed (real clusters)")
    args = ap.parse_args()

    from repro.launch.mesh import force_host_device_count
    force_host_device_count(args.devices)
    import jax
    if args.multihost:
        jax.distributed.initialize()

    import logging
    import jax.numpy as jnp
    from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
    from repro.core.optimizers import preset
    from repro.models import model_zoo
    from repro.train.trainer import Trainer

    logging.basicConfig(level=logging.INFO)
    mesh = None
    if args.mesh and args.tp:
        raise SystemExit("--mesh and --tp both fix the mesh shape — "
                         "pass one or the other")
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        if d * m != len(jax.devices()):
            raise SystemExit(
                f"--mesh {args.mesh} needs {d * m} devices, have "
                f"{len(jax.devices())} (use --devices on CPU)")
        mesh = jax.make_mesh((d, m), ("data", "model"))
    elif args.tp:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(args.tp)

    bundle = model_zoo.build_arch(args.arch, smoke=args.smoke,
                                  dtype=jnp.float32 if args.smoke
                                  else jnp.bfloat16)
    ladder = tuple(int(x) for x in args.rank_ladder.split(",") if x)
    qcfg = preset(args.optimizer, QGaLoreConfig(
        rank=args.rank, min_dim=64 if args.smoke else 128,
        compress_dp_grads=args.compress,
        adaptive_rank=args.adaptive_rank, rank_ladder=ladder,
        explained_ratio_threshold=args.rank_threshold,
        rank_patience=args.rank_patience, min_rank=args.min_rank))
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       steps=args.steps, learning_rate=args.lr,
                       warmup_steps=max(args.steps // 20, 1), log_every=10,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=args.checkpoint_every)
    cell = ShapeCell("train", args.seq, args.batch, "train")
    trainer = Trainer(bundle, tcfg, qcfg, cell=cell, accum=args.accum,
                      mesh=mesh, zero_shard=args.zero and mesh is not None,
                      zero2=None if args.zero2 < 0 else bool(args.zero2),
                      param_dtype=jnp.float32 if args.smoke
                      else jnp.bfloat16)
    if mesh is not None:
        leaves = [l for l in jax.tree_util.tree_leaves(trainer.state.opt)
                  if hasattr(l, "addressable_shards")]
        tot = sum(l.nbytes for l in leaves)
        per_dev = sum(max(s.data.nbytes for s in l.addressable_shards)
                      for l in leaves)
        logging.getLogger("repro.launch").info(
            "optimizer state: %.1f MB global, %.1f MB max/device "
            "(zero_shard=%s)", tot / 2**20, per_dev / 2**20, args.zero)
    trainer.maybe_restore()
    hist = trainer.run()
    print(f"final loss {hist[-1]['loss']:.4f}; "
          f"SVD used {trainer.controller.total_svd_count()} / "
          f"{trainer.controller.baseline_svd_count(args.steps)} baseline")
    if args.adaptive_rank:
        from repro.core import qgalore
        for t in trainer.controller.rank_transition_summary():
            print(f"rank transition: step {t['step']} {t['path']} "
                  f"{t['old']} -> {t['new']}")
        bytes_now = qgalore.optimizer_state_bytes(
            trainer.state.params, trainer.rules, specs=trainer.specs)
        print(f"optimizer state {bytes_now / 2**20:.2f} MB; "
              f"DP payload {qgalore.dp_payload_bytes(trainer.specs)} B/step")


if __name__ == "__main__":
    main()
