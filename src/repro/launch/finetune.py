"""Fine-tuning launcher: the paper's Tables 3-4 scenario on the composable
optimizer API (param-group rules, repro.core.rules).

Freezes the embedding, final norm, head, and the first ``--freeze-layers``
transformer layers (the block stack is split into ``seg0_``/``seg1_``
segments so layer ranges are addressable at leaf granularity), Q-GaLore
fine-tunes the rest at ``--rank``, and reports the weights+optimizer memory
against a QLoRA baseline at the SAME rank (INT8 frozen base + fp32 LoRA
adapters + fp32 Adam moments on the adapters — ``models/lora.py``).

The run *asserts* the new-API contract before writing the report:

* frozen-group leaves hold ZERO optimizer state (no Adam moments, no
  projection) and their weights come back bit-identical;
* per-group ranks are honored in ``leaf_specs``;
* reported Q-GaLore optimizer+weight memory <= the QLoRA baseline.

    PYTHONPATH=src python -m repro.launch.finetune --smoke --steps 8 \
        --out finetune_memory.json
    PYTHONPATH=src python -m repro.launch.finetune --arch llama-60m \
        --steps 200 --rank 128 --freeze-layers 2    # full shapes
"""
from __future__ import annotations

import argparse
import json


def build_finetune_rules(base_qcfg, rank: int, freeze_early: bool = True):
    """The fine-tune rule-set: frozen base (embedding / final_norm / head,
    plus the early layers = ``seg0_`` unless ``freeze_early=False`` — use
    that when the model was built WITHOUT ``split_layers``, where the one
    block segment is itself named ``seg0_``), Q-GaLore at ``rank`` on the
    remaining blocks."""
    from repro.core.optimizers import preset
    from repro.core.rules import ParamGroup, ParamRules
    frozen_pat = r"embedding|final_norm|head"
    tune_pat = r"seg\d+_"
    if freeze_early:
        frozen_pat += r"|seg0_"
        tune_pat = r"seg1_"
    return ParamRules(
        base=preset("qgalore", base_qcfg),
        groups=(
            ParamGroup("frozen_base", pattern=frozen_pat, frozen=True),
            ParamGroup("qgalore_blocks", pattern=tune_pat, rank=rank),
        ),
    )


def run(arch: str = "llama-60m", smoke: bool = True, steps: int = 8,
        rank: int = 8, freeze_layers: int = 1, lr: float = 1e-3,
        seq: int = 32, batch: int = 4, out: str = "") -> dict:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
    from repro.core import qgalore, quant
    from repro.core.qgalore import _is_inner_leaf
    from repro.models import lora as lora_lib, model_zoo
    from repro.train.trainer import Trainer

    bundle = model_zoo.build_arch(
        arch, smoke=smoke, dtype=jnp.float32 if smoke else jnp.bfloat16,
        split_layers=freeze_layers)
    min_dim = 32 if smoke else 128
    rules = build_finetune_rules(
        QGaLoreConfig(rank=rank, min_dim=min_dim,
                      update_interval=max(steps // 4, 2)), rank,
        freeze_early=freeze_layers > 0)
    tcfg = TrainConfig(global_batch=batch, seq_len=seq, steps=steps,
                       learning_rate=lr, warmup_steps=max(steps // 10, 1),
                       log_every=0)
    cell = ShapeCell("finetune", seq, batch, "train")
    trainer = Trainer(bundle, tcfg, rules, cell=cell,
                      param_dtype=jnp.float32 if smoke else jnp.bfloat16)

    specs = trainer.specs
    frozen_idx = [i for i, s in enumerate(specs) if s.frozen]
    tuned = [s for s in specs if not s.frozen]
    assert frozen_idx, "rule-set froze nothing — pattern mismatch?"

    # --- contract check 1: frozen-group leaves hold zero optimizer state
    inner_flat = jax.tree_util.tree_flatten(
        trainer.state.opt.inner, is_leaf=_is_inner_leaf)[0]
    proj_flat = jax.tree_util.tree_flatten(
        trainer.state.opt.proj,
        is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]
    for i in frozen_idx:
        assert inner_flat[i] is None and proj_flat[i] is None, \
            f"frozen leaf {specs[i].path} holds optimizer state"

    # --- contract check 2: per-group ranks honored in leaf_specs
    galore = [s for s in specs if s.galore]
    assert galore, "no leaf got Q-GaLore treatment"
    for s in galore:
        want = min(rank, min(s.mat_shape))
        assert s.rank == want, (s.path, s.rank, want)
        assert s.group == "qgalore_blocks", (s.path, s.group)

    frozen_before = [np.asarray(jax.device_get(x)) for i in frozen_idx
                     for x in jax.tree_util.tree_leaves(
                         jax.tree_util.tree_flatten(
                             trainer.state.params,
                             is_leaf=quant.is_qtensor)[0][i])]
    hist = trainer.run()
    losses = [h["loss"] for h in hist]
    assert np.isfinite(losses).all(), "fine-tune diverged"

    # --- contract check 3: frozen weights bit-identical after training
    frozen_after = [np.asarray(jax.device_get(x)) for i in frozen_idx
                    for x in jax.tree_util.tree_leaves(
                        jax.tree_util.tree_flatten(
                            trainer.state.params,
                            is_leaf=quant.is_qtensor)[0][i])]
    for a, b in zip(frozen_before, frozen_after):
        np.testing.assert_array_equal(a, b)

    # --- memory: Q-GaLore (group-aware report) vs QLoRA at matched rank,
    # BOTH sides under memory_report's convention (fp weights at the bf16
    # baseline, non-quantized Adam at fp_state_bytes) — the QLoRA side is
    # literally memory_report over the adapter tree with a full-Adam
    # recipe (adapter weights + their m/v), plus the shared INT8 base.
    from repro.core.optimizers import preset
    rep = qgalore.memory_report(trainer.state.params, rules)
    adapters = lora_lib.init_adapters(trainer.state.params, rank,
                                      jax.random.PRNGKey(0))
    adapter_gb = qgalore.memory_report(adapters, preset("full"))["total_gb"]
    qlora_total = rep["weights_gb"] + adapter_gb
    report = {
        "arch": arch, "smoke": smoke, "steps": steps, "rank": rank,
        "freeze_layers": freeze_layers,
        "groups": {g: sum(1 for s in specs if s.group == g)
                   for g in sorted({s.group for s in specs})},
        "frozen_leaves": len(frozen_idx),
        "tuned_leaves": len(tuned),
        "final_loss": float(np.mean(losses[-3:])),
        "first_loss": float(losses[0]),
        "qgalore": {"weights_gb": rep["weights_gb"],
                    "optimizer_gb": rep["optimizer_gb"],
                    "total_gb": rep["total_gb"]},
        "qlora": {"weights_gb": rep["weights_gb"],
                  "adapter_plus_opt_gb": adapter_gb,
                  "total_gb": qlora_total},
        "qgalore_leq_qlora": bool(rep["total_gb"] <= qlora_total),
        "svd_used": trainer.controller.total_svd_count(),
    }
    # --- contract check 4: memory <= QLoRA at matched rank
    assert report["qgalore_leq_qlora"], (
        f"Q-GaLore fine-tune memory {rep['total_gb']:.6f} GB exceeds the "
        f"QLoRA baseline {qlora_total:.6f} GB at rank {rank}")

    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--rank", type=int, default=8)
    ap.add_argument("--freeze-layers", type=int, default=1,
                    help="early layers to freeze (become seg0_)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--out", default="finetune_memory.json")
    args = ap.parse_args()

    report = run(arch=args.arch, smoke=args.smoke, steps=args.steps,
                 rank=args.rank, freeze_layers=args.freeze_layers,
                 lr=args.lr, seq=args.seq, batch=args.batch, out=args.out)
    print(json.dumps(report, indent=2, sort_keys=True))
    print(f"\nQ-GaLore fine-tune total {report['qgalore']['total_gb'] * 1024:.2f} MiB "
          f"vs QLoRA {report['qlora']['total_gb'] * 1024:.2f} MiB at rank "
          f"{report['rank']} -> qgalore_leq_qlora="
          f"{report['qgalore_leq_qlora']}")


if __name__ == "__main__":
    main()
