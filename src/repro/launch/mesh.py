"""Production meshes. Functions, not module constants — importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/small runs (e.g. (2, 2) on 4 host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes))
