"""Production meshes. Functions, not module constants — importing this module
never touches jax device state (``force_host_device_count`` must therefore be
called before anything else imports jax)."""
from __future__ import annotations

import os


def force_host_device_count(n: int) -> None:
    """Emulate ``n`` devices on the host CPU platform (CI / laptops): appends
    the XLA flag, so it MUST run before jax initializes its backends. The
    distributed tests and ``benchmarks/dist_bench.py`` run their meshes this
    way; on real hardware it is a no-op (don't call it)."""
    if n and n > 0:
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n}")


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    import jax
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/small runs (e.g. (2, 2) on 4 host devices)."""
    import jax
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_dp_mesh(num_devices: int = 0):
    """Pure data-parallel mesh ``(D, 1)`` over ``("data", "model")`` — the
    shape the compressed-DP + ZeRO training mode runs on when the model
    fits one device (the Q-GaLore regime: INT8 weights + low-rank INT8
    state). ``num_devices`` defaults to every visible device. The model
    axis exists but has size 1, so nothing is tensor-parallel — use
    :func:`make_tp_mesh` to split devices between the two axes."""
    import jax
    d = num_devices or len(jax.devices())
    return jax.make_mesh((d, 1), ("data", "model"))


def make_tp_mesh(tp: int, num_devices: int = 0):
    """2-D ``(D/tp, tp)`` mesh over ``("data", "model")``: ``tp``-way
    tensor parallelism, data parallelism over the rest. Validates that
    ``tp`` divides the device count — a ragged split would silently drop
    devices."""
    import jax
    n = num_devices or len(jax.devices())
    if tp <= 0 or n % tp != 0:
        raise ValueError(
            f"tensor-parallel degree {tp} must be a positive divisor of "
            f"the device count {n} (got remainder {n % tp if tp else n})")
    return jax.make_mesh((n // tp, tp), ("data", "model"))
