import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^^ MUST precede any jax-touching import: jax locks the device count at
# first backend init. Everything below may import jax.

import argparse          # noqa: E402
import json              # noqa: E402
import sys               # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import hlo as hlo_lib                    # noqa: E402
from repro.config import QGaLoreConfig, TrainConfig, cells_for_arch  # noqa: E402
from repro.core.optimizers import preset                     # noqa: E402
from repro.distributed import sharding as shard_lib          # noqa: E402
from repro.launch.mesh import make_production_mesh           # noqa: E402
from repro.models import model_zoo                           # noqa: E402
from repro.serve import engine as serve_engine               # noqa: E402
from repro.serve import shard as serve_shard                 # noqa: E402
from repro.train import step as step_lib                     # noqa: E402

"""Multi-pod dry-run: ``.lower().compile()`` for every
(architecture × input-shape × mesh) cell, recording cost/memory analysis and
collective payloads for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Runs each cell in-process via ``run_cell`` or as a fleet of subprocesses via
``--all`` (isolation: one bad cell cannot take down the sweep; the 512
host-device flag is per-process)."""

QCFG = QGaLoreConfig(rank=128)   # paper's production optimizer settings


def _qchunk(cell) -> int:
    # memory-bounded attention chunking for long sequences
    return 1024 if cell.seq_len >= 8192 else max(cell.seq_len, 256)


def _accum(arch: str, cell) -> int:
    """Microbatch (gradient-accumulation) factor for the train cell —
    bounds the per-step activation footprint on big models."""
    if cell.kind != "train":
        return 1
    big = {"deepseek-v3-671b": 8, "qwen3-32b": 4, "qwen3-moe-30b-a3b": 4,
           "mistral-nemo-12b": 4, "yi-9b": 4, "gemma-7b": 4,
           "zamba2-2.7b": 2, "llama-7b": 4}
    return big.get(arch, 1)


def run_cell(arch: str, cell_name: str, multi_pod: bool,
             refresh: bool = False, compress: bool = False):
    """Lower + compile one (arch × cell × mesh); returns the artifact dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = model_zoo.get_config(arch)
    cell = next(c for c in cells_for_arch(arch) if c.name == cell_name)
    # compress mode: MoE experts ride the shard_map sharded over 'data' with
    # manual all-to-all dispatch (moe_apply_ep); otherwise GSPMD-auto EP.
    moe_ep_axis = None
    if (compress and cell.kind == "train" and cfg.moe is not None
            and cfg.moe.num_experts % mesh.shape["data"] == 0):
        moe_ep_axis = "data"
    shard_lib.set_ep_full_mesh(moe_ep_axis is not None)
    build_kw = {}
    if moe_ep_axis and cfg.family == "moe":
        build_kw["ep_axis"] = moe_ep_axis
    bundle = model_zoo.build(cfg, q_chunk=_qchunk(cell), dtype=jnp.bfloat16,
                             **build_kw)

    art = {
        "arch": arch, "cell": cell_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": cell.kind, "refresh": refresh, "compress": compress,
        "params": model_zoo.count_params_analytic(cfg),
        "active_params": model_zoo.count_active_params(cfg),
        "ok": False,
    }
    t0 = time.time()

    if cell.kind == "train":
        qcfg = QCFG
        tcfg = TrainConfig(global_batch=cell.global_batch,
                           seq_len=cell.seq_len, grad_clip=1.0)
        accum = _accum(arch, cell)
        raw_step, specs = step_lib.build_train_step(
            bundle, qcfg, tcfg, impl="fused", accum=accum,
            param_dtype=jnp.bfloat16, mesh=mesh, dp_compress=compress,
            moe_ep_axis=moe_ep_axis)
        state_abs = step_lib.abstract_state(bundle, qcfg, jnp.bfloat16)
        batch_abs = bundle.input_specs(cell)

        p_shard = shard_lib.param_sharding(state_abs.params, mesh)
        o_shard = shard_lib.opt_state_sharding(state_abs.params,
                                               state_abs.opt, qcfg, mesh)
        b_shard = shard_lib.data_sharding(batch_abs, mesh)
        state_shard = step_lib.TrainState(p_shard, o_shard)
        rep = shard_lib.replicated(mesh)

        if refresh:
            masks_abs = {
                i: jax.ShapeDtypeStruct((s.nbatch,), jnp.bool_)
                for i, s in enumerate(specs) if s.galore}
            fn = jax.jit(
                lambda st, b, lr, rng, masks: raw_step(
                    st, b, lr, rng, refresh_masks=masks, refresh=True),
                in_shardings=(state_shard, b_shard, rep, rep,
                              {i: rep for i in masks_abs}),
                donate_argnums=(0,))
            args = (state_abs, batch_abs,
                    jax.ShapeDtypeStruct((), jnp.float32),
                    jax.ShapeDtypeStruct((2,), jnp.uint32), masks_abs)
        else:
            fn = jax.jit(
                lambda st, b, lr, rng: raw_step(st, b, lr, rng,
                                                refresh_masks=None,
                                                refresh=False),
                in_shardings=(state_shard, b_shard, rep, rep),
                donate_argnums=(0,))
            args = (state_abs, batch_abs,
                    jax.ShapeDtypeStruct((), jnp.float32),
                    jax.ShapeDtypeStruct((2,), jnp.uint32))
        tokens = cell.global_batch * cell.seq_len
        art["model_flops"] = 6.0 * art["active_params"] * tokens

    elif cell.kind == "prefill":
        params_abs = jax.eval_shape(
            lambda k: step_lib.prepare_params(bundle.init_params(k), QCFG),
            jax.random.PRNGKey(0))
        batch_abs = bundle.input_specs(cell)
        p_shard = shard_lib.param_sharding(params_abs, mesh)
        b_shard = shard_lib.data_sharding(batch_abs, mesh)
        # VLM: the KV window must cover prefix embeddings + prompt
        prefill = serve_engine.build_prefill(
            bundle, max_len=cell.seq_len + cfg.num_prefix_embeddings)
        fn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        args = (params_abs, batch_abs)
        art["model_flops"] = 2.0 * art["active_params"] \
            * cell.global_batch * cell.seq_len

    else:  # decode
        params_abs = jax.eval_shape(
            lambda k: step_lib.prepare_params(bundle.init_params(k), QCFG),
            jax.random.PRNGKey(0))
        p_shard = shard_lib.param_sharding(params_abs, mesh)
        state_abs = serve_engine.abstract_decode_state(
            bundle, cell.global_batch, cell.seq_len, jnp.bfloat16)
        s_shard = serve_shard.decode_state_sharding(state_abs, mesh)
        tok_abs = jax.ShapeDtypeStruct((cell.global_batch, 1), jnp.int32)
        t_shard = shard_lib.data_sharding({"t": tok_abs}, mesh)["t"]
        decode = serve_engine.build_decode(bundle)
        fn = jax.jit(decode, in_shardings=(p_shard, s_shard, t_shard),
                     donate_argnums=(1,))
        args = (params_abs, state_abs, tok_abs)
        art["model_flops"] = 2.0 * art["active_params"] * cell.global_batch

    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()

    art["compile_s"] = time.time() - t0
    try:
        mem = compiled.memory_analysis()
        art["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
        print("memory_analysis:", art["memory_analysis"])
    except Exception as e:  # noqa: BLE001 — backend-dependent
        art["memory_analysis"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        art["cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "optimal_seconds")}
        print("cost_analysis flops=%.3e bytes=%.3e" % (
            art["cost_analysis"].get("flops", 0),
            art["cost_analysis"].get("bytes accessed", 0)))
    except Exception as e:  # noqa: BLE001
        art["cost_analysis"] = {"error": str(e)}
    try:
        text = compiled.as_text()
        art["collectives"] = hlo_lib.parse_collectives(text)
        art["hlo_ops"] = hlo_lib.count_ops(text)
        art["hlo_chars"] = len(text)
    except Exception as e:  # noqa: BLE001
        art["collectives"] = {"error": str(e)}
    art["ok"] = True
    return art


def _out_path(out_dir, arch, cell, multi_pod, refresh):
    mesh = "2x16x16" if multi_pod else "16x16"
    sfx = "__refresh" if refresh else ""
    return os.path.join(out_dir, mesh, f"{arch}__{cell}{sfx}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell")
    ap.add_argument("--multi-pod", type=int, default=0)
    ap.add_argument("--refresh", type=int, default=0)
    ap.add_argument("--compress", type=int, default=0,
                    help="DP low-rank gradient compression (beyond-paper)")
    ap.add_argument("--unroll", type=int, default=0,
                    help="unroll layer scans for exact FLOP/collective "
                         "accounting (XLA cost_analysis counts loop bodies "
                         "once)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--all", action="store_true",
                    help="run every cell in subprocesses")
    ap.add_argument("--skip-existing", type=int, default=1)
    args = ap.parse_args()

    if args.all:
        import subprocess
        archs = [a for a in model_zoo.ARCH_IDS if not a.startswith("llama-")]
        jobs = []
        for mp in (0, 1):
            for arch in archs:
                for cell in cells_for_arch(arch):
                    jobs.append((arch, cell.name, mp, 0))
        # refresh-variant proof for one representative arch
        jobs.append(("yi-9b", "train_4k", 0, 1))
        failures = []
        for arch, cell, mp, rf in jobs:
            path = _out_path(args.out, arch, cell, mp, rf)
            if args.skip_existing and os.path.exists(path):
                print(f"[skip] {path}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--cell", cell, "--multi-pod", str(mp),
                   "--refresh", str(rf), "--out", args.out]
            print("[run]", " ".join(cmd), flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=7200)
            if r.returncode != 0:
                failures.append((arch, cell, mp))
                os.makedirs(os.path.dirname(path), exist_ok=True)
                with open(path, "w") as f:
                    json.dump({"arch": arch, "cell": cell, "ok": False,
                               "error": r.stderr[-2000:]}, f, indent=1)
                print(r.stderr[-800:], flush=True)
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    if args.unroll:
        os.environ["REPRO_SCAN_UNROLL"] = "full"
    art = None
    try:
        art = run_cell(args.arch, args.cell, bool(args.multi_pod),
                       bool(args.refresh), bool(args.compress))
        art["unroll"] = bool(args.unroll)
    except Exception:
        art = {"arch": args.arch, "cell": args.cell, "ok": False,
               "error": traceback.format_exc()[-3000:]}
        raise
    finally:
        if art is not None:
            path = _out_path(args.out, args.arch, args.cell,
                             bool(args.multi_pod), bool(args.refresh))
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as f:
                json.dump(art, f, indent=1)
            print("wrote", path)


if __name__ == "__main__":
    main()
