"""llama-130m: GaLore/Q-GaLore pre-training config (paper Tables 1-2)."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="llama-130m", family="dense",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=2048, vocab_size=32000,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=4, d_ff=128, vocab_size=512)
