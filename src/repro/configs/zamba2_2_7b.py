"""zamba2-2.7b: Mamba2 backbone + shared attention every 6 layers with per-site LoRA [arXiv:2411.15242]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk_size=256),
    hybrid=HybridConfig(attn_every=6, shared_lora_rank=64),
)


def smoke_config():
    return replace(CONFIG, num_layers=6, d_model=64, num_heads=4,
                   num_kv_heads=4, d_ff=128, vocab_size=512,
                   ssm=SSMConfig(state_dim=16, head_dim=16, expand=2,
                                 conv_kernel=4, chunk_size=16),
                   hybrid=HybridConfig(attn_every=3, shared_lora_rank=8))
