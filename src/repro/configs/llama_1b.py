"""llama-1b: GaLore/Q-GaLore pre-training config (paper Tables 1-2)."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="llama-1b", family="dense",
    num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=5461, vocab_size=32000,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=4, d_ff=128, vocab_size=512)
