"""seamless-m4t-medium: enc-dec multimodal backbone; audio frontend stubbed [arXiv:2308.11596]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, num_encoder_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, num_encoder_layers=2, d_model=64,
                   num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=512)
