"""llama-350m: GaLore/Q-GaLore pre-training config (paper Tables 1-2)."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="llama-350m", family="dense",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=2736, vocab_size=32000,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=4, d_ff=128, vocab_size=512)
