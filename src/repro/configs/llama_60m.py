"""llama-60m: GaLore/Q-GaLore pre-training config (paper Tables 1-2)."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="llama-60m", family="dense",
    num_layers=8, d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=1376, vocab_size=32000,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=4, d_ff=128, vocab_size=512)
