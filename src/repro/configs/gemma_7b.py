"""gemma-7b: GeGLU, head_dim=256, tied embeddings, 256k vocab [arXiv:2403.08295]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256000,
    ffn_activation="gelu", tie_embeddings=True,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=4, head_dim=32, d_ff=128, vocab_size=512)
