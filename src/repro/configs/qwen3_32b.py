"""qwen3-32b: dense GQA with qk-norm [hf:Qwen/Qwen3-32B]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=25600, vocab_size=151936, qk_norm=True,
    rope_theta=1_000_000.0,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512)
