"""qwen3-moe-30b-a3b: 128 experts top-8, qk-norm, GQA [hf:Qwen/Qwen3-30B-A3B]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=768, vocab_size=151936, qk_norm=True,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, top_k=8, expert_ff=768),
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, head_dim=16, vocab_size=512, d_ff=32,
                   moe=MoEConfig(num_experts=8, top_k=2, expert_ff=32))
