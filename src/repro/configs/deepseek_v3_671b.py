"""deepseek-v3-671b: MLA, 1 shared + 256 routed top-8 experts, MTP [arXiv:2412.19437]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    d_ff=2048, vocab_size=129280,
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, num_shared_experts=1,
                  expert_ff=2048, first_dense_layers=3, dense_ff=18432),
    mtp_depth=1,
)


def smoke_config():
    return replace(CONFIG, num_layers=3, d_model=64, num_heads=4,
                   num_kv_heads=4, vocab_size=512, d_ff=32,
                   mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                                 qk_nope_head_dim=16, qk_rope_head_dim=8,
                                 v_head_dim=16),
                   moe=MoEConfig(num_experts=8, top_k=2,
                                 num_shared_experts=1, expert_ff=32,
                                 first_dense_layers=1, dense_ff=128))
