"""internvl2-2b: InternViT frontend (stubbed) + InternLM2-1.8B backbone [arXiv:2404.16821]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    d_ff=8192, vocab_size=92553, rope_theta=1_000_000.0,
    num_prefix_embeddings=256,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, d_ff=128, vocab_size=512,
                   num_prefix_embeddings=8)
