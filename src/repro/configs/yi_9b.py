"""yi-9b: llama-arch dense GQA [arXiv:2403.04652]."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="yi-9b", family="dense",
    num_layers=48, d_model=4096, num_heads=32, num_kv_heads=4,
    head_dim=128, d_ff=11008, vocab_size=64000,
    rope_theta=5_000_000.0,
)


def smoke_config():
    return replace(CONFIG, num_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=512)
