"""xlstm-125m: sLSTM + mLSTM blocks [arXiv:2405.04517]. d_ff=0 (projections live in-block)."""
from repro.config import (ModelConfig, MoEConfig, MLAConfig, SSMConfig,
                          XLSTMConfig, HybridConfig, replace)

CONFIG = ModelConfig(
    name="xlstm-125m", family="xlstm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=6, proj_factor=2.0, chunk_size=256),
)


def smoke_config():
    return replace(CONFIG, num_layers=4, d_model=64, num_heads=2,
                   num_kv_heads=2, vocab_size=512,
                   xlstm=XLSTMConfig(slstm_every=2, proj_factor=2.0,
                                     chunk_size=16))
