"""Deterministic synthetic data pipeline.

Restartable by construction: batch at step ``s`` is a pure function of
``(seed, s)`` — resuming from a checkpoint needs no iterator state (the
property real pipelines buy with checkpointed readers; documented trade-off
for the offline container, see DESIGN.md).

The LM stream mixes a Markov-chain token process with repeated n-grams so
that models can actually reduce loss (pure uniform noise has no learnable
structure and makes the paper's perplexity comparisons meaningless).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # structure of the synthetic language
    ngram: int = 3
    motif_vocab: int = 64        # tokens drawn from a small "frequent" set


class SyntheticLM:
    """Deterministic, skip-anywhere LM batches."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed random transition table: each context token prefers a small
        # set of successors — learnable bigram structure
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self._succ = rng.integers(0, v, size=(min(v, 4096), 4),
                                  dtype=np.int32)

    def batch_at(self, step: int) -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        key = jax.random.PRNGKey(cfg.seed)
        key = jax.random.fold_in(key, step)
        B, S, V = cfg.global_batch, cfg.seq_len, cfg.vocab_size
        k1, k2, k3 = jax.random.split(key, 3)
        # base: markov-ish stream via successor table
        start = jax.random.randint(k1, (B, 1), 0, min(V, 4096))
        noise = jax.random.randint(k2, (B, S), 0, 4)
        succ = jnp.asarray(self._succ)

        def step_fn(tok, nz):
            return succ[tok % succ.shape[0], nz], None

        def row(s0, nrow):
            def body(c, n):
                nxt = succ[c % succ.shape[0], n]
                return nxt, nxt
            _, toks = jax.lax.scan(body, s0[0], nrow)
            return toks

        tokens = jax.vmap(row)(start, noise)
        # sprinkle uniform noise to keep entropy > 0
        flip = jax.random.bernoulli(k3, 0.1, (B, S))
        rand_tok = jax.random.randint(jax.random.fold_in(k3, 1), (B, S), 0, V)
        tokens = jnp.where(flip, rand_tok, tokens).astype(jnp.int32)
        return {"tokens": tokens, "labels": tokens}

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def batch_for_bundle(bundle, cell, step: int, seed: int = 0):
    """Materialize a batch matching ``bundle.input_specs(cell)`` (covers the
    modality-stub extras: patch_embeds / frames)."""
    specs = bundle.input_specs(cell)
    out = {}
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    lm = None
    for name, spec in specs.items():
        key, sub = jax.random.split(key)
        if name in ("tokens", "labels"):
            if lm is None:
                lm = SyntheticLM(DataConfig(
                    vocab_size=bundle.cfg.vocab_size,
                    seq_len=spec.shape[1], global_batch=spec.shape[0],
                    seed=seed))
                lm_batch = lm.batch_at(step)
            out[name] = lm_batch[name]
        else:
            out[name] = (jax.random.normal(sub, spec.shape, jnp.float32)
                         * 0.5).astype(spec.dtype)
    return out
