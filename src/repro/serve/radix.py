"""Radix (trie) prefix cache for the paged serving runtime.

Maps token prefixes → physical KV-cache blocks so requests sharing a
prefix (the system-prompt case) reuse already-prefilled blocks instead of
re-running prefill. The trie is **block-granular**: each edge is keyed by a
full ``block_size``-token tuple, so a match length is always a multiple of
``block_size`` and a matched block is always *completely* covered by
prompt tokens. That granularity is what lets copy-on-write degenerate to
share-only: a request writes K/V exclusively at positions ``>=`` its
matched length, which land in blocks it allocated privately — shared
blocks are never written (asserted by ``tests/test_paged.py`` comparing a
prefix-cache-hit request's blocks bit-for-bit against a cold prefill).

Ownership protocol (the trie holds block *references*, the
``BlockAllocator`` in ``repro.serve.paged`` holds the counts):

* :meth:`insert` walks a finished prompt's full blocks into the trie and
  returns the phys ids of **newly adopted** nodes — the caller takes one
  allocator ref per adopted block on the trie's behalf. Prefixes already
  in the trie keep their existing phys ids (the caller's duplicate blocks
  stay private to the request and die with it).
* :meth:`match` returns the cached phys ids covering the longest cached
  block-aligned prefix — the caller refs each returned block for the
  requesting slot (shared blocks are alive as long as any user remains).
* :meth:`evict` removes the least-recently-used **leaf** whose block the
  caller deems evictable (allocator refcount 1 ⇔ only the trie holds it)
  and returns its phys id for the caller to deref. Internal nodes are
  protected until their children go — eviction peels prefixes from the
  deepest (most specific, least shared) end first.

Pure host-side data structure: no jax, no device state.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclass
class _Node:
    """One cached block: ``key`` is its block_size-token tuple (edge label
    from the parent), ``phys`` the physical block index holding its K/V."""
    key: Tuple[int, ...]
    phys: int
    parent: Optional["_Node"]
    children: Dict[Tuple[int, ...], "_Node"] = field(default_factory=dict)
    last_use: int = 0


class RadixCache:
    def __init__(self, block_size: int):
        self.block_size = block_size
        self._root = _Node(key=(), phys=-1, parent=None)
        self._clock = 0          # monotonic LRU clock (bumped per touch)
        self._nodes = 0          # cached blocks (root excluded)

    def __len__(self) -> int:
        return self._nodes

    def reset(self) -> None:
        self._root = _Node(key=(), phys=-1, parent=None)
        self._clock = 0
        self._nodes = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def _blocks(self, tokens: Sequence[int]) -> List[Tuple[int, ...]]:
        bs = self.block_size
        n_full = len(tokens) // bs
        return [tuple(int(t) for t in tokens[i * bs:(i + 1) * bs])
                for i in range(n_full)]

    # -- lookup ------------------------------------------------------------

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Phys ids covering the longest cached block-aligned prefix of
        ``tokens`` (possibly empty). Touches the whole matched path's LRU
        clock — a hit protects its prefix chain from eviction."""
        node, phys = self._root, []
        now = self._tick()
        for key in self._blocks(tokens):
            child = node.children.get(key)
            if child is None:
                break
            child.last_use = now
            phys.append(child.phys)
            node = child
        return phys

    # -- insertion ---------------------------------------------------------

    def insert(self, tokens: Sequence[int], phys: Sequence[int]) -> List[int]:
        """Walk ``tokens``' full blocks into the trie; ``phys[i]`` is the
        physical block holding block i's K/V. Returns the phys ids of
        newly created nodes — the caller must take one allocator ref per
        id (the trie's ownership share). Existing nodes keep their phys
        (two requests can cold-prefill the same prefix concurrently; first
        insert wins, the loser's blocks stay private)."""
        node = self._root
        adopted: List[int] = []
        now = self._tick()
        for key, p in zip(self._blocks(tokens), phys):
            child = node.children.get(key)
            if child is None:
                child = _Node(key=key, phys=int(p), parent=node)
                node.children[key] = child
                self._nodes += 1
                adopted.append(int(p))
            child.last_use = now
            node = child
        return adopted

    # -- eviction ----------------------------------------------------------

    def evict(self, evictable: Callable[[int], bool]) -> Optional[int]:
        """Remove the LRU leaf whose phys block passes ``evictable`` and
        return its phys id (the caller derefs it); None when nothing
        qualifies. Leaf-only: a node with children pins a live prefix."""
        best: Optional[_Node] = None
        stack = [self._root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is self._root or node.children:
                continue
            if not evictable(node.phys):
                continue
            if best is None or node.last_use < best.last_use:
                best = node
        if best is None:
            return None
        del best.parent.children[best.key]
        self._nodes -= 1
        return best.phys

    def cached_blocks(self) -> List[int]:
        """Every phys id currently held by the trie (tests/debugging)."""
        out, stack = [], [self._root]
        while stack:
            node = stack.pop()
            stack.extend(node.children.values())
            if node is not self._root:
                out.append(node.phys)
        return out
