"""Sharding for serving state (KV caches, recurrent states).

Heuristic per cache leaf: dim 1 is batch (dim 0 is the stacked layer axis) —
shard it over data when divisible; then shard the LARGEST remaining dim over
model when divisible (for attention caches that is the time axis →
context-parallel decode; for SSM states it is heads/channels). GSPMD turns
the seq-sharded attention contraction into partial-softmax + all-reduce —
the LSE-combine pattern of ring/context-parallel decode."""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import batch_axes
from repro.serve.engine import DecodeState


def _leaf_spec(shape: Tuple[int, ...], mesh: Mesh,
               batch_dim: int = 1) -> P:
    parts = [None] * len(shape)
    dp = batch_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    if len(shape) > batch_dim and dp and shape[batch_dim] % dp_total == 0 \
            and shape[batch_dim] > 1:
        parts[batch_dim] = dp
    if "model" in mesh.axis_names:
        msize = mesh.shape["model"]
        # largest unsharded dim divisible by the model axis
        cands = [(shape[i], i) for i in range(len(shape))
                 if parts[i] is None and i != batch_dim
                 and shape[i] % msize == 0 and shape[i] >= msize]
        if cands:
            _, idx = max(cands)
            parts[idx] = "model"
    return P(*parts)


def decode_state_sharding(state_abs: DecodeState, mesh: Mesh) -> DecodeState:
    def one(leaf):
        return NamedSharding(mesh, _leaf_spec(tuple(leaf.shape), mesh))

    caches = jax.tree_util.tree_map(one, state_abs.caches)
    extras = jax.tree_util.tree_map(
        lambda l: NamedSharding(mesh, _leaf_spec(tuple(l.shape), mesh,
                                                 batch_dim=0)),
        state_abs.extras)
    return DecodeState(
        caches=caches,
        lengths=NamedSharding(mesh, P()),
        extras=extras,
    )


def pool_sharding(bundle, num_slots: int, max_len: int, mesh: Mesh,
                  dtype=None) -> DecodeState:
    """Shardings for the continuous-batching KV-cache pool
    (``repro.serve.scheduler``): the SLOT axis is just the batch axis of a
    ``DecodeState`` (dim 1 of every cache leaf, after the stacked-layer
    axis — the ``SegmentDef.cache_spec`` contract), so the standard decode
    rules apply — slots shard over the data mesh axes, the largest
    remaining dim (KV time for attention caches) over model. ``lengths``
    stays replicated: the host scheduler reads it for admission control.

    Feed the result to ``Scheduler(..., shardings=...)``; inserts and
    decode steps then keep every pool buffer on the data axis (a slot
    admission touches only the shards owning that slot)."""
    import jax.numpy as jnp

    from repro.serve import engine
    dtype = dtype if dtype is not None else jnp.bfloat16
    abs_state = engine.abstract_decode_state(bundle, num_slots, max_len,
                                             dtype)
    return decode_state_sharding(abs_state, mesh)


def paged_pool_sharding(bundle, num_blocks: int, block_size: int,
                        mesh: Mesh, dtype=None):
    """Shardings for the PAGED block pool (``repro.serve.paged``): cache
    leaves are ``(L, num_blocks, block_size, …)`` — the BLOCK axis sits
    where the batch axis normally does (dim 1, the batch-major
    ``cache_spec`` contract), so it shards over the data mesh axes
    (``num_blocks`` must divide; the ``num_slots·MB + 1`` default does
    not — pick a divisible count for sharded pools), and KV time WITHIN a
    block (dim 2) goes on model when divisible — the context-parallel
    rule applied per block. Returns a caches-shaped dict for
    ``PagedScheduler(..., shardings=...)``; the jitted gather/append/
    scatter programs then keep every pool buffer distributed (GSPMD turns
    traced-index block gathers into collective gathers)."""
    import jax.numpy as jnp

    from repro.serve import engine
    dtype = dtype if dtype is not None else jnp.bfloat16
    abs_state = engine.abstract_decode_state(bundle, num_blocks, block_size,
                                             dtype)
    dp = batch_axes(mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def one(leaf):
        shape = tuple(leaf.shape)
        parts: list = [None] * len(shape)
        if len(shape) > 1 and dp and shape[1] % dp_total == 0 \
                and shape[1] > 1:
            parts[1] = dp
        if "model" in mesh.axis_names and len(shape) > 2:
            msize = mesh.shape["model"]
            if shape[2] % msize == 0 and shape[2] >= msize:
                parts[2] = "model"
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map(one, abs_state.caches)
