"""Continuous-batching serving runtime: slot scheduler + KV-cache pool.

The lockstep host loop (``engine.generate``) decodes one fixed batch to
completion — every row pays for the slowest row's output length, and new
requests wait for the whole batch to drain. This module replaces it with
the standard continuous-batching design:

* a **KV-cache pool**: one ``DecodeState`` whose batch axis is a fixed set
  of ``num_slots`` *slots* (cache leaves are ``(L, num_slots, max_len, …)``
  — the stacked-layer axis leads, the slot axis is dim 1, exactly the
  layout ``SegmentDef.cache_spec`` promises and ``repro.serve.shard`` puts
  on the data mesh axis);
* :func:`insert_request` — a **jit-stable** per-slot reset/insert: every
  leaf of a single-row prefill ``DecodeState`` is ``dynamic_update_slice``d
  into the pool at a *traced* slot index, so admitting into slot 0 and slot
  37 is the same compiled program (no per-slot recompiles);
* a host-side :class:`Scheduler` that admits pending requests into free
  slots mid-flight (prefill-into-slot), runs ONE batched decode step over
  the heterogeneous in-flight sequences (per-slot ``lengths`` drive both
  attention masking and cache writes — see ``engine.build_decode``), and
  retires slots on EOS / max-tokens, freeing them for the next admission.

Per-slot decode results are row-independent (attention/FFN reduce within a
row; MoE decode runs drop-free), so continuous batching is **token-identical**
to the lockstep baseline under greedy sampling — verified by
``tests/test_scheduler.py`` and benchmarked by ``benchmarks/serve_bench.py``
(``BENCH_serve.json``).

INT8-native weights (PR 2) are consumed as-is: both the per-request prefill
and the batched decode step stream QTensor blocks through
``quantized_dense`` — admission does not materialize weights either.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Any, Deque, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelBundle
from repro.serve import engine
from repro.serve.engine import DecodeState


# ---------------------------------------------------------------------------
# Requests / completions
# ---------------------------------------------------------------------------

@dataclass
class Request:
    """One generation request: ``tokens`` is the unpadded prompt.

    ``cont`` carries the in-progress :class:`Completion` of a PREEMPTED
    request (paged backend only): the paged scheduler may evict a running
    sequence when the block pool drains and requeue it as a continuation
    whose prompt is the original prompt plus everything emitted so far —
    on re-admission the completion keeps accumulating instead of starting
    over (greedy decode makes the replayed prefix token-identical)."""
    rid: int
    tokens: Sequence[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    cont: Optional["Completion"] = None


@dataclass
class Completion:
    rid: int
    prompt_len: int
    tokens: List[int]                 # generated tokens (eos included)
    t_submit: float = 0.0
    t_admit: float = 0.0
    t_first: float = 0.0              # first token emitted (TTFT anchor)
    t_finish: float = 0.0

    @property
    def latency(self) -> float:
        return self.t_finish - self.t_submit

    @property
    def ttft(self) -> float:
        """Time-to-first-token: submit → first emitted token. For the slot
        backend admission and first token coincide; for the paged backend
        chunked prefill separates them (``t_admit < t_first``)."""
        return self.t_first - self.t_submit


@dataclass
class _Slot:
    rid: int = -1
    remaining: int = 0
    eos_id: Optional[int] = None
    completion: Optional[Completion] = None
    free: bool = True


# ---------------------------------------------------------------------------
# KV-cache pool
# ---------------------------------------------------------------------------

def init_pool(bundle: ModelBundle, num_slots: int, max_len: int,
              dtype=jnp.bfloat16) -> DecodeState:
    """Concrete zero-filled slot pool matching ``abstract_decode_state``."""
    abs_state = engine.abstract_decode_state(bundle, num_slots, max_len,
                                             dtype)
    zeros = lambda s: jnp.zeros(s.shape, s.dtype)
    return DecodeState(
        caches=jax.tree_util.tree_map(zeros, abs_state.caches),
        lengths=jnp.zeros((num_slots,), jnp.int32),
        extras=jax.tree_util.tree_map(zeros, abs_state.extras),
    )


def insert_request(pool: DecodeState, slot, row: DecodeState) -> DecodeState:
    """Insert a single-row prefill state into pool slot ``slot``.

    jit-stable: ``slot`` is a traced scalar; every leaf updates via
    ``dynamic_update_slice`` (cache leaves at batch dim 1 — dim 0 is the
    stacked layer axis; ``lengths``/extras at dim 0). One compiled program
    serves every slot."""
    slot = jnp.asarray(slot, jnp.int32)

    def ins(batch_dim, pool_leaf, row_leaf):
        starts = [jnp.zeros((), jnp.int32)] * pool_leaf.ndim
        starts[batch_dim] = slot
        return jax.lax.dynamic_update_slice(
            pool_leaf, row_leaf.astype(pool_leaf.dtype), starts)

    caches = jax.tree_util.tree_map(
        lambda p, r: ins(1, p, r), pool.caches, row.caches)
    lengths = jax.lax.dynamic_update_slice(
        pool.lengths, row.lengths.astype(pool.lengths.dtype), (slot,))
    extras = jax.tree_util.tree_map(
        lambda p, r: ins(0, p, r), pool.extras, row.extras)
    return DecodeState(caches, lengths, extras)


def insert_requests(pool: DecodeState, slots, rows: DecodeState
                    ) -> DecodeState:
    """Batched :func:`insert_request`: ``rows`` is a B-row prefill state,
    ``slots`` a (B,) slot-index vector — one scatter per pool leaf admits
    the whole group (the common case right after startup or a burst of
    retirements). Compiles once per group size B; slot VALUES stay traced."""
    slots = jnp.asarray(slots, jnp.int32)
    caches = jax.tree_util.tree_map(
        lambda p, r: p.at[:, slots].set(r.astype(p.dtype),
                                        unique_indices=True),
        pool.caches, rows.caches)
    lengths = pool.lengths.at[slots].set(
        rows.lengths.astype(pool.lengths.dtype), unique_indices=True)
    extras = jax.tree_util.tree_map(
        lambda p, r: p.at[slots].set(r.astype(p.dtype),
                                     unique_indices=True),
        pool.extras, rows.extras)
    return DecodeState(caches, lengths, extras)


def build_decode_step(bundle: ModelBundle, temperature: float = 0.0,
                      pad_id: int = 0):
    """One batched continuous-decode step over the slot pool.

    ``active`` (B,) masks retired/free slots: their ``lengths`` do not
    advance (the cache write lands on a dead slot's scratch position and is
    overwritten at the next admission) and their sampled token is ``pad_id``.
    Active slots decode exactly as in the lockstep path — per-row ``lengths``
    select the RoPE position, the cache write slot, and the attention mask.
    """
    decode = engine.build_decode(bundle)

    def step(params, pool: DecodeState, tokens, active, key):
        logits, new = decode(params, pool, tokens[:, None])
        lengths = jnp.where(active, new.lengths, pool.lengths)
        toks = engine.sample(logits, key, temperature)
        toks = jnp.where(active, toks, pad_id)
        return toks, DecodeState(new.caches, lengths, new.extras)

    return step


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------

def _bucket(n: int, bucket: int) -> int:
    return max(bucket, -(-n // bucket) * bucket)


class Scheduler:
    """Slot-based continuous-batching scheduler over a model bundle.

    Host-side control (admit / retire / token bookkeeping) around three
    jitted programs: group prefill (pending requests batched, padded to a
    ``prompt_bucket`` multiple → bounded compile count), jit-stable
    :func:`insert_requests` (traced slot indices), and the batched masked
    decode step.

    Restricted to bundles without ``decode_extras`` (enc-dec carries a
    per-request encoder memory whose admission contract is not slot-shaped
    yet). Recurrent-state families work — their cache leaves are simply
    stateful ``(L, B, …)`` tensors with no time axis — but they fold every
    input position into their state (``bundle.ragged_prefill_ok=False``),
    so the scheduler admits them ONE request at a time with an
    exact-length (unpadded, unbucketed) prefill; batched right-padded
    group admission is reserved for ragged-safe (causal-attention)
    bundles.

    ``shardings``: optional ``DecodeState`` of ``NamedSharding``s for the
    pool (see ``repro.serve.shard.pool_sharding``) — keeps the slot axis on
    the data mesh axis across inserts and decode steps.
    """

    def __init__(self, bundle: ModelBundle, params, *, num_slots: int,
                 max_len: int, pad_id: int = 0, temperature: float = 0.0,
                 prompt_bucket: int = 16, dtype=None, key=None,
                 shardings: Optional[DecodeState] = None):
        if bundle.decode_extras:
            raise NotImplementedError(
                "continuous batching requires slot-shaped decode state; "
                f"bundle carries decode_extras={bundle.decode_extras!r}")
        self.bundle = bundle
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.pad_id = pad_id
        self.temperature = temperature
        self.prompt_bucket = prompt_bucket
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._key0 = self._key   # snapshot: reset() restores it
        dtype = dtype if dtype is not None else jnp.bfloat16

        self._prefill = jax.jit(
            engine.build_prefill(bundle, max_len, pad_id=None))
        insert_kw: Dict[str, Any] = {}
        if shardings is not None:
            insert_kw["out_shardings"] = shardings
        self._insert = jax.jit(insert_requests, **insert_kw)
        self._step = jax.jit(build_decode_step(bundle, temperature, pad_id))

        self.pool = init_pool(bundle, num_slots, max_len, dtype)
        if shardings is not None:
            self.pool = jax.device_put(self.pool, shardings)
        self.slots = [_Slot() for _ in range(num_slots)]
        self.cur_tokens = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.pending: Deque[Request] = deque()
        self._submit_t: Dict[int, float] = {}
        self.completed: List[Completion] = []
        self.t = 0   # global decode-step counter (sampling key schedule)
        self.stats = {"admitted": 0, "retired": 0, "decode_steps": 0,
                      "prefills": 0, "evictions": 0}

    def reset(self) -> None:
        """Clear all serving state but keep the compiled programs — a fresh
        pool without paying prefill/decode retrace (benchmark warm runs).
        The sampling key is restored to its construction-time snapshot so
        warm rounds are bit-reproducible under ``temperature > 0`` (the
        per-step/admission keys fold in from the same root every run)."""
        self._key = self._key0
        self.pool = jax.tree_util.tree_map(jnp.zeros_like, self.pool)
        self.slots = [_Slot() for _ in range(self.num_slots)]
        self.cur_tokens = np.zeros((self.num_slots,), np.int32)
        self.active = np.zeros((self.num_slots,), bool)
        self.pending.clear()
        self._submit_t.clear()
        self.completed = []
        self.t = 0
        self.stats = {k: 0 for k in self.stats}

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request; rejects it up front (nothing else is lost)
        when it cannot fit the cache window."""
        L = len(req.tokens)
        if L == 0:
            raise ValueError(
                f"request {req.rid}: empty prompt — every request needs "
                ">= 1 token (an all-pad prefill row would decode from "
                "garbage logits; see engine.check_prompt_lengths)")
        if L + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt {L} + max_new "
                f"{req.max_new_tokens} exceeds max_len {self.max_len}")
        self._submit_t[req.rid] = time.monotonic()
        self.pending.append(req)

    # -- admission ---------------------------------------------------------

    def _free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.free]

    def _admit_group(self, slot_ids: List[int],
                     group: List[Request]) -> None:
        """Prefill a group of requests as ONE ragged (right-padded) batch
        and scatter-insert every row into its slot mid-flight. Per-row
        ``lengths`` keep padded rows exact (see ``engine.build_prefill``),
        so a B-row group admission emits the same first tokens as B
        single-row prefills."""
        B = len(group)
        lens = [len(req.tokens) for req in group]
        if self.bundle.ragged_prefill_ok:
            Lp = min(_bucket(max(lens), self.prompt_bucket), self.max_len)
        else:
            # recurrent state folds pads in — exact-length, one at a time
            assert B == 1, "padded group admission needs ragged_prefill_ok"
            Lp = lens[0]
        padded = np.full((B, Lp), self.pad_id, np.int32)
        for i, req in enumerate(group):
            padded[i, : lens[i]] = np.asarray(req.tokens, np.int32)
        batch = {"tokens": jnp.asarray(padded),
                 "lengths": jnp.asarray(lens, jnp.int32)}
        logits, rows = self._prefill(self.params, batch)
        self.stats["prefills"] += 1
        self.pool = self._insert(self.pool,
                                 np.asarray(slot_ids, np.int32), rows)

        # admission keys live in a disjoint range from the per-step keys
        # (fold_in data is uint32)
        key = jax.random.fold_in(self._key,
                                 2 ** 31 + self.stats["admitted"])
        toks = np.asarray(engine.sample(logits, key, self.temperature))
        now = time.monotonic()
        for i, (slot_id, req) in enumerate(zip(slot_ids, group)):
            tok = int(toks[i])
            comp = Completion(rid=req.rid, prompt_len=lens[i],
                              tokens=[tok],
                              t_submit=self._submit_t.pop(req.rid, now),
                              t_admit=now, t_first=now)
            self.stats["admitted"] += 1
            if self._finished(tok, 1, req):
                # done at the first token: the slot was filled but never
                # activates — it stays free for the next admission
                comp.t_finish = time.monotonic()
                self.completed.append(comp)
                self.stats["retired"] += 1
                continue
            slot = self.slots[slot_id]
            slot.rid, slot.free = req.rid, False
            slot.remaining = req.max_new_tokens - 1
            slot.eos_id = req.eos_id
            slot.completion = comp
            self.cur_tokens[slot_id] = tok
            self.active[slot_id] = True

    @staticmethod
    def _finished(tok: int, n_emitted: int, req: Request) -> bool:
        return n_emitted >= req.max_new_tokens or \
            (req.eos_id is not None and tok == req.eos_id)

    def _retire(self, slot_id: int) -> None:
        """Evict a finished sequence: record its completion and free the
        slot for the next admission (the pool row is reset on insert)."""
        slot = self.slots[slot_id]
        slot.completion.t_finish = time.monotonic()
        self.completed.append(slot.completion)
        slot.free, slot.rid, slot.completion = True, -1, None
        self.active[slot_id] = False
        self.cur_tokens[slot_id] = self.pad_id
        self.stats["retired"] += 1
        self.stats["evictions"] += 1

    # -- the serving loop --------------------------------------------------

    def step(self) -> bool:
        """Admit pending requests into free slots, then run one batched
        decode step. Returns False when idle (nothing active or pending)."""
        free = self._free_slots()
        while free and self.pending:
            n = min(len(free), len(self.pending)) \
                if self.bundle.ragged_prefill_ok else 1
            self._admit_group(free[:n],
                              [self.pending.popleft() for _ in range(n)])
            free = free[n:]

        if not self.active.any():
            return bool(self.pending)

        key = jax.random.fold_in(self._key, self.t)
        toks, self.pool = self._step(
            self.params, self.pool, jnp.asarray(self.cur_tokens),
            jnp.asarray(self.active), key)
        self.t += 1
        self.stats["decode_steps"] += 1

        toks = np.asarray(toks)
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            tok = int(toks[i])
            slot.completion.tokens.append(tok)
            slot.remaining -= 1
            if slot.remaining <= 0 or \
                    (slot.eos_id is not None and tok == slot.eos_id):
                self._retire(i)
            else:
                self.cur_tokens[i] = tok
        return True

    def run(self, requests: Sequence[Request] = (),
            arrivals: Optional[Sequence[float]] = None
            ) -> List[Completion]:
        """Drive to completion. ``arrivals``: optional per-request offsets
        (seconds from start) modelling an offered request rate — requests
        are withheld from the pending queue until their arrival time."""
        if arrivals is None:
            for r in requests:
                self.submit(r)
            waiting: List[tuple] = []
        else:
            order = np.argsort(np.asarray(arrivals, float), kind="stable")
            waiting = [(float(arrivals[i]), requests[i]) for i in order]
        t0 = time.monotonic()
        while True:
            now = time.monotonic() - t0
            while waiting and waiting[0][0] <= now:
                _, r = waiting.pop(0)
                self.submit(r)
            busy = self.step()
            if not busy and not waiting:
                break
            if not busy and waiting:
                time.sleep(min(0.001, max(0.0, waiting[0][0] - now)))
        return self.completed


def make_scheduler(bundle: ModelBundle, params, *, backend: str = "auto",
                   num_slots: int, max_len: int, **kw) -> "Scheduler":
    """Backend selection for the serving runtime.

    ``backend``: ``"slot"`` — the contiguous per-slot pool above (every
    architecture); ``"paged"`` — the block-pool runtime with radix prefix
    sharing and chunked prefill (``repro.serve.paged``, requires
    ``engine.append_ok`` — dense GQA transformer families); ``"auto"`` —
    paged when the bundle supports it, slot otherwise. Both are
    token-identical under greedy decode; see ``docs/serving.md`` for when
    each wins."""
    if backend == "auto":
        backend = "paged" if engine.append_ok(bundle) else "slot"
    if backend == "paged":
        from repro.serve.paged import PagedScheduler
        return PagedScheduler(bundle, params, num_slots=num_slots,
                              max_len=max_len, **kw)
    if backend == "slot":
        kw = {k: v for k, v in kw.items()
              if k not in ("block_size", "num_blocks", "prefill_chunk",
                           "use_radix")}
        return Scheduler(bundle, params, num_slots=num_slots,
                         max_len=max_len, **kw)
    raise ValueError(f"unknown serving backend {backend!r} "
                     "(expected 'slot', 'paged', or 'auto')")
