"""Paged serving runtime: block KV pool + radix prefix sharing + chunked
prefill (serving v2 — see ``docs/serving.md``).

The slot scheduler (``repro.serve.scheduler``) reserves ``max_len``
contiguous KV positions per slot — memory scales with the worst case, a
shared system prompt re-prefills per request, and a long prompt's one-shot
prefill stalls every in-flight decode. This module keeps the scheduler's
continuous-batching control flow but swaps the pool for vLLM-style paging:

* **Block pool** — cache leaves are ``(L, num_blocks, block_size, …)``
  (``SegmentDef.cache_spec`` with the block axis where the batch axis
  normally sits, so the batch-major contract and the shard rules carry
  over). A host-side ``(num_slots, MB)`` **block table** maps each slot's
  logical KV positions to physical blocks; :class:`BlockAllocator` hands
  blocks out of a free list with refcounts (shared blocks live until the
  last user derefs). Physical block 0 is reserved **scratch**: unallocated
  table entries and dead-slot decode writes land there, so the jitted
  programs never branch on allocation state.
* **jit-stable gather + two-phase write** — one step gathers each slot's
  blocks into a contiguous ``(L, S, MB·block_size, …)`` view (``jnp.take``
  at traced indices) and runs the unmodified ``engine.build_decode`` /
  ``engine.build_append`` over it. The compute program is READ-ONLY on the
  pool: it returns just the freshly written K/V (captured inside the layer
  scan via the ``capture=`` hook — the one-hot cache update fuses into the
  capture gather, so updated full views are never materialized) plus a
  flat ``(physical block, offset)`` write plan; :func:`pool_write_kv`
  applies the plan as its own donated pure-write dispatch. A scatter
  inside the compute program would make the pool both gather-input and
  scatter-output — XLA cannot alias that, and every step would copy the
  whole pool. Shared prefix blocks are never written by decode: a slot's
  write position is always ``>=`` its private-suffix start.
* **Radix prefix cache** — ``repro.serve.radix`` maps block-aligned token
  prefixes to physical blocks; admission maps matched blocks straight into
  the new slot's table (+1 ref each) and prefill starts after them.
  Matching is capped one token short of the prompt so at least one suffix
  token runs through prefill (the first-token logits must be produced).
  When the free list drains, LRU trie leaves with no other users are
  evicted; if the pool is still dry mid-decode, the youngest running slot
  is **preempted** — its blocks freed and the request requeued as a
  continuation (prompt + emitted tokens, see ``Request.cont``).
* **Chunked prefill** — prompts run through ``engine.build_append`` in
  fixed-width chunks, one chunk per prefilling slot per scheduler step in
  a SINGLE batched dispatch, interleaved with the batched decode step — a
  long prompt no longer stalls in-flight decodes, and concurrent prompts
  no longer serialize behind each other. Prefilling rows are compacted to
  a power-of-two bucket before dispatch (jit retraces once per bucket), so
  append compute scales with live prefill rows, not pool size.
  Chunked append is bit-identical to one-shot prefill (the
  ``SegmentDef.append`` contract), so the paged engine is token-identical
  to the slot engine under greedy decode (``tests/test_paged.py``).

Admission backpressure: ``submit`` raises only for requests that can
NEVER fit (window or whole-pool bound); a momentarily-full pool just
queues (``stats["admission_blocked"]`` counts deferrals).

Requires :func:`engine.append_ok` bundles — dense GQA transformer
families. Recurrent/MoE/MLA/enc-dec stay on the slot backend
(``make_scheduler`` in ``repro.serve.scheduler`` picks).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.base import ModelBundle
from repro.serve import engine
from repro.serve.engine import DecodeState
from repro.serve.radix import RadixCache
from repro.serve.scheduler import Completion, Request, Scheduler


# ---------------------------------------------------------------------------
# Block allocator
# ---------------------------------------------------------------------------

class BlockAllocator:
    """Refcounted free-list allocator over ``num_blocks`` physical blocks.

    Block 0 is reserved scratch (pinned, never allocated): unallocated
    block-table entries point at it so gathers/scatters at dead or
    not-yet-filled positions stay in-bounds without branching.

    Invariant (property-tested): every block is either scratch, on the
    free list with refcount 0, or allocated with refcount >= 1 — derefs
    below zero and refs of free blocks raise instead of corrupting the
    pool.
    """

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(f"need >= 2 blocks (scratch + 1 usable), "
                             f"got {num_blocks}")
        self.num_blocks = num_blocks
        self.refcount = np.zeros((num_blocks,), np.int32)
        self.refcount[0] = 1                      # scratch, pinned forever
        self._free = deque(range(1, num_blocks))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1

    def alloc(self) -> Optional[int]:
        """Pop a free block (refcount 1) or None when the pool is dry."""
        if not self._free:
            return None
        p = self._free.popleft()
        self.refcount[p] = 1
        return p

    def ref(self, p: int) -> None:
        """Add a reference to a LIVE block (prefix sharing)."""
        if p == 0:
            raise ValueError("block 0 is scratch — never share it")
        if self.refcount[p] <= 0:
            raise ValueError(f"ref of free block {p}")
        self.refcount[p] += 1

    def deref(self, p: int) -> None:
        """Drop a reference; the block returns to the free list at zero."""
        if p == 0:
            raise ValueError("block 0 is scratch — never free it")
        if self.refcount[p] <= 0:
            raise ValueError(f"double free of block {p}")
        self.refcount[p] -= 1
        if self.refcount[p] == 0:
            self._free.append(p)

    def reset(self) -> None:
        self.refcount[:] = 0
        self.refcount[0] = 1
        self._free = deque(range(1, self.num_blocks))

    def check(self) -> None:
        """Assert the pool invariant (tests)."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate blocks on the free list")
        for p in range(1, self.num_blocks):
            rc = int(self.refcount[p])
            if (p in free) != (rc == 0):
                raise AssertionError(
                    f"block {p}: refcount {rc} vs free-list "
                    f"{'present' if p in free else 'absent'}")


# ---------------------------------------------------------------------------
# jitted programs: gather view → engine step → scatter written block
# ---------------------------------------------------------------------------

def _gather_views(caches, tables, MB: int, block_size: int):
    """Per-slot contiguous views of the block pool: leaf
    ``(L, NB, blk, …)`` + tables ``(S, MB)`` → ``(L, S, MB·blk, …)``.
    Row-major take order makes the single reshape land block m's positions
    at view offset ``m·blk`` — the slot's logical KV timeline."""
    S = tables.shape[0]

    def g(leaf):
        v = jnp.take(leaf, tables.reshape(-1), axis=1)
        return v.reshape(leaf.shape[0], S, MB * block_size,
                         *leaf.shape[3:])

    return {k: jax.tree_util.tree_map(g, c) for k, c in caches.items()}


def _take_pos(leaf, pos):
    """Gather positions ``pos`` (B, P) out of a cache leaf (B, T, …) →
    (B, P, …): the freshly written K/V of this step, recovered WITHOUT
    materializing the updated view — the segments' one-hot cache update
    is elementwise, so XLA fuses it into this gather and computes only
    the gathered positions."""
    idx = jnp.minimum(pos, leaf.shape[1] - 1)
    for _ in range(leaf.ndim - 2):
        idx = idx[..., None]
    idx = jnp.broadcast_to(idx, pos.shape + leaf.shape[2:])
    return jnp.take_along_axis(leaf, idx, axis=1)


def _capture_decode(new_cache, ctx):
    """Engine ``capture`` hook: keep only position ``length`` of each
    updated cache leaf — the one K/V this decode step wrote."""
    pos = ctx["length"].astype(jnp.int32)[:, None]
    return jax.tree_util.tree_map(lambda l: _take_pos(l, pos), new_cache)


def _capture_append(new_cache, ctx):
    """Engine ``capture`` hook: keep only the chunk's absolute positions
    of each updated cache leaf — the C K/Vs this append chunk wrote
    (masked tail columns carry garbage; the write plan scratches them)."""
    pos = ctx["positions"].astype(jnp.int32)
    return jax.tree_util.tree_map(lambda l: _take_pos(l, pos), new_cache)


def _flatten_kv(captured):
    """Captured leaves (L, B, P, …) → (L, B·P, …), row-major — the layout
    :func:`pool_write_kv` expects alongside flat ``phys``/``off``."""
    return {
        k: jax.tree_util.tree_map(
            lambda l: l.reshape((l.shape[0], -1) + l.shape[3:]), c)
        for k, c in captured.items()}


def _append_write_plan(tables_g, base, chunk_len, C: int,
                       block_size: int, MB: int):
    """Pool targets for a chunk append: position ``base + i`` of row r
    lands in block ``tables_g[r, (base+i)//blk]`` at offset ``%blk``;
    columns past ``chunk_len`` (and padded rows) redirect to scratch
    block 0. Returns flat ``phys``/``off`` (g·C,) in capture order."""
    pos = base[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
    valid = jnp.arange(C, dtype=jnp.int32)[None] < chunk_len[:, None]
    posc = jnp.clip(pos, 0, MB * block_size - 1)
    phys = jnp.take_along_axis(tables_g, posc // block_size, axis=1)
    phys = jnp.where(valid, phys, 0)
    return phys.reshape(-1), (posc % block_size).reshape(-1)


def pool_write_kv(caches, phys, off, kvs):
    """Phase-2 pool write: set K/V at (block ``phys``, offset ``off``) —
    ``kvs`` leaves (L, N, …) against pool leaves (L, NB, blk, …).

    Kept as its OWN jitted dispatch (donated pool, pure write) instead of
    scattering inside the compute programs: there the pool is also the
    gather's input, so XLA cannot alias the update and every step copies
    the whole pool; here donation leaves only the N written positions.
    Scratch-bound entries may collide at block 0 — content is dead."""
    def one(big, kv):
        return big.at[:, phys, off].set(kv.astype(big.dtype))

    return {k: jax.tree_util.tree_map(one, caches[k], kvs[k])
            for k in caches}


def build_paged_decode_step(bundle: ModelBundle, block_size: int, MB: int,
                            temperature: float = 0.0, pad_id: int = 0):
    """One batched decode step over the block pool.

    Gather every slot's view, run ``engine.build_decode`` on it (per-slot
    ``lengths`` mask exactly as in the slot pool — scratch garbage beyond
    ``lengths`` contributes exact zeros), and return the ONE K/V each
    slot wrote (captured in-scan, never materializing updated views) plus
    its pool target for :func:`pool_write_kv`. Inactive slots redirect
    their write to scratch block 0. The pool itself is READ-ONLY here —
    that is what lets the phase-2 write alias it in place.
    """
    decode = engine.build_decode(bundle, capture=_capture_decode)

    def step(params, caches, tables, lengths, tokens, active, key):
        views = _gather_views(caches, tables, MB, block_size)
        state = DecodeState(views, lengths, {})
        logits, new = decode(params, state, tokens[:, None])
        toks = engine.sample(logits, key, temperature)
        toks = jnp.where(active, toks, pad_id)

        pos = jnp.clip(lengths.astype(jnp.int32), 0,
                       MB * block_size - 1)
        phys = jnp.take_along_axis(tables, (pos // block_size)[:, None],
                                   axis=1)[:, 0]
        phys = jnp.where(active, phys, 0)
        new_lengths = lengths + active.astype(lengths.dtype)
        return (toks, new_lengths, phys, pos % block_size,
                _flatten_kv(new.caches))

    return step


def build_paged_append(bundle: ModelBundle, block_size: int, MB: int,
                       chunk: int, temperature: float = 0.0):
    """One BATCHED chunk of paged prefill over a COMPACTED row set: the
    ``g`` prefilling slots named by ``psids`` advance up to ``chunk``
    tokens in a single dispatch.

    Compaction is the throughput lever: prefill compute scales with rows
    × chunk width, and late-admitted stragglers would otherwise pad every
    idle slot to full width (at 8 slots / 1 prefilling, 8× the useful
    work). The host buckets ``g`` to the next power of two — jit retraces
    once per bucket shape — and pads ``psids`` with slot 0 / ``chunk_len
    0`` rows, which compute garbage that is masked and scatter to scratch.

    Gathers only the compacted rows' views, runs ``engine.build_append``
    (bit-identical to one-shot prefill) with per-row ``chunk_len``, and
    returns the chunk's freshly written K/Vs (captured in-scan) plus
    their pool targets for :func:`pool_write_kv`; masked tail columns
    redirect to scratch block 0. Radix-shared prefix blocks are never in
    the written range — a chunk starts at ``pos >= matched_len``, inside
    the slot's private blocks — so prefix sharing needs no copy-on-write
    here.

    Also samples a first token PER ROW from each chunk's last-real-token
    logits, using the admission key schedule (``fold_in(key, 2^31 +
    admit_idx)``) — sampling in-program means a slot finishing its prompt
    costs zero extra dispatches. Rows of unfinished prompts are garbage —
    the scheduler only reads rows whose prompt just completed.
    """
    append = engine.build_append(bundle, MB * block_size,
                                 capture=_capture_append)

    def run(params, caches, tables, lengths, psids, tokens, chunk_len,
            admit_idx, key):
        tables_g = jnp.take(tables, psids, axis=0)          # (g, MB)
        base = jnp.take(lengths, psids, axis=0).astype(jnp.int32)
        views = _gather_views(caches, tables_g, MB, block_size)
        state = DecodeState(views, base, {})
        logits, new = append(params, state, tokens, chunk_len)

        def sample_row(row, idx):
            # uint32 wrap matches the host-side fold_in(key, 2**31 + i)
            k = jax.random.fold_in(
                key, jnp.uint32(2 ** 31) + idx.astype(jnp.uint32))
            return engine.sample(row[None], k, temperature)[0]

        toks = jax.vmap(sample_row)(logits, admit_idx)

        # capture width: the engine pads width-1 chunks to 2
        C = jax.tree_util.tree_leaves(new.caches)[0].shape[2]
        phys, off = _append_write_plan(tables_g, base, chunk_len, C,
                                       block_size, MB)
        # duplicate padded psids rows add chunk_len 0 — harmless
        new_lengths = lengths.at[psids].add(
            chunk_len.astype(lengths.dtype))
        return toks, new_lengths, phys, off, _flatten_kv(new.caches)

    return run


def build_paged_fused(bundle: ModelBundle, block_size: int, MB: int,
                      chunk: int, temperature: float = 0.0,
                      pad_id: int = 0):
    """One scheduler step's decode AND prefill chunk in a single dispatch.

    The prefilling and active slot sets are disjoint, so both programs
    can run off the SAME gathered view (decode reads nothing the append
    writes and vice versa) and their scatters land in disjoint physical
    blocks (idle rows of either path redirect to scratch block 0). Fusing
    halves the dispatch + gather cost of the mixed prefill/decode phase —
    per-step host overhead is what dominates small-batch serving.

    Decode runs over all ``S`` slots (a 1-token step is cheap); the
    append side runs over the COMPACTED ``psids`` rows only — see
    :func:`build_paged_append` for why compaction is the prefill
    throughput lever and how padded rows stay harmless.
    """
    append = engine.build_append(bundle, MB * block_size,
                                 capture=_capture_append)
    decode = engine.build_decode(bundle, capture=_capture_decode)

    def run(params, caches, tables, lengths, cur_tokens, active,
            psids, tokens, chunk_len, admit_idx, akey, dkey):
        views = _gather_views(caches, tables, MB, block_size)
        state = DecodeState(views, lengths, {})

        dlogits, dnew = decode(params, state, cur_tokens[:, None])
        dtoks = engine.sample(dlogits, dkey, temperature)
        dtoks = jnp.where(active, dtoks, pad_id)

        tables_g = jnp.take(tables, psids, axis=0)          # (g, MB)
        base = jnp.take(lengths, psids, axis=0).astype(jnp.int32)
        aviews = {
            k: jax.tree_util.tree_map(
                lambda v: jnp.take(v, psids, axis=1), c)
            for k, c in views.items()}
        astate = DecodeState(aviews, base, {})
        alogits, anew = append(params, astate, tokens, chunk_len)

        def sample_row(row, idx):
            k = jax.random.fold_in(
                akey, jnp.uint32(2 ** 31) + idx.astype(jnp.uint32))
            return engine.sample(row[None], k, temperature)[0]

        atoks = jax.vmap(sample_row)(alogits, admit_idx)

        # one combined write plan covering BOTH phases: the decode-
        # written position of every slot plus the chunk positions of
        # every compacted row (disjoint physical blocks; idle entries
        # redirect to scratch block 0)
        pos_d = jnp.clip(lengths.astype(jnp.int32), 0,
                         MB * block_size - 1)
        phys_d = jnp.take_along_axis(
            tables, (pos_d // block_size)[:, None], axis=1)[:, 0]
        phys_d = jnp.where(active, phys_d, 0)
        C = jax.tree_util.tree_leaves(anew.caches)[0].shape[2]
        phys_a, off_a = _append_write_plan(tables_g, base, chunk_len, C,
                                           block_size, MB)
        phys = jnp.concatenate([phys_d, phys_a])
        off = jnp.concatenate([pos_d % block_size, off_a])
        dkv, akv = _flatten_kv(dnew.caches), _flatten_kv(anew.caches)
        kvs = {
            k: jax.tree_util.tree_map(
                lambda d, a: jnp.concatenate([d, a], axis=1), dkv[k],
                akv[k])
            for k in dkv}
        new_lengths = (lengths + active.astype(lengths.dtype)).at[
            psids].add(chunk_len.astype(lengths.dtype))
        return dtoks, atoks, new_lengths, phys, off, kvs

    return run


# ---------------------------------------------------------------------------
# Paged scheduler
# ---------------------------------------------------------------------------

@dataclass
class _PSlot:
    rid: int = -1
    free: bool = True
    remaining: int = 0
    eos_id: Optional[int] = None
    completion: Optional[Completion] = None
    prompt: Optional[np.ndarray] = None
    pos: int = 0                  # prompt positions already in the cache
    n_blocks: int = 0             # allocated table entries
    prefilling: bool = False
    reserved: int = 0             # full-window block budget (admission)
    admit_idx: int = 0            # admission ordinal → first-token key
    t_admit: float = 0.0          # preemption picks the youngest victim
    emitted_in_prompt: int = 0    # completion tokens already IN ``prompt``
                                  # (continuation resume — avoids doubling
                                  # them on a second preemption)


class PagedScheduler(Scheduler):
    """Continuous batching over the paged block pool.

    Same external contract as :class:`Scheduler` (``submit`` / ``step`` /
    ``run`` / ``completed`` / ``reset``) — ``run()`` and the finish rule
    are inherited — but admission maps radix-matched prefix blocks into
    the slot's table, prefill advances one fixed-width chunk of EVERY
    prefilling slot per step in one batched dispatch (interleaved with
    the batched decode step), and memory is accounted in blocks, not
    slots. Token-identical to :class:`Scheduler`
    under greedy decode.

    ``num_blocks`` defaults to ``num_slots * ceil(max_len/block_size) + 1``
    — the slot pool's exact KV footprint plus the scratch block — so
    slot-vs-paged comparisons are at fixed memory; capacity wins come from
    raising ``num_slots`` at the same ``num_blocks``.

    ``shardings``: optional caches-shaped dict of ``NamedSharding``s (see
    ``repro.serve.shard.paged_pool_sharding``) placing the block axis on
    the data mesh and time-within-block on model.
    """

    def __init__(self, bundle: ModelBundle, params, *, num_slots: int,
                 max_len: int, block_size: int = 16,
                 num_blocks: Optional[int] = None, prefill_chunk: int = 32,
                 pad_id: int = 0, temperature: float = 0.0, dtype=None,
                 key=None, shardings=None, use_radix: bool = True,
                 reserve_decode: bool = True):
        if not engine.append_ok(bundle):
            raise ValueError(
                f"{bundle.cfg.name}: paged serving requires chunk-append "
                "support (engine.append_ok) — use the slot Scheduler")
        self.bundle = bundle
        self.params = params
        self.num_slots = num_slots
        self.max_len = max_len
        self.block_size = block_size
        self.MB = -(-max_len // block_size)
        if num_blocks is None:
            num_blocks = num_slots * self.MB + 1
        self.num_blocks = num_blocks
        self.prefill_chunk = max(int(prefill_chunk), 1)
        self.reserve_decode = reserve_decode
        self.pad_id = pad_id
        self.temperature = temperature
        self._key = key if key is not None else jax.random.PRNGKey(0)
        self._key0 = self._key
        dtype = dtype if dtype is not None else jnp.bfloat16

        abs_state = engine.abstract_decode_state(
            bundle, num_blocks, block_size, dtype)
        zeros = lambda s: jnp.zeros(s.shape, s.dtype)
        self.caches = jax.tree_util.tree_map(zeros, abs_state.caches)
        if shardings is not None:
            self.caches = jax.device_put(self.caches, shardings)

        # two-phase step: the compute programs read the pool (gather) and
        # return fresh K/V + a write plan; pool_write_kv then applies it
        # as its own donated, pure-write dispatch. Scattering inside the
        # compute programs would force a full pool copy per step — the
        # pool is also the gather's input there, so XLA cannot alias.
        self._append = jax.jit(
            build_paged_append(bundle, block_size, self.MB,
                               self.prefill_chunk, temperature))
        self._step = jax.jit(build_paged_decode_step(
            bundle, block_size, self.MB, temperature, pad_id))
        self._fused = jax.jit(build_paged_fused(
            bundle, block_size, self.MB, self.prefill_chunk, temperature,
            pad_id))
        self._write = jax.jit(pool_write_kv, donate_argnums=(0,))

        self.alloc = BlockAllocator(num_blocks)
        self.radix: Optional[RadixCache] = \
            RadixCache(block_size) if use_radix else None
        self.tables = np.zeros((num_slots, self.MB), np.int32)
        self.lengths = np.zeros((num_slots,), np.int32)
        self.slots = [_PSlot() for _ in range(num_slots)]
        self.cur_tokens = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.pending: deque = deque()
        self._submit_t: Dict[int, float] = {}
        self.completed: List[Completion] = []
        self.t = 0
        # device-resident mirrors of the host control arrays: lengths and
        # cur_tokens round-trip through the jitted programs' outputs, so
        # in steady-state decode NOTHING is uploaded per step — host-side
        # mutations (admission, prompt-finish, block allocs) mark their
        # array dirty and it re-uploads once. Stale device rows of DEAD
        # slots are safe by construction: inactive/zero-chunk rows compute
        # garbage that is masked and their writes redirect to scratch.
        self._dev: Dict[str, Any] = {}
        self._dirty = {"tables", "lengths", "cur", "active"}
        self.stats = {"admitted": 0, "retired": 0, "decode_steps": 0,
                      "prefill_chunks": 0, "prefill_stalls": 0,
                      "radix_hit_blocks": 0, "radix_evictions": 0,
                      "admission_blocked": 0, "preemptions": 0,
                      "max_concurrent": 0}

    def reset(self) -> None:
        self._key = self._key0
        self.caches = jax.tree_util.tree_map(jnp.zeros_like, self.caches)
        self.alloc.reset()
        if self.radix is not None:
            self.radix.reset()
        self.tables[:] = 0
        self.lengths[:] = 0
        self.slots = [_PSlot() for _ in range(self.num_slots)]
        self.cur_tokens[:] = 0
        self.active[:] = False
        self.pending.clear()
        self._submit_t.clear()
        self.completed = []
        self.t = 0
        self._dev = {}
        self._dirty = {"tables", "lengths", "cur", "active"}
        self.stats = {k: 0 for k in self.stats}

    # -- request intake ----------------------------------------------------

    def submit(self, req: Request) -> None:
        """Queue a request. Raises ONLY for requests that can NEVER fit —
        prompt + max_new beyond the per-slot window or beyond the whole
        usable pool; a momentarily-full pool just queues (admission defers
        until blocks free up — the queue-then-admit regression test)."""
        L = len(req.tokens)
        if L == 0:
            raise ValueError(
                f"request {req.rid}: empty prompt — every request needs "
                ">= 1 token (see engine.check_prompt_lengths)")
        total = L + req.max_new_tokens
        if total > self.MB * self.block_size:
            raise ValueError(
                f"request {req.rid}: prompt {L} + max_new "
                f"{req.max_new_tokens} exceeds the per-request window "
                f"{self.MB * self.block_size} (MB={self.MB} blocks)")
        need = -(-total // self.block_size)
        if need > self.alloc.usable_blocks:
            raise ValueError(
                f"request {req.rid}: needs {need} blocks but the pool has "
                f"only {self.alloc.usable_blocks} usable — can never fit")
        if req.cont is None:
            self._submit_t[req.rid] = time.monotonic()
        self.pending.append(req)

    # -- block accounting --------------------------------------------------

    def _alloc_block(self) -> Optional[int]:
        """Allocate, evicting LRU radix leaves (trie-only blocks) while
        the free list is dry."""
        p = self.alloc.alloc()
        while p is None and self.radix is not None:
            victim = self.radix.evict(
                lambda b: int(self.alloc.refcount[b]) == 1)
            if victim is None:
                break
            self.alloc.deref(victim)
            self.stats["radix_evictions"] += 1
            p = self.alloc.alloc()
        return p

    def _can_alloc(self) -> bool:
        if self.alloc.free_blocks > 0:
            return True
        if self.radix is None:
            return False
        return any(int(self.alloc.refcount[b]) == 1
                   for b in self.radix.cached_blocks())

    def _available_blocks(self, exclude=()) -> int:
        """Free blocks plus radix blocks evictable on demand (held only by
        the trie), minus any the caller is about to adopt."""
        n = self.alloc.free_blocks
        if self.radix is not None:
            n += sum(1 for b in self.radix.cached_blocks()
                     if int(self.alloc.refcount[b]) == 1
                     and b not in exclude)
        return n

    def _outstanding_reserved(self) -> int:
        """Blocks promised to live slots but not yet allocated — their
        remaining prompt + decode growth up to ``max_new`` (only counted
        under ``reserve_decode`` admission)."""
        return sum(max(0, s.reserved - s.n_blocks)
                   for s in self.slots if not s.free)

    # -- device-resident control state ------------------------------------

    def _mark(self, *names: str) -> None:
        self._dirty.update(names)

    def _device_state(self):
        """Return (tables, lengths, cur_tokens, active) as device arrays,
        re-uploading only the ones a host mutation dirtied. The np.array
        snapshots matter: the host arrays are mutated in place while
        earlier dispatches may still be in flight, and the CPU backend
        zero-copy-aliases numpy buffers."""
        if "tables" in self._dirty:
            self._dev["tables"] = jnp.asarray(np.array(self.tables))
        if "lengths" in self._dirty:
            self._dev["lengths"] = jnp.asarray(np.array(self.lengths))
        if "cur" in self._dirty:
            self._dev["cur"] = jnp.asarray(np.array(self.cur_tokens))
        if "active" in self._dirty:
            self._dev["active"] = jnp.asarray(np.array(self.active))
        self._dirty.clear()
        return (self._dev["tables"], self._dev["lengths"],
                self._dev["cur"], self._dev["active"])

    def _release_slot(self, sid: int) -> None:
        """Deref every allocated block and zero the table row."""
        s = self.slots[sid]
        for j in range(s.n_blocks):
            self.alloc.deref(int(self.tables[sid, j]))
        self.tables[sid, :] = 0
        s.n_blocks = 0
        s.reserved = 0
        s.free, s.rid, s.completion, s.prompt = True, -1, None, None
        s.prefilling = False
        self.active[sid] = False
        self.cur_tokens[sid] = self.pad_id
        self.lengths[sid] = 0
        # device rows of a dead slot are stale-but-masked; only `active`
        # gates emissions and writes, so it alone must resync
        self._mark("active")

    # -- admission ---------------------------------------------------------

    def _admit(self, sid: int, req: Request) -> bool:
        """Map radix-matched prefix blocks into the slot's table and start
        chunked prefill after them. False (leave queued) when the pool
        cannot cover the request right now — the admission watermark.

        Default (``reserve_decode=True``): admission reserves the FULL
        window, ``ceil((P + max_new)/block_size)`` blocks minus radix
        hits, against free + evictable blocks net of every live slot's
        outstanding reservation. Block granularity plus sharing still
        admits far more concurrency than the slot pool's flat ``max_len``
        reserve on mixed-length traffic, but no admitted request can be
        starved — preemption becomes a backstop, not the steady state.
        Admitting optimistically (``reserve_decode=False``) thrashes when
        the offered windows exceed the pool: slots preempt each other
        mid-decode and burn the savings re-prefilling continuations."""
        prompt = np.asarray(req.tokens, np.int32)
        P = len(prompt)
        s = self.slots[sid]
        now = time.monotonic()

        matched: List[int] = []
        if self.radix is not None:
            # cap one token short of the prompt: at least one suffix token
            # must run through append to produce the first-token logits
            cap = ((P - 1) // self.block_size)
            matched = self.radix.match(prompt)[:cap]
        reserved = -(-(P + req.max_new_tokens) // self.block_size)
        if self.reserve_decode:
            need = reserved - len(matched)
            avail = self._available_blocks(exclude=set(matched)) \
                - self._outstanding_reserved()
        else:
            need = -(-(P + 1) // self.block_size) - len(matched)
            avail = self._available_blocks(exclude=set(matched))
        if need > avail:
            self.stats["admission_blocked"] += 1
            return False
        if matched:
            for p in matched:
                self.alloc.ref(int(p))
            self.stats["radix_hit_blocks"] += len(matched)
        s.reserved = reserved if self.reserve_decode else 0
        self.tables[sid, :len(matched)] = matched
        s.n_blocks = len(matched)
        s.pos = len(matched) * self.block_size
        self.lengths[sid] = s.pos
        self._mark("tables", "lengths")

        s.rid, s.free, s.prefilling = req.rid, False, True
        s.prompt = prompt
        s.remaining = req.max_new_tokens
        s.eos_id = req.eos_id
        s.t_admit = now
        s.admit_idx = self.stats["admitted"]
        if req.cont is not None:
            s.completion = req.cont       # preempted request resuming
            s.emitted_in_prompt = len(req.cont.tokens)
        else:
            s.emitted_in_prompt = 0
            s.completion = Completion(
                rid=req.rid, prompt_len=P, tokens=[],
                t_submit=self._submit_t.pop(req.rid, now), t_admit=now)
        self.stats["admitted"] += 1
        return True

    # -- chunked prefill ---------------------------------------------------

    def _collect_prefill(self):
        """Gather one fixed-width chunk of work for EVERY prefilling slot
        (allocating the blocks the chunks land in) for a single batched
        dispatch. Chunking (rather than one-shot prefill) keeps long
        prompts from stalling the in-flight decode batch; the batching
        keeps prefill from serializing across slots. A slot whose chunk
        cannot get its blocks runs short (as far as its allocated blocks
        reach) or stalls this step entirely (``chunk_len == 0`` — decode
        retirements free blocks; preemption only triggers from the decode
        side, where lack of a block blocks EVERY step)."""
        C = self.prefill_chunk
        chunk = np.full((self.num_slots, C), self.pad_id, np.int32)
        ns = np.zeros((self.num_slots,), np.int32)
        for sid, s in enumerate(self.slots):
            if s.free or not s.prefilling:
                continue
            n = min(C, len(s.prompt) - s.pos)
            need = -(-(s.pos + n) // self.block_size)
            while s.n_blocks < need:
                p = self._alloc_block()
                if p is None:
                    break
                self.tables[sid, s.n_blocks] = p
                s.n_blocks += 1
                self._mark("tables")
            # pool dry mid-alloc: run as far as allocated blocks reach
            n = min(n, s.n_blocks * self.block_size - s.pos)
            if n <= 0:
                self.stats["prefill_stalls"] += 1
                continue
            chunk[sid, :n] = s.prompt[s.pos:s.pos + n]
            ns[sid] = n
        return chunk, ns

    def _apply_prefill(self, psids, ns_g, atoks) -> None:
        """Advance slot cursors past the chunks just processed (``psids``
        names the compacted dispatch rows); slots whose prompt completed
        take their in-program-sampled first token (``atoks`` stays on
        device unless somebody finished)."""
        toks_host = None
        for r, sid in enumerate(psids):
            s = self.slots[sid]
            if s.free or not s.prefilling:
                continue        # preempted between collect and apply
            s.pos += int(ns_g[r])
            self.lengths[sid] = s.pos
            self.stats["prefill_chunks"] += 1
            if s.pos == len(s.prompt):
                if toks_host is None:
                    toks_host = np.asarray(atoks)
                self._finish_prefill(sid, int(toks_host[r]))

    def _finish_prefill(self, sid: int, tok: int) -> None:
        """Record the first token (sampled inside the append program),
        publish the prompt's full blocks to the radix cache, and either
        retire (eos / single-token budget) or activate the slot for
        batched decode."""
        s = self.slots[sid]
        P = len(s.prompt)
        now = time.monotonic()
        comp = s.completion
        if not comp.tokens:
            comp.t_first = now
        comp.tokens.append(tok)
        s.prefilling = False
        s.remaining -= 1

        if self.radix is not None:
            nfull = P // self.block_size
            if nfull:
                adopted = self.radix.insert(
                    s.prompt[:nfull * self.block_size],
                    [int(b) for b in self.tables[sid, :nfull]])
                for p in adopted:
                    self.alloc.ref(p)

        if s.remaining <= 0 or (s.eos_id is not None and tok == s.eos_id):
            comp.t_finish = time.monotonic()
            self.completed.append(comp)
            self.stats["retired"] += 1
            self._release_slot(sid)
        else:
            self.cur_tokens[sid] = tok
            self.active[sid] = True
            self._mark("cur", "active")

    # -- decode ------------------------------------------------------------

    def _ensure_decode_blocks(self) -> None:
        """Every active slot needs the block holding position ``lengths``
        allocated before the step writes there. When the pool is dry even
        after radix eviction, PREEMPT the youngest other running slot —
        its blocks free up and its request requeues as a continuation."""
        for sid in np.nonzero(self.active)[0]:
            if not self.active[sid]:
                continue            # preempted by an earlier slot's alloc
            s = self.slots[sid]
            bidx = int(self.lengths[sid]) // self.block_size
            while bidx >= s.n_blocks:
                p = self._alloc_block()
                if p is None:
                    victims = [
                        i for i, v in enumerate(self.slots)
                        if not v.free and i != sid]
                    if not victims:
                        raise RuntimeError(
                            "block pool exhausted by a single request — "
                            "submit() should have rejected it")
                    self._preempt(max(
                        victims, key=lambda i: self.slots[i].t_admit))
                    continue
                self.tables[sid, s.n_blocks] = p
                s.n_blocks += 1
                self._mark("tables")

    def _preempt(self, vid: int) -> None:
        """Evict a running slot: free its blocks and requeue the request
        as a continuation (original prompt + emitted tokens). Greedy
        decode replays the prefix bit-identically, so the resumed stream
        continues exactly where it stopped."""
        v = self.slots[vid]
        comp = v.completion
        fresh = comp.tokens[v.emitted_in_prompt:]   # not yet in the prompt
        prompt = np.concatenate([
            v.prompt, np.asarray(fresh, np.int32)]) if fresh else v.prompt
        req = Request(rid=v.rid, tokens=prompt,
                      max_new_tokens=max(v.remaining, 1),
                      eos_id=v.eos_id, cont=comp)
        self._release_slot(vid)
        self.pending.appendleft(req)
        self.stats["preemptions"] += 1

    # -- the serving loop --------------------------------------------------

    def step(self) -> bool:
        """Admit while blocks allow, then run ONE dispatch covering this
        step's decode and/or prefill chunk (the fused program when both
        phases have work — per-step dispatch overhead dominates
        small-batch serving). Returns False when idle."""
        free = [i for i, s in enumerate(self.slots) if s.free]
        while free and self.pending:
            if not self._admit(free[0], self.pending[0]):
                break
            self.pending.popleft()
            free.pop(0)
        self.stats["max_concurrent"] = max(
            self.stats["max_concurrent"],
            sum(1 for s in self.slots if not s.free))

        # decode's write blocks first (running slots must never stall),
        # then prefill chunks take what's left of the pool
        if self.active.any():
            self._ensure_decode_blocks()
        chunk, ns = self._collect_prefill()
        act = self.active.copy()
        psids = np.nonzero(ns)[0]
        any_p, any_d = len(psids) > 0, bool(act.any())
        if not (any_p or any_d):
            return bool(self.pending) or \
                any(not s.free for s in self.slots)

        tables, lengths, cur, act_dev = self._device_state()
        atoks = dtoks = None
        if any_p:
            # compact the prefilling rows, padded to a power-of-two
            # bucket so jit compiles once per bucket, not per count;
            # pad rows (slot 0, chunk_len 0) are masked + scratch-bound
            g = 1 << (len(psids) - 1).bit_length()
            psids_g = np.zeros((g,), np.int32)
            psids_g[:len(psids)] = psids
            chunk_g = np.full((g, chunk.shape[1]), self.pad_id, np.int32)
            chunk_g[:len(psids)] = chunk[psids]
            ns_g = np.zeros((g,), np.int32)
            ns_g[:len(psids)] = ns[psids]
            admit_g = np.zeros((g,), np.int32)
            admit_g[:len(psids)] = [self.slots[i].admit_idx
                                    for i in psids]
            psids_dev = jnp.asarray(psids_g)
            chunk_dev, ns_dev = jnp.asarray(chunk_g), jnp.asarray(ns_g)
            admit_idx = jnp.asarray(admit_g)
        if any_p and any_d:
            dkey = jax.random.fold_in(self._key, self.t)
            dtoks, atoks, new_len, phys, off, kvs = self._fused(
                self.params, self.caches, tables, lengths, cur, act_dev,
                psids_dev, chunk_dev, ns_dev, admit_idx, self._key, dkey)
        elif any_p:
            atoks, new_len, phys, off, kvs = self._append(
                self.params, self.caches, tables, lengths,
                psids_dev, chunk_dev, ns_dev, admit_idx, self._key)
        else:
            dkey = jax.random.fold_in(self._key, self.t)
            dtoks, new_len, phys, off, kvs = self._step(
                self.params, self.caches, tables, lengths, cur, act_dev,
                dkey)
        self.caches = self._write(self.caches, phys, off, kvs)
        # the programs advance lengths/cur_tokens exactly as the host
        # bookkeeping below does — keep their outputs as the mirrors
        self._dev["lengths"] = new_len
        if dtoks is not None:
            self._dev["cur"] = dtoks

        if any_p:
            self._apply_prefill(psids_g[:len(psids)], ns_g, atoks)
        if any_d:
            self.t += 1
            self.stats["decode_steps"] += 1
            self.lengths[act] += 1
            toks = np.asarray(dtoks)
            for sid in np.nonzero(act)[0]:
                s = self.slots[sid]
                tok = int(toks[sid])
                s.completion.tokens.append(tok)
                s.remaining -= 1
                if s.remaining <= 0 or \
                        (s.eos_id is not None and tok == s.eos_id):
                    s.completion.t_finish = time.monotonic()
                    self.completed.append(s.completion)
                    self.stats["retired"] += 1
                    self._release_slot(sid)
                else:
                    self.cur_tokens[sid] = tok
        return True

    # -- introspection -----------------------------------------------------

    def pool_bytes(self) -> int:
        """Device bytes held by the block pool (capacity accounting)."""
        return sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree_util.tree_leaves(self.caches))
