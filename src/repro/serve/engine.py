"""Serving runtime: batched prefill + single-token decode over the generic
segment contract, with stacked per-layer caches.

Quantized (INT8 QTensor) parameters are consumed **directly**: the model
layers route every QTensor matmul through the ``quantized_dense`` kernels
(`repro.kernels.ops`), so prefill and decode stream weights at 1 byte/elem
with zero per-token dequantization — the old per-step
``tree_dequantize`` of the whole stacked layer pytree inside the decode
scan body is gone.

``DecodeState`` is a pure pytree → the decode step jits/pjits cleanly; cache
sharding (see ``repro.serve.shard``) puts the KV time axis on the model mesh
axis for long contexts (context-parallel decode) and batch on data.

Per-row ``lengths`` drive every positional effect (RoPE, cache write slot,
attention mask), so one compiled decode step serves heterogeneous in-flight
sequences — the substrate for both the lockstep ``generate`` host loop and
the continuous-batching scheduler (``repro.serve.scheduler``, see
``docs/serving.md``).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.base import ModelBundle


class DecodeState(NamedTuple):
    caches: Dict[str, Any]          # {seg_key: stacked per-layer caches}
    lengths: jax.Array              # (B,) valid positions
    extras: Dict[str, Any]          # persistent carry entries (e.g. memory)


def prompt_lengths(tokens, pad_id: Optional[int]) -> jax.Array:
    """Per-row valid prompt length of a right-padded (B, S) token batch:
    S minus the trailing run of ``pad_id`` (pad ids *inside* the prompt are
    treated as content)."""
    B, S = tokens.shape
    if pad_id is None:
        return jnp.full((B,), S, jnp.int32)
    trailing = jnp.cumprod(
        (tokens[:, ::-1] == pad_id).astype(jnp.int32), axis=1).sum(axis=1)
    return (S - trailing).astype(jnp.int32)


def check_prompt_lengths(batch, pad_id: Optional[int]) -> None:
    """Host-side guard for the eager entry points: raise on any row with
    zero valid tokens (explicit ``lengths`` or trailing-pad detection).
    Inside jit the prefill gather only *clamps* — this is where empty
    rows fail loudly instead."""
    import numpy as np
    if "lengths" in batch:
        lens = np.asarray(batch["lengths"])
    else:
        lens = np.asarray(prompt_lengths(batch["tokens"], pad_id))
    if (lens <= 0).any():
        bad = np.nonzero(lens <= 0)[0].tolist()
        raise ValueError(
            f"empty prompt row(s) {bad}: every row needs >= 1 valid "
            "token (an all-pad row would decode from garbage logits)")


def matmul_shape_grid(bundle: ModelBundle, batch: int, prompt_len: int,
                      *, decode: bool = False):
    """The (M, K, N) problems the ``quantized_dense`` path hits during a
    prefill (or one decode step, ``decode=True``) of this bundle — the
    shape source for ``benchmarks/autotune_blocks.py``.

    M is the flattened token count the wrapper sees; K/N come from the
    config's projection shapes (attention in/out, FFN up/down, LM head).
    Exotic families contribute extra matmuls, but these dominant shapes
    are what the block tuner needs to cover the zoo's serving traffic.
    """
    cfg = bundle.cfg
    M = batch * (1 if decode else prompt_len)
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    hd = cfg.resolved_head_dim
    q_out = cfg.num_heads * hd
    kv_out = cfg.num_kv_heads * hd
    shapes = {
        (M, d, q_out + 2 * kv_out),   # attention in-projection
        (M, q_out, d),                # attention out-projection
        (M, d, ff),                   # FFN up/gate
        (M, ff, d),                   # FFN down
        (M, d, v),                    # LM head
    }
    return sorted(shapes)


def build_prefill(bundle: ModelBundle, max_len: int,
                  pad_id: Optional[int] = None):
    """Returns prefill(params, batch) -> (last_logits, DecodeState).

    Ragged (right-padded) prompts: per-row valid lengths come from
    ``batch["lengths"]`` when present, else from the trailing-``pad_id``
    run (``pad_id=None`` ⇒ every row is full). The returned logits are
    taken at each row's LAST VALID position, and ``DecodeState.lengths``
    records the per-row length — so the first decode step writes its KV at
    the right cache slot and RoPE continues from the true position. Padded
    positions never influence valid ones under causal attention (they sit
    strictly to the right), and the garbage K/V they leave in the cache
    beyond ``lengths`` is masked out by decode (``pos < length``) until
    overwritten. Bundles that can't guarantee this row independence
    (recurrent families fold every position into their state; MoE routing
    couples rows through capacity-limited expert buffers) declare
    ``ragged_prefill_ok=False`` and reject ``pad_id`` here — send them
    unpadded prompts (full-length ``batch["lengths"]`` stays legal).
    """
    if pad_id is not None and not bundle.ragged_prefill_ok:
        raise ValueError(
            f"{bundle.cfg.name}: ragged (right-padded) prefill is not "
            "exact for this architecture (ragged_prefill_ok=False) — "
            "prefill unpadded prompts instead of passing pad_id")

    def prefill(params, batch):
        carry, ctx = bundle.embed(params, batch)
        ctx = {**ctx, "max_len": max_len}
        caches: Dict[str, Any] = {}
        for i, seg in enumerate(bundle.segments):
            key = bundle.seg_key(i)
            if seg.pre is not None:
                carry = seg.pre(params, carry, ctx)
            if seg.prefill is None:
                def body(c, lp, _seg=seg):
                    return _seg.apply(lp, c, ctx), None
                from repro.models.base import scan_layers
                carry, _ = scan_layers(body, carry, params[key])
            else:
                def body(c, lp, _seg=seg):
                    return _seg.prefill(lp, c, ctx)
                from repro.models.base import scan_layers
                carry, cache = scan_layers(body, carry, params[key])
                caches[key] = cache
        prompt_len = batch["tokens"].shape[1]
        if "lengths" in batch:
            lengths = batch["lengths"].astype(jnp.int32)
        else:
            lengths = prompt_lengths(batch["tokens"], pad_id)
        # head logits at each row's last valid position (h may carry a
        # non-token prefix, e.g. VLM patch embeddings → offset). An
        # all-pad row would make ``lengths - 1`` negative and
        # take_along_axis silently wrap to the LAST position (garbage
        # logits, decode writing KV at a wrapped slot) — clamp the gather
        # in-graph; the host-side entry points (``generate``,
        # ``Scheduler.submit``) reject empty rows loudly before tracing.
        idx_lengths = jnp.maximum(lengths, 1)
        h = carry["h"]
        offset = h.shape[1] - prompt_len
        idx = (idx_lengths - 1 + offset)[:, None, None]
        h_last = jnp.take_along_axis(h, jnp.broadcast_to(
            idx, (h.shape[0], 1, h.shape[2])), axis=1)
        logits = bundle.head_logits(params, {**carry, "h": h_last})
        extras = {k: carry[k] for k in bundle.decode_extras}
        return logits, DecodeState(caches, lengths, extras)

    return prefill


def append_ok(bundle: ModelBundle) -> bool:
    """True ⇔ this bundle supports chunk-append prefill — the substrate of
    the paged serving runtime (``repro.serve.paged``). Requirements: every
    segment offers ``SegmentDef.append`` (row-independent causal attention
    with per-position cache writes), ragged prompts are exact
    (``ragged_prefill_ok`` — chunk tails are right-padded inside a chunk),
    and there is no per-request ``decode_extras`` state."""
    return (bundle.ragged_prefill_ok and not bundle.decode_extras
            and all(seg.append is not None for seg in bundle.segments))


def build_append(bundle: ModelBundle, max_len: int, capture=None):
    """Returns append(params, state, tokens (B,C), chunk_len (B,)) ->
    (last_logits, new_state) — chunk-continuation prefill.

    ``capture``: optional ``(new_cache_slice, ctx) -> pytree`` hook applied
    to each layer's updated cache INSIDE the layer scan; the returned
    state then carries the captured pytrees instead of full caches. The
    paged runtime uses this to extract just the chunk's freshly written
    K/V (a per-position gather the one-hot cache update fuses into) so
    the full updated views are never materialized.

    ``state`` already holds the first ``state.lengths`` positions of each
    row's prompt; ``tokens`` carries the next chunk (right-padded to C,
    per-row valid count ``chunk_len``). Valid tokens write their K/V at
    absolute positions ``lengths + i`` (padded tail positions write
    nothing), queries attend the whole cache under the absolute causal
    mask, and the returned logits sit at each row's LAST VALID chunk
    position. Running a prompt through ``append`` chunk-by-chunk (any
    chunking, including one chunk of the full prompt) is bit-identical to
    :func:`build_prefill` — the invariant the paged serving runtime and
    its prefix cache rest on (``tests/test_paged.py``).

    Only :func:`append_ok` bundles qualify; like ragged prefill this
    leans on row/positional independence, which recurrent families,
    capacity-routed MoE, and MLA's absorbed decode cannot offer.
    """
    if not append_ok(bundle):
        raise ValueError(
            f"{bundle.cfg.name}: chunk-append prefill requires "
            "row-independent attention segments (SegmentDef.append) and "
            "ragged_prefill_ok — this bundle must use one-shot prefill")

    def append(params, state: DecodeState, tokens, chunk_len):
        B, C = tokens.shape
        if C == 1:
            # XLA lowers M=1 matmuls through a different (gemv-style)
            # contraction than M>=2, breaking bit-identity with one-shot
            # prefill by ~1 ulp — pad width-1 chunks to width 2; the pad
            # position is masked so it writes nothing and costs nothing.
            tokens = jnp.pad(tokens, ((0, 0), (0, 1)))
            C = 2
        carry, _ = bundle.embed(params, {"tokens": tokens})
        base = state.lengths.astype(jnp.int32)
        chunk_len = chunk_len.astype(jnp.int32)
        positions = base[:, None] + jnp.arange(C, dtype=jnp.int32)[None]
        mask = jnp.arange(C, dtype=jnp.int32)[None] < chunk_len[:, None]
        ctx = {"length": base, "positions": positions, "chunk_mask": mask,
               "max_len": max_len}
        new_caches: Dict[str, Any] = {}
        for i, seg in enumerate(bundle.segments):
            key = bundle.seg_key(i)
            def body(c, xs, _seg=seg):
                lp, cache = xs
                new_c, new_cache = _seg.append(lp, c, cache, ctx)
                if capture is not None:
                    new_cache = capture(new_cache, ctx)
                return new_c, new_cache
            from repro.models.base import scan_layers
            carry, new_cache = scan_layers(
                body, carry, (params[key], state.caches[key]))
            new_caches[key] = new_cache
        # head logits at each row's last valid chunk position (clamped —
        # callers never send chunk_len 0, see check_prompt_lengths)
        h = carry["h"]
        idx = (jnp.maximum(chunk_len, 1) - 1)[:, None, None]
        h_last = jnp.take_along_axis(h, jnp.broadcast_to(
            idx, (h.shape[0], 1, h.shape[2])), axis=1)
        logits = bundle.head_logits(params, {**carry, "h": h_last})
        return logits, DecodeState(new_caches, base + chunk_len,
                                   state.extras)

    return append


def build_decode(bundle: ModelBundle, capture=None):
    """Returns decode(params, state, tokens (B,1)) -> (logits, new_state).

    ``capture``: optional ``(new_cache_slice, ctx) -> pytree`` hook, as in
    :func:`build_append` — the returned state's caches are then the
    captured pytrees (e.g. just this step's K/V), not full caches."""
    def decode(params, state: DecodeState, tokens):
        if bundle.embed_decode is not None:
            carry, ctx = bundle.embed_decode(params, tokens, state.extras)
        else:
            carry, ctx = bundle.embed(params, {"tokens": tokens})
            carry = {**carry, **state.extras}
        ctx = {**ctx, "length": state.lengths}
        new_caches: Dict[str, Any] = {}
        for i, seg in enumerate(bundle.segments):
            key = bundle.seg_key(i)
            if seg.decode is None or key not in state.caches:
                continue
            def body(c, xs, _seg=seg):
                lp, cache = xs
                new_c, new_cache = _seg.decode(lp, c, cache, ctx)
                if capture is not None:
                    new_cache = capture(new_cache, ctx)
                return new_c, new_cache
            from repro.models.base import scan_layers
            carry, new_cache = scan_layers(
                body, carry, (params[key], state.caches[key]))
            new_caches[key] = new_cache
        logits = bundle.head_logits(params, carry)
        return logits, DecodeState(new_caches, state.lengths + 1,
                                   state.extras)

    return decode


def sample(logits, key, temperature: float = 0.0):
    """Greedy (T=0) or temperature sampling on (B, 1, V) logits."""
    lf = logits[:, -1, :].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(lf, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, lf / temperature, axis=-1) \
        .astype(jnp.int32)


def generate(bundle: ModelBundle, params, batch, *, steps: int,
             max_len: int, temperature: float = 0.0, key=None,
             eos_id: Optional[int] = None, pad_id: Optional[int] = None):
    """Prefill + `steps` greedy/temperature decode steps (host loop).

    ``eos_id``: rows that emit it are RETIRED — they stop sampling (all
    later emissions are ``pad_id``, default 0) and their cache length
    freezes, so ``state.lengths`` reports prompt + true generated length.
    The lockstep batch still runs every row to ``steps`` (static shapes);
    continuous batching (``repro.serve.scheduler``) reclaims those slots
    instead.

    Ragged prompts: pass per-row ``batch["lengths"]`` (or ``pad_id`` for
    trailing-pad detection) — see :func:`build_prefill`. Rows with zero
    valid tokens are rejected here (loudly) rather than producing the
    silently-wrapped logits an in-graph gather would.
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    check_prompt_lengths(batch, pad_id)
    prefill = jax.jit(build_prefill(bundle, max_len, pad_id=pad_id))
    decode = jax.jit(build_decode(bundle))
    logits, state = prefill(params, batch)
    pad = 0 if pad_id is None else pad_id
    toks = []
    tok = sample(logits, key, temperature)
    done = jnp.zeros(tok.shape, bool)
    for s in range(steps):
        toks.append(tok)
        prev_lengths = state.lengths
        logits, state = decode(params, state, tok[:, None])
        key = jax.random.fold_in(key, s)
        next_tok = sample(logits, key, temperature)
        if eos_id is not None:
            done = done | (tok == eos_id)
            next_tok = jnp.where(done, pad, next_tok)
            state = state._replace(
                lengths=jnp.where(done, prev_lengths, state.lengths))
        tok = next_tok
    toks.append(tok)
    return jnp.stack(toks, axis=1), state   # (B, steps+1)


# ---------------------------------------------------------------------------
# Abstract decode-state (for the dry-run: no allocation)
# ---------------------------------------------------------------------------

def abstract_decode_state(bundle: ModelBundle, batch: int, max_len: int,
                          dtype=jnp.bfloat16) -> DecodeState:
    caches = {}
    for i, seg in enumerate(bundle.segments):
        if seg.cache_spec is None or seg.decode is None:
            continue
        per_layer = seg.cache_spec(batch, max_len, dtype)
        caches[bundle.seg_key(i)] = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((seg.n_layers,) + s.shape,
                                           s.dtype), per_layer)
    extras = {}
    if "memory" in bundle.decode_extras:
        # encoder memory length: seq // DEC_RATIO convention (see encdec)
        from repro.models.encdec import DEC_RATIO
        extras["memory"] = jax.ShapeDtypeStruct(
            (batch, max(max_len // DEC_RATIO, 16), bundle.cfg.d_model),
            dtype)
    return DecodeState(
        caches=caches,
        lengths=jax.ShapeDtypeStruct((batch,), jnp.int32),
        extras=extras,
    )
