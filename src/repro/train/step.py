"""The pjit-able training step: fused projected backward + Q-GaLore update.

INT8 (QTensor) weights are the native compute format throughout: the
forward/backward consume them through the ``quantized_dense`` custom-VJP op
(`repro.kernels.ops`), so the training step never materializes a
full-precision weight — the per-layer dL/dW appears transiently, is
projected low-rank inside the backward scan, and the fused Q-GaLore update
kernel writes the new INT8 codes without leaving VMEM.

Two compiled variants per run:
  * ``refresh=False`` — steady state: grads for GaLore leaves are emitted
    low-rank straight out of the backward scan (never materializing the
    full-rank gradient), then the 8-bit Adam / SR weight update applies.
  * ``refresh=True``  — subspace-refresh steps: full-rank grads are
    materialized for GaLore leaves so the masked per-layer SVD can run
    in-graph (lax.cond inside a layer scan, §3.2).

Gradient accumulation scans over microbatches; with the fused path the
accumulated payload is the LOW-RANK gradient, which is also what crosses the
data-parallel axis — the paper-beyond gradient-compression effect.

The optimizer half of the step (``qgalore.apply_updates``) batches
same-shaped leaves through one scanned program and runs eligible leaves
through the fused update kernel (Adam + INT4 back-projection + SR requant
in one pass); the kernel backend is chosen per platform by
``repro.kernels.dispatch`` (pallas-tpu on TPU, pure-XLA ref elsewhere,
``REPRO_KERNEL_BACKEND`` to override).
"""
from __future__ import annotations

import functools
import logging
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.config import QGaLoreConfig, TrainConfig
from repro.core import qgalore, quant, transform
from repro.core.qgalore import QGaLoreState
from repro.core.rules import as_rules
from repro.models.base import ModelBundle
from repro.train import stack


class TrainState(NamedTuple):
    params: Any
    opt: QGaLoreState


def prepare_params(params, qcfg, param_dtype=jnp.bfloat16):
    """Quantize eligible weights to INT8 (Q-GaLore) or cast to the param
    dtype (baselines). Norm scales / small vectors stay float32.

    ``qcfg`` may be a ``QGaLoreConfig`` or a ``ParamRules``: each leaf's
    ``weight_bits`` comes from its resolved param group, so a rule-set can
    keep an INT8 frozen base under fp trainable groups (or vice versa)."""
    rules = as_rules(qcfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        eff = rules.config_for(jax.tree_util.keystr(path))
        if eff.weight_bits == 8:
            if leaf.ndim >= 2 and leaf.shape[-1] >= 32:
                out.append(quant.quantize_blockwise(
                    leaf, bits=8, block=eff.quant_block, symmetric=True))
            else:
                out.append(leaf)
        elif leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating):
            out.append(leaf.astype(param_dtype))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)


def init_state(bundle: ModelBundle, qcfg, key,
               param_dtype=jnp.bfloat16, specs=None) -> TrainState:
    params = prepare_params(bundle.init_params(key), qcfg, param_dtype)
    opt = qgalore.init(params, qcfg, jax.random.fold_in(key, 1),
                       specs=specs)
    return TrainState(params, opt)


def abstract_state(bundle: ModelBundle, qcfg,
                   param_dtype=jnp.bfloat16, specs=None) -> TrainState:
    """eval_shape'd TrainState (no allocation) — for sharding and dry-run.
    ``specs`` carries runtime rank overrides (dynamic rank adaptation), so
    the abstract low-rank state matches a shrunk checkpoint."""
    return jax.eval_shape(
        lambda k: init_state(bundle, qcfg, k, param_dtype, specs),
        jax.random.PRNGKey(0))


def _specs_for(bundle, qcfg, param_dtype):
    params_abs = abstract_state(bundle, qcfg, param_dtype).params
    return qgalore.leaf_specs(params_abs, qcfg)


def _microbatches(batch, accum: int):
    def split(x):
        b = x.shape[0]
        return x.reshape(accum, b // accum, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def build_train_step(bundle: ModelBundle, qcfg,
                     tcfg: TrainConfig, *, impl: str = "fused",
                     accum: int = 1, param_dtype=jnp.bfloat16,
                     mesh=None, dp_compress: bool = False,
                     moe_ep_axis=None, state_shardings=None,
                     zero2_dims=None, specs=None):
    """Returns ``step(state, batch, lr, rng, refresh_masks) -> (state,
    metrics)`` with ``refresh`` a static flag baked per variant via
    functools.partial before jit.

    ``qcfg`` may be a plain ``QGaLoreConfig`` or a ``ParamRules`` rule-set
    (``repro.core.rules``): per-leaf recipes resolve through the param
    groups, frozen-group leaves are excluded from the grad-norm clip and
    pass through the optimizer untouched. The optimizer half of the step
    is the canonical transform chain
    (``repro.core.transform.qgalore_transform`` — project → quantized_adam
    → backproject → sr_requant), whose fused/batched executor is
    ``qgalore.apply_updates``.

    ``state_shardings``: the TrainState sharding pytree (mesh runs) —
    forwarded to the optimizer so the batched-leaf scan operands carry
    explicit layouts (quiets GSPMD's involuntary-rematerialization
    warnings under ZeRO sharding).

    ``zero2_dims``: {leaf index: scatter dim} from
    ``sharding.zero2_scatter_dims`` — steady-state low-rank gradients for
    these leaves are reduce-scattered over the DP axes along the SAME dim
    their ZeRO moment shard uses (each rank receives only its owned slice
    of the reduced gradient: (D-1)/D of the pmean's bytes and no
    replicated low-rank grads), instead of the replicated ``pmean``.

    ``dp_compress`` (beyond-paper): run the gradient phase under a
    partial-manual ``shard_map`` over the data(+pod) axes — the backward scan
    projects each layer's cotangent to rank r *before* any cross-replica
    communication, and ONE explicit ``pmean`` at the end reduces the
    LOW-RANK payload (≈ min(m,n)/r smaller, once per step instead of once
    per microbatch). The model axis stays auto (GSPMD). GSPMD alone places
    the DP all-reduce at the full-rank dW einsum — this is the fix.

    Refresh steps in this mode run the DISTRIBUTED subspace refresh
    (``qcfg.dist_refresh``): for each stacked GaLore leaf whose layer dim
    divides the DP world size, the full-rank gradient is reduce-scattered
    over the layer-stack dim (each device receives the *reduced* gradient
    for only its owned layers — half the wire bytes of an all-reduce and no
    full-rank replica anywhere), the owning shard runs the mask-gated SVD
    for its layers, projects its slice low-rank with the new P, and
    all-gathers only the small results (low-rank grads + INT4 P + sims).
    ``apply_updates`` then sees those leaves as already-refreshed steady
    leaves. RNG folding uses global unit indices, so the distributed refresh
    draws the same randoms as the replicated one. Leaves that don't divide
    (or expert-parallel leaves) fall back to the replicated in-optimizer
    refresh. Note the gradient-clip norm at such refresh steps is computed
    on the LOW-RANK payload for distributed leaves (exactly as every
    steady-state compressed step already does), so plain-mode and
    dist-refresh trajectories agree only to clip-scale tolerance.
    """
    rules = as_rules(qcfg)
    base = rules.base
    if specs is None:
        specs = _specs_for(bundle, rules, param_dtype)
        if mesh is not None:
            # shard-dim-aware contract: direct callers get the same
            # (shard_dim, tp) annotations the trainer derives, so the
            # batching signatures never mix differently-TP-sharded leaves
            from repro.distributed import sharding as _sh
            specs = _sh.annotate_tp(specs, mesh)
    tx = transform.qgalore_transform(rules, specs=specs)
    any_galore = any(s.galore for s in specs)
    seg_keys = {bundle.seg_key(i) for i in range(len(bundle.segments))}
    zero2_dims = dict(zero2_dims or {})

    from repro.kernels import dispatch as kdispatch
    from repro.models import layers as _layers
    logging.getLogger(__name__).info(
        "train step: kernel backend=%s quantized_dense=%s (backend=%s) "
        "fused_update=%s batch_leaves=%s groups=%s",
        kdispatch.default_backend("fused_qgalore_update"),
        _layers.QUANTIZED_DENSE,
        kdispatch.default_backend("quantized_dense"),
        base.fused_update, base.batch_leaves,
        sorted({s.group for s in specs}))

    def grad_phase(params, proj_trees, batch):
        """(loss, metrics, grads) on the (possibly shard-local) batch."""
        def one_micro(mb):
            if impl == "fused":
                return stack.fused_value_and_grad(bundle, params, mb,
                                                  proj_trees)
            return stack.simple_value_and_grad(bundle, params, mb)

        if accum > 1:
            micro = _microbatches(batch, accum)

            def body(acc, mb):
                (loss, metrics), g = one_micro(mb)
                acc_g, acc_loss = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), acc_g, g)
                return (acc_g, acc_loss + loss), metrics

            zero_g = jax.eval_shape(lambda b: one_micro(b)[1],
                                    jax.tree_util.tree_map(
                                        lambda x: x[0], micro))
            zero_g = jax.tree_util.tree_map(
                lambda s: jnp.zeros(s.shape, jnp.float32), zero_g)
            from repro.models.base import scan_layers
            (g_sum, loss_sum), metrics = scan_layers(
                body, (zero_g, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree_util.tree_map(lambda g: g / accum, g_sum)
            loss = loss_sum / accum
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        else:
            (loss, metrics), grads = one_micro(batch)
        return loss, metrics, grads

    dp_axes: tuple = ()
    dp_size = 1
    refresh_axes: tuple = ()
    refresh_world = 1
    if dp_compress and mesh is not None:
        from jax.sharding import PartitionSpec as P
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp_size = int(np.prod([mesh.shape[a] for a in dp_axes])) \
            if dp_axes else 1
        # 2-D (data x model) mesh: the distributed refresh scatters the
        # layer stack over the COMBINED front — D*t ranks each own
        # L/(D*t) layers, so per-device refresh memory shrinks by the
        # model degree too and the per-layer SVD stays the bit-exact
        # replicated computation (no Gram/eigh numerics drift).
        refresh_axes, refresh_world = dp_axes, dp_size
        if dp_axes and "model" in mesh.axis_names \
                and int(mesh.shape["model"]) > 1:
            refresh_axes = dp_axes + ("model",)
            refresh_world = dp_size * int(mesh.shape["model"])

    # BF16 grad reduction (paper §3.1 keeps gradients BF16) halves the
    # residual full-rank payloads on the wire. It is OFF by default because
    # XLA:CPU cannot lower a bf16 psum inside a shard_map body — compilation
    # crashes with "Invalid binary instruction opcode copy"
    # (hlo_instruction.cc): the CPU emitter is missing the bf16<->f32
    # convert-around-reduce pattern the TPU backend inserts. The workaround
    # is simply to reduce in f32 on CPU (this flag) — numerics are a
    # superset of the bf16 reduction, so CI exercises the same code path at
    # higher precision. Set REPRO_BF16_REDUCE=1 on TPU backends, where the
    # cast is applied right before the pmean below. See EXPERIMENTS.md
    # §Perf iteration 4.
    import os as _os
    _BF16_REDUCE = _os.environ.get("REPRO_BF16_REDUCE", "0") == "1"

    def _is_expert(path: str) -> bool:
        return moe_ep_axis is not None and "experts_" in path

    def _manual_specs(tree):
        """Per-leaf specs over the MANUAL axes: expert leaves ride the
        shard_map sharded on their E dim (index 1: stacks are (L, E, ...)),
        everything else enters replicated."""
        from jax.sharding import PartitionSpec as P
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = []
        for path, leaf in flat:
            pstr = jax.tree_util.keystr(path)
            nd = getattr(leaf, "ndim", 0)
            if _is_expert(pstr) and nd >= 3:
                parts = [None] * nd
                parts[1] = moe_ep_axis
                specs.append(P(*parts))
            else:
                specs.append(P())
        return jax.tree_util.tree_unflatten(treedef, specs)

    # Leaves eligible for the distributed refresh: stacked GaLore leaves
    # whose layer-stack dim divides the DP world size (so psum_scatter can
    # tile it), excluding expert-parallel leaves (their gradients are owned
    # per EP shard and never cross the DP front whole).
    dist_refresh_ok = set()
    if dp_axes and any_galore and base.dist_refresh:
        for i, sp in enumerate(specs):
            if (sp.galore and sp.batch and sp.batch[0] % dp_size == 0
                    and not _is_expert(sp.path)):
                dist_refresh_ok.add(i)

    # Per-leaf refresh front: on a 2-D (data x model) mesh, leaves whose
    # layer stack also divides D*t scatter over the COMBINED front (each
    # of the D*t ranks owns L/(D*t) layers); everything else keeps the
    # DP-only front. The per-layer SVD is the same bit-exact computation
    # either way — only the ownership map changes.
    dist_front = {
        i: ((refresh_axes, refresh_world)
            if refresh_world > dp_size
            and specs[i].batch[0] % refresh_world == 0
            else (dp_axes, dp_size))
        for i in dist_refresh_ok}

    # ZeRO-2 gradient reduce-scatter only applies where the steady-state
    # gradient is LOW-RANK (fused backward) and the leaf's moments are
    # actually DP-sharded; drop anything else defensively.
    if impl != "fused" or not dp_axes:
        zero2_dims = {}
    zero2_dims = {i: d for i, d in zero2_dims.items()
                  if specs[i].galore and not _is_expert(specs[i].path)
                  and specs[i].low_shape[d] % dp_size == 0}

    def grad_phase_dp(params, proj_trees, batch, refresh_proj=None,
                      refresh_masks=None, rng=None):
        """The manual-DP gradient phase.

        Steady state (``refresh_proj is None``): one pmean on the low-rank
        payload. Refresh steps: additionally runs the distributed subspace
        refresh for the leaves in ``refresh_proj`` (keys = str(leaf index))
        and returns their new projections + similarities; those leaves'
        gradients come back LOW-RANK.
        """
        from jax.sharding import PartitionSpec as P
        other_axes = tuple(a for a in dp_axes if a != moe_ep_axis)
        dist_now = sorted(int(k) for k in refresh_proj) \
            if refresh_proj is not None else []
        # steady state with low-rank emission only: at refresh steps (or
        # with the fused backward off) galore grads are full-rank
        zero2_now = dict(zero2_dims) \
            if refresh_proj is None and proj_trees else {}

        def inner(p, pt, b):
            loss, metrics, grads = grad_phase(p, pt, b)
            # paper §3.1 keeps gradients in BF16 — reduce in BF16 too
            # (halves the remaining full-rank payloads, e.g. gemma's 256k-
            # vocab embedding grad); ONE reduction, on the low-rank payload.
            # Expert-parallel leaves are OWNED per shard (the all_to_all
            # already routed every token to the owner) — no reduction over
            # the EP axis at all.
            flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
            out = []
            for i, (path, g) in enumerate(flat):
                pstr = jax.tree_util.keystr(path)
                if specs[i].frozen:
                    # frozen-group leaves never reach the optimizer —
                    # don't pay the cross-replica reduce for a gradient
                    # that is discarded (the frozen embedding is the
                    # dominant wire payload in the fine-tune workload);
                    # zeros keep the replicated out-spec truthful.
                    out.append(jnp.zeros_like(g))
                    continue
                if i in dist_now:
                    # distributed refresh, phase 1: reduce-scatter the
                    # full-rank gradient over the layer stack — each shard
                    # leaves this region holding the REDUCED gradient of
                    # its owned layers only ((D-1)/D of an all-reduce's
                    # bytes, and no device ever holds a full-rank replica).
                    out.append(jax.lax.psum_scatter(
                        g.astype(jnp.float32), dp_axes,
                        scatter_dimension=0, tiled=True) / dp_size)
                    continue
                if i in zero2_now and tuple(g.shape) == specs[i].low_shape:
                    # ZeRO-2: the low-rank gradient is reduce-scattered
                    # along the SAME dim the leaf's ZeRO moment shard uses
                    # — each DP rank leaves with only its owned slice of
                    # the reduced gradient, aligned with the state it
                    # updates (no replicated low-rank grads on the wire).
                    out.append(jax.lax.psum_scatter(
                        g.astype(jnp.float32), dp_axes,
                        scatter_dimension=zero2_now[i], tiled=True)
                        / dp_size)
                    continue
                if _BF16_REDUCE and g.dtype == jnp.float32:
                    g = g.astype(jnp.bfloat16)
                if _is_expert(pstr):
                    if other_axes:
                        g = jax.lax.pmean(g, other_axes)
                else:
                    g = jax.lax.pmean(g, dp_axes)
                out.append(g)
            grads = jax.tree_util.tree_unflatten(treedef, out)
            loss = jax.lax.pmean(loss, dp_axes)
            metrics = jax.tree_util.tree_map(
                lambda m: jax.lax.pmean(m.astype(jnp.float32), dp_axes),
                metrics)
            return loss, metrics, grads

        batch_specs = jax.tree_util.tree_map(
            lambda x: P(dp_axes, *([None] * (x.ndim - 1))), batch)

        # grads have the params' tree structure but ONE (virtual) leaf per
        # QTensor — build their out_specs at that granularity
        from repro.core import quant as _q
        gflat, gtreedef = jax.tree_util.tree_flatten_with_path(
            params, is_leaf=_q.is_qtensor)
        gspecs = []
        for i, (path, leaf) in enumerate(gflat):
            pstr = jax.tree_util.keystr(path)
            nd = len(leaf.shape)
            if i in dist_now:
                # reduced full-rank gradient leaves the region layer-
                # sharded over the DP front (psum_scatter tiling)
                gspecs.append(P(dp_axes, *([None] * (nd - 1))))
            elif i in zero2_now:
                # ZeRO-2: low-rank gradient leaves sharded on its moment
                # dim (same rank count as the virtual shape)
                parts = [None] * len(specs[i].low_shape)
                parts[zero2_now[i]] = dp_axes
                gspecs.append(P(*parts))
            elif _is_expert(pstr) and nd >= 3:
                parts = [None] * nd
                parts[1] = moe_ep_axis
                gspecs.append(P(*parts))
            else:
                gspecs.append(P())
        grads_specs = jax.tree_util.tree_unflatten(gtreedef, gspecs)

        from repro.compat import shard_map
        loss, metrics, grads = shard_map(
            inner, mesh=mesh, axis_names=set(dp_axes),
            in_specs=(_manual_specs(params), _manual_specs(proj_trees),
                      batch_specs),
            out_specs=(P(), P(), grads_specs),
            check_vma=False)(params, proj_trees, batch)
        if not dist_now:
            return loss, metrics, grads, {}, {}, {}

        # ---- distributed refresh, phase 2: per-owner SVD + broadcast ----
        # A SECOND region, manual over ALL mesh axes: the mask-gated SVD
        # scan lowers to custom calls the partial-manual SPMD partitioner
        # cannot propagate shardings through (same XLA limitation the
        # manual-EP MoE documents in models/moe.py) — in a fully-manual
        # region they are plain local ops. Only the small refresh state
        # enters (layer-sharded reduced grads, P, masks); params and batch
        # stay out, so the model axes simply see replicated copies.
        g_flat2, g_treedef2 = jax.tree_util.tree_flatten(grads)
        gd = {}
        for i in dist_now:
            g = g_flat2[i]
            front, world = dist_front[i]
            if world > dp_size:
                # re-tile the layer-sharded reduced gradient over the
                # combined (data x model) front BEFORE the fully-manual
                # region: each of the D*t ranks owns L/(D*t) layers, so no
                # rank re-materializes even the DP-front shard, let alone
                # a full-rank replica.
                g = jax.lax.with_sharding_constraint(
                    g, jax.sharding.NamedSharding(
                        mesh, P(front, *([None] * (g.ndim - 1)))))
            gd[str(i)] = g

        def refresh_inner(gd, pd, md, key, sid, sid_all):
            new_low, new_proj, sims, ratios = {}, {}, {}, {}
            for i in dist_now:
                sp = specs[i]
                front, world = dist_front[i]
                # sid enters sharded over its front: the local element IS
                # this shard's flat index (lax.axis_index lowers to
                # PartitionId, which XLA:CPU rejects — see repro.compat).
                sidx = sid_all[0] if world > dp_size else sid[0]
                b_loc = sp.nbatch // world
                m, n = sp.mat_shape
                g_loc = gd[str(i)].reshape(b_loc, m, n)
                nlead = len(sp.batch)
                P_flat = jax.tree_util.tree_map(
                    lambda x: x.reshape((b_loc,) + x.shape[nlead:]),
                    pd[str(i)])
                mask_flat = md[str(i)].reshape(b_loc)
                idx = jnp.arange(b_loc, dtype=jnp.int32) + sidx * b_loc
                P_new_flat, sim_loc, ratio_loc = qgalore.refresh_slice(
                    g_loc, P_flat, mask_flat, idx,
                    qgalore._eff_cfg(sp, rules), sp.rank,
                    sp.side, jax.random.fold_in(key, i))
                low_loc = stack.project_leaf(g_loc, P_new_flat, sp.side)
                gather = functools.partial(
                    compat.all_gather_tiled, axes=front, axis=0,
                    world=world, index=sidx)
                new_low[str(i)] = gather(low_loc).reshape(sp.low_shape)
                new_proj[str(i)] = jax.tree_util.tree_map(
                    lambda x: gather(x).reshape(sp.batch + x.shape[1:]),
                    P_new_flat)
                sims[sp.path] = gather(sim_loc)
                if ratio_loc is not None:
                    ratios[sp.path] = gather(ratio_loc)
            return new_low, new_proj, sims, ratios

        front0 = lambda t: {
            k: jax.tree_util.tree_map(
                lambda x: P(dist_front[int(k)][0],
                            *([None] * (x.ndim - 1))), v)
            for k, v in t.items()}
        repl = lambda t: jax.tree_util.tree_map(lambda _: P(), t)
        sims_out_specs = {specs[i].path: P() for i in dist_now}
        ratios_out_specs = {
            specs[i].path: P() for i in dist_now
            if qgalore._eff_cfg(specs[i], rules).adaptive_rank}
        shard_ids = jnp.arange(dp_size, dtype=jnp.int32)
        shard_ids_all = jnp.arange(refresh_world, dtype=jnp.int32)
        new_low, new_proj, sims, ratios = shard_map(
            refresh_inner, mesh=mesh, axis_names=None,
            in_specs=(front0(gd), front0(refresh_proj),
                      front0(refresh_masks), P(), P(dp_axes),
                      P(refresh_axes)),
            out_specs=(repl(gd), repl(refresh_proj), sims_out_specs,
                       ratios_out_specs),
            check_vma=False)(gd, refresh_proj, refresh_masks, rng,
                             shard_ids, shard_ids_all)
        for i in dist_now:
            g_flat2[i] = new_low[str(i)]
        grads = jax.tree_util.tree_unflatten(g_treedef2, g_flat2)
        return loss, metrics, grads, new_proj, sims, ratios

    def step(state: TrainState, batch, lr, rng,
             refresh_masks: Optional[Dict[int, jax.Array]] = None,
             refresh: bool = False):
        params, opt = state

        # projection trees for the fused backward (low-rank emission) —
        # skipped at refresh steps (full-rank grads needed for SVD).
        # Non-segment galore leaves (head, embedding) ride along so their
        # cotangents also go low-rank before clip / DP reduction.
        proj_trees: Dict[str, Any] = {}
        if impl == "fused" and any_galore and not refresh:
            for k, sub in opt.proj.items():
                leaves = jax.tree_util.tree_leaves(
                    sub, is_leaf=lambda x: x is None or quant.is_qtensor(x))
                if k in seg_keys or any(l is not None for l in leaves):
                    proj_trees[k] = sub

        dist_sims: Dict[str, jax.Array] = {}
        dist_ratios: Dict[str, jax.Array] = {}
        if dp_axes:
            dist_idx = [i for i in sorted(dist_refresh_ok)
                        if refresh and refresh_masks and i in refresh_masks]
            if dist_idx:
                # distributed refresh: each owning shard recomputes its
                # layers' P inside the gradient shard_map; apply_updates
                # then treats these leaves as steady (low-rank grad, new P).
                pr_flat, pr_treedef = jax.tree_util.tree_flatten(
                    opt.proj,
                    is_leaf=lambda x: quant.is_qtensor(x) or x is None)
                rp = {str(i): pr_flat[i] for i in dist_idx}
                rm = {str(i): jnp.asarray(refresh_masks[i]).reshape(
                    specs[i].batch) for i in dist_idx}
                (loss, metrics, grads, new_proj, dist_sims,
                 dist_ratios) = grad_phase_dp(
                    params, proj_trees, batch, refresh_proj=rp,
                    refresh_masks=rm, rng=rng)
                for i in dist_idx:
                    pr_flat[i] = new_proj[str(i)]
                opt = opt._replace(proj=jax.tree_util.tree_unflatten(
                    pr_treedef, pr_flat))
                refresh_masks = {i: m for i, m in refresh_masks.items()
                                 if i not in set(dist_idx)}
            else:
                loss, metrics, grads, _, _, _ = grad_phase_dp(
                    params, proj_trees, batch)
        else:
            loss, metrics, grads = grad_phase(params, proj_trees, batch)

        grads, gnorm = transform.clip_by_global_norm(grads, tcfg.grad_clip,
                                                     specs=specs)
        new_params, new_opt, opt_metrics = tx.update(
            grads, opt, params, lr=lr, rng=rng,
            refresh_masks=refresh_masks, refresh=refresh, specs=specs,
            shardings=state_shardings)
        if dist_sims:
            opt_metrics = {**opt_metrics,
                           "sims": {**dist_sims,
                                    **opt_metrics.get("sims", {})},
                           "ratios": {**dist_ratios,
                                      **opt_metrics.get("ratios", {})}}
        metrics = {**metrics, "loss": loss, "grad_norm": gnorm,
                   "lr": jnp.asarray(lr, jnp.float32)}
        return TrainState(new_params, new_opt), metrics, opt_metrics

    # introspection for tests / benchmarks: which front each dist-refresh
    # leaf scatters over, and the mesh-wide refresh geometry
    step.dist_front = dict(dist_front)
    step.refresh_axes = refresh_axes
    step.refresh_world = refresh_world
    step.dp_axes = dp_axes
    step.dp_size = dp_size
    return step, specs
