"""The training loop: adaptive subspace control, checkpoint/auto-resume,
fault tolerance, straggler detection.

Fault-tolerance contract (designed for 1000+-node operation, exercised at
container scale by tests):

* every step is replayable: data is a pure function of step, RNG keys are
  folded from (seed, step), the controller state is checkpointed — so a
  restart from step N reproduces the exact trajectory;
* ``run()`` retries a failed step after restoring the last checkpoint
  (``max_failures`` budget) — the single-process analogue of a coordinator
  restarting a pod after a node failure;
* a straggler monitor tracks the running median step time and flags steps
  slower than ``straggler_factor``× the median (on a real cluster the hook
  feeds preemption/re-scheduling; here it feeds metrics + logs).
"""
from __future__ import annotations

import functools
import json
import logging
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QGaLoreConfig, TrainConfig
from repro.core import adaptive, optimizers, qgalore
from repro.core.rules import as_rules, group_assignment
from repro.data.synthetic import batch_for_bundle
from repro.models.base import ModelBundle
from repro.train import checkpoint as ckpt_lib
from repro.train import step as step_lib

log = logging.getLogger("repro.trainer")


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 50
    times: List[float] = field(default_factory=list)
    events: List[Dict] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        if len(self.times) > self.window:
            self.times.pop(0)
        med = float(np.median(self.times))
        if len(self.times) >= 10 and dt > self.factor * med:
            self.events.append({"step": step, "dt": dt, "median": med})
            log.warning("straggler step %d: %.3fs vs median %.3fs",
                        step, dt, med)
            return True
        return False


class Trainer:
    def __init__(self, bundle: ModelBundle, tcfg: TrainConfig,
                 qcfg, *, cell=None, impl: str = "fused",
                 param_dtype=jnp.float32, accum: int = 1,
                 mesh=None, zero_shard: bool = False,
                 zero2: Optional[bool] = None,
                 fault_hook: Optional[Callable[[int], None]] = None):
        """``qcfg``: a plain ``QGaLoreConfig`` or a ``ParamRules`` rule-set
        (``repro.core.rules``) — per-group ranks / intervals / bits /
        frozen groups resolve through the param-group rules; a plain
        config is the single-default-group case (bit-identical to the
        pre-rules trainer).

        ``mesh``: run the step distributed — params/optimizer state are
        placed with the ``distributed.sharding`` rules, batches are sharded
        over the DP axes, and the jitted steps pin state in/out shardings
        so the layout survives every step. ``zero_shard`` additionally
        partitions the quantized optimizer state (low-rank Adam moments +
        INT4 projections) over the DP axes — ZeRO-style, each DP rank owns
        a 1/D slice, gathered only where the fused update consumes it.
        ``zero2`` (default: follows ``zero_shard``) reduce-scatters the
        steady-state low-rank gradients along each leaf's moment-shard dim
        instead of ``pmean``-replicating them (requires
        ``compress_dp_grads``)."""
        self.rules = as_rules(qcfg)
        self.qcfg = self.rules.base
        self.bundle = bundle
        self.tcfg = tcfg
        self.impl = impl
        self.param_dtype = param_dtype
        from repro.config import ShapeCell
        self.cell = cell or ShapeCell("train", tcfg.seq_len,
                                      tcfg.global_batch, "train")
        self.fault_hook = fault_hook          # tests inject failures here
        self.stragglers = StragglerMonitor()
        self.mesh = mesh
        self.zero_shard = zero_shard
        self.zero2 = zero_shard if zero2 is None else zero2
        dp_compress = self.qcfg.compress_dp_grads and mesh is not None
        if zero2 and not (mesh is not None and zero_shard and dp_compress):
            # an explicit force-on that cannot take effect must not
            # silently fall back to the replicated pmean
            raise ValueError(
                "zero2=True requires a mesh, zero_shard=True, and "
                "compress_dp_grads=True (the reduce-scatter dims come "
                "from the ZeRO moment sharding inside the compressed-DP "
                f"shard_map); got mesh={mesh is not None}, "
                f"zero_shard={zero_shard}, "
                f"compress_dp_grads={self.qcfg.compress_dp_grads}")

        self._accum = accum
        self._dp_compress = dp_compress
        self._rank_overrides: Dict[str, int] = {}
        self._build_execution()

        self.controller = adaptive.SubspaceController(self._base_specs,
                                                      self.rules)
        self.mgr = None
        if tcfg.checkpoint_dir:
            self.mgr = ckpt_lib.CheckpointManager(
                tcfg.checkpoint_dir, max_to_keep=tcfg.keep_checkpoints,
                async_save=tcfg.async_checkpoint)

        self.state = step_lib.init_state(
            bundle, self.rules, jax.random.PRNGKey(tcfg.seed), param_dtype,
            specs=self.specs)
        if self.state_sharding is not None:
            self.state = jax.device_put(self.state, self.state_sharding)
        self.start_step = 0
        self.history: List[Dict[str, float]] = []

    # ------------------------------------------------------------------
    def _build_execution(self):
        """(Re)derive specs / shardings / the compiled step pair under the
        current rank overrides. Called at construction (no overrides), when
        a restore brings in a shrunk checkpoint's overrides, and after each
        runtime rank migration — a rank change alters state shapes, the
        batching signatures, the ZeRO layout, and the DP wire payload, so
        the whole execution stack is rebuilt (two fresh jit variants)."""
        self._base_specs = step_lib._specs_for(self.bundle, self.rules,
                                               self.param_dtype)
        self.specs = qgalore.apply_rank_overrides(self._base_specs,
                                                  self._rank_overrides)
        mesh, tcfg = self.mesh, self.tcfg
        if mesh is not None:
            # shard-dim annotation BEFORE anything consumes the specs: the
            # batching signatures, the optimizer-state placement and the
            # TP-aware refresh fronts must all see the same (shard_dim, tp)
            # a leaf's weight actually gets from the placement rules.
            from repro.distributed import sharding as _sh
            self.specs = _sh.annotate_tp(self.specs, mesh)
        self.state_sharding = None
        self._batch_sharding = None
        zero2_dims = None
        if mesh is not None:
            from repro.distributed import sharding as sh
            abs_state = self._abstract_state()
            zaxes = sh.zero_axes_for(mesh) if self.zero_shard else ()
            self.state_sharding = step_lib.TrainState(
                sh.param_sharding(abs_state.params, mesh),
                sh.opt_state_sharding(abs_state.params, abs_state.opt,
                                      self.rules, mesh, zero_axes=zaxes,
                                      specs=self.specs))
            if self.zero2 and zaxes and self._dp_compress:
                zero2_dims = sh.zero2_scatter_dims(
                    self.state_sharding.opt, self.specs, zaxes)
            elif self.zero2 and zaxes and not self._dp_compress:
                # zero_shard-implied default that can't take effect —
                # say so rather than silently keeping the pmean path
                log.info("zero2 inactive: compress_dp_grads is off (the "
                         "reduce-scatter lives in the compressed-DP "
                         "shard_map); pass --compress / "
                         "compress_dp_grads=True to enable it")

        raw_step, _ = step_lib.build_train_step(
            self.bundle, self.rules, tcfg, impl=self.impl,
            accum=self._accum, param_dtype=self.param_dtype, mesh=mesh,
            dp_compress=self._dp_compress,
            state_shardings=self.state_sharding, zero2_dims=zero2_dims,
            specs=self.specs)
        self._raw_step = raw_step

        if mesh is not None:
            from repro.distributed import sharding as sh
            batch_abs = jax.eval_shape(
                lambda: batch_for_bundle(self.bundle, self.cell, 0,
                                         tcfg.seed))
            self._batch_sharding = sh.data_sharding(batch_abs, mesh)
            rep = sh.replicated(mesh)
            # positional wrappers: jit in_shardings rejects kwargs, and the
            # out sharding pins the (ZeRO) state layout across steps
            self._step_normal = jax.jit(
                lambda st, b, lr, rng: raw_step(
                    st, b, lr, rng, refresh_masks=None, refresh=False),
                in_shardings=(self.state_sharding, self._batch_sharding,
                              rep, rep),
                out_shardings=(self.state_sharding, None, None))
            self._step_refresh = jax.jit(
                lambda st, b, lr, rng, masks: raw_step(
                    st, b, lr, rng, refresh_masks=masks, refresh=True),
                in_shardings=(self.state_sharding, self._batch_sharding,
                              rep, rep, rep),
                out_shardings=(self.state_sharding, None, None))
        else:
            self._step_normal = jax.jit(
                functools.partial(raw_step, refresh=False,
                                  refresh_masks=None))
            self._step_refresh = jax.jit(
                functools.partial(raw_step, refresh=True),
                static_argnames=())

    def _abstract_state(self):
        return step_lib.abstract_state(self.bundle, self.rules,
                                       self.param_dtype, specs=self.specs)

    def _adaptive_rank_enabled(self) -> bool:
        return self.qcfg.adaptive_rank or any(
            g.adaptive_rank for g in self.rules.groups)

    def maybe_restore(self) -> int:
        if self.mgr is None or self.mgr.latest_step() is None:
            return 0
        # group-metadata compatibility FIRST (meta only, no arrays): a
        # checkpoint written under different param-group rules (or holding
        # rank-shrunk state this run cannot adapt to) has differently-
        # shaped (or missing) optimizer state per leaf — fail with the
        # loud meta-mismatch error, not a shape error from the array
        # restore.
        meta = self.mgr.read_meta()
        ckpt_lib.check_rules_compat(meta, self.rules.fingerprint(),
                                    group_assignment(self._base_specs),
                                    adaptive_rank=
                                    self._adaptive_rank_enabled())
        # adopt the checkpoint's rank overrides before touching arrays:
        # the abstract state / shardings / compiled steps must describe
        # the SHRUNK shapes the checkpoint actually holds
        overrides = {str(k): int(v)
                     for k, v in (meta.get("rank_overrides") or {}).items()}
        if overrides != self._rank_overrides:
            self._rank_overrides = overrides
            self._build_execution()
            self.controller.update_specs(self.specs)
        # state_sharding may describe a different mesh than the checkpoint
        # was saved on — restore is elastic (arrays are host-gathered at
        # save; device_put here re-places them under the current rules)
        state, meta = self.mgr.restore(None, self._abstract_state(),
                                       self.state_sharding)
        self.state = state
        if meta.get("controller"):
            self.controller.from_json(meta["controller"])
        self.start_step = int(meta["step"]) + 1
        log.info("restored checkpoint at step %d", meta["step"])
        return self.start_step

    def save(self, step: int):
        if self.mgr is None:
            return
        self.mgr.save(step, self.state,
                      {"controller": self.controller.to_json(),
                       "rules_fingerprint": self.rules.fingerprint(),
                       "groups": group_assignment(self._base_specs),
                       "rank_overrides": self.controller.current_ranks(),
                       # provenance only — restore is mesh-elastic and
                       # never requires the saving layout (checkpoint.py)
                       "mesh": None if self.mesh is None else
                       {a: int(self.mesh.shape[a])
                        for a in self.mesh.axis_names}})

    # ------------------------------------------------------------------
    def _run_one(self, step: int):
        if self.fault_hook is not None:
            self.fault_hook(step)             # may raise (simulated failure)
        batch = batch_for_bundle(self.bundle, self.cell, step,
                                 self.tcfg.seed)
        if self._batch_sharding is not None:
            batch = jax.device_put(batch, self._batch_sharding)
        lr = optimizers.lr_at(step, self.tcfg)
        rng = jax.random.fold_in(jax.random.PRNGKey(self.tcfg.seed + 17),
                                 step)
        masks = self.controller.masks_for_step(step) \
            if self.controller.units else {}
        if masks:
            # pass masks for EVERY galore leaf (False where not due) so the
            # refresh variant compiles exactly once
            jmasks = {
                i: jnp.asarray(masks[i]) if i in masks
                else jnp.zeros((s.nbatch,), bool)
                for i, s in enumerate(self.specs) if s.galore}
            state, metrics, opt_metrics = self._step_refresh(
                self.state, batch, lr, rng, jmasks)
            sims = {k: np.asarray(v)
                    for k, v in opt_metrics.get("sims", {}).items()}
            ratios = {k: np.asarray(v)
                      for k, v in opt_metrics.get("ratios", {}).items()}
            self.controller.observe(step, masks, sims, ratios)
            decisions = self.controller.take_rank_decisions()
        else:
            state, metrics, _ = self._step_normal(self.state, batch, lr, rng)
            decisions = []
        self.state = state
        if decisions:
            self._migrate_ranks(step, decisions)
        return metrics

    def _migrate_ranks(self, step: int, decisions):
        """Apply pending rank-shrink decisions from the controller:
        truncate the live low-rank state (INT8 moments + INT4 projection,
        deterministic), swap in rank-overridden specs, and rebuild the
        compiled steps / shardings around the new shapes."""
        i_flat, i_tree = jax.tree_util.tree_flatten(
            self.state.opt.inner, is_leaf=qgalore._is_inner_leaf)
        pr_flat, pr_tree = jax.tree_util.tree_flatten(
            self.state.opt.proj,
            is_leaf=lambda x: qgalore.quant.is_qtensor(x) or x is None)
        for idx, old, new in decisions:
            spec = self.specs[idx]
            i_flat[idx], pr_flat[idx] = qgalore.migrate_rank_state(
                i_flat[idx], pr_flat[idx], spec, new, self.rules)
            self._rank_overrides[spec.path] = new
            log.info("rank transition at step %d: %s %d -> %d "
                     "(explained-variance threshold held %d refreshes)",
                     step, spec.path, old, new,
                     self.controller._cfg_for(idx).rank_patience)
        self.state = step_lib.TrainState(
            self.state.params,
            qgalore.QGaLoreState(
                inner=jax.tree_util.tree_unflatten(i_tree, i_flat),
                proj=jax.tree_util.tree_unflatten(pr_tree, pr_flat),
                count=self.state.opt.count))
        self._build_execution()
        self.controller.update_specs(self.specs)
        if self.state_sharding is not None:
            # ZeRO re-shard: the shrunk arrays re-place under the sharding
            # derived from the NEW shapes (divisibility re-checked)
            self.state = jax.device_put(self.state, self.state_sharding)

    def run(self, steps: Optional[int] = None, max_failures: int = 3):
        steps = steps if steps is not None else self.tcfg.steps
        failures = 0
        step = self.start_step
        while step < steps:
            t0 = time.monotonic()
            try:
                metrics = self._run_one(step)
            except Exception as e:   # noqa: BLE001 — fault-tolerance path
                failures += 1
                log.warning("step %d failed (%s); recovering (%d/%d)",
                            step, e, failures, max_failures)
                if failures > max_failures:
                    raise
                if self.mgr is not None and self.mgr.latest_step() is not None:
                    self.maybe_restore()
                    step = self.start_step
                continue
            dt = time.monotonic() - t0
            self.stragglers.observe(step, dt)
            row = {k: float(v) for k, v in metrics.items()
                   if np.ndim(v) == 0}
            row["step"] = step
            row["dt"] = dt
            self.history.append(row)
            if self.tcfg.log_every and step % self.tcfg.log_every == 0:
                log.info("step %d loss %.4f (%.2fs)", step,
                         row.get("loss", float("nan")), dt)
            if (self.tcfg.checkpoint_every
                    and step % self.tcfg.checkpoint_every == 0
                    and step > 0):
                self.save(step)
            step += 1
        if self.mgr is not None:
            self.save(steps - 1)
            self.mgr.wait()
        return self.history

    # ------------------------------------------------------------------
    def eval_loss(self, n_batches: int = 4, offset: int = 10_000) -> float:
        """Held-out loss on batches the training never sees."""
        from repro.models import base
        losses = []
        # INT8 params are consumed natively by the model (quantized_dense)
        fn = jax.jit(lambda p, b: base.loss_fn(self.bundle, p, b)[0])
        for i in range(n_batches):
            batch = batch_for_bundle(self.bundle, self.cell, offset + i,
                                     self.tcfg.seed + 1)
            losses.append(float(fn(self.state.params, batch)))
        return float(np.mean(losses))
