"""Fused projected-backward over segment stacks — the JAX-native realization
of the paper's fused-backward + low-rank projection (§3.5).

The forward scan saves only each layer's *input* carry (full per-layer
activation remat). The backward scan then, per layer:

  1. recomputes the layer forward and its VJP (``jax.vjp``),
  2. obtains the full-rank weight cotangents **transiently**,
  3. immediately projects every GaLore-eligible cotangent into its rank-r
     subspace (``P^T G`` / ``G P``) and emits only the low-rank tensor.

Consequences (matching the paper's memory story):
  * the full-rank gradient of the whole stack never co-resides — at any
    moment only ONE layer's (m, n) cotangent exists;
  * the emitted per-stack gradient is (L, r, n) / (L, m, r): 8-32× smaller;
  * under data parallelism the cross-replica reduction runs on the low-rank
    payload (gradient compression for free — see train.step).

Quantized (INT8 QTensor) parameters are *virtualized* per layer inside the
scan bodies (``quant.tree_virtualize``): the model consumes the INT8 codes
directly through the ``quantized_dense`` custom-VJP op — forward and the
``dL/dx`` backward stream INT8 blocks, and no full-precision weight view
exists even transiently. The ``QVirtual`` shadow (a dead zeros array of the
virtual shape) is what ``jax.vjp`` differentiates; its cotangent IS the
virtual-weight gradient, which the backward scan then projects low-rank as
before.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import projector, quant
from repro.models.base import ModelBundle, SegmentDef

_FLOAT0 = jax.dtypes.float0


def _virt(tree):
    """QTensor leaves → QVirtual: INT8 stays the compute format, gradients
    land on the (virtual-shaped) shadow cotangent."""
    return quant.tree_virtualize(tree)


def _is_float(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating) \
        and x.dtype != _FLOAT0


def _zero_cotangent_carry(tree):
    """Zeros for float leaves; scalar dummies for non-differentiable leaves
    (so the tree can ride a scan carry — float0 arrays cannot)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, x.dtype) if _is_float(x)
        else jnp.zeros((), jnp.float32), tree)


def _to_float0_cotangent(acc_tree, primal_tree):
    """Rebuild a proper vjp cotangent: float0 zeros at non-float primal
    positions, accumulated values elsewhere."""
    return jax.tree_util.tree_map(
        lambda acc, p: acc if _is_float(p)
        else np.zeros(p.shape, _FLOAT0), acc_tree, primal_tree)


def _tree_add(a, b):
    """a (accumulator with dummies) += b (vjp output, may contain float0)."""
    def add(x, y):
        if y is None or not _is_float(y):
            return x
        if not _is_float(x):
            return x
        return x + y
    return jax.tree_util.tree_map(add, a, b,
                                  is_leaf=lambda x: x is None)


def project_leaf(g, P, side: Optional[str] = None):
    """Project one (possibly stacked) full-rank gradient leaf into the
    rank-r subspace of ``P`` (QTensor or array; leading batch dims ride the
    einsum). Shared by the backward-scan low-rank emission here and the
    distributed refresh in ``train.step`` (which projects the freshly
    reduced gradient slices with the just-recomputed P).

    ``side`` defaults to ``galore_side(g.shape)``, which is only valid on
    GLOBAL (logical) shapes — inside a manual region over the model axis
    a TP shard's local shape can flip the m>=n test, so shard-level
    callers must pass the spec's side explicitly (the distributed refresh
    does; ``projector.project_sharded`` is the shard-aware variant)."""
    if P is None:
        return g
    Pd = projector.maybe_dequantize(P, jnp.float32)
    side = side or projector.galore_side(g.shape)
    return projector.project(g.astype(jnp.float32), Pd, side)


def _project_cotangents(g_lp, P_lp):
    """Per-leaf: if a projection matrix is provided, emit the low-rank
    projection of the cotangent; else the full cotangent."""
    return jax.tree_util.tree_map(
        project_leaf, g_lp, P_lp,
        is_leaf=lambda x: x is None or quant.is_qtensor(x))


def segment_forward(seg: SegmentDef, seg_params, carry, ctx):
    """Forward scan saving per-layer input carries."""
    def body(c, lp):
        return seg.apply(_virt(lp), c, ctx), c
    from repro.models.base import scan_layers
    return scan_layers(body, carry, seg_params)


def segment_backward(seg: SegmentDef, seg_params, saved, g_carry, ctx,
                     P_tree: Optional[Any]):
    """Reverse scan: recompute + vjp + project. Returns
    (g_seg_params, g_carry_in, g_ctx_acc)."""
    g_ctx0 = _zero_cotangent_carry(ctx)
    g_carry0 = _zero_cotangent_carry(g_carry)
    # normalize incoming carry cotangent (may contain float0 from upstream)
    g_carry = _tree_add(g_carry0, g_carry)

    if P_tree is None:
        P_tree = jax.tree_util.tree_map(lambda _: None, seg_params,
                                        is_leaf=quant.is_qtensor)

    def body(state, inp):
        g_c, g_ctx = state
        lp, c_in, P_l = inp

        lp_v = _virt(lp)
        _, vjp = jax.vjp(lambda p, c, x: seg.apply(p, c, x),
                         lp_v, c_in, ctx)
        g_lp, g_cin, g_ctx_l = vjp(g_c)
        # collapse QVirtual cotangents to the shadow (= dL/dW virtual):
        # restores the plain per-QTensor gradient leaf and drops the
        # float0 code cotangents before they hit the scan ys.
        g_lp = quant.tree_devirtualize_grads(g_lp)
        g_lp = _project_cotangents(g_lp, P_l)
        g_cin = _tree_add(_zero_cotangent_carry(c_in), g_cin)
        return (g_cin, _tree_add(g_ctx, g_ctx_l)), g_lp

    from repro.models.base import scan_layers
    (g_carry_in, g_ctx), g_params = scan_layers(
        body, (g_carry, g_ctx0), (seg_params, saved, P_tree), reverse=True)
    return g_params, g_carry_in, g_ctx


def fused_value_and_grad(bundle: ModelBundle, params, batch,
                         proj_trees: Dict[str, Any]):
    """Loss + gradients with per-layer fused backward and in-scan projection.

    ``proj_trees``: {segment_key: pytree matching that segment's params with
    stacked P (or None per leaf)} — segment cotangents project INSIDE the
    backward scan; entries under NON-segment keys (``head``, ``embedding``
    when ``galore_embeddings``) project right after the head/embed vjps, so
    every GaLore leaf leaves this function low-rank and the DP reduction
    payload is low-rank across the board (the unembedding gradient otherwise
    dominates bytes-on-wire at small-model shapes). Pass {} to get full-rank
    grads everywhere (e.g. at subspace-refresh steps or for non-GaLore
    baselines).

    Returns ((loss, metrics), grads) where grads for projected leaves are
    low-rank (spec.low_shape) and full-rank elsewhere. Grad leaves for
    quantized params are w.r.t. the dequantized (virtual) weights.
    """
    seg_keys = [bundle.seg_key(i) for i in range(len(bundle.segments))]
    nonseg = {k: v for k, v in params.items() if k not in seg_keys}
    nonseg_v = _virt(nonseg)

    # ---- forward ----
    (carry, ctx), vjp_embed = jax.vjp(
        lambda ns: bundle.embed({**params, **ns}, batch), nonseg_v)

    saved_per_seg = []
    pre_vjps = []
    for i, seg in enumerate(bundle.segments):
        if seg.pre is not None:
            carry, vjp_pre = jax.vjp(
                lambda ns, c, x, _seg=seg: _seg.pre({**params, **ns}, c, x),
                nonseg_v, carry, ctx)
            pre_vjps.append(vjp_pre)
        else:
            pre_vjps.append(None)
        carry, saved = segment_forward(seg, params[seg_keys[i]], carry, ctx)
        saved_per_seg.append(saved)

    loss_and_metrics, vjp_head, metrics = jax.vjp(
        lambda ns, c: bundle.head_loss({**params, **ns}, c, batch),
        nonseg_v, carry, has_aux=True)
    loss = loss_and_metrics

    # ---- backward ----
    g_nonseg, g_carry = vjp_head(jnp.ones((), loss.dtype))
    g_nonseg = _tree_add(_zero_cotangent_carry(nonseg_v), g_nonseg)
    g_ctx_total = _zero_cotangent_carry(ctx)
    g_segs: Dict[str, Any] = {}
    for i in reversed(range(len(bundle.segments))):
        seg = bundle.segments[i]
        g_seg, g_carry, g_ctx = segment_backward(
            seg, params[seg_keys[i]], saved_per_seg[i], g_carry, ctx,
            proj_trees.get(seg_keys[i]))
        g_segs[seg_keys[i]] = g_seg
        g_ctx_total = _tree_add(g_ctx_total, g_ctx)
        if pre_vjps[i] is not None:
            g_ns_pre, g_carry, g_ctx_pre = pre_vjps[i](g_carry)
            g_carry = _tree_add(_zero_cotangent_carry(carry), g_carry) \
                if not isinstance(g_carry, dict) else g_carry
            g_nonseg = _tree_add(g_nonseg, g_ns_pre)
            g_ctx_total = _tree_add(g_ctx_total, g_ctx_pre)

    g_ns_embed, = vjp_embed(
        (g_carry, _to_float0_cotangent(g_ctx_total, ctx)))
    g_nonseg = _tree_add(g_nonseg, g_ns_embed)

    grads = {**g_nonseg, **g_segs}
    grads = {k: grads[k] for k in params.keys()}
    grads = quant.tree_devirtualize_grads(grads)
    for k, P_sub in proj_trees.items():
        if k not in g_segs and k in grads:      # nonseg galore leaves
            grads[k] = _project_cotangents(grads[k], P_sub)
    return (loss, metrics), grads


def simple_value_and_grad(bundle: ModelBundle, params, batch):
    """Oracle path: one vjp through the scanned forward (full-rank grads;
    higher peak memory). Used for tests and small baselines.

    Uses ``jax.vjp`` rather than ``value_and_grad`` because the virtualized
    params tree carries the (non-differentiable) INT8 code arrays alongside
    the float shadows; their float0 cotangents are dropped on extraction.
    """
    from repro.models import base

    virt = _virt(params)

    def loss_of(v):
        loss, metrics = base.loss_fn(bundle, v, batch)
        return loss, metrics

    loss, vjp, metrics = jax.vjp(loss_of, virt, has_aux=True)
    grads, = vjp(jnp.ones((), loss.dtype))
    grads = quant.tree_devirtualize_grads(grads)
    return (loss, metrics), grads
