"""Checkpointing: atomic, async, mesh-independent, elastic-restorable.

Format: one directory per step (``step_00001234/``) containing
``arrays.npz`` (flat path→ndarray map covering params + optimizer state),
``meta.json`` (step, controller state, rng, config fingerprint, and —
since the param-group redesign — the optimizer group metadata:
``rules_fingerprint`` plus the per-leaf ``groups`` map written by
``Trainer.save``; :func:`check_rules_compat` refuses a restore under a
different rule-set, since frozen/regrouped leaves change which state
arrays even exist). Writes go to ``<dir>.tmp`` and are published with an
atomic ``os.rename`` — a crash mid-write never corrupts the latest
checkpoint.

Mesh independence: arrays are gathered to host before writing, so a
checkpoint saved on one mesh restores onto any other (elastic scaling) —
including a ZeRO-sharded optimizer state saved on one DP world size and
restored onto another (each leaf is a global jax.Array; ``device_get``
assembles the full value regardless of layout); the restore path
``device_put``s each leaf with the *target* sharding. (A real >10B
deployment would write per-shard TensorStore slices instead; the resharding
logic — restore-with-new-sharding — is the part that transfers, and is what
``tests/test_distributed.py::test_elastic_checkpoint_reshard`` and
``::test_zero_sharded_state_matches_and_reshards`` exercise.)

Async: ``save`` snapshots to host synchronously (cheap device_get) and hands
serialization to a background thread; ``wait()`` joins before the next save
or shutdown.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.core import quant

_STEP_RE = re.compile(r"^step_(\d{8})$")


def check_rules_compat(meta: Dict, fingerprint: str,
                       groups: Optional[Dict[str, str]] = None,
                       adaptive_rank: Optional[bool] = None) -> None:
    """Refuse to adopt a checkpoint written under different param-group
    rules. Old checkpoints (no ``rules_fingerprint`` in meta) pass — they
    predate the group system and carry full per-leaf state.

    ``adaptive_rank``: the restoring run's dynamic-rank setting. A
    checkpoint holding rank-SHRUNK optimizer state (non-empty
    ``rank_overrides`` in meta) cannot be adopted by a run with rank
    adaptation off — it would build full-rank abstract state and fail on
    array shapes; fail loudly HERE, meta-first."""
    shrunk = meta.get("rank_overrides") or {}
    if shrunk and adaptive_rank is False:
        ov = sorted(shrunk.items())[:8]
        raise ValueError(
            "checkpoint holds rank-shrunk optimizer state "
            f"(rank_overrides={ov}) but this run has adaptive_rank "
            "disabled — it cannot adopt the shrunk low-rank moments / "
            "projections. Enable QGaLoreConfig.adaptive_rank (or restore "
            "a pre-transition checkpoint).")
    saved = meta.get("rules_fingerprint")
    if saved is None:
        return
    if saved != fingerprint:
        saved_groups = meta.get("groups") or {}
        changed = sorted(
            p for p in set(saved_groups) | set(groups or {})
            if saved_groups.get(p) != (groups or {}).get(p))[:8]
        raise ValueError(
            "checkpoint was written under different param-group rules "
            f"(saved fingerprint {saved}, current {fingerprint}; "
            f"first differing leaves: {changed}). Restore with the "
            "original rules or start fresh state.")


def _flatten_arrays(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _unflatten_into(abstract_tree, arrays: Dict[str, np.ndarray],
                    shardings=None):
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_tree)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    leaves = []
    for i, (path, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"expected {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            leaves.append(jax.device_put(arr, shard_flat[i]))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, max_to_keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.max_to_keep = max_to_keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- introspection -------------------------------------------------------
    def all_steps(self):
        steps = []
        for name in os.listdir(self.directory):
            m = _STEP_RE.match(name)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    # -- save ----------------------------------------------------------------
    def save(self, step: int, state, extra_meta: Optional[Dict] = None):
        """Snapshot now; serialize (possibly) in the background.

        ``_flatten_arrays`` host-gathers every array, so the on-disk
        format is layout-free: restore may place the state onto ANY mesh
        — different DP world, different ZeRO axes, or a different TP
        degree (an ``(8,1)`` <-> ``(2,4)`` reshard is bit-exact; pinned
        by ``tests/test_tp.py``). The Trainer records the saving mesh in
        the meta for provenance only."""
        self.wait()
        arrays = _flatten_arrays(state)           # host copy, synchronous
        meta = {"step": step, **(extra_meta or {})}

        def work():
            final = self._path(step)
            tmp = final + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{k: v for k, v in arrays.items()})
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)                 # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.max_to_keep]:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------
    def read_meta(self, step: Optional[int] = None) -> Dict:
        """Load just ``meta.json`` for a step (latest by default) — lets
        callers validate compatibility (``check_rules_compat``) BEFORE the
        arrays are materialized, so a rules mismatch surfaces as the
        intended loud error rather than a missing-leaf KeyError."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        with open(os.path.join(self._path(step), "meta.json")) as f:
            return json.load(f)

    def restore(self, step: Optional[int], abstract_state,
                shardings=None):
        """Restore into the structure of ``abstract_state`` (eval_shape'd),
        placing leaves with ``shardings`` if given (elastic reshard)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._path(step)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        state = _unflatten_into(abstract_state, arrays, shardings)
        return state, meta
