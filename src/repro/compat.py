"""JAX API compatibility shims.

``shard_map`` graduated from ``jax.experimental.shard_map`` to
``jax.shard_map`` with a changed signature::

    old: shard_map(f, mesh, in_specs, out_specs, check_rep=True,
                   auto=frozenset())
    new: jax.shard_map(f, mesh=..., in_specs=..., out_specs=...,
                       axis_names=<manual axes>, check_vma=True)

The two express the manual/auto split inversely: the new API names the
MANUAL axes (everything else stays automatic / GSPMD), the old API names
the AUTO axes. ``check_vma`` is the new name for ``check_rep``.

Every shard_map call in this repo goes through :func:`shard_map` below,
which speaks the NEW keyword signature and lowers to whichever API the
installed JAX provides — on old JAX (< jax.shard_map) it converts
``axis_names`` to ``auto = mesh.axis_names - axis_names`` and
``check_vma`` to ``check_rep``.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Set

import jax


def has_new_shard_map() -> bool:
    """True when the installed JAX exposes top-level ``jax.shard_map``."""
    try:
        return callable(getattr(jax, "shard_map"))
    except AttributeError:
        # jax>=0.4.35 raises (DeprecationWarning machinery) instead of
        # returning a missing-attribute sentinel.
        return False


def shard_map(f: Callable, *, mesh, in_specs: Any, out_specs: Any,
              axis_names: Optional[Set[str]] = None,
              check_vma: bool = True) -> Callable:
    """New-API ``shard_map`` on any supported JAX.

    ``axis_names``: the mesh axes the body is MANUAL over (receives
    shard-local views + collectives); remaining axes stay automatic.
    ``None`` means all mesh axes (both APIs' default).
    """
    if has_new_shard_map():
        kwargs = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        if axis_names is not None:
            kwargs["axis_names"] = set(axis_names)
        return jax.shard_map(f, **kwargs)

    from jax.experimental.shard_map import shard_map as _old_shard_map
    auto: frozenset = frozenset()
    if axis_names is not None:
        auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _old_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma,
                          auto=auto)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` (new) / ``psum(1, axis)`` (old) — the static
    size of a manual mesh axis, inside a shard_map body."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


# ---------------------------------------------------------------------------
# Partial-manual collective shims (XLA:CPU SPMD partitioner gaps)
# ---------------------------------------------------------------------------
# Inside a PARTIALLY-manual shard_map (some mesh axes stay automatic /
# GSPMD — the dp_compress training step keeps the model axis auto), the
# XLA:CPU partitioner supports psum and psum_scatter but
#   * aborts on all_gather ("Check failed: target.IsManualSubgroup() ==
#     sharding().IsManualSubgroup()", spmd_partitioner.cc), and
#   * rejects lax.axis_index ("PartitionId instruction is not supported
#     for SPMD partitioning").
# (psum_scatter additionally crashes when its operand is a body-created
# constant such as an iota — the partitioner constant-folds it into a
# manual-subgroup mismatch — so shard indices must arrive as SHARDED
# INPUTS, e.g. an arange(D) with in_spec P(dp_axes): each shard reads its
# own id. See train/step.py.)
# The gather helper below is expressed in terms of the collectives that DO
# lower everywhere, so the distributed-refresh path runs identically on
# the CPU CI mesh and on real hardware. TPU/GPU backends take the native
# op (the emulated gather costs ~2x the ring all-gather bytes, which only
# matters for large payloads — here they are low-rank grads and INT4 Ps).


def _emulate_collectives() -> bool:
    return jax.default_backend() == "cpu"


def all_gather_tiled(x, axes, *, axis: int, world: int, index):
    """``lax.all_gather(..., tiled=True)`` that also lowers on XLA:CPU
    partial-manual regions: each shard writes its block at its offset in a
    zeros global-size buffer and the psum concatenates (exactly one shard
    contributes per position, so integer payloads can't overflow)."""
    if not _emulate_collectives():
        return jax.lax.all_gather(x, axes, axis=axis, tiled=True)
    jnp = jax.numpy
    shape = list(x.shape)
    shape[axis] *= world
    start = [0] * x.ndim
    start[axis] = index * x.shape[axis]
    buf = jax.lax.dynamic_update_slice(jnp.zeros(shape, x.dtype), x,
                                       tuple(start))
    return jax.lax.psum(buf, axes)
