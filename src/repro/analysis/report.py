"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m repro.analysis.report \
        --dryrun experiments/dryrun --perf experiments/perf
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

from repro.analysis import roofline


def dryrun_table(directory: str) -> str:
    arts = roofline.load_artifacts(directory)
    lines = [
        "| arch × cell | compile (s) | HLO FLOPs/chip (raw) | HLO bytes/chip"
        " | collective GB/chip | #coll ops | temp GiB/chip | status |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key, art in arts.items():
        if not art.get("ok"):
            lines.append(f"| {key} | — | — | — | — | — | — | "
                         f"FAILED: {str(art.get('error', ''))[:40]} |")
            continue
        cost = art.get("cost_analysis", {})
        coll = art.get("collectives", {}).get("total", {})
        mem = art.get("memory_analysis", {})
        lines.append(
            f"| {key} | {art.get('compile_s', 0):.0f} "
            f"| {cost.get('flops', 0):.2e} "
            f"| {cost.get('bytes accessed', 0):.2e} "
            f"| {coll.get('bytes', 0) / 1e9:.2f} "
            f"| {coll.get('count', 0)} "
            f"| {mem.get('temp_size_in_bytes', 0) / 2**30:.1f} | ok |")
    return "\n".join(lines)


def roofline_table(directory: str) -> str:
    arts = roofline.load_artifacts(directory)
    lines = [
        "| arch × cell | compute (s) | memory floor (s) | memory HLO-UB (s)"
        " | collective (s) | dominant | MODEL/HLO | MFU bound | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for key, art in arts.items():
        if not art.get("ok"):
            continue
        r = roofline.from_artifact(art)
        lever = {
            "compute": "raise arithmetic density (fuse dequant, larger "
            "microbatch)",
            "memory": "cut HBM traffic (INT8 KV cache, fused SR update)",
            "collective": "compress DP payload (project-before-reduce), "
            "overlap",
        }[r.dominant]
        lines.append(
            f"| {key} | {r.compute_s:.4f} | {r.dram_s:.4f} | "
            f"{r.memory_s:.4f} | {r.collective_s:.4f} | **{r.dominant}** | "
            f"{r.useful_flops_ratio:.2f} | {r.mfu_bound:.1%} | {lever} |")
    return "\n".join(lines)


def compare(base_dir: str, opt_dir: str) -> str:
    """§Perf before/after table for cells present in both dirs."""
    base = roofline.load_artifacts(base_dir)
    opt = roofline.load_artifacts(opt_dir)
    lines = [
        "| cell | term | baseline | optimized | Δ |",
        "|---|---|---|---|---|",
    ]
    # NOTE: the HLO-UB memory term is NOT comparable across differently-
    # structured programs (its loop-correction ratio differs); the honest
    # before/after metrics are collective bytes (identical parser), compute
    # (analytic, invariant) and memory_analysis temp/args.
    for key in sorted(set(base) & set(opt)):
        rb = roofline.from_artifact(base[key])
        ro = roofline.from_artifact(opt[key])
        for term in ("compute_s", "collective_s"):
            b, o = getattr(rb, term), getattr(ro, term)
            if b <= 0:
                continue
            lines.append(f"| {key} | {term} | {b:.4f} | {o:.4f} | "
                         f"{(o - b) / b:+.0%} |")
        for field, name in (("temp_size_in_bytes", "temp GiB"),
                            ("argument_size_in_bytes", "args GiB")):
            mb = base[key].get("memory_analysis", {}).get(field, 0)
            mo = opt[key].get("memory_analysis", {}).get(field, 0)
            if mb:
                lines.append(f"| {key} | {name} | {mb/2**30:.1f} | "
                             f"{mo/2**30:.1f} | {(mo - mb) / mb:+.0%} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="experiments/dryrun")
    ap.add_argument("--perf", default="experiments/perf")
    args = ap.parse_args()
    for mesh in ("16x16", "2x16x16"):
        d = os.path.join(args.dryrun, mesh)
        if os.path.isdir(d):
            print(f"\n## Dry-run ({mesh})\n")
            print(dryrun_table(d))
    d = os.path.join(args.dryrun, "16x16")
    print("\n## Roofline (single-pod)\n")
    print(roofline_table(d))
    p = os.path.join(args.perf, "16x16")
    if os.path.isdir(p):
        print("\n## Perf before/after\n")
        print(compare(d, p))


if __name__ == "__main__":
    main()
