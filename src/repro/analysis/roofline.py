"""Three-term roofline from dry-run artifacts (TPU v5e targets).

    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s)
    memory     = HLO_bytes   / (chips × 819e9  B/s)
    collective = coll_bytes  / (chips × 50e9   B/s per link)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` of the
PER-DEVICE partitioned module — i.e. already divided by the device count —
so the per-chip terms divide by 1; we keep the formulas in per-chip form and
document it. collective_bytes is parsed from the post-SPMD HLO (per device).
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    bytes_accessed: float
    collective_bytes: float
    model_flops: float
    dram_s: float = 0.0          # analytic DRAM-stream estimate (see
    #                              analytic.cell_bytes — fusion-aware floor)

    @property
    def dominant(self) -> str:
        """Dominant term, judged against the *fused* DRAM floor (dram_s):
        ``memory_s`` (raw HLO bytes) assumes zero fusion and would classify
        every cell memory-bound; XLA:TPU fuses elementwise chains, so the
        floor is the realistic stream count. Both are reported."""
        mem = self.dram_s if self.dram_s > 0 else self.memory_s
        terms = {"compute": self.compute_s, "memory": mem,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        mem = self.dram_s if self.dram_s > 0 else self.memory_s
        return max(self.compute_s, mem, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — how much compiled compute is 'useful'
        (catches remat/redundancy waste). >1 ⇒ compiler fused away work;
        <1 ⇒ remat / overhead."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization *upper bound* implied by the roofline:
        useful FLOPs / (chip peak × bound time)."""
        if self.bound_s == 0:
            return 0.0
        return self.model_flops / (PEAK_FLOPS * self.bound_s)

    def row(self) -> Dict[str, float]:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "useful_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }


def _chips(art: Dict) -> int:
    return 512 if art.get("mesh") == "2x16x16" else 256


def from_artifact(art: Dict, corrected: bool = True) -> Optional[Roofline]:
    """Per-chip roofline from a dry-run JSON artifact.

    ``corrected=True`` replaces the raw cost_analysis FLOPs with the analytic
    per-cell model (divided by chips) when the artifact was NOT compiled with
    unrolled scans — XLA's CPU cost model counts while-loop bodies once
    (§Roofline-methodology). Bytes are scaled by the same factor (weight and
    activation traffic are also per-layer). Unrolled artifacts are exact and
    used verbatim.
    """
    cost = art.get("cost_analysis") or {}
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = float((art.get("collectives") or {})
                 .get("total", {}).get("bytes", 0.0))
    chips = _chips(art)
    model_fl = float(art.get("model_flops", 0.0)) / chips
    dram = 0.0

    if corrected and not art.get("unroll") and art.get("arch"):
        try:
            from repro.analysis import analytic
            from repro.config import cells_for_arch
            from repro.models import model_zoo
            cfg = model_zoo.get_config(art["arch"])
            cell = next(c for c in cells_for_arch(art["arch"])
                        if c.name == art["cell"])
            # FLOPs: analytic (validated vs unrolled HLO; CPU cost model
            # counts loop bodies once). Bytes: keep raw HLO (the prescribed
            # metric) but scale by the loop-repeat factor so per-layer
            # streams are counted L× — for decode (ratio≈1) this is a no-op.
            # Collectives: raw (dominant grad all-reduces sit outside loops).
            ana = analytic.cell_flops(cfg, cell) / chips
            if flops > 0 and ana > flops:
                byts *= ana / flops
            flops = max(ana, flops)
            dram = analytic.cell_bytes(cfg, cell) / chips
        except Exception:       # noqa: BLE001 — fall back to raw numbers
            pass

    return Roofline(
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll / ICI_BW,
        flops=flops,
        bytes_accessed=byts,
        collective_bytes=coll,
        model_flops=model_fl,
        dram_s=dram / HBM_BW,
    )


def load_artifacts(directory: str) -> Dict[str, Dict]:
    out = {}
    if not os.path.isdir(directory):
        return out
    for name in sorted(os.listdir(directory)):
        if name.endswith(".json"):
            with open(os.path.join(directory, name)) as f:
                out[name[:-5]] = json.load(f)
    return out


def table(directory: str) -> str:
    """Markdown roofline table for EXPERIMENTS.md."""
    arts = load_artifacts(directory)
    lines = [
        "| arch × cell | compute (s) | memory (s) | collective (s) | "
        "dominant | useful FLOPs ratio | MFU bound |",
        "|---|---|---|---|---|---|---|",
    ]
    for key, art in arts.items():
        if not art.get("ok"):
            lines.append(f"| {key} | FAILED: {art.get('error','?')[:60]} "
                         "| | | | | |")
            continue
        r = from_artifact(art)
        lines.append(
            f"| {key} | {r.compute_s:.4f} | {r.memory_s:.4f} | "
            f"{r.collective_s:.4f} | **{r.dominant}** | "
            f"{r.useful_flops_ratio:.2f} | {r.mfu_bound:.1%} |")
    return "\n".join(lines)
