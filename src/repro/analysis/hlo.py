"""Post-SPMD HLO parsing: collective ops and their payload bytes.

``compiled.as_text()`` (per-device module after GSPMD partitioning) contains
lines like::

    %all-reduce.5 = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), ...
    %all-gather = bf16[8,128]{...} all-gather(bf16[1,128]{...} %p), ...

We sum OPERAND sizes per collective kind (the data each device injects into
the interconnect), which is the roofline-relevant payload.
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# shape token: dtype[dims]{layout}?  e.g. bf16[8,128]{1,0}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([\d,]*)\]")
# post-optimization HLO prints operands WITHOUT types, so we read the RESULT
# type and convert to operand bytes with the replica-group size:
#   %ag = bf16[8,128]{..} all-gather(%p), ..., replica_groups=[16,16]<=[256]
_LINE_RE = re.compile(
    r"=\s*(\(?[a-z0-9]+\[[\d,]*\][^ ]*\)?[^=]*?)\s+(" +
    "|".join(COLLECTIVES) + r")(-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _result_bytes(result_str: str) -> int:
    return sum(_shape_bytes(m.group(1), m.group(2))
               for m in _SHAPE_RE.finditer(result_str))


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_V1_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """{kind: {"count", "bytes"}} with *operand* bytes per device:
    all-reduce/all-to-all/permute → result size; all-gather → result /
    group; reduce-scatter → result × group."""
    out: Dict[str, Dict[str, float]] = {
        k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        kind, variant = m.group(2), m.group(3)
        if variant == "-done":
            continue
        b = _result_bytes(m.group(1))
        if variant == "-start" and line.count("(") >= 2 and \
                m.group(1).startswith("("):
            b //= 2          # -start results carry (operand, result) tuples
        g = _group_size(line)
        if kind == "all-gather":
            b //= g
        elif kind == "reduce-scatter":
            b *= g
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    out["total"] = {
        "count": sum(v["count"] for v in out.values()),
        "bytes": sum(v["bytes"] for v in out.values()),
    }
    return out


def count_ops(hlo_text: str, names=("fusion", "custom-call", "while",
                                    "dot", "convolution")) -> Dict[str, int]:
    return {n: len(re.findall(rf"\b{n}\(", hlo_text)) for n in names}
