"""Analytic FLOP model per (arch × cell) — the roofline's compute source.

Why analytic: XLA's ``cost_analysis`` on the CPU backend counts a
``while``-loop body ONCE, so scanned-layer models under-report FLOPs by ~L×
(verified by calibration, see EXPERIMENTS.md §Roofline-methodology). The
dry-run therefore records raw cost_analysis (for bytes & structure) and this
model provides total FLOPs; both are cross-validated against fully-unrolled
compiles on selected cells (agreement within ~15%).

Conventions: 1 MAC = 2 FLOPs; causal attention uses the S/2 average context;
train multiplier = 4× forward for the rematerialized stack (fwd + recompute
+ 2× backward) and 3× for embed/head; optimizer adds the GaLore projection
pair (4·m·n·r per matrix) amortized per step.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.config import ModelConfig, ShapeCell


def _attn_flops_per_token(cfg: ModelConfig, ctx: int) -> float:
    """GQA/MLA attention layer, forward, per token with `ctx` average
    context length."""
    d = cfg.d_model
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    if cfg.attention == "mla":
        m = cfg.mla
        qk = m.qk_nope_head_dim + m.qk_rope_head_dim
        proj = (2 * d * m.q_lora_rank + 2 * m.q_lora_rank * H * qk
                + 2 * d * (m.kv_lora_rank + m.qk_rope_head_dim)
                + 2 * m.kv_lora_rank * H * (m.qk_nope_head_dim
                                            + m.v_head_dim)
                + 2 * H * m.v_head_dim * d)
        attn = 2 * ctx * H * qk + 2 * ctx * H * m.v_head_dim
        return proj + attn
    proj = 2 * d * H * hd + 2 * 2 * d * KH * hd + 2 * H * hd * d
    attn = 2 * ctx * H * hd + 2 * ctx * H * hd
    return proj + attn


def _ffn_flops_per_token(d: int, f: int) -> float:
    return 6 * d * f                      # gate + up + down


def _moe_flops_per_token(cfg: ModelConfig) -> float:
    mc = cfg.moe
    d = cfg.d_model
    fl = 2 * d * mc.num_experts                     # router
    fl += mc.top_k * _ffn_flops_per_token(d, mc.expert_ff)
    if mc.num_shared_experts:
        fl += _ffn_flops_per_token(d, mc.expert_ff
                                   * mc.num_shared_experts)
    return fl


def _mamba_flops_per_token(cfg: ModelConfig) -> float:
    sc = cfg.ssm
    d = cfg.d_model
    di = sc.expand * d
    conv_ch = di + 2 * sc.state_dim
    H = di // sc.head_dim
    fl = 2 * d * (di + conv_ch + H)                 # in_proj
    fl += 2 * sc.conv_kernel * conv_ch              # depthwise conv
    # SSD: B x^T (state write) + C h (read) + intra-chunk quadratic
    fl += 2 * 2 * di * sc.state_dim
    fl += 2 * sc.chunk_size * di                    # intra-chunk L matmuls
    fl += 2 * di * d                                # out_proj
    return fl


def _mlstm_flops_per_token(cfg: ModelConfig) -> float:
    xc = cfg.xlstm
    d = cfg.d_model
    inner = int(xc.proj_factor * d)
    fl = 2 * d * 2 * inner                          # up
    fl += 3 * 2 * inner * inner                     # q, k, v
    fl += 2 * xc.chunk_size * inner * 2             # intra-chunk qk / pv
    fl += 2 * inner * inner / max(cfg.num_heads, 1)  # inter-chunk C read
    fl += 2 * inner * d                             # down
    return fl


def _slstm_flops_per_token(cfg: ModelConfig) -> float:
    d = cfg.d_model
    dh = d // cfg.num_heads
    fl = 2 * d * 4 * d                              # input gates
    fl += 4 * 2 * d * dh                            # block-diag recurrent
    fl += _ffn_flops_per_token(d, int(4 * d / 3))
    return fl


def forward_flops_per_token(cfg: ModelConfig, ctx: int) -> float:
    d, L = cfg.d_model, cfg.num_layers
    head = 2 * d * cfg.vocab_size
    if cfg.family in ("dense", "vlm"):
        per = _attn_flops_per_token(cfg, ctx) \
            + _ffn_flops_per_token(d, cfg.d_ff)
        return L * per + head
    if cfg.family == "moe":
        mc = cfg.moe
        n_dense = mc.first_dense_layers
        dense_ff = mc.dense_ff or cfg.d_ff
        per_attn = _attn_flops_per_token(cfg, ctx)
        fl = n_dense * (per_attn + _ffn_flops_per_token(d, dense_ff))
        fl += (L - n_dense) * (per_attn + _moe_flops_per_token(cfg))
        if cfg.mtp_depth:
            fl += per_attn + _ffn_flops_per_token(d, dense_ff) + head
        return fl + head
    if cfg.family == "xlstm":
        every = cfg.xlstm.slstm_every or L
        n_s = L // every
        n_m = L - n_s
        return n_m * _mlstm_flops_per_token(cfg) \
            + n_s * _slstm_flops_per_token(cfg) + head
    if cfg.family == "hybrid":
        hc = cfg.hybrid
        n_sites = L // hc.attn_every
        n_mamba = n_sites * (hc.attn_every - 1)
        site = (2 * 2 * d * d                       # fuse (2d->d)
                + _attn_flops_per_token(cfg, ctx)
                + _ffn_flops_per_token(d, cfg.d_ff)
                + 2 * d * d)                        # site_out
        return n_mamba * _mamba_flops_per_token(cfg) + n_sites * site + head
    if cfg.family == "encdec":
        n_enc = cfg.num_encoder_layers or L
        enc = n_enc * (_attn_flops_per_token(cfg, ctx)
                       + _ffn_flops_per_token(d, cfg.d_ff))
        dec = L * (2 * _attn_flops_per_token(cfg, ctx)
                   + _ffn_flops_per_token(d, cfg.d_ff))
        # enc tokens ≈ 4× dec tokens (DEC_RATIO); normalize per dec token
        return 4 * enc + dec + head
    raise ValueError(cfg.family)


def galore_projection_flops(cfg: ModelConfig, rank: int = 128) -> float:
    """Per-step projection + back-projection over all 2-D stack weights —
    approximated as 4·r·Σ(m·n) ≈ 4·r·N_stack."""
    from repro.models import model_zoo
    n = model_zoo.count_params_analytic(cfg)
    emb = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    return 4.0 * rank * max(n - emb, 0)


def cell_flops(cfg: ModelConfig, cell: ShapeCell, rank: int = 128) -> float:
    """Total FLOPs of one step of this cell (all chips).

    Validated against fully-unrolled HLO compiles: seamless train_4k 0.86×,
    xlstm train_4k 1.10× (EXPERIMENTS.md §Roofline-methodology).
    """
    # enc-dec per-token flops are normalized per DECODER token (4× encoder
    # tokens folded in) — see forward_flops_per_token.
    tok_scale = (1.0 / 4.0) if cfg.family == "encdec" else 1.0
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len * tok_scale
        ctx = cell.seq_len // 2
        fwd = forward_flops_per_token(cfg, ctx)
        head = 2 * cfg.d_model * cfg.vocab_size
        return tokens * (4 * (fwd - head) + 3 * head) \
            + galore_projection_flops(cfg, rank)
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len * tok_scale
        return tokens * forward_flops_per_token(cfg, cell.seq_len // 2)
    # decode: one token per sequence, full context
    return cell.global_batch * forward_flops_per_token(cfg, cell.seq_len)


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """6·N_active·D (train) / 2·N_active·D (inference) — the 'useful' FLOPs."""
    from repro.models import model_zoo
    n = model_zoo.count_active_params(cfg)
    if cell.kind == "train":
        return 6.0 * n * cell.global_batch * cell.seq_len
    if cell.kind == "prefill":
        return 2.0 * n * cell.global_batch * cell.seq_len
    return 2.0 * n * cell.global_batch


# ---------------------------------------------------------------------------
# Analytic HBM-bytes model
# ---------------------------------------------------------------------------

def _kv_cache_bytes(cfg: ModelConfig, batch: int, seq: int,
                    bytes_per: int = 2) -> float:
    L = cfg.num_layers
    if cfg.family in ("xlstm", "hybrid"):
        # recurrent state, O(1) in seq
        if cfg.family == "xlstm":
            inner = int(cfg.xlstm.proj_factor * cfg.d_model)
            dh = inner // cfg.num_heads
            per_layer = batch * cfg.num_heads * dh * dh * 4
            state = L * per_layer
            if cfg.family == "hybrid":
                pass
            return state
        sc = cfg.ssm
        di = sc.expand * cfg.d_model
        H = di // sc.head_dim
        n_sites = L // cfg.hybrid.attn_every
        mamba = (L - n_sites) * batch * H * sc.head_dim * sc.state_dim * 4
        kv = n_sites * 2 * batch * seq * cfg.num_kv_heads \
            * cfg.resolved_head_dim * bytes_per
        return mamba + kv
    if cfg.attention == "mla":
        m = cfg.mla
        return L * batch * seq * (m.kv_lora_rank + m.qk_rope_head_dim) \
            * bytes_per
    return L * 2 * batch * seq * cfg.num_kv_heads \
        * cfg.resolved_head_dim * bytes_per


def cell_bytes(cfg: ModelConfig, cell: ShapeCell, *,
               weight_bytes_per_param: float = 1.0,
               rank: int = 128) -> float:
    """Total HBM bytes of one step (all chips). Counts the dominant streams:

    train   : 3× weights (fwd + recompute + bwd) + 4× low-rank opt states
              + 2× saved layer activations + grads payload
    prefill : 1× active weights + 3× activations + KV-cache write
    decode  : 1× active weights + 2× KV cache (read + update write)
    """
    from repro.models import model_zoo
    n_total = model_zoo.count_params_analytic(cfg)
    n_active = model_zoo.count_active_params(cfg)
    d = cfg.d_model
    B, S = cell.global_batch, cell.seq_len

    if cell.kind == "train":
        w = 3.0 * n_total * weight_bytes_per_param
        opt = 4.0 * (n_total * rank / max(d, rank)) \
            * 1.0                                    # int8 low-rank moments
        acts = 2.0 * cfg.num_layers * B * S * d * 2.0
        grads = 2.0 * n_total * rank / max(d, rank) * 4.0
        return w + opt + acts + grads
    if cell.kind == "prefill":
        w = n_active * weight_bytes_per_param
        acts = 3.0 * cfg.num_layers * B * S * d * 2.0
        return w + acts + _kv_cache_bytes(cfg, B, S)
    # decode
    w = n_active * weight_bytes_per_param
    return w + 2.0 * _kv_cache_bytes(cfg, B, S)
