"""Logical-axis sharding rules: parameter / activation / optimizer-state
PartitionSpecs derived from leaf path names, with divisibility-aware fallback
(a dim is only sharded if the mesh axis divides it — e.g. 8 KV heads on a
16-way model axis fall back to replication, matching Megatron's handling of
narrow GQA).

QTensor leaves: ``q`` gets the weight's spec; ``scale``/``zero`` inherit the
leading-dim specs with the block-group dim sharded only when divisible.

Optimizer state (Q-GaLore): low-rank Adam moments keep the *surviving*
gradient dim (m for right-projection, n for left), so they inherit that dim's
sharding from the parent weight; the INT4 projection P (d, r) inherits the
*projected-away* dim's sharding on d. This keeps the deepseek-671b expert
moments (~27 GB INT8) sharded 16-way rather than replicated.

ZeRO sharding (``opt_state_sharding(..., zero_axes=...)``): on top of the
model-axis rules, the low-rank Adam moments and INT4 projections are
partitioned over the data-parallel axes — each DP rank owns a 1/D slice of
the quantized optimizer state and the slice is gathered (by GSPMD, at the
point of use) only inside the fused update. Dim choice is divisibility-aware
and composes with an existing model-axis sharding when the combined product
still divides the dim; leaves where nothing divides stay as-is (graceful
fallback, mirroring the narrow-GQA rule above). See docs/distributed.md.
"""
from __future__ import annotations

import re
from typing import List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map  # noqa: F401 — canonical re-export: every
# manual-collective entry point (train/step.py dp_compress, moe_ep tests,
# future distributed serving) takes shard_map from here / repro.compat so the
# old-vs-new jax.shard_map signature break stays fixed in ONE place.
from repro.config import QGaLoreConfig
from repro.core import quant
from repro.core.adam8bit import Adam8bitState
from repro.core.quant import QTensor
from repro.core import qgalore
from repro.core.qgalore import LeafSpec

# (regex on normalized path, (row_logical, col_logical)) for the LAST TWO dims
_MATMUL_RULES = [
    (r"(wq|wk|wv|wq_b|wkv_b)$", (None, "tp")),
    (r"wo$", ("tp", None)),
    (r"(wq_a|wkv_a)$", (None, None)),        # MLA down-proj: small, replicate
    (r"(wi|wg|w_up|in_proj|w_gates|fuse|mtp_proj)$", (None, "tp")),
    (r"(wd|w_down|out_proj|site_out)$", ("tp", None)),
    (r"embedding$", (None, "tp")),
    (r"head$", (None, "tp")),
    (r"(router|conv_w|r_gates)$", (None, None)),
    (r"lora_[qo]/(A|B)$", (None, None)),
]


def _norm_path_str(s: str) -> str:
    """keystr-format path string → '/a/b/c'."""
    return "/" + re.sub(r"\['([^']*)'\]", r"\1/", s).rstrip("/") \
        .replace("][", "/").replace("[", "").replace("]", "")


def norm_path(path) -> str:
    """jax key-path → '/a/b/c' string."""
    return _norm_path_str(jax.tree_util.keystr(path))


def logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    if ndim == 0:
        return ()
    axes: List[Optional[str]] = [None] * ndim
    if ndim >= 2:
        for pat, (row, col) in _MATMUL_RULES:
            if re.search(pat, path):
                axes[-2], axes[-1] = row, col
                break
    if "experts_" in path and ndim >= 3:
        axes[-3] = "ep"
    return tuple(axes)


def _mesh_axis(logical: Optional[str], mesh: Mesh) -> Optional[str]:
    if logical in ("tp", "ep"):
        return "model" if "model" in mesh.axis_names else None
    return None


_EP_FULL_MESH = False


def set_ep_full_mesh(value: bool) -> None:
    """Full-mesh expert sharding requires the manual-EP all-to-all MoE path
    (moe_apply_ep inside the dp_compress shard_map): with plain GSPMD it
    degenerates into activation/weight all-gathers (measured — EXPERIMENTS
    §Perf iteration 3). The launcher enables it only alongside that path."""
    global _EP_FULL_MESH
    _EP_FULL_MESH = value


def _ep_axes(dim: int, mesh: Mesh):
    """Expert dim: shard over as much of the mesh as divides it (deepseek's
    256 experts → one per chip on 16×16; kills both replication and the
    expert grad all-reduce), else model only."""
    if _EP_FULL_MESH:
        avail = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)
        for cand in (avail, avail[1:], avail[2:]):
            if not cand:
                break
            total = 1
            for a in cand:
                total *= mesh.shape[a]
            if total > 1 and dim % total == 0 and dim >= total:
                return cand
    if "model" in mesh.axis_names and dim % mesh.shape["model"] == 0:
        return ("model",)
    return None


def spec_for(shape, logical, mesh: Mesh) -> P:
    """Each mesh axis may shard at most one dim: 'ep' (expert) takes
    precedence over 'tp' when both want the model axis (EP supersedes
    intra-matrix TP for expert-stacked weights)."""
    order = sorted(range(len(logical)),
                   key=lambda i: 0 if logical[i] == "ep" else 1)
    used = set()
    parts = [None] * len(logical)
    for i in order:
        dim, log = shape[i], logical[i]
        if log == "ep":
            axes = _ep_axes(dim, mesh)
            if axes and not (set(axes) & used):
                parts[i] = axes if len(axes) > 1 else axes[0]
                used.update(axes)
            continue
        ax = _mesh_axis(log, mesh)
        if ax is not None and ax not in used and dim > 0 \
                and dim % mesh.shape[ax] == 0:
            parts[i] = ax
            used.add(ax)
    return P(*parts)


def _extend_with_zero(spec: P, shape, mesh: Mesh, zero_axes,
                      skip_last: bool = False) -> P:
    """Add DP-axis (ZeRO) partitioning to an existing spec.

    Picks the largest dim that can absorb ``zero_axes`` — either free and
    divisible by their product, or already sharded with the combined product
    still dividing — and appends the zero axes to that dim's sharding. Leaves
    the spec unchanged when nothing divides. ``skip_last`` protects the
    quantized last axis of QTensor inner arrays (codes vs per-block scales
    disagree on its size, so sharding it would desynchronize them).
    """
    if not zero_axes:
        return spec
    ztot = int(np.prod([mesh.shape[a] for a in zero_axes]))
    if ztot <= 1:
        return spec
    parts = list(spec) + [None] * (len(shape) - len(spec))
    ndims = len(shape) - (1 if skip_last else 0)
    for i in sorted(range(ndims), key=lambda j: (-shape[j], j)):
        cur = parts[i]
        cur_t = () if cur is None else (
            (cur,) if isinstance(cur, str) else tuple(cur))
        if set(zero_axes) & set(cur_t):
            continue
        combined = ztot * int(np.prod([mesh.shape[a] for a in cur_t]) or 1)
        if shape[i] > 0 and shape[i] % combined == 0:
            new = cur_t + tuple(zero_axes)
            parts[i] = new if len(new) > 1 else new[0]
            return P(*parts)
    return spec


def _qtensor_sharding(qt: QTensor, logical, mesh: Mesh,
                      zero_axes=()) -> QTensor:
    qspec = _extend_with_zero(spec_for(qt.q.shape, logical, mesh),
                              qt.q.shape, mesh, zero_axes, skip_last=True)
    sspec = _extend_with_zero(spec_for(qt.scale.shape, logical, mesh),
                              qt.scale.shape, mesh, zero_axes,
                              skip_last=True)
    return QTensor(
        NamedSharding(mesh, qspec), NamedSharding(mesh, sspec),
        None if qt.zero is None else NamedSharding(mesh, sspec),
        qt.bits, qt.block, qt.orig_last, qt.dtype)


def param_sharding(params, mesh: Mesh):
    """Pytree of NamedShardings matching ``params`` (QTensor-aware)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=quant.is_qtensor)
    out = []
    for path, leaf in flat:
        pstr = norm_path(path)
        logical = logical_axes(pstr, len(leaf.shape))
        if quant.is_qtensor(leaf):
            out.append(_qtensor_sharding(leaf, logical, mesh))
        else:
            out.append(NamedSharding(mesh, spec_for(leaf.shape, logical,
                                                    mesh)))
    return jax.tree_util.tree_unflatten(treedef, out)


# ---------------------------------------------------------------------------
# Tensor-parallel leaf-spec annotation
# ---------------------------------------------------------------------------

def annotate_tp(specs: List[LeafSpec], mesh: Optional[Mesh]
                ) -> List[LeafSpec]:
    """Stamp each spec with its TP shard annotation (``shard_dim``/``tp``)
    derived from the SAME matmul rules + divisibility / EP-precedence
    checks that place the parameters (:func:`spec_for`) — so the
    annotation can never disagree with the actual weight layout. No-op
    (annotations keep their replicated defaults) without a mesh or with a
    size-1 model axis, keeping DP-only and single-device specs
    bit-identical to the pre-TP contract."""
    if mesh is None or "model" not in mesh.axis_names \
            or mesh.shape["model"] <= 1:
        return specs
    import dataclasses
    tp = int(mesh.shape["model"])
    out = []
    for spec in specs:
        if len(spec.shape) < 2:
            out.append(spec)
            continue
        pstr = _norm_path_str(spec.path)
        parts = spec_for(spec.shape, logical_axes(pstr, len(spec.shape)),
                         mesh)
        shard_dim = None
        for d in (0, 1):
            part = parts[len(spec.shape) - 2 + d] \
                if len(parts) >= len(spec.shape) - 1 + d else None
            names = (part,) if isinstance(part, str) else tuple(part or ())
            if "model" in names:
                shard_dim = d
                break
        if shard_dim is None:
            out.append(spec)
        else:
            out.append(dataclasses.replace(spec, shard_dim=shard_dim,
                                           tp=tp))
    return out


# ---------------------------------------------------------------------------
# Optimizer-state sharding
# ---------------------------------------------------------------------------

def _galore_state_logicals(spec: LeafSpec, logical):
    """(moment_logical, proj_logical) for a galore leaf."""
    lead = logical[:-2]
    row, col = logical[-2], logical[-1]
    m, n = spec.mat_shape
    if spec.side == "right":       # low (…, m, r); P (…, n, r)
        mom = lead + (row, None)
        proj = lead + (col, None)
    else:                          # low (…, r, n); P (…, m, r)
        mom = lead + (None, col)
        proj = lead + (row, None)
    return mom, proj


def _shard_like(leaf, logical, mesh, zero_axes=()):
    if quant.is_qtensor(leaf):
        return _qtensor_sharding(leaf, logical, mesh, zero_axes)
    if leaf is None:
        return None
    spec = _extend_with_zero(spec_for(leaf.shape, logical, mesh),
                             leaf.shape, mesh, zero_axes)
    return NamedSharding(mesh, spec)


def zero_axes_for(mesh: Mesh) -> Tuple[str, ...]:
    """The DP axes a ZeRO-sharded optimizer state partitions over."""
    return batch_axes(mesh)


def opt_state_sharding(params, opt_state, cfg, mesh: Mesh,
                       zero_axes: Tuple[str, ...] = (), specs=None):
    """Sharding pytree for a QGaLoreState aligned with ``params``.

    ``cfg``: QGaLoreConfig or ParamRules — per-leaf galore/rank decisions
    (and therefore moment/projection layouts) resolve through the param
    groups; frozen-group leaves hold no state (None stays None).

    ``zero_axes``: DP mesh axes to additionally partition the Adam moments
    and projection matrices over (ZeRO-style optimizer-state sharding).
    Empty tuple = the pre-existing model-axis-only behavior.

    ``specs``: pre-resolved (possibly rank-overridden) leaf specs; the
    divisibility-aware ZeRO dim choice re-runs against the actual (shrunk)
    state shapes, so a rank transition re-shards cleanly.
    """
    if specs is None:
        specs = qgalore.leaf_specs(params, cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=quant.is_qtensor)
    inner_flat = jax.tree_util.tree_flatten(
        opt_state.inner, is_leaf=qgalore._is_inner_leaf)[0]
    proj_flat = jax.tree_util.tree_flatten(
        opt_state.proj,
        is_leaf=lambda x: quant.is_qtensor(x) or x is None)[0]

    inner_out, proj_out = [], []
    for (path, leaf), spec, inner, proj in zip(flat, specs, inner_flat,
                                               proj_flat):
        pstr = norm_path(path)
        logical = logical_axes(pstr, len(spec.shape))
        if spec.galore:
            mom_log, proj_log = _galore_state_logicals(spec, logical)
        else:
            mom_log, proj_log = logical, None
        inner_out.append(None if inner is None else Adam8bitState(
            _shard_like(inner.m, mom_log, mesh, zero_axes),
            _shard_like(inner.v, mom_log, mesh, zero_axes)))
        proj_out.append(None if proj is None
                        else _shard_like(proj, proj_log, mesh, zero_axes))

    from repro.core.qgalore import QGaLoreState
    return QGaLoreState(
        inner=jax.tree_util.tree_unflatten(treedef, inner_out),
        proj=jax.tree_util.tree_unflatten(treedef, proj_out),
        count=NamedSharding(mesh, P()),
    )


def lowrank_shardings(specs: List[LeafSpec], mesh: Mesh,
                      zero_axes: Tuple[str, ...] = ()):
    """Per-leaf layout hints for LOW-RANK values (projected gradients /
    Adam directions), keyed by ``LeafSpec.path``.

    Each galore leaf gets its MOMENT layout — the surviving weight dim
    model-sharded exactly when the TP placement shards that dim of the
    weight, the rank dim never sharded, optionally ZeRO-extended over
    ``zero_axes``. The transform chain applies these between its stages
    (``shardings=`` on ``chain(...).update``) so a 2-D mesh keeps the
    low-rank flow on the TP layout instead of re-replicating it at every
    stage boundary."""
    out = {}
    for spec in specs:
        if not spec.galore:
            continue
        logical = logical_axes(_norm_path_str(spec.path), len(spec.shape))
        mom_log, _ = _galore_state_logicals(spec, logical)
        pspec = _extend_with_zero(
            spec_for(spec.low_shape, mom_log, mesh), spec.low_shape, mesh,
            zero_axes)
        out[spec.path] = NamedSharding(mesh, pspec)
    return out


def zero2_scatter_dims(opt_sharding, specs: List[LeafSpec],
                       zero_axes: Tuple[str, ...]):
    """{leaf index: low-rank-gradient dim} for the ZeRO-2 gradient
    reduce-scatter (ROADMAP item): for each galore leaf whose ZeRO moment
    shard partitions some dim over EXACTLY the zero (DP) axes, return that
    dim — the steady-state low-rank gradient is then ``psum_scatter``ed
    along it (train/step.py), so each DP rank receives only the reduced
    slice that feeds the moment shard it owns, instead of a replicated
    ``pmean``. Leaves whose moments the ZeRO pass left unsharded (nothing
    divides) are omitted and keep the pmean."""
    if not zero_axes:
        return {}
    inner_flat = jax.tree_util.tree_flatten(
        opt_sharding.inner, is_leaf=qgalore._is_inner_leaf)[0]
    out = {}
    for i, (spec, ish) in enumerate(zip(specs, inner_flat)):
        if not spec.galore or ish is None:
            continue
        m_sh = ish.m.q if quant.is_qtensor(ish.m) else ish.m
        if not isinstance(m_sh, NamedSharding):
            continue
        for d, part in enumerate(m_sh.spec):
            parts = (part,) if isinstance(part, str) else tuple(part or ())
            # the dim carrying the zero axes (it may additionally be
            # model-sharded: the scatter is manual over the DP axes only,
            # GSPMD keeps handling the model factor outside the region)
            if set(zero_axes) <= set(parts) and d < len(spec.low_shape):
                out[i] = d
                break
    return out


# ---------------------------------------------------------------------------
# Batch / activation sharding
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_sharding(batch_specs, mesh: Mesh):
    """Shard every batch input on its leading (batch) dim over pod+data."""
    dp = batch_axes(mesh)
    total = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def one(spec):
        b = spec.shape[0]
        rest = [None] * (len(spec.shape) - 1)
        if dp and b % total == 0:
            return NamedSharding(mesh, P(dp, *rest))
        # fall back to the largest prefix of dp axes that divides b
        for sub in (dp[:1],):
            t = int(np.prod([mesh.shape[a] for a in sub]))
            if b % t == 0:
                return NamedSharding(mesh, P(sub, *rest))
        return NamedSharding(mesh, P(None, *rest))

    return jax.tree_util.tree_map(one, batch_specs)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
