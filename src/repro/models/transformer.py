"""Decoder-only transformer family: dense GQA, MLA, MoE, VLM-backbone.

Covers: llama-* (paper's own), mistral-nemo-12b, qwen3-32b, gemma-7b, yi-9b,
internvl2-2b (vlm), qwen3-moe-30b-a3b, deepseek-v3-671b (MLA + MoE + MTP).
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeCell
from repro.models import attention, layers, moe as moe_lib
from repro.models.base import ModelBundle, SegmentDef
from repro.models.layers import cross_entropy, dense, dense_init, \
    embed_init, ffn_apply, ffn_init, rmsnorm, rmsnorm_init, softcap


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, dtype):
    if cfg.attention == "mla":
        return attention.mla_init(key, cfg, dtype)
    return attention.gqa_init(key, cfg, dtype)


def block_init(key, cfg: ModelConfig, *, moe_layer: bool, d_ff: int,
               dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": rmsnorm_init(cfg.d_model),
        "attn": _attn_init(k1, cfg, dtype),
        "ffn_norm": rmsnorm_init(cfg.d_model),
    }
    if moe_layer:
        p["moe"] = moe_lib.moe_init(k2, cfg, dtype)
    else:
        p["ffn"] = ffn_init(k2, cfg.d_model, d_ff, dtype=dtype)
    return p


def block_apply(lp, carry, ctx, cfg: ModelConfig, *, moe_layer: bool,
                q_chunk: int, dtype, ep_axis=None) -> dict:
    h = carry["h"]
    x = rmsnorm(h, lp["attn_norm"], cfg.rmsnorm_eps)
    if cfg.attention == "mla":
        a = attention.mla_apply(lp["attn"], x, cfg,
                                positions=ctx["positions"],
                                q_chunk=q_chunk, dtype=dtype)
    else:
        a = attention.gqa_apply(lp["attn"], x, cfg,
                                positions=ctx["positions"],
                                q_chunk=q_chunk, dtype=dtype)
    h = h + a
    x = rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps)
    if moe_layer:
        if ep_axis is not None:
            f, aux = moe_lib.moe_apply_ep(lp["moe"], x, cfg,
                                          ep_axis=ep_axis, dtype=dtype)
        else:
            f, aux = moe_lib.moe_apply(lp["moe"], x, cfg, dtype=dtype)
        carry = {**carry, "aux": carry["aux"] + aux}
    else:
        f = ffn_apply(lp["ffn"], x, cfg.ffn_activation, dtype)
    return {**carry, "h": h + f}


def block_prefill(lp, carry, ctx, cfg: ModelConfig, *, moe_layer: bool,
                  q_chunk: int, dtype):
    h = carry["h"]
    x = rmsnorm(h, lp["attn_norm"], cfg.rmsnorm_eps)
    if cfg.attention == "mla":
        a, cache = attention.mla_prefill(lp["attn"], x, cfg,
                                         positions=ctx["positions"],
                                         q_chunk=q_chunk, dtype=dtype)
    else:
        a, cache = attention.gqa_prefill(lp["attn"], x, cfg,
                                         positions=ctx["positions"],
                                         q_chunk=q_chunk, dtype=dtype)
    if "max_len" in ctx:
        # grow the cache to the serving window (time axis = 1)
        pad = ctx["max_len"] - cache[0].shape[1]
        cache = tuple(
            jnp.pad(c, ((0, 0), (0, pad)) + ((0, 0),) * (c.ndim - 2))
            for c in cache)
    h = h + a
    x = rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps)
    if moe_layer:
        f, aux = moe_lib.moe_apply(lp["moe"], x, cfg, dtype=dtype)
        carry = {**carry, "aux": carry["aux"] + aux}
    else:
        f = ffn_apply(lp["ffn"], x, cfg.ffn_activation, dtype)
    return {**carry, "h": h + f}, cache


def block_append(lp, carry, cache, ctx, cfg: ModelConfig, *,
                 q_chunk: int, dtype):
    """Chunk-append (paged / chunked prefill): carry["h"] is a (B, C, D)
    chunk of prompt tokens at absolute ``ctx["positions"]``; the cache
    already holds every earlier position. Dense GQA only — MoE routing
    capacity depends on the tokens routed together (chunking would change
    which tokens drop), and MLA's absorbed decode contracts in a different
    order than its prefill, so neither can promise the chunked==one-shot
    bit-identity this path is gated on (``SegmentDef.append`` stays None
    there)."""
    h = carry["h"]
    x = rmsnorm(h, lp["attn_norm"], cfg.rmsnorm_eps)
    a, cache = attention.gqa_append(lp["attn"], x, cfg, cache=cache,
                                    positions=ctx["positions"],
                                    mask=ctx["chunk_mask"], dtype=dtype)
    h = h + a
    x = rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps)
    f = ffn_apply(lp["ffn"], x, cfg.ffn_activation, dtype)
    return {**carry, "h": h + f}, cache


def block_decode(lp, carry, cache, ctx, cfg: ModelConfig, *,
                 moe_layer: bool, dtype):
    h = carry["h"]                              # (B, 1, D)
    x = rmsnorm(h, lp["attn_norm"], cfg.rmsnorm_eps)
    if cfg.attention == "mla":
        a, cache = attention.mla_decode(lp["attn"], x, cfg, cache=cache,
                                        length=ctx["length"], dtype=dtype)
    else:
        a, cache = attention.gqa_decode(lp["attn"], x, cfg, cache=cache,
                                        length=ctx["length"], dtype=dtype)
    h = h + a
    x = rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps)
    if moe_layer:
        # decode: drop-free capacity (T is just the batch size)
        f, _ = moe_lib.moe_apply(lp["moe"], x, cfg, dtype=dtype,
                                 capacity=x.shape[0] * x.shape[1])
    else:
        f = ffn_apply(lp["ffn"], x, cfg.ffn_activation, dtype)
    return {**carry, "h": h + f}, cache


def _cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype):
    if cfg.attention == "mla":
        return attention.mla_cache_spec(cfg, batch, max_len, dtype)
    return attention.gqa_cache_spec(cfg, batch, max_len, dtype)


# ---------------------------------------------------------------------------
# Bundle assembly
# ---------------------------------------------------------------------------

def _embed_tokens(params, tokens, cfg: ModelConfig, dtype):
    # INT8 tables: per-token row gather + dequant, never the full table
    h = layers.embed_lookup(params["embedding"], tokens, dtype)
    if cfg.name.startswith("gemma"):
        h = h * math.sqrt(cfg.d_model)
    return h


def _head_logits(params, h, cfg: ModelConfig, dtype):
    h = rmsnorm(h, params["final_norm"], cfg.rmsnorm_eps)
    if cfg.tie_embeddings:
        # tied head: h @ W_emb^T — streams the same INT8 blocks transposed
        logits = layers.dense_t(h, params["embedding"], dtype)
    else:
        logits = dense(h, params["head"], dtype)
    return softcap(logits, cfg.logit_softcap)


def build(cfg: ModelConfig, *, q_chunk: int = 1024,
          dtype=jnp.bfloat16, ep_axis=None,
          split_layers: int = 0) -> ModelBundle:
    """Decoder-only LM bundle (dense / moe / vlm families).

    ``ep_axis``: manual mesh axis name for expert-parallel MoE — only valid
    when the TRAIN step runs inside a shard_map over that axis (serving
    paths stay GSPMD-auto).

    ``split_layers``: split the (dense) block stack into two segments after
    the first N layers — ``seg0_dense`` (layers 0..N-1) and ``seg1_dense``
    (the rest). Numerically identical to the single-segment model; it
    exists so param-group rules (``repro.core.rules``) can address layer
    RANGES at leaf granularity — e.g. the fine-tune entrypoint freezes
    ``seg0_`` (early layers) while Q-GaLore trains ``seg1_``. MoE models
    already split at ``first_dense_layers``; combining both is unsupported.
    """
    mc = cfg.moe
    is_vlm = cfg.family == "vlm"
    if split_layers and not (0 < split_layers < cfg.num_layers):
        # a silently-ignored split would leave ONE segment named
        # seg0_dense — and freeze-by-"seg0_" patterns would then freeze
        # every block
        raise ValueError(
            f"split_layers={split_layers} out of range for "
            f"num_layers={cfg.num_layers} (need 0 < split < num_layers)")

    # ---- segment layout ----
    if mc is not None and mc.first_dense_layers:
        if split_layers:
            raise ValueError("split_layers unsupported for MoE models with "
                             "first_dense_layers (already two segments)")
        segs = [("dense", mc.first_dense_layers, False),
                ("moe", cfg.num_layers - mc.first_dense_layers, True)]
    elif mc is not None:
        if split_layers:
            raise ValueError("split_layers unsupported for MoE models")
        segs = [("moe", cfg.num_layers, True)]
    elif split_layers:
        segs = [("dense", split_layers, False),
                ("dense", cfg.num_layers - split_layers, False)]
    else:
        segs = [("dense", cfg.num_layers, False)]

    def init_params(key):
        ks = jax.random.split(key, 8 + len(segs))
        params = {
            "embedding": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(
                ks[1], cfg.d_model, cfg.vocab_size,
                scale=1.0 / math.sqrt(cfg.d_model))
        d_ff_dense = (mc.dense_ff or cfg.d_ff) if mc else cfg.d_ff
        for i, (name, n, is_moe) in enumerate(segs):
            params[f"seg{i}_{name}"] = layers.stacked_init(
                functools.partial(block_init, cfg=cfg, moe_layer=is_moe,
                                  d_ff=(cfg.d_ff if is_moe else d_ff_dense)),
                ks[2 + i], n)
        if cfg.mtp_depth:
            params["mtp_block"] = block_init(
                ks[7], cfg, moe_layer=False,
                d_ff=(mc.dense_ff or cfg.d_ff) if mc else cfg.d_ff)
            params["mtp_norm"] = rmsnorm_init(cfg.d_model)
            params["mtp_proj"] = dense_init(ks[6], 2 * cfg.d_model,
                                            cfg.d_model)
        return params

    def embed(params, batch):
        tokens = batch["tokens"]
        h = _embed_tokens(params, tokens, cfg, dtype)
        if is_vlm and "patch_embeds" in batch:
            h = jnp.concatenate(
                [batch["patch_embeds"].astype(dtype), h], axis=1)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        carry = {"h": h, "aux": jnp.zeros((), jnp.float32)}
        ctx = {"positions": positions}
        return carry, ctx

    segments = tuple(
        SegmentDef(
            name=name, n_layers=n,
            apply=functools.partial(block_apply, cfg=cfg, moe_layer=is_moe,
                                    q_chunk=q_chunk, dtype=dtype,
                                    ep_axis=ep_axis if is_moe else None),
            prefill=functools.partial(block_prefill, cfg=cfg,
                                      moe_layer=is_moe, q_chunk=q_chunk,
                                      dtype=dtype),
            decode=functools.partial(block_decode, cfg=cfg, moe_layer=is_moe,
                                     dtype=dtype),
            append=(functools.partial(block_append, cfg=cfg,
                                      q_chunk=q_chunk, dtype=dtype)
                    if not is_moe and cfg.attention != "mla" else None),
            cache_spec=functools.partial(_cache_spec, cfg),
        )
        for (name, n, is_moe) in segs)

    def head_loss(params, carry, batch):
        h = carry["h"]
        labels = batch["labels"]
        if is_vlm:
            n_img = h.shape[1] - labels.shape[1]
            h = h[:, n_img:]
        logits = _head_logits(params, h, cfg, dtype)
        # next-token prediction: logits[t] predicts labels[t]
        loss, metrics = cross_entropy(logits[:, :-1], labels[:, 1:])
        if cfg.mtp_depth:
            # DeepSeek-style multi-token prediction: one extra block predicts
            # t+2 from [h_t ; emb(label_{t+1})].
            emb_next = _embed_tokens(params, batch["labels"], cfg, dtype)
            hm = jnp.concatenate([carry["h"][:, :-1] if not is_vlm
                                  else h[:, :-1], emb_next[:, 1:]], axis=-1)
            hm = dense(hm, params["mtp_proj"], dtype)
            B, S = hm.shape[:2]
            pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                   (B, S))
            mtp_carry = {"h": hm, "aux": jnp.zeros((), jnp.float32)}
            mtp_carry = block_apply(params["mtp_block"], mtp_carry,
                                    {"positions": pos}, cfg,
                                    moe_layer=False, q_chunk=q_chunk,
                                    dtype=dtype)
            hm = rmsnorm(mtp_carry["h"], params["mtp_norm"], cfg.rmsnorm_eps)
            mtp_logits = _head_logits(params, hm, cfg, dtype)
            mtp_loss, _ = cross_entropy(mtp_logits[:, :-1], labels[:, 2:])
            loss = loss + 0.3 * mtp_loss
            metrics = {**metrics, "mtp_loss": mtp_loss}
        total = loss + carry["aux"]
        return total, {**metrics, "ce_loss": loss, "aux_loss": carry["aux"]}

    def head_logits(params, carry):
        return _head_logits(params, carry["h"][:, -1:], cfg, dtype)

    def input_specs(cell: ShapeCell):
        B, S = cell.global_batch, cell.seq_len
        spec = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
        if is_vlm and cfg.num_prefix_embeddings:
            spec["patch_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_prefix_embeddings, cfg.d_model), dtype)
        return spec

    # Ragged (right-padded) prefill is exact only when rows can't interact:
    # causal attention qualifies, but capacity-limited MoE routing couples
    # rows through the shared expert buffers once T·k exceeds the drop-free
    # threshold (pads of one row can evict valid tokens of another) — MoE
    # bundles therefore keep the one-request-at-a-time unpadded admission.
    return ModelBundle(cfg=cfg, init_params=init_params, embed=embed,
                       segments=segments, head_loss=head_loss,
                       head_logits=head_logits, input_specs=input_specs,
                       ragged_prefill_ok=(mc is None))
