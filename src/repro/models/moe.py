"""Mixture-of-Experts FFN with sort-based capacity dispatch.

TPU-native design: token→expert routing is realized as a *sort + static
scatter* into per-expert buffers of fixed capacity ``C = ceil(T·k/E · cf)``
(static shapes — XLA requirement), followed by a batched expert matmul
``(E, C, d) × (E, d, f)``. Expert-stacked weights shard on ``E`` over the
``model`` axis (expert parallelism); the scatter/gather lowers to
all-to-all-style collectives under GSPMD.

Overflowing tokens (beyond capacity) fall into a garbage slot and contribute
zero — the standard capacity-factor trade-off; a load-balance auxiliary loss
keeps overflow rare.

Supports DeepSeek-style shared experts (always-on dense path added to the
routed output).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, MoEConfig
from repro.models import layers
from repro.models.layers import dense, dense_init


def moe_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    mc: MoEConfig = cfg.moe
    d, f, E = cfg.d_model, mc.expert_ff, mc.num_experts
    ks = jax.random.split(key, 8)
    std = 1.0 / math.sqrt(d)

    def expert_stack(k, din, dout):
        return (jax.random.truncated_normal(
            k, -2.0, 2.0, (E, din, dout), jnp.float32)
            * (1.0 / math.sqrt(din))).astype(dtype)

    p = {
        "router": dense_init(ks[0], d, E, scale=std, dtype=jnp.float32),
        "experts_wi": expert_stack(ks[1], d, f),
        "experts_wg": expert_stack(ks[2], d, f),
        "experts_wd": expert_stack(ks[3], f, d),
    }
    if mc.num_shared_experts:
        fs = f * mc.num_shared_experts
        p["shared"] = layers.ffn_init(ks[4], d, fs, dtype=dtype)
    return p


def _route(router_w, x, mc: MoEConfig):
    """Top-k routing. x (T, d) → (weights (T,k), experts (T,k), aux_loss)."""
    logits = dense(x, router_w, jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    weights, experts = jax.lax.top_k(probs, mc.top_k)
    weights = weights / jnp.maximum(
        weights.sum(-1, keepdims=True), 1e-9)         # renormalize over k
    # Switch-style load-balance loss
    E = logits.shape[-1]
    density = jnp.mean(jax.nn.one_hot(experts[..., 0], E), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * E * mc.router_aux_coef
    return weights.astype(x.dtype), experts, aux


def moe_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
              capacity_factor: float = 1.25,
              capacity: Optional[int] = None,
              dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) → (out (B, S, d), aux_loss scalar).

    ``capacity`` overrides the factor-derived per-expert buffer (decode uses
    ``capacity=T`` — drop-free, exact)."""
    mc: MoEConfig = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mc.num_experts, mc.top_k
    xt = x.reshape(T, d)

    weights, experts, aux = _route(p["router"], xt, mc)

    # --- sort-based dispatch -------------------------------------------------
    if capacity is not None:
        C = capacity
    elif T * k <= 4096:
        C = T          # tiny workloads (tests / decode): drop-free, exact
    else:
        C = max(1, int(math.ceil(T * k / E * capacity_factor)))
    e_flat = experts.reshape(-1)                    # (T*k,)
    tok_flat = jnp.repeat(jnp.arange(T), k)         # (T*k,)
    w_flat = weights.reshape(-1)
    order = jnp.argsort(e_flat)                     # group by expert
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]
    # position within each expert group: index − start-of-group
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    pos_in_group = jnp.arange(T * k) - group_start[e_sorted]
    keep = pos_in_group < C
    slot = jnp.where(keep, e_sorted * C + pos_in_group, E * C)  # garbage slot

    # scatter tokens into (E*C+1, d) buffers
    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].set(xt[tok_sorted].astype(dtype), mode="drop",
                           unique_indices=True)
    expert_in = buf[: E * C].reshape(E, C, d)

    # --- batched expert FFN (INT8 expert stacks stay INT8 per expert) -------
    h = jax.nn.silu(layers.dense_batched(expert_in, p["experts_wg"], dtype)) \
        * layers.dense_batched(expert_in, p["experts_wi"], dtype)
    expert_out = layers.dense_batched(h, p["experts_wd"], dtype)  # (E, C, d)

    # --- combine --------------------------------------------------------------
    out_flat = expert_out.reshape(E * C, d)
    gathered = jnp.where(
        keep[:, None],
        out_flat[jnp.minimum(slot, E * C - 1)], 0.0)          # (T*k, d)
    y = jnp.zeros((T, d), jnp.float32)
    y = y.at[tok_sorted].add(gathered.astype(jnp.float32)
                             * w_sorted[:, None].astype(jnp.float32))
    out = y.astype(dtype)

    if mc.num_shared_experts:
        out = out + layers.ffn_apply(p["shared"], xt,
                                     cfg.ffn_activation, dtype)
    return out.reshape(B, S, d), aux


def moe_apply_ep(p: dict, x: jax.Array, cfg: ModelConfig, *,
                 ep_axis: str, capacity_factor: float = 1.25,
                 dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Expert-parallel MoE for use INSIDE a shard_map whose manual axis
    ``ep_axis`` shards both the batch (tokens) and the expert dim of the
    expert weights (E_loc = E / n_shards per shard).

    Flow per shard: route local tokens against the (replicated) router →
    sort-dispatch into per-expert buffers for ALL experts → ``all_to_all``
    ships each expert's tokens to its owner shard → local expert FFN →
    reverse ``all_to_all`` → weighted combine. Expert grads then live
    entirely on the owner shard (no cross-data reduction at all), and the
    activation payload on the wire is 2 × T·k·d instead of GSPMD's
    weight/activation all-gathers.
    """
    mc: MoEConfig = cfg.moe
    B, S, d = x.shape                       # LOCAL batch
    T = B * S
    E, k = mc.num_experts, mc.top_k
    from repro.compat import axis_size
    n_shards = axis_size(ep_axis)
    assert E % n_shards == 0, (E, n_shards)
    E_loc = E // n_shards
    xt = x.reshape(T, d)

    weights, experts, aux = _route(p["router"], xt, mc)

    if T * k <= 4096:
        C = T
    else:
        C = max(1, int(math.ceil(T * k / E * capacity_factor)))
    e_flat = experts.reshape(-1)
    tok_flat = jnp.repeat(jnp.arange(T), k)
    w_flat = weights.reshape(-1)
    order = jnp.argsort(e_flat)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    w_sorted = w_flat[order]
    group_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    pos_in_group = jnp.arange(T * k) - group_start[e_sorted]
    keep = pos_in_group < C
    slot = jnp.where(keep, e_sorted * C + pos_in_group, E * C)

    buf = jnp.zeros((E * C + 1, d), dtype)
    buf = buf.at[slot].set(xt[tok_sorted].astype(dtype), mode="drop",
                           unique_indices=True)
    send = buf[: E * C].reshape(E, C, d)

    # ---- EP exchange: (E, C, d) → (E_loc, n_shards·C, d) ----
    recv = jax.lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=1,
                              tiled=True)

    # local (E_loc, d, f) expert stacks, consumed in INT8 per expert
    h = jax.nn.silu(layers.dense_batched(recv, p["experts_wg"], dtype)) \
        * layers.dense_batched(recv, p["experts_wi"], dtype)
    expert_out = layers.dense_batched(h, p["experts_wd"], dtype)

    # ---- reverse exchange: back to (E, C, d) on the token-owner shard ----
    back = jax.lax.all_to_all(expert_out, ep_axis, split_axis=1,
                              concat_axis=0, tiled=True)

    out_flat = back.reshape(E * C, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(slot, E * C - 1)], 0.0)
    y = jnp.zeros((T, d), jnp.float32)
    y = y.at[tok_sorted].add(gathered.astype(jnp.float32)
                             * w_sorted[:, None].astype(jnp.float32))
    out = y.astype(dtype)
    if mc.num_shared_experts:
        out = out + layers.ffn_apply(p["shared"], xt,
                                     cfg.ffn_activation, dtype)
    return out.reshape(B, S, d), aux


def moe_ep_sharded(p: dict, x: jax.Array, cfg: ModelConfig, *, mesh,
                   ep_axis: str, capacity_factor: float = 1.25,
                   dtype=jnp.bfloat16) -> Tuple[jax.Array, jax.Array]:
    """Top-level expert-parallel entry: wraps :func:`moe_apply_ep` in a
    ``shard_map`` (via the ``repro.compat`` shim, so it runs on both the
    old ``jax.experimental.shard_map`` and the new ``jax.shard_map`` API)
    manual over ``ep_axis``.

    Expert stacks shard on their leading E dim over ``ep_axis``; router and
    shared-expert weights enter replicated; the token batch shards on dim 0.
    The aux loss is pmeaned over the shards (per-shard top-1 densities).

    The region is manual over ALL mesh axes (``axis_names=None``): the body
    only issues ``ep_axis`` collectives, and the older XLA behind the compat
    shim miscompiles partial-manual subgroups for this program — non-EP
    inputs therefore enter replicated (gathered) over the other axes."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    def inner(pp, xb):
        out, aux = moe_apply_ep(pp, xb, cfg, ep_axis=ep_axis,
                                capacity_factor=capacity_factor, dtype=dtype)
        return out, jax.lax.pmean(aux, ep_axis)

    pspecs = {k: (P(ep_axis) if k.startswith("experts_") else P())
              for k in p}
    f = shard_map(inner, mesh=mesh, axis_names=None,
                  in_specs=(pspecs, P(ep_axis)),
                  out_specs=(P(ep_axis), P()), check_vma=False)
    return f(p, x)


def moe_ref(p: dict, x: jax.Array, cfg: ModelConfig,
            dtype=jnp.float32) -> jax.Array:
    """Oracle: dense per-token loop over experts (no capacity drops).
    Used by tests to validate the sort-based dispatch."""
    mc: MoEConfig = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    weights, experts, _ = _route(p["router"], xt, mc)
    wi = layers.materialize(p["experts_wi"], dtype)
    wg = layers.materialize(p["experts_wg"], dtype)
    wd = layers.materialize(p["experts_wd"], dtype)

    def per_token(xv, ws, es):
        def per_choice(w, e):
            h = jax.nn.silu(xv @ wg[e]) * (xv @ wi[e])
            return w * (h @ wd[e])
        return sum(per_choice(ws[i], es[i]) for i in range(mc.top_k))

    out = jax.vmap(per_token)(xt.astype(dtype), weights.astype(dtype),
                              experts)
    if mc.num_shared_experts:
        out = out + layers.ffn_apply(p["shared"], xt, cfg.ffn_activation,
                                     dtype)
    return out.reshape(B, S, d)
