"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory with recurrent mixing, sequential scan).

mLSTM recurrence (per head, stabilized with running max ``m``):

    C_t = f_t C_{t-1} + i_t v_t k_t^T        n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q̃_t) / max(|n_t·q̃_t|, 1)      q̃ = q / sqrt(d)

with exponential input gate ``i = exp(ĩ)`` and sigmoid-forget in log space.
Training uses a chunkwise form: intra-chunk quadratic attention-like matmuls
plus an inter-chunk ``lax.scan`` over the (C, n, m) state — mirrors the
Mamba2 SSD layout so both lower to MXU-friendly einsums.

sLSTM is inherently sequential (recurrent weights mix the previous hidden
state into the gates) — it runs as a ``lax.scan`` over time, vectorized over
batch/heads, exactly as the architecture demands.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, XLSTMConfig
from repro.models import layers
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# mLSTM core (chunkwise)
# ---------------------------------------------------------------------------

class MLSTMState(NamedTuple):
    C: jax.Array      # (B, H, d, d)
    n: jax.Array      # (B, H, d)
    m: jax.Array      # (B, H) log-stabilizer


def mlstm_chunked(q, k, v, logi, logf, chunk: int,
                  initial: Optional[MLSTMState] = None):
    """q,k,v: (B,S,H,d); logi/logf: (B,S,H). Returns (h, final_state)."""
    B, S, H, d = q.shape
    pad = (-S) % chunk
    if pad:
        z3 = ((0, 0), (0, pad), (0, 0), (0, 0))
        z2 = ((0, 0), (0, pad), (0, 0))
        q, k, v = (jnp.pad(a, z3) for a in (q, k, v))
        logi = jnp.pad(logi, z2, constant_values=-1e30)   # i=0: no update
        logf = jnp.pad(logf, z2)                          # f=1: no decay
    Sp = q.shape[1]
    nc = Sp // chunk
    qc = q.reshape(B, nc, chunk, H, d).astype(jnp.float32) / math.sqrt(d)
    kc = k.reshape(B, nc, chunk, H, d).astype(jnp.float32)
    vc = v.reshape(B, nc, chunk, H, d).astype(jnp.float32)
    li = logi.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)  # (B,H,c,L)
    lf = logf.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)
    b_cum = jnp.cumsum(lf, axis=-1)                           # (B,H,c,L)

    if initial is None:
        C0 = jnp.zeros((B, H, d, d), jnp.float32)
        n0 = jnp.zeros((B, H, d), jnp.float32)
        m0 = jnp.full((B, H), -1e30, jnp.float32)
    else:
        C0, n0, m0 = initial

    # intra-chunk log-decay matrix D[t,s] = b_t − b_s + logi_s  (s ≤ t)
    Dlog = (b_cum[..., :, None] - b_cum[..., None, :]
            + li[..., None, :])                               # (B,H,c,L,L)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    Dlog = jnp.where(tri, Dlog, -jnp.inf)
    m_intra = jnp.max(Dlog, axis=-1)                          # (B,H,c,L)

    # scan over chunks, carrying (C', n', m)
    def body(carry, idx):
        C, n, m_prev = carry
        dl = Dlog[:, :, idx]            # (B,H,L,L)
        bc = b_cum[:, :, idx]           # (B,H,L)
        m_inter = m_prev[..., None] + bc
        m_t = jnp.maximum(m_intra[:, :, idx], m_inter)        # (B,H,L)
        m_t = jnp.maximum(m_t, -1e30)
        dexp = jnp.exp(dl - m_t[..., None])                   # (B,H,L,L)
        qi = qc[:, idx]                                       # (B,L,H,d)
        ki = kc[:, idx]
        vi = vc[:, idx]
        s = jnp.einsum("blhd,bshd->bhls", qi, ki)             # (B,H,L,L)
        numer = jnp.einsum("bhls,bshd->blhd", dexp * s, vi)
        numer = numer + jnp.exp(m_inter - m_t)[..., None].transpose(0, 2, 1, 3) \
            * jnp.einsum("blhd,bhde->blhe", qi, C)
        denom = jnp.einsum("bhls->bhl", dexp * s)
        denom = denom + jnp.exp(m_inter - m_t) \
            * jnp.einsum("blhd,bhd->bhl", qi, n)
        h = numer / jnp.maximum(
            jnp.abs(denom), jnp.exp(-m_t))[..., None].transpose(0, 2, 1, 3)
        # state update to chunk end
        bL = bc[..., -1]                                      # (B,H)
        m_new = jnp.maximum(m_prev + bL,
                            jnp.max(bL[..., None] - bc + li[:, :, idx],
                                    axis=-1))
        decay_in = jnp.exp(bL[..., None] - bc + li[:, :, idx]
                           - m_new[..., None])                # (B,H,L)
        C_new = jnp.exp(m_prev + bL - m_new)[..., None, None] * C + \
            jnp.einsum("bhl,blhd,blhe->bhde", decay_in, ki, vi)
        n_new = jnp.exp(m_prev + bL - m_new)[..., None] * n + \
            jnp.einsum("bhl,blhd->bhd", decay_in, ki)
        return (C_new, n_new, m_new), h

    (Cf, nf, mf), hs = jax.lax.scan(body, (C0, n0, m0), jnp.arange(nc))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, Sp, H, d)
    if pad:
        h = h[:, :S]
    return h.astype(q.dtype), MLSTMState(Cf, nf, mf)


def mlstm_step(state: MLSTMState, q, k, v, logi, logf):
    """One decode step. q,k,v (B,H,d); logi/logf (B,H)."""
    C, n, m_prev = state
    d = q.shape[-1]
    qf = q.astype(jnp.float32) / math.sqrt(d)
    m_t = jnp.maximum(logf + m_prev, logi)
    f_ = jnp.exp(logf + m_prev - m_t)
    i_ = jnp.exp(logi - m_t)
    C_new = f_[..., None, None] * C + \
        i_[..., None, None] * jnp.einsum("bhd,bhe->bhde",
                                         k.astype(jnp.float32),
                                         v.astype(jnp.float32))
    n_new = f_[..., None] * n + i_[..., None] * k.astype(jnp.float32)
    numer = jnp.einsum("bhd,bhde->bhe", qf, C_new)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n_new)),
                        jnp.exp(-m_t))
    return (numer / denom[..., None]).astype(q.dtype), \
        MLSTMState(C_new, n_new, m_t)


def mlstm_reference(q, k, v, logi, logf, initial=None):
    """Sequential oracle."""
    B, S, H, d = q.shape
    state = initial or MLSTMState(
        jnp.zeros((B, H, d, d)), jnp.zeros((B, H, d)),
        jnp.full((B, H), -1e30))
    hs = []
    for t in range(S):
        h, state = mlstm_step(state, q[:, t], k[:, t], v[:, t],
                              logi[:, t], logf[:, t])
        hs.append(h)
    return jnp.stack(hs, 1), state


# ---------------------------------------------------------------------------
# sLSTM core (sequential)
# ---------------------------------------------------------------------------

class SLSTMState(NamedTuple):
    c: jax.Array      # (B, D)
    n: jax.Array      # (B, D)
    h: jax.Array      # (B, D)
    m: jax.Array      # (B, D)


def slstm_scan(gates_x, R, state: SLSTMState, num_heads: int):
    """gates_x: (B,S,4D) pre-activations from the input; R: (4, H, dh, dh)
    block-diagonal recurrent weights. Order: [i, f, z, o]."""
    B, S, D4 = gates_x.shape
    D = D4 // 4
    dh = D // num_heads

    def step(st, gx):
        c, n, h, m = st
        hh = h.reshape(B, num_heads, dh)
        rec = jnp.stack([
            jnp.einsum("bhd,hde->bhe", hh, R[g]).reshape(B, D)
            for g in range(4)], axis=-1)                      # (B,D,4)
        g = gx.reshape(B, D, 4) + rec
        it, ft, zt, ot = g[..., 0], g[..., 1], g[..., 2], g[..., 3]
        m_new = jnp.maximum(ft + m, it)
        i_ = jnp.exp(it - m_new)
        f_ = jnp.exp(ft + m - m_new)
        z = jnp.tanh(zt)
        o = jax.nn.sigmoid(ot)
        c_new = f_ * c + i_ * z
        n_new = f_ * n + i_
        h_new = o * c_new / jnp.maximum(n_new, 1.0)
        return SLSTMState(c_new, n_new, h_new, m_new), h_new

    gx_seq = gates_x.astype(jnp.float32).reshape(B, S, D, 4) \
        .transpose(1, 0, 2, 3).reshape(S, B, D * 4)
    final, hs = jax.lax.scan(step, state, gx_seq)
    return hs.transpose(1, 0, 2), final                       # (B,S,D)


def slstm_init_state(batch: int, d: int) -> SLSTMState:
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((batch, d), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

class XLSTMCache(NamedTuple):
    kind: int                 # 0 = mLSTM, 1 = sLSTM (static via pytree aux)
    mlstm: MLSTMState
    slstm: SLSTMState


def mlstm_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    xc: XLSTMConfig = cfg.xlstm
    d = cfg.d_model
    inner = int(xc.proj_factor * d)
    H = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "norm": rmsnorm_init(d),
        "w_up": dense_init(ks[0], d, 2 * inner, dtype=dtype),
        "wq": dense_init(ks[1], inner, inner, dtype=dtype),
        "wk": dense_init(ks[2], inner, inner, dtype=dtype),
        "wv": dense_init(ks[3], inner, inner, dtype=dtype),
        "w_gates": dense_init(ks[4], inner, 2 * H, scale=0.02, dtype=dtype),
        "gate_bias": jnp.concatenate(
            [jnp.zeros((H,)), jnp.linspace(3.0, 6.0, H)]).astype(jnp.float32),
        "out_norm": rmsnorm_init(inner),
        "w_down": dense_init(ks[5], inner, d,
                             scale=1.0 / math.sqrt(inner), dtype=dtype),
    }


def _mlstm_qkvg(p, u, cfg, dtype):
    xc = cfg.xlstm
    B, S, inner = u.shape
    H = cfg.num_heads
    dh = inner // H
    q = dense(u, p["wq"], dtype).reshape(B, S, H, dh)
    k = dense(u, p["wk"], dtype).reshape(B, S, H, dh)
    v = dense(u, p["wv"], dtype).reshape(B, S, H, dh)
    gates = dense(u, p["w_gates"], jnp.float32) \
        + layers.materialize(p["gate_bias"], jnp.float32)
    logi = gates[..., :H]
    logf = jax.nn.log_sigmoid(gates[..., H:])
    return q, k, v, logi, logf


def mlstm_block_apply(p, x, cfg: ModelConfig, *, dtype=jnp.bfloat16,
                      cache: Optional[MLSTMState] = None,
                      return_cache: bool = False):
    xc = cfg.xlstm
    B, S, d = x.shape
    u = dense(rmsnorm(x, p["norm"], cfg.rmsnorm_eps), p["w_up"], dtype)
    inner = u.shape[-1] // 2
    u_m, u_g = u[..., :inner], u[..., inner:]
    q, k, v, logi, logf = _mlstm_qkvg(p, u_m, cfg, dtype)
    h, final = mlstm_chunked(q, k, v, logi, logf,
                             chunk=min(xc.chunk_size, max(S, 2)),
                             initial=cache)
    h = h.reshape(B, S, inner)
    h = rmsnorm(h, p["out_norm"], cfg.rmsnorm_eps) * jax.nn.silu(u_g)
    out = x + dense(h, p["w_down"], dtype)
    if return_cache:
        return out, final
    return out


def mlstm_block_decode(p, x, cfg: ModelConfig, *, cache: MLSTMState,
                       dtype=jnp.bfloat16):
    B = x.shape[0]
    u = dense(rmsnorm(x, p["norm"], cfg.rmsnorm_eps), p["w_up"], dtype)
    inner = u.shape[-1] // 2
    u_m, u_g = u[..., :inner], u[..., inner:]
    q, k, v, logi, logf = _mlstm_qkvg(p, u_m, cfg, dtype)
    h, new_state = mlstm_step(cache, q[:, 0], k[:, 0], v[:, 0],
                              logi[:, 0], logf[:, 0])
    h = h.reshape(B, 1, inner)
    h = rmsnorm(h, p["out_norm"], cfg.rmsnorm_eps) * jax.nn.silu(u_g)
    return x + dense(h, p["w_down"], dtype), new_state


def slstm_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    ks = jax.random.split(key, 6)
    f_ff = int(4 * d / 3 / 64) * 64 or 4 * d // 3
    return {
        "norm": rmsnorm_init(d),
        "w_gates": dense_init(ks[0], d, 4 * d, dtype=dtype),
        "r_gates": (jax.random.normal(ks[1], (4, H, dh, dh), jnp.float32)
                    / math.sqrt(dh)).astype(jnp.float32),
        "gate_bias": jnp.zeros((4 * d,), jnp.float32),
        "out_norm": rmsnorm_init(d),
        "ffn": layers.ffn_init(ks[2], d, f_ff, dtype=dtype),
        "ffn_norm": rmsnorm_init(d),
    }


def slstm_block_apply(p, x, cfg: ModelConfig, *, dtype=jnp.bfloat16,
                      cache: Optional[SLSTMState] = None,
                      return_cache: bool = False):
    B, S, d = x.shape
    u = rmsnorm(x, p["norm"], cfg.rmsnorm_eps)
    gx = dense(u, p["w_gates"], jnp.float32) \
        + layers.materialize(p["gate_bias"], jnp.float32)
    st = cache if cache is not None else slstm_init_state(B, d)
    hs, final = slstm_scan(gx, layers.materialize(p["r_gates"],
                                                  jnp.float32),
                           st, cfg.num_heads)
    h = rmsnorm(hs.astype(dtype), p["out_norm"], cfg.rmsnorm_eps)
    y = x + h
    y = y + layers.ffn_apply(p["ffn"],
                             rmsnorm(y, p["ffn_norm"], cfg.rmsnorm_eps),
                             cfg.ffn_activation, dtype)
    if return_cache:
        return y, final
    return y


def slstm_block_decode(p, x, cfg: ModelConfig, *, cache: SLSTMState,
                       dtype=jnp.bfloat16):
    y, final = slstm_block_apply(x=x, p=p, cfg=cfg, dtype=dtype, cache=cache,
                                 return_cache=True)
    return y, final


def is_slstm_layer(layer_idx: int, cfg: ModelConfig) -> bool:
    xc = cfg.xlstm
    return xc.slstm_every > 0 and (layer_idx + 1) % xc.slstm_every == 0


def mlstm_cache_spec(cfg: ModelConfig, batch: int):
    inner = int(cfg.xlstm.proj_factor * cfg.d_model)
    H = cfg.num_heads
    dh = inner // H
    return MLSTMState(
        jax.ShapeDtypeStruct((batch, H, dh, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, H, dh), jnp.float32),
        jax.ShapeDtypeStruct((batch, H), jnp.float32))


def slstm_cache_spec(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return SLSTMState(*[jax.ShapeDtypeStruct((batch, d), jnp.float32)] * 4)
