"""The model contract consumed by training, serving, and the dry-run.

Every architecture is expressed as::

    embed → [segment_0 | segment_1 | ...] → head

where each segment is a homogeneous stack of blocks scanned over a leading
layer axis (params leaves are stacked ``(L, ...)``). This single contract
powers three executions:

* the **simple path** (``loss_fn``): plain ``lax.scan`` + ``jax.grad``;
* the **fused projected-backward path** (``repro.train.stack``): a manual
  forward/backward scan pair that projects each layer's weight gradient into
  the GaLore subspace *inside* the backward scan — the JAX-native analogue of
  the paper's fused backward (full-rank grads never co-reside);
* **serving** (``repro.serve``): per-segment prefill/decode with stacked
  caches.

All three executions consume INT8 (``QTensor``) weights natively: layer
params flow into the blocks quantized (serving) or virtualized
(``QVirtual``, training), and every matmul inside a block streams the
INT8 representation through ``quantized_dense`` — see
``repro.models.layers`` and ``docs/kernels.md``.

``carry`` is a dict with at least ``h`` (hidden states) and ``aux``
(accumulated auxiliary losses, e.g. MoE load-balance); architectures may add
extras (``x0`` for Zamba's shared-block input, ``memory`` for enc-dec).
``ctx`` is a read-only pytree shared by all layers of all segments (positions,
shared-block params, …) built by ``embed``.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig


def scan_layers(body, init, xs, *, reverse: bool = False, length=None):
    """lax.scan over a LAYER axis, honoring REPRO_SCAN_UNROLL.

    XLA's cost_analysis counts a while-loop body once, so the dry-run cost
    pass sets REPRO_SCAN_UNROLL=full to unroll layer scans (exact FLOP /
    collective accounting). Time-step scans (sLSTM, decode loops) must NOT
    use this helper.
    """
    unroll = os.environ.get("REPRO_SCAN_UNROLL", "1")
    if unroll == "full":
        n = length
        if n is None:
            n = jax.tree_util.tree_leaves(xs)[0].shape[0]
        u: Any = max(int(n), 1)
    else:
        u = int(unroll)
    return jax.lax.scan(body, init, xs, reverse=reverse, unroll=u,
                        length=length)


def stack_to_batch_major(tree):
    """(n, B, ...) leaves → (B, n, ...): models whose per-layer cache
    nests an INNER block stack (xLSTM superblocks, Zamba mamba runs) use
    this at the prefill/decode boundary so every cache leaf still leads
    with the batch axis — the ``SegmentDef.cache_spec`` contract the
    serving slot pool and shard rules rely on."""
    return jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 0, 1), tree)


def stack_to_layer_major(tree):
    """Inverse of :func:`stack_to_batch_major` — back to scan layout."""
    return jax.tree_util.tree_map(lambda a: jnp.moveaxis(a, 1, 0), tree)


@dataclass(frozen=True)
class SegmentDef:
    name: str
    n_layers: int
    # (layer_params, carry, ctx) -> carry
    apply: Callable
    # (layer_params, carry, cache_slice, ctx) -> (carry, cache_slice)
    decode: Optional[Callable] = None
    # (layer_params, carry, ctx) -> (carry, cache_slice)   [prefill]
    prefill: Optional[Callable] = None
    # chunk-append (paged/chunked prefill): (layer_params, carry,
    # cache_slice, ctx) -> (carry, cache_slice), where carry["h"] holds a
    # CHUNK of C tokens starting at per-row position ctx["length"] and the
    # cache already contains the first ctx["length"] positions. ctx carries
    # "positions" (B, C) absolute, "chunk_mask" (B, C) valid-token mask
    # (padded tail positions must write NOTHING into the cache). Appending
    # a prompt chunk-by-chunk must be bit-identical to one-shot prefill —
    # the contract the paged serving runtime (repro.serve.paged) asserts.
    # Only row-independent attention segments can offer this (None for
    # recurrent / capacity-routed MoE / MLA-absorbed blocks).
    append: Optional[Callable] = None
    # (batch, max_len, dtype) -> per-layer cache spec pytree.
    # CONTRACT: every leaf leads with the batch axis (recurrent states
    # included), so stacked caches are (n_layers, batch, ...). The serving
    # runtime relies on this: the continuous-batching cache pool
    # (repro.serve.scheduler) treats dim 1 as the SLOT axis — per-slot
    # reset/insert is a dynamic_update_slice there — and the shard rules
    # (repro.serve.shard) put that axis on the data mesh.
    cache_spec: Optional[Callable] = None
    # optional carry transformation applied before this segment's scan
    pre: Optional[Callable] = None          # (params, carry, ctx) -> carry


@dataclass(frozen=True)
class ModelBundle:
    cfg: ModelConfig
    init_params: Callable                    # key -> params dict
    embed: Callable                          # (params, batch) -> (carry, ctx)
    segments: Tuple[SegmentDef, ...]
    head_loss: Callable                      # (params, carry, batch) -> (loss, metrics)
    head_logits: Callable                    # (params, carry) -> logits (last pos)
    input_specs: Callable                    # (cell) -> batch pytree of SDS
    # decode-time embedding: (params, tokens (B,1), extras) -> (carry, ctx).
    # None ⇒ derive from `embed` with a token-only batch (decoder-only LMs).
    embed_decode: Optional[Callable] = None
    # names of carry entries that must persist across decode steps (e.g.
    # the encoder "memory") — captured at prefill, fed back at decode.
    decode_extras: Tuple[str, ...] = ()
    # True ⇔ right-padded (ragged) prompt batches prefill exactly, given
    # per-row lengths: causal attention never lets valid positions see the
    # trailing pads. Recurrent families (SSM/xLSTM/Zamba) fold EVERY input
    # position into their state, so they must keep this False — the
    # serving scheduler then prefills each request unpadded.
    ragged_prefill_ok: bool = False

    def seg_key(self, i: int) -> str:
        return f"seg{i}_{self.segments[i].name}"


def run_segments(bundle: ModelBundle, params, carry, ctx, *,
                 remat: str = "none"):
    """The simple full-sequence forward over all segments."""
    for i, seg in enumerate(bundle.segments):
        if seg.pre is not None:
            carry = seg.pre(params, carry, ctx)
        body = lambda c, lp, _seg=seg: (_seg.apply(lp, c, ctx), None)
        if remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.checkpoint_dots,
                prevent_cse=False)
        carry, _ = scan_layers(body, carry, params[bundle.seg_key(i)])
    return carry


def loss_fn(bundle: ModelBundle, params, batch, *, remat: str = "none"):
    """Simple-path training loss (used by baselines, tests, and as the
    oracle for the fused path)."""
    carry, ctx = bundle.embed(params, batch)
    carry = run_segments(bundle, params, carry, ctx, remat=remat)
    return bundle.head_loss(params, carry, batch)


def count_params(bundle: ModelBundle) -> int:
    """Parameter count without allocation (eval_shape)."""
    shapes = jax.eval_shape(bundle.init_params, jax.random.PRNGKey(0))
    total = 0
    for leaf in jax.tree_util.tree_leaves(shapes):
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
    return total
