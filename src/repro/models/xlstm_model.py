"""xLSTM language model bundle: superblocks of (slstm_every−1) mLSTM blocks
followed by one sLSTM block (paper's xLSTM[a:b] notation)."""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeCell
from repro.models import layers, xlstm
from repro.models.base import ModelBundle, SegmentDef
from repro.models.layers import cross_entropy, dense, dense_init, \
    embed_init, rmsnorm, rmsnorm_init


class XGroupCache(NamedTuple):
    mlstm: Any          # stacked MLSTMState, BATCH-major leaves (B, n_m, …)
    slstm: xlstm.SLSTMState


def group_init(key, cfg: ModelConfig, n_m: int, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "mlstm": layers.stacked_init(
            functools.partial(xlstm.mlstm_block_init, cfg=cfg, dtype=dtype),
            k1, n_m),
        "slstm": xlstm.slstm_block_init(k2, cfg, dtype),
    }


def group_apply(lp, carry, ctx, cfg: ModelConfig, *, dtype):
    h = carry["h"]

    def body(hc, mp):
        return xlstm.mlstm_block_apply(mp, hc, cfg, dtype=dtype), None

    from repro.models.base import scan_layers
    h, _ = scan_layers(body, h, lp["mlstm"])
    h = xlstm.slstm_block_apply(lp["slstm"], h, cfg, dtype=dtype)
    return {**carry, "h": h}


def group_prefill(lp, carry, ctx, cfg: ModelConfig, *, dtype):
    h = carry["h"]

    def body(hc, mp):
        out, state = xlstm.mlstm_block_apply(mp, hc, cfg, dtype=dtype,
                                             return_cache=True)
        return out, state

    from repro.models.base import scan_layers, stack_to_batch_major
    h, mstates = scan_layers(body, h, lp["mlstm"])
    h, sstate = xlstm.slstm_block_apply(lp["slstm"], h, cfg, dtype=dtype,
                                        return_cache=True)
    return {**carry, "h": h}, \
        XGroupCache(stack_to_batch_major(mstates), sstate)


def group_decode(lp, carry, cache: XGroupCache, ctx, cfg: ModelConfig, *,
                 dtype):
    h = carry["h"]

    def body(hc, inp):
        mp, st = inp
        out, new = xlstm.mlstm_block_decode(mp, hc, cfg, cache=st,
                                            dtype=dtype)
        return out, new

    from repro.models.base import scan_layers, stack_to_batch_major, \
        stack_to_layer_major
    h, new_m = scan_layers(
        body, h, (lp["mlstm"], stack_to_layer_major(cache.mlstm)))
    h, new_s = xlstm.slstm_block_decode(lp["slstm"], h, cfg,
                                        cache=cache.slstm, dtype=dtype)
    return {**carry, "h": h}, \
        XGroupCache(stack_to_batch_major(new_m), new_s)


def build(cfg: ModelConfig, *, q_chunk: int = 1024,
          dtype=jnp.bfloat16) -> ModelBundle:
    xc = cfg.xlstm
    every = xc.slstm_every or cfg.num_layers
    n_groups = max(cfg.num_layers // every, 1)
    n_m = every - 1

    def init_params(key):
        ks = jax.random.split(key, 4)
        return {
            "embedding": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "seg0_xlstm": layers.stacked_init(
                functools.partial(group_init, cfg=cfg, n_m=n_m),
                ks[1], n_groups),
            "final_norm": rmsnorm_init(cfg.d_model),
            "head": dense_init(ks[2], cfg.d_model, cfg.vocab_size,
                               scale=1.0 / math.sqrt(cfg.d_model)),
        }

    def embed(params, batch):
        h = layers.embed_lookup(params["embedding"], batch["tokens"], dtype)
        carry = {"h": h, "aux": jnp.zeros((), jnp.float32)}
        return carry, {}

    def cache_spec(batch, max_len, cdtype):
        mspec = xlstm.mlstm_cache_spec(cfg, batch)
        # inner mlstm stack sits AFTER the batch axis (batch-major cache)
        mstack = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                (s.shape[0], n_m) + s.shape[1:], s.dtype), mspec)
        return XGroupCache(mstack, xlstm.slstm_cache_spec(cfg, batch))

    segments = (SegmentDef(
        name="xlstm", n_layers=n_groups,
        apply=functools.partial(group_apply, cfg=cfg, dtype=dtype),
        prefill=functools.partial(group_prefill, cfg=cfg, dtype=dtype),
        decode=functools.partial(group_decode, cfg=cfg, dtype=dtype),
        cache_spec=cache_spec,
    ),)

    def head_loss(params, carry, batch):
        h = rmsnorm(carry["h"], params["final_norm"], cfg.rmsnorm_eps)
        logits = dense(h, params["head"], dtype)
        loss, metrics = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return loss, {**metrics, "ce_loss": loss}

    def head_logits(params, carry):
        h = rmsnorm(carry["h"][:, -1:], params["final_norm"],
                    cfg.rmsnorm_eps)
        return dense(h, params["head"], dtype)

    def input_specs(cell: ShapeCell):
        B, S = cell.global_batch, cell.seq_len
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    return ModelBundle(cfg=cfg, init_params=init_params, embed=embed,
                       segments=segments, head_loss=head_loss,
                       head_logits=head_logits, input_specs=input_specs)
