"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block applied
every ``attn_every`` layers with per-site LoRA adapters.

Layout: ``num_layers`` Mamba2 blocks grouped into superblocks of
``attn_every``; each superblock ends with one invocation of the shared
attention+FFN block on ``concat(h, x0)`` (x0 = the original embedding, the
Zamba "global residual"). Shared weights live once in the params tree and are
threaded to every site through ``ctx``; per-site LoRA A/B pairs are stacked
per superblock — exactly matching the weight-sharing structure, so GaLore
assigns the shared matrices a single gradient subspace.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeCell
from repro.models import attention, layers, ssm
from repro.models.base import ModelBundle, SegmentDef
from repro.models.layers import cross_entropy, dense, dense_init, \
    embed_init, ffn_apply, ffn_init, rmsnorm, rmsnorm_init


def _lora_init(key, in_dim, out_dim, rank):
    ka, kb = jax.random.split(key)
    return {
        "A": (jax.random.normal(ka, (in_dim, rank), jnp.float32)
              / math.sqrt(in_dim)),
        "B": jnp.zeros((rank, out_dim), jnp.float32),
    }


def _lora_apply(p, x, dtype):
    # dense handles quantized adapters (rank >= the predicate floor)
    # through the INT8-native compute path
    return dense(dense(x, p["A"], dtype), p["B"], dtype)


def shared_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    """The single shared attention+FFN block (operates on 2·d → d)."""
    ks = jax.random.split(key, 4)
    return {
        "fuse": dense_init(ks[0], 2 * cfg.d_model, cfg.d_model, dtype=dtype),
        "norm": rmsnorm_init(2 * cfg.d_model),
        "attn": attention.gqa_init(ks[1], cfg, dtype),
        "ffn_norm": rmsnorm_init(cfg.d_model),
        "ffn": ffn_init(ks[2], cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def superblock_init(key, cfg: ModelConfig, n_mamba: int,
                    dtype=jnp.float32) -> dict:
    hc = cfg.hybrid
    ks = jax.random.split(key, 4)
    H, hd = cfg.num_heads, cfg.resolved_head_dim
    p = {
        "mamba_norms": jnp.zeros((n_mamba, cfg.d_model), jnp.float32),
        "mamba": layers.stacked_init(
            functools.partial(ssm.mamba2_init, cfg=cfg, dtype=dtype),
            ks[0], n_mamba),
        # per-site LoRA on the shared block's q and o projections
        "lora_q": _lora_init(ks[1], cfg.d_model, H * hd,
                             hc.shared_lora_rank),
        "lora_o": _lora_init(ks[2], H * hd, cfg.d_model,
                             hc.shared_lora_rank),
        "site_out": dense_init(ks[3], cfg.d_model, cfg.d_model,
                               scale=0.02, dtype=dtype),
    }
    return p


def _shared_site_apply(shared, lp, h, x0, positions, cfg: ModelConfig,
                       dtype, q_chunk):
    """One invocation of the shared block with this site's LoRA."""
    u = jnp.concatenate([h, x0], axis=-1)
    u = rmsnorm(u, shared["norm"], cfg.rmsnorm_eps)
    u = dense(u, shared["fuse"], dtype)
    # attention with LoRA-augmented q / o
    B, S, _ = u.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ap = shared["attn"]
    q = (dense(u, ap["wq"], dtype)
         + _lora_apply(lp["lora_q"], u, dtype)).reshape(B, S, H, hd)
    k = dense(u, ap["wk"], dtype).reshape(B, S, KH, hd)
    v = dense(u, ap["wv"], dtype).reshape(B, S, KH, hd)
    sin, cos = layers.rope_angles(positions, hd, cfg.rope_theta)
    q = layers.apply_rope(q, sin, cos)
    k = layers.apply_rope(k, sin, cos)
    o = attention.chunked_attention(q, k, v, causal=True, q_chunk=q_chunk)
    o = o.reshape(B, S, H * hd)
    a = dense(o, ap["wo"], dtype) + _lora_apply(lp["lora_o"], o, dtype)
    u = u + a
    f = ffn_apply(shared["ffn"],
                  rmsnorm(u, shared["ffn_norm"], cfg.rmsnorm_eps),
                  cfg.ffn_activation, dtype)
    return dense(u + f, lp["site_out"], dtype), (k, v)


def superblock_apply(lp, carry, ctx, cfg: ModelConfig, *, dtype, q_chunk):
    h = carry["h"]

    def mamba_body(hc, inp):
        norm_w, mp = inp
        return hc + ssm.mamba2_apply(
            mp, rmsnorm(hc, norm_w, cfg.rmsnorm_eps), cfg, dtype=dtype), None

    from repro.models.base import scan_layers
    h, _ = scan_layers(mamba_body, h, (lp["mamba_norms"], lp["mamba"]))
    site, _ = _shared_site_apply(ctx["shared"], lp, h, carry["x0"],
                                 ctx["positions"], cfg, dtype, q_chunk)
    return {**carry, "h": h + site}


class ZambaCache(NamedTuple):
    mamba: Any          # stacked Mamba2Cache, BATCH-major (B, n_mamba, …)
    kv: Tuple[jax.Array, jax.Array]


def superblock_prefill(lp, carry, ctx, cfg: ModelConfig, *, dtype, q_chunk):
    h = carry["h"]

    def mamba_body(hc, inp):
        norm_w, mp = inp
        out, cache = ssm.mamba2_apply(
            mp, rmsnorm(hc, norm_w, cfg.rmsnorm_eps), cfg, dtype=dtype,
            return_cache=True)
        return hc + out, cache

    from repro.models.base import scan_layers, stack_to_batch_major
    h, mcaches = scan_layers(mamba_body, h,
                             (lp["mamba_norms"], lp["mamba"]))
    mcaches = stack_to_batch_major(mcaches)
    site, kv = _shared_site_apply(ctx["shared"], lp, h, carry["x0"],
                                  ctx["positions"], cfg, dtype, q_chunk)
    # pad kv caches to max_len
    max_len = ctx["max_len"]
    k, v = kv
    pad = max_len - k.shape[1]
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {**carry, "h": h + site}, ZambaCache(mcaches, (k, v))


def superblock_decode(lp, carry, cache: ZambaCache, ctx,
                      cfg: ModelConfig, *, dtype):
    h = carry["h"]

    def mamba_body(hc, inp):
        norm_w, mp, mcache = inp
        out, new_cache = ssm.mamba2_decode(
            mp, rmsnorm(hc, norm_w, cfg.rmsnorm_eps), cfg, cache=mcache,
            dtype=dtype)
        return hc + out, new_cache

    from repro.models.base import scan_layers, stack_to_batch_major, \
        stack_to_layer_major
    h, new_mcaches = scan_layers(
        mamba_body, h, (lp["mamba_norms"], lp["mamba"],
                        stack_to_layer_major(cache.mamba)))
    new_mcaches = stack_to_batch_major(new_mcaches)

    # shared attention site, decode form
    shared = ctx["shared"]
    length = ctx["length"]
    u = jnp.concatenate([h, carry["x0"]], axis=-1)
    u = rmsnorm(u, shared["norm"], cfg.rmsnorm_eps)
    u = dense(u, shared["fuse"], dtype)
    B = u.shape[0]
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ap = shared["attn"]
    q = (dense(u, ap["wq"], dtype)
         + _lora_apply(lp["lora_q"], u, dtype)).reshape(B, 1, H, hd)
    k = dense(u, ap["wk"], dtype).reshape(B, 1, KH, hd)
    v = dense(u, ap["wv"], dtype).reshape(B, 1, KH, hd)
    sin, cos = layers.rope_angles(length[:, None].astype(jnp.float32), hd,
                                  cfg.rope_theta)
    q = layers.apply_rope(q, sin, cos)
    k = layers.apply_rope(k, sin, cos)
    k_cache, v_cache = cache.kv
    oh = jax.nn.one_hot(length, k_cache.shape[1], dtype=k.dtype)
    k_cache = k_cache * (1 - oh[..., None, None]) + oh[..., None, None] * k
    v_cache = v_cache * (1 - oh[..., None, None]) + oh[..., None, None] * v
    o = attention.decode_attention(q, k_cache, v_cache, length + 1)
    o = o.reshape(B, 1, H * hd)
    a = dense(o, ap["wo"], dtype) + _lora_apply(lp["lora_o"], o, dtype)
    u = u + a
    f = ffn_apply(shared["ffn"],
                  rmsnorm(u, shared["ffn_norm"], cfg.rmsnorm_eps),
                  cfg.ffn_activation, dtype)
    site = dense(u + f, lp["site_out"], dtype)
    return {**carry, "h": h + site}, ZambaCache(new_mcaches,
                                                (k_cache, v_cache))


def build(cfg: ModelConfig, *, q_chunk: int = 1024,
          dtype=jnp.bfloat16) -> ModelBundle:
    hc = cfg.hybrid
    n_sb = cfg.num_layers // hc.attn_every
    n_mamba_per = hc.attn_every - 1      # one site per superblock

    def init_params(key):
        ks = jax.random.split(key, 5)
        return {
            "embedding": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "shared_attn": shared_block_init(ks[1], cfg),
            "seg0_zamba": layers.stacked_init(
                functools.partial(superblock_init, cfg=cfg,
                                  n_mamba=n_mamba_per),
                ks[2], n_sb),
            "final_norm": rmsnorm_init(cfg.d_model),
            "head": dense_init(ks[3], cfg.d_model, cfg.vocab_size,
                               scale=1.0 / math.sqrt(cfg.d_model)),
        }

    def embed(params, batch):
        h = layers.embed_lookup(params["embedding"], batch["tokens"], dtype)
        B, S = h.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        carry = {"h": h, "x0": h, "aux": jnp.zeros((), jnp.float32)}
        ctx = {"positions": positions, "shared": params["shared_attn"]}
        return carry, ctx

    def cache_spec(batch, max_len, cdtype):
        mspec = ssm.mamba2_cache_spec(cfg, batch, cdtype)
        # inner mamba stack sits AFTER the batch axis (batch-major cache)
        mstack = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(
                (s.shape[0], n_mamba_per) + s.shape[1:], s.dtype), mspec)
        KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        kv = (jax.ShapeDtypeStruct((batch, max_len, KH, hd), cdtype),
              jax.ShapeDtypeStruct((batch, max_len, KH, hd), cdtype))
        return ZambaCache(mstack, kv)

    segments = (SegmentDef(
        name="zamba", n_layers=n_sb,
        apply=functools.partial(superblock_apply, cfg=cfg, dtype=dtype,
                                q_chunk=q_chunk),
        prefill=functools.partial(superblock_prefill, cfg=cfg, dtype=dtype,
                                  q_chunk=q_chunk),
        decode=functools.partial(superblock_decode, cfg=cfg, dtype=dtype),
        cache_spec=cache_spec,
    ),)

    def head_loss(params, carry, batch):
        h = rmsnorm(carry["h"], params["final_norm"], cfg.rmsnorm_eps)
        logits = dense(h, params["head"], dtype)
        loss, metrics = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return loss + carry["aux"], {**metrics, "ce_loss": loss}

    def head_logits(params, carry):
        h = rmsnorm(carry["h"][:, -1:], params["final_norm"],
                    cfg.rmsnorm_eps)
        return dense(h, params["head"], dtype)

    def input_specs(cell: ShapeCell):
        B, S = cell.global_batch, cell.seq_len
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}

    return ModelBundle(cfg=cfg, init_params=init_params, embed=embed,
                       segments=segments, head_loss=head_loss,
                       head_logits=head_logits, input_specs=input_specs)
