"""Mamba2 blocks via the chunked SSD (state-space duality) algorithm.

Training uses the chunkwise-parallel form (intra-chunk quadratic in the
chunk length + inter-chunk ``lax.scan`` over carried states) — TPU-friendly:
the intra-chunk einsums are MXU matmuls, the scan carries a small
``(B, H, P, N)`` state. Decode is the O(1) recurrent step.

Follows the reference ``ssd_minimal_discrete`` of the Mamba2 paper with one
group (B/C shared across heads).
"""
from __future__ import annotations

import functools
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, SSMConfig
from repro.models import layers
from repro.models.layers import dense, dense_init, rmsnorm, rmsnorm_init


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------

def _segsum(x: jax.Array) -> jax.Array:
    """x (..., L) → (..., L, L) with out[..., i, j] = sum_{j < t <= i} x_t
    (−inf above the diagonal)."""
    L = x.shape[-1]
    c = jnp.cumsum(x, axis=-1)
    out = c[..., :, None] - c[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x, A, B, C, chunk: int,
                initial_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (b, l, h, p)   — already multiplied by dt
    A: (b, l, h)      — dt * A_log-discretized (negative reals)
    B, C: (b, l, n)   — one group, shared across heads
    Returns (y (b,l,h,p), final_state (b,h,p,n)).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    pad = (-l) % chunk
    if pad:
        # pad with identity steps: A=0 (no decay), x=B=0 (no state update)
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        A = jnp.pad(A, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        l_orig = l
        l = l + pad
    c = l // chunk
    xr = x.reshape(b, c, chunk, h, p)
    Ar = A.reshape(b, c, chunk, h).transpose(0, 3, 1, 2)   # (b,h,c,L)
    Br = B.reshape(b, c, chunk, n)
    Cr = C.reshape(b, c, chunk, n)

    A_cum = jnp.cumsum(Ar, axis=-1)                        # (b,h,c,L)
    # 1. intra-chunk (diagonal blocks)
    Lmat = jnp.exp(_segsum(Ar))                            # (b,h,c,L,L)
    Y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp",
                        Cr, Br, Lmat, xr)
    # 2. per-chunk end states
    decay_states = jnp.exp(A_cum[..., -1:] - A_cum)        # (b,h,c,L)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn",
                        Br, decay_states, xr)              # (b,c,h,p,n)
    # 3. inter-chunk recurrence
    chunk_decay = jnp.exp(A_cum[..., -1])                  # (b,h,c)
    init = (jnp.zeros((b, h, p, n), x.dtype) if initial_state is None
            else initial_state)

    def scan_body(carry, inp):
        st, dec = inp                                      # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state *before*

    _, prev_states = jax.lax.scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    # prev_states: (c, b, h, p, n) — state entering each chunk
    final_state = prev_states[-1] * chunk_decay[..., -1][..., None, None] \
        + states[:, -1]
    # 4. inter-chunk contribution to outputs
    state_decay_out = jnp.exp(A_cum)                       # (b,h,c,L)
    Y_off = jnp.einsum("bcln,cbhpn,bhcl->bclhp",
                       Cr, prev_states, state_decay_out)
    y = (Y_diag + Y_off).reshape(b, l, h, p)
    if pad:
        y = y[:, :l_orig]
    return y, final_state


def ssd_recurrent_step(state, x_t, A_t, B_t, C_t):
    """One decode step.

    state (b,h,p,n); x_t (b,h,p) (dt-scaled); A_t (b,h) (dt·A);
    B_t, C_t (b,n). Returns (y (b,h,p), new_state)."""
    decay = jnp.exp(A_t)[..., None, None]
    new_state = state * decay + jnp.einsum("bhp,bn->bhpn", x_t, B_t)
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_t)
    return y, new_state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------

class Mamba2Cache(NamedTuple):
    conv: jax.Array     # (B, K-1, conv_channels) rolling window
    ssm: jax.Array      # (B, H, P, N)


def mamba2_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    sc: SSMConfig = cfg.ssm
    d = cfg.d_model
    d_inner = sc.expand * d
    H = d_inner // sc.head_dim
    conv_ch = d_inner + 2 * sc.state_dim
    ks = jax.random.split(key, 6)
    return {
        # order: [z (d_inner), xBC (conv_ch), dt (H)]
        "in_proj": dense_init(ks[0], d, d_inner + conv_ch + H, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (sc.conv_kernel, conv_ch),
                                     jnp.float32) * 0.1).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.clip(jnp.exp(jax.random.uniform(ks[2], (H,)) * 3.5 - 4.6),
                     1e-4, 0.1))).astype(jnp.float32),
        "ssm_norm": rmsnorm_init(d_inner),
        "out_proj": dense_init(ks[3], d_inner, d,
                               scale=1.0 / math.sqrt(d_inner), dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,C); w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i: i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def _split_proj(p, x, cfg: ModelConfig, dtype):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    conv_ch = d_inner + 2 * sc.state_dim
    H = d_inner // sc.head_dim
    zxbcdt = dense(x, p["in_proj"], dtype)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner: d_inner + conv_ch]
    dt_raw = zxbcdt[..., d_inner + conv_ch:]
    return z, xBC, dt_raw, d_inner, conv_ch, H


def mamba2_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
                 dtype=jnp.bfloat16,
                 initial_cache: Optional[Mamba2Cache] = None,
                 return_cache: bool = False):
    """Full-sequence (train / prefill) Mamba2 block."""
    sc: SSMConfig = cfg.ssm
    Bb, S, _ = x.shape
    z, xBC_pre, dt_raw, d_inner, conv_ch, H = _split_proj(p, x, cfg, dtype)
    # materialize: stacked per-layer vectors (conv_b (L,C), dt_bias/A_log/D
    # (L,H)) can arrive quantized — no-op for plain arrays
    mat = functools.partial(layers.materialize, dtype=jnp.float32)
    xBC = jax.nn.silu(_causal_conv(
        xBC_pre.astype(jnp.float32),
        mat(p["conv_w"]), mat(p["conv_b"]))).astype(dtype)
    xs = xBC[..., :d_inner]
    Bmat = xBC[..., d_inner: d_inner + sc.state_dim].astype(jnp.float32)
    Cmat = xBC[..., d_inner + sc.state_dim:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + mat(p["dt_bias"]))
    A = -jnp.exp(mat(p["A_log"]))                                    # (H,)
    xh = xs.reshape(Bb, S, H, sc.head_dim).astype(jnp.float32)
    y, final_state = ssd_chunked(
        xh * dt[..., None], dt * A, Bmat, Cmat,
        chunk=min(sc.chunk_size, S),
        initial_state=None if initial_cache is None else initial_cache.ssm)
    y = y + xh * mat(p["D"])[:, None]
    y = y.reshape(Bb, S, d_inner).astype(dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.rmsnorm_eps)
    out = dense(y, p["out_proj"], dtype)
    if return_cache:
        # conv state holds the last K-1 *pre-conv* inputs
        K = sc.conv_kernel
        conv_state = jnp.pad(
            xBC_pre, ((0, 0), (max(K - 1 - S, 0), 0), (0, 0)))[:, -(K - 1):, :]
        return out, Mamba2Cache(conv_state.astype(dtype), final_state)
    return out


def mamba2_decode(p: dict, x: jax.Array, cfg: ModelConfig, *,
                  cache: Mamba2Cache, dtype=jnp.bfloat16):
    """One-token decode. x (B,1,D)."""
    sc: SSMConfig = cfg.ssm
    Bb = x.shape[0]
    z, xBC_raw, dt_raw, d_inner, conv_ch, H = _split_proj(p, x, cfg, dtype)
    mat = functools.partial(layers.materialize, dtype=jnp.float32)
    # rolling conv window
    window = jnp.concatenate([cache.conv, xBC_raw.astype(dtype)], axis=1)
    conv_out = jnp.einsum(
        "bkc,kc->bc", window.astype(jnp.float32),
        mat(p["conv_w"])) + mat(p["conv_b"])
    xBC = jax.nn.silu(conv_out)[:, None, :].astype(dtype)
    new_conv = window[:, 1:, :]
    xs = xBC[..., :d_inner]
    Bmat = xBC[0:, 0, d_inner: d_inner + sc.state_dim].astype(jnp.float32)
    Cmat = xBC[0:, 0, d_inner + sc.state_dim:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) +
                         mat(p["dt_bias"]))
    A = -jnp.exp(mat(p["A_log"]))
    xh = xs[:, 0].reshape(Bb, H, sc.head_dim).astype(jnp.float32)
    y, new_ssm = ssd_recurrent_step(cache.ssm, xh * dt[..., None],
                                    dt * A, Bmat, Cmat)
    y = y + xh * mat(p["D"])[:, None]
    y = y.reshape(Bb, 1, d_inner).astype(dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.rmsnorm_eps)
    return dense(y, p["out_proj"], dtype), Mamba2Cache(new_conv, new_ssm)


def mamba2_cache_spec(cfg: ModelConfig, batch: int, dtype):
    sc = cfg.ssm
    d_inner = sc.expand * cfg.d_model
    H = d_inner // sc.head_dim
    conv_ch = d_inner + 2 * sc.state_dim
    return Mamba2Cache(
        jax.ShapeDtypeStruct((batch, sc.conv_kernel - 1, conv_ch), dtype),
        jax.ShapeDtypeStruct((batch, H, sc.head_dim, sc.state_dim),
                             jnp.float32),
    )


def ssd_reference(x, A, B, C, initial_state=None):
    """O(L) sequential oracle for tests."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    state = (jnp.zeros((b, h, p, n)) if initial_state is None
             else initial_state)
    ys = []
    for t in range(l):
        y, state = ssd_recurrent_step(state, x[:, t], A[:, t],
                                      B[:, t], C[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), state
