"""Encoder-decoder model (seamless-m4t-medium backbone).

The audio frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, S_enc, d) directly to the encoder. Decoder
length is ``seq_len // 4`` in train/prefill cells (ASR-like output ratio,
documented in DESIGN.md); decode cells use a self-attention cache of
``seq_len`` and an encoder memory of ``seq_len // 4``.
"""
from __future__ import annotations

import functools
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeCell
from repro.models import attention, layers
from repro.models.base import ModelBundle, SegmentDef
from repro.models.layers import cross_entropy, dense, dense_init, \
    embed_init, ffn_apply, ffn_init, rmsnorm, rmsnorm_init

DEC_RATIO = 4      # decoder length = seq_len // DEC_RATIO for train/prefill


def enc_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "attn_norm": rmsnorm_init(cfg.d_model),
        "attn": attention.gqa_init(k1, cfg, dtype),
        "ffn_norm": rmsnorm_init(cfg.d_model),
        "ffn": ffn_init(k2, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def dec_block_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "self_norm": rmsnorm_init(cfg.d_model),
        "self_attn": attention.gqa_init(k1, cfg, dtype),
        "cross_norm": rmsnorm_init(cfg.d_model),
        "cross_attn": attention.gqa_init(k2, cfg, dtype),
        "ffn_norm": rmsnorm_init(cfg.d_model),
        "ffn": ffn_init(k3, cfg.d_model, cfg.d_ff, dtype=dtype),
    }


def enc_block_apply(lp, carry, ctx, cfg: ModelConfig, *, dtype, q_chunk):
    h = carry["h"]
    a = attention.gqa_apply(lp["attn"],
                            rmsnorm(h, lp["attn_norm"], cfg.rmsnorm_eps),
                            cfg, positions=ctx["enc_positions"],
                            causal=False, q_chunk=q_chunk, dtype=dtype)
    h = h + a
    f = ffn_apply(lp["ffn"], rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps),
                  cfg.ffn_activation, dtype)
    return {**carry, "h": h + f}


def dec_block_apply(lp, carry, ctx, cfg: ModelConfig, *, dtype, q_chunk):
    h = carry["h"]
    a = attention.gqa_apply(lp["self_attn"],
                            rmsnorm(h, lp["self_norm"], cfg.rmsnorm_eps),
                            cfg, positions=ctx["dec_positions"],
                            causal=True, q_chunk=q_chunk, dtype=dtype)
    h = h + a
    c = attention.cross_apply(lp["cross_attn"],
                              rmsnorm(h, lp["cross_norm"], cfg.rmsnorm_eps),
                              carry["memory"], cfg, dtype=dtype)
    h = h + c
    f = ffn_apply(lp["ffn"], rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps),
                  cfg.ffn_activation, dtype)
    return {**carry, "h": h + f}


def dec_block_prefill(lp, carry, ctx, cfg: ModelConfig, *, dtype, q_chunk):
    h = carry["h"]
    x = rmsnorm(h, lp["self_norm"], cfg.rmsnorm_eps)
    a, kv = attention.gqa_prefill(lp["self_attn"], x, cfg,
                                  positions=ctx["dec_positions"],
                                  q_chunk=q_chunk, dtype=dtype)
    h = h + a
    c = attention.cross_apply(lp["cross_attn"],
                              rmsnorm(h, lp["cross_norm"], cfg.rmsnorm_eps),
                              carry["memory"], cfg, dtype=dtype)
    h = h + c
    f = ffn_apply(lp["ffn"], rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps),
                  cfg.ffn_activation, dtype)
    k, v = kv
    pad = ctx["max_len"] - k.shape[1]
    kv = (jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
          jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
    return {**carry, "h": h + f}, kv


def dec_block_decode(lp, carry, cache, ctx, cfg: ModelConfig, *, dtype):
    h = carry["h"]
    x = rmsnorm(h, lp["self_norm"], cfg.rmsnorm_eps)
    a, cache = attention.gqa_decode(lp["self_attn"], x, cfg, cache=cache,
                                    length=ctx["length"], dtype=dtype)
    h = h + a
    c = attention.cross_apply(lp["cross_attn"],
                              rmsnorm(h, lp["cross_norm"], cfg.rmsnorm_eps),
                              carry["memory"], cfg, dtype=dtype)
    h = h + c
    f = ffn_apply(lp["ffn"], rmsnorm(h, lp["ffn_norm"], cfg.rmsnorm_eps),
                  cfg.ffn_activation, dtype)
    return {**carry, "h": h + f}, cache


def build(cfg: ModelConfig, *, q_chunk: int = 1024,
          dtype=jnp.bfloat16) -> ModelBundle:
    n_enc = cfg.num_encoder_layers or cfg.num_layers
    n_dec = cfg.num_layers

    def init_params(key):
        ks = jax.random.split(key, 6)
        return {
            "embedding": embed_init(ks[0], cfg.vocab_size, cfg.d_model),
            "frame_norm": rmsnorm_init(cfg.d_model),
            "seg0_encoder": layers.stacked_init(
                functools.partial(enc_block_init, cfg=cfg), ks[1], n_enc),
            "enc_final_norm": rmsnorm_init(cfg.d_model),
            "seg1_decoder": layers.stacked_init(
                functools.partial(dec_block_init, cfg=cfg), ks[2], n_dec),
            "final_norm": rmsnorm_init(cfg.d_model),
            "head": dense_init(ks[3], cfg.d_model, cfg.vocab_size,
                               scale=1.0 / math.sqrt(cfg.d_model)),
        }

    def embed(params, batch):
        frames = batch["frames"].astype(dtype)       # stubbed audio frontend
        h = rmsnorm(frames, params["frame_norm"], cfg.rmsnorm_eps)
        dec_h = layers.embed_lookup(params["embedding"], batch["tokens"],
                                    dtype)
        B, Se = h.shape[:2]
        Sd = dec_h.shape[1]
        carry = {"h": h, "dec_h": dec_h,
                 "memory": jnp.zeros((B, 1, cfg.d_model), dtype),
                 "aux": jnp.zeros((), jnp.float32)}
        ctx = {
            "enc_positions": jnp.broadcast_to(
                jnp.arange(Se, dtype=jnp.int32)[None], (B, Se)),
            "dec_positions": jnp.broadcast_to(
                jnp.arange(Sd, dtype=jnp.int32)[None], (B, Sd)),
        }
        return carry, ctx

    def bridge(params, carry, ctx):
        """After the encoder: promote h → memory, start the decoder."""
        mem = rmsnorm(carry["h"], params["enc_final_norm"], cfg.rmsnorm_eps)
        return {**carry, "memory": mem, "h": carry["dec_h"]}

    segments = (
        SegmentDef(name="encoder", n_layers=n_enc,
                   apply=functools.partial(enc_block_apply, cfg=cfg,
                                           dtype=dtype, q_chunk=q_chunk)),
        SegmentDef(name="decoder", n_layers=n_dec,
                   apply=functools.partial(dec_block_apply, cfg=cfg,
                                           dtype=dtype, q_chunk=q_chunk),
                   prefill=functools.partial(dec_block_prefill, cfg=cfg,
                                             dtype=dtype, q_chunk=q_chunk),
                   decode=functools.partial(dec_block_decode, cfg=cfg,
                                            dtype=dtype),
                   cache_spec=functools.partial(
                       attention.gqa_cache_spec, cfg),
                   pre=bridge),
    )

    def head_loss(params, carry, batch):
        h = rmsnorm(carry["h"], params["final_norm"], cfg.rmsnorm_eps)
        logits = dense(h, params["head"], dtype)
        loss, metrics = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
        return loss, {**metrics, "ce_loss": loss}

    def head_logits(params, carry):
        h = rmsnorm(carry["h"][:, -1:], params["final_norm"],
                    cfg.rmsnorm_eps)
        return dense(h, params["head"], dtype)

    def input_specs(cell: ShapeCell):
        B, S = cell.global_batch, cell.seq_len
        Sd = max(S // DEC_RATIO, 16)
        if cell.kind == "decode":
            # decode cells: self-cache of S; memory from S // DEC_RATIO frames
            Sd = max(S // DEC_RATIO, 16)
        return {
            "frames": jax.ShapeDtypeStruct(
                (B, Sd if cell.kind == "decode" else S, cfg.d_model),
                jnp.bfloat16),
            "tokens": jax.ShapeDtypeStruct((B, Sd), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, Sd), jnp.int32),
        }

    def embed_decode(params, tokens, extras):
        h = layers.embed_lookup(params["embedding"], tokens, dtype)
        carry = {"h": h, "memory": extras["memory"],
                 "aux": jnp.zeros((), jnp.float32)}
        return carry, {}

    return ModelBundle(cfg=cfg, init_params=init_params, embed=embed,
                       segments=segments, head_loss=head_loss,
                       head_logits=head_logits, input_specs=input_specs,
                       embed_decode=embed_decode, decode_extras=("memory",))
