"""LoRA / QLoRA / plain low-rank-factorization baselines (paper Tables 1/3/4).

These are *model-side* transforms (adapters), unlike GaLore's optimizer-side
projection:

* ``lora``      — W = W₀ (frozen) + (α/r)·A B ; optimize A, B.
* ``qlora``     — same, with W₀ kept in INT8 (frozen quantized base).
* ``factorized``— W = U V from scratch (the paper's "Low-Rank" row).

Training merges adapters into a virtual weight tree and reuses the standard
bundle loss — correctness by construction, at the memory cost the paper
ascribes to these baselines (which is the point of the comparison).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.models.base import ModelBundle


def _eligible(path: str, leaf) -> bool:
    # 2-D mats and layer-stacked (L, m, n) mats both take adapters (the
    # stacked case is the scanned-segment layout every bundle uses — one
    # (L, m, r)/(L, r, n) adapter pair per stacked leaf).
    nd = len(leaf.shape) if quant.is_qtensor(leaf) \
        else getattr(leaf, "ndim", 0)
    if nd not in (2, 3):
        return False
    p = path.lower()
    return not any(k in p for k in ("embed", "head", "norm"))


def init_adapters(params, rank: int, key, mode: str = "lora"):
    """{path: {"A","B"} or {"U","V"}} for every eligible 2-D or layer-
    stacked 3-D leaf (adapters carry the leading stack dim)."""
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=quant.is_qtensor)[0]
    out = {}
    for i, (path, leaf) in enumerate(flat):
        pstr = jax.tree_util.keystr(path)
        if not _eligible(pstr, leaf):
            continue
        lead = tuple(leaf.shape[:-2])
        m, n = leaf.shape[-2], leaf.shape[-1]
        k = jax.random.fold_in(key, i)
        r = min(rank, m, n)
        if mode == "factorized":
            out[pstr] = {
                "U": jax.random.normal(k, lead + (m, r)) / math.sqrt(m),
                "V": jax.random.normal(jax.random.fold_in(k, 1),
                                       lead + (r, n)) / math.sqrt(r),
            }
        else:
            out[pstr] = {
                "A": jax.random.normal(k, lead + (m, r)) / math.sqrt(m),
                "B": jnp.zeros(lead + (r, n)),
            }
    return out


def merge(params, adapters: Dict, alpha: float = 32.0, rank: int = 16,
          mode: str = "lora"):
    """Virtual weight tree: base (+ scaled adapter product)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=quant.is_qtensor)
    leaves = []
    for path, leaf in flat:
        pstr = jax.tree_util.keystr(path)
        ad = adapters.get(pstr)
        if ad is None:
            leaves.append(quant.dequantize(leaf)
                          if quant.is_qtensor(leaf) else leaf)
            continue
        if mode == "factorized":
            leaves.append((ad["U"] @ ad["V"]).astype(jnp.float32))
        else:
            base = quant.dequantize(leaf, jnp.float32) \
                if quant.is_qtensor(leaf) else leaf.astype(jnp.float32)
            r = ad["A"].shape[-1]
            # @ broadcasts over the leading stack dim for 3-D adapters
            leaves.append(base + (alpha / r) * (ad["A"] @ ad["B"]))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def adapter_nbytes(adapters) -> int:
    return sum(l.size * l.dtype.itemsize
               for l in jax.tree_util.tree_leaves(adapters))
