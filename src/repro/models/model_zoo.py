"""Architecture registry: build any assigned config into a ModelBundle."""
from __future__ import annotations

import importlib
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.base import ModelBundle, count_params

ARCH_IDS = (
    "internvl2-2b", "xlstm-125m", "deepseek-v3-671b", "qwen3-moe-30b-a3b",
    "mistral-nemo-12b", "qwen3-32b", "gemma-7b", "yi-9b",
    "seamless-m4t-medium", "zamba2-2.7b",
    # paper's own pre-training family
    "llama-60m", "llama-130m", "llama-350m", "llama-1b", "llama-7b",
)


def _module_for(arch_id: str) -> str:
    return "repro.configs." + arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_module_for(arch_id))
    return mod.smoke_config() if smoke else mod.CONFIG


def build(cfg: ModelConfig, *, q_chunk: int = 1024,
          dtype=jnp.bfloat16, ep_axis=None,
          split_layers: int = 0) -> ModelBundle:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        from repro.models import transformer
        return transformer.build(cfg, q_chunk=q_chunk, dtype=dtype,
                                 ep_axis=ep_axis,
                                 split_layers=split_layers)
    if split_layers:
        raise ValueError(f"split_layers unsupported for family {fam}")
    if fam == "xlstm":
        from repro.models import xlstm_model
        return xlstm_model.build(cfg, q_chunk=q_chunk, dtype=dtype)
    if fam == "hybrid":
        from repro.models import zamba
        return zamba.build(cfg, q_chunk=q_chunk, dtype=dtype)
    if fam == "encdec":
        from repro.models import encdec
        return encdec.build(cfg, q_chunk=q_chunk, dtype=dtype)
    raise ValueError(f"unknown family {fam}")


def build_arch(arch_id: str, smoke: bool = False, **kw) -> ModelBundle:
    return build(get_config(arch_id, smoke=smoke), **kw)


# ---------------------------------------------------------------------------
# Analytic parameter counts (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params_analytic(cfg: ModelConfig) -> int:
    """Total parameters via eval_shape — exact, no allocation."""
    return count_params(build(cfg))


def count_active_params(cfg: ModelConfig) -> int:
    """Active parameters per token (MoE: only top-k + shared experts)."""
    total = count_params_analytic(cfg)
    if cfg.moe is None or not cfg.moe.num_experts:
        return total
    mc = cfg.moe
    per_expert = 3 * cfg.d_model * mc.expert_ff       # wi, wg, wd
    n_moe_layers = cfg.num_layers - mc.first_dense_layers
    inactive = n_moe_layers * (mc.num_experts - mc.top_k) * per_expert
    return total - inactive
