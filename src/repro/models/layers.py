"""Shared neural-net building blocks (pure JAX, quantization-aware).

Every matmul goes through :func:`dense` (or its siblings :func:`dense_t`
for transposed/tied weights and :func:`dense_batched` for expert stacks),
which route ``QTensor``/``QVirtual`` (INT8) weights through the
dispatch-registered ``quantized_dense`` op: the weight streams as INT8
blocks in both the forward and the ``dL/dx`` backward, and is never
materialized in full precision (``repro.kernels.ops``). Embedding tables
are consumed through :func:`embed_lookup`, which gathers INT8 rows per
token instead of dequantizing the whole table. ``materialize`` remains the
escape hatch for consumers that genuinely need the full-precision array
(MLA's absorbed decode matmul, test oracles) — with QVirtual weights its
gradient still flows to the virtual-weight slot.

Set ``REPRO_QUANTIZED_DENSE=0`` (or ``layers.QUANTIZED_DENSE = False``
before tracing) to fall back to the legacy dequantize-then-einsum path —
the A/B baseline used by ``benchmarks/train_bench.py``.

Parameter trees are plain nested dicts; leaf names follow the conventions
consumed by ``repro.distributed.sharding`` (wq/wk/wv/wo, wi/wg/wd, experts_*,
embedding, head, *_norm).
"""
from __future__ import annotations

import math
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import quant
from repro.core.quant import QTensor, QVirtual
from repro.kernels import ops as kops

# Trace-time switch: route QTensor/QVirtual matmuls through the INT8
# quantized_dense kernels (default) or the legacy materialize+einsum path.
QUANTIZED_DENSE = os.environ.get("REPRO_QUANTIZED_DENSE", "1") != "0"


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, scale: Optional[float] = None,
               dtype=jnp.float32) -> jax.Array:
    """Truncated-normal fan-in init."""
    std = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim),
                                        jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, dim), jnp.float32)
            * 0.02).astype(dtype)


def stacked_init(init_fn, key, num: int, *args, **kwargs):
    """vmap an init over a leading layer axis."""
    keys = jax.random.split(key, num)
    return jax.vmap(lambda k: init_fn(k, *args, **kwargs))(keys)


# ---------------------------------------------------------------------------
# Quantization-aware matmul
# ---------------------------------------------------------------------------

def materialize(w, dtype=jnp.bfloat16) -> jax.Array:
    """Full-precision view of a (possibly quantized) weight.

    For QVirtual weights the dequantization carries a custom VJP that
    routes the gradient to the virtual-weight shadow — use only where the
    materialized array is genuinely required; matmuls belong in
    :func:`dense`/:func:`dense_t`/:func:`dense_batched`.
    """
    if isinstance(w, QVirtual):
        return quant.virtual_dequantize(w.shadow, w.qt).astype(dtype)
    if isinstance(w, QTensor):
        return quant.dequantize(w, dtype)
    return w.astype(dtype)


def _qdense_eligible(w, ndim: int) -> bool:
    if not QUANTIZED_DENSE or not isinstance(w, (QTensor, QVirtual)):
        return False
    qt = w.qt if isinstance(w, QVirtual) else w
    return qt.bits == 8 and qt.zero is None and qt.ndim == ndim


def dense(x: jax.Array, w, dtype=jnp.bfloat16) -> jax.Array:
    """x (..., d) @ w (d, f); INT8 weights stream through the
    ``quantized_dense`` kernel (never materialized)."""
    if _qdense_eligible(w, 2):
        return kops.quantized_dense(x, w, dtype=dtype)
    wm = materialize(w, dtype)
    return jnp.einsum("...d,df->...f", x.astype(dtype), wm)


def dense_t(x: jax.Array, w, dtype=jnp.bfloat16) -> jax.Array:
    """x (..., d) @ w (v, d)^T — the tied-embedding head matmul; INT8
    weights stream through the transposed kernel over the same blocks."""
    if _qdense_eligible(w, 2):
        return kops.quantized_dense_t(x, w, dtype=dtype)
    wm = materialize(w, dtype)
    return jnp.einsum("...d,vd->...v", x.astype(dtype), wm)


def dense_batched(x: jax.Array, w, dtype=jnp.bfloat16) -> jax.Array:
    """Paired-leading-axis matmul x (E, ..., d) @ w (E, d, f) → (E, ..., f)
    (MoE expert stacks); INT8 expert weights stay INT8 per expert."""
    if _qdense_eligible(w, 3):
        return kops.quantized_dense_batched(x, w, dtype=dtype)
    wm = materialize(w, dtype)
    return jnp.einsum("e...d,edf->e...f", x.astype(dtype), wm)


def embed_lookup(w, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    """Embedding-table row lookup. For INT8 tables, gathers codes + scales
    per token and dequantizes only the gathered rows — the full table is
    never materialized (the big decode-path win for large vocabs)."""
    if isinstance(w, (QTensor, QVirtual)) and QUANTIZED_DENSE:
        qt, shadow = (w.qt, w.shadow) if isinstance(w, QVirtual) \
            else (w, None)
        if qt.ndim == 2:
            if shadow is None:
                rows = quant.dequantize(quant.gather_rows(qt, tokens))
            else:
                rows = _embed_rows(tokens, shadow, qt)
            return rows.astype(dtype)
    return jnp.take(materialize(w, dtype), tokens, axis=0)


@jax.custom_vjp
def _embed_rows(tokens, shadow, qt):
    return quant.dequantize(quant.gather_rows(qt, tokens), shadow.dtype)


def _embed_rows_fwd(tokens, shadow, qt):
    return _embed_rows(tokens, shadow, qt), (tokens, shadow, qt)


def _embed_rows_bwd(res, g):
    tokens, shadow, qt = res
    d_shadow = jnp.zeros(shadow.shape, shadow.dtype) \
        .at[tokens].add(g.astype(shadow.dtype))
    return (quant._zero_cotangent(tokens), d_shadow,
            quant.zero_qtensor_cotangent(qt))


_embed_rows.defvjp(_embed_rows_fwd, _embed_rows_bwd)


# ---------------------------------------------------------------------------
# Norms / activations / rotary
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    # materialize: stacked norm scales can arrive quantized (2-D leaves)
    return (y * (1.0 + materialize(w, jnp.float32))).astype(dt)


def rmsnorm_init(dim: int) -> jax.Array:
    # stored as offset from 1 (gemma-style "zero-centered" scale)
    return jnp.zeros((dim,), jnp.float32)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def geglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.gelu(gate, approximate=True) * up


def activation_fn(name: str):
    return {"silu": swiglu, "gelu": geglu}[name]


def rope_angles(positions: jax.Array, head_dim: int,
                theta: float) -> Tuple[jax.Array, jax.Array]:
    """positions (..., S) → (sin, cos) each (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x (..., S, H, hd); sin/cos (..., S, hd//2) — rotate-half convention."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = xf[..., :half], xf[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]   # broadcast over heads
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(dt)


# ---------------------------------------------------------------------------
# FFN (dense / gated)
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi": dense_init(k1, d_model, d_ff, dtype=dtype),
        "wg": dense_init(k2, d_model, d_ff, dtype=dtype),
        "wd": dense_init(k3, d_ff, d_model, dtype=dtype),
    }


def ffn_apply(p: dict, x: jax.Array, activation: str = "silu",
              dtype=jnp.bfloat16) -> jax.Array:
    act = activation_fn(activation)
    h = act(dense(x, p["wg"], dtype), dense(x, p["wi"], dtype))
    return dense(h, p["wd"], dtype)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------

def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return logits
    return jnp.tanh(logits / cap) * cap


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> Tuple[jax.Array, dict]:
    """Token-mean CE in float32; labels == -1 are ignored."""
    lf = logits.astype(jnp.float32)
    valid = (labels >= 0)
    if mask is not None:
        valid = valid & (mask > 0)
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * valid
    denom = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / denom
    acc = ((jnp.argmax(lf, -1) == safe) & valid).sum() / denom
    return loss, {"accuracy": acc, "tokens": denom}
