"""Attention blocks: GQA (with qk-norm, RoPE, GeGLU head dims) and
Multi-head Latent Attention (DeepSeek-V3), with train / prefill / decode
variants and memory-bounded chunked (flash-style) computation.

Chunked attention scans over query chunks with an online-softmax over KV
chunks, keeping the transient score tensor at ``chunk_q × chunk_kv`` — this
is what makes 32k-token prefill lowerable within VMEM/HBM budgets (XLA does
not rewrite naive attention into flash form by itself).
"""
from __future__ import annotations

import functools
import math
import os
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import MLAConfig, ModelConfig
from repro.models import layers
from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, \
    rmsnorm_init, rope_angles

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core softmax-attention with chunking
# ---------------------------------------------------------------------------

def _attend_dense(q, k, v, *, causal: bool, q_offset, softcap: float = 0.0):
    """q (B,Sq,H,dh), k/v (B,Skv,KH,dh) — one dense block of scores.

    GQA: H must be a multiple of KH; kv heads are repeated via reshape.
    """
    B, Sq, H, dh = q.shape
    KH = k.shape[2]
    dv = v.shape[-1]            # may differ from dh (MLA)
    G = H // KH
    qf = q.astype(jnp.float32) / math.sqrt(dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qg = qf.reshape(B, Sq, KH, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    if causal:
        Skv = k.shape[1]
        qpos = q_offset + jnp.arange(Sq)
        kpos = jnp.arange(Skv)
        mask = kpos[None, :] <= qpos[:, None]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    z = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", e / z, vf)
    return o.reshape(B, Sq, H, dv)


def _flash_eligible(q, k, causal, q_offset, softcap) -> bool:
    """The dispatch-routed flash kernel covers the self-attention core
    only: causal, no soft-cap, queries aligned with keys (full sequence,
    no offset). Decode, cross-attention and ragged prefill keep the
    chunked path."""
    return (os.environ.get("REPRO_FLASH_ATTENTION", "") == "1"
            and causal and not softcap and q_offset == 0
            and q.shape[1] == k.shape[1] and q.shape[1] > 1
            and q.shape[2] % k.shape[2] == 0)


def chunked_attention(q, k, v, *, causal: bool = True, q_chunk: int = 1024,
                      q_offset: int = 0, softcap: float = 0.0) -> jax.Array:
    """Memory-bounded attention: scan over query chunks (scores stay
    (chunk, Skv)); falls back to a single dense block for short sequences.

    With ``REPRO_FLASH_ATTENTION=1`` eligible calls route through the
    kernel registry instead (``kernels.ops.flash_attention`` — Pallas
    flash kernel or its ref oracle per ``REPRO_KERNEL_BACKEND``), with kv
    heads repeated to fold GQA. Default OFF: the chunked path is the
    numerics the golden-trajectory fixtures pin."""
    B, Sq, H, dh = q.shape
    if _flash_eligible(q, k, causal, q_offset, softcap):
        from repro.kernels import ops
        G = H // k.shape[2]
        kf = jnp.repeat(k, G, axis=2) if G > 1 else k
        vf = jnp.repeat(v, G, axis=2) if G > 1 else v
        return ops.flash_attention(q, kf, vf, causal=True).astype(q.dtype)
    if Sq <= q_chunk:
        return _attend_dense(q, k, v, causal=causal, q_offset=q_offset,
                             softcap=softcap).astype(q.dtype)
    pad = (-Sq) % q_chunk
    if pad:
        # ragged tail (e.g. VLM prefix + text): pad queries, crop outputs —
        # padded rows still see valid causal keys, results are discarded.
        out = chunked_attention(
            jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))), k, v,
            causal=causal, q_chunk=q_chunk, q_offset=q_offset,
            softcap=softcap)
        return out[:, :Sq]
    nq = Sq // q_chunk
    qs = q.reshape(B, nq, q_chunk, H, dh)

    def body(carry, inp):
        qc, i = inp
        off = q_offset + i * q_chunk
        o = _attend_dense(qc, k, v, causal=causal, q_offset=off,
                          softcap=softcap)
        return carry, o

    _, outs = jax.lax.scan(
        body, 0, (qs.transpose(1, 0, 2, 3, 4), jnp.arange(nq)))
    dv = v.shape[-1]
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, dv).astype(q.dtype)


def append_attention(q, k_cache, v_cache, q_positions) -> jax.Array:
    """Chunk-append attention: C queries at absolute positions
    ``q_positions`` (B, C) against a (B, Smax, KH, dh) cache that already
    holds every position ``<= q_positions`` (this chunk's K/V included).

    Mirrors :func:`_attend_dense` op-for-op (same einsum contraction, same
    max/exp/sum order) with the causal mask taken against absolute
    positions — masked keys contribute an exact 0 to the softmax sums, so
    appending a prompt in chunks is bit-identical to one dense prefill
    block over the unpadded prompt (asserted by ``tests/test_paged.py``).
    """
    B, C, H, dh = q.shape
    KH = k_cache.shape[2]
    dv = v_cache.shape[-1]
    G = H // KH
    qf = q.astype(jnp.float32) / math.sqrt(dh)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    qg = qf.reshape(B, C, KH, G, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf)
    kpos = jnp.arange(k_cache.shape[1])
    mask = kpos[None, None] <= q_positions[:, :, None]        # (B, C, Skv)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - jax.lax.stop_gradient(m))
    z = jnp.sum(e, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", e / z, vf)
    return o.reshape(B, C, H, dv).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, length) -> jax.Array:
    """Single-token decode: q (B,1,H,dh) against a (B,S,KH,dh) cache with
    ``length`` valid positions (per batch, int32 (B,))."""
    B, _, H, dh = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    qf = q.astype(jnp.float32) / math.sqrt(dh)
    qg = qf.reshape(B, KH, G, dh)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    pos = jnp.arange(k_cache.shape[1])
    mask = pos[None] < length[:, None]              # (B, S)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v_cache.astype(jnp.float32))
    return o.reshape(B, 1, H, v_cache.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, H, KH = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": dense_init(k1, d, H * hd, dtype=dtype),
        "wk": dense_init(k2, d, KH * hd, dtype=dtype),
        "wv": dense_init(k3, d, KH * hd, dtype=dtype),
        "wo": dense_init(k4, H * hd, d, scale=1.0 / math.sqrt(H * hd),
                         dtype=dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd)
        p["k_norm"] = rmsnorm_init(hd)
    return p


def _qkv(p, x, cfg: ModelConfig, positions, dtype):
    B, S, _ = x.shape
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = dense(x, p["wq"], dtype).reshape(B, S, H, hd)
    k = dense(x, p["wk"], dtype).reshape(B, S, KH, hd)
    v = dense(x, p["wv"], dtype).reshape(B, S, KH, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rmsnorm_eps)
    sin, cos = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    return q, k, v


def gqa_apply(p: dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, causal: bool = True,
              q_chunk: int = 1024, dtype=jnp.bfloat16) -> jax.Array:
    """Full-sequence (train / encoder) attention."""
    q, k, v = _qkv(p, x, cfg, positions, dtype)
    o = chunked_attention(q, k, v, causal=causal, q_chunk=q_chunk)
    B, S = x.shape[:2]
    return dense(o.reshape(B, S, -1), p["wo"], dtype)


def gqa_prefill(p, x, cfg: ModelConfig, *, positions, q_chunk=1024,
                dtype=jnp.bfloat16):
    """Like gqa_apply but also returns the (k, v) cache."""
    q, k, v = _qkv(p, x, cfg, positions, dtype)
    o = chunked_attention(q, k, v, causal=True, q_chunk=q_chunk)
    B, S = x.shape[:2]
    return dense(o.reshape(B, S, -1), p["wo"], dtype), (k, v)


def gqa_decode(p, x, cfg: ModelConfig, *, cache: Tuple, length,
               dtype=jnp.bfloat16):
    """x (B,1,D); cache (k,v) each (B,Smax,KH,hd); length (B,) — writes the
    new token at ``length`` and attends over ``length+1`` positions."""
    k_cache, v_cache = cache
    B = x.shape[0]
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = dense(x, p["wq"], dtype).reshape(B, 1, H, hd)
    k = dense(x, p["wk"], dtype).reshape(B, 1, KH, hd)
    v = dense(x, p["wv"], dtype).reshape(B, 1, KH, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rmsnorm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rmsnorm_eps)
    sin, cos = rope_angles(length[:, None].astype(jnp.float32), hd,
                           cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    # scatter the new kv at position `length` (per batch row)
    oh = jax.nn.one_hot(length, k_cache.shape[1], dtype=k.dtype)  # (B,S)
    k_cache = k_cache * (1 - oh[..., None, None]) + oh[..., None, None] * k
    v_cache = v_cache * (1 - oh[..., None, None]) + oh[..., None, None] * v
    o = decode_attention(q, k_cache, v_cache, length + 1)
    return dense(o.reshape(B, 1, -1), p["wo"], dtype), (k_cache, v_cache)


def gqa_append(p, x, cfg: ModelConfig, *, cache: Tuple, positions, mask,
               dtype=jnp.bfloat16):
    """Chunk-append: x (B,C,D) holds the next C prompt tokens at absolute
    ``positions`` (B,C); ``mask`` (B,C) marks valid (non-pad-tail) tokens.
    Valid tokens write their K/V at their position; padded tail positions
    write NOTHING (the cache stays bit-exact — a later chunk or decode
    step owns those slots). Queries attend the whole cache under the
    absolute causal mask, so chunked prefill reproduces one-shot prefill
    bit-for-bit (see :func:`append_attention`)."""
    k_cache, v_cache = cache
    B, C, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions, dtype)
    S = k_cache.shape[1]
    # disjoint one-hot scatter of the chunk's K/V at its positions; padded
    # chunk positions are masked OUT (no garbage ever enters the cache)
    oh = jax.nn.one_hot(positions, S, dtype=k.dtype) \
        * mask[..., None].astype(k.dtype)                     # (B, C, S)
    written = oh.sum(axis=1)                                  # (B, S) 0/1
    k_cache = k_cache * (1 - written[..., None, None]) \
        + jnp.einsum("bcs,bchd->bshd", oh, k.astype(k_cache.dtype))
    v_cache = v_cache * (1 - written[..., None, None]) \
        + jnp.einsum("bcs,bchd->bshd", oh, v.astype(v_cache.dtype))
    o = append_attention(q, k_cache, v_cache, positions)
    return dense(o.reshape(B, C, -1), p["wo"], dtype), (k_cache, v_cache)


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype):
    KH, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, max_len, KH, hd)
    return (jax.ShapeDtypeStruct(shape, dtype),
            jax.ShapeDtypeStruct(shape, dtype))


# ---------------------------------------------------------------------------
# Cross attention (encoder-decoder)
# ---------------------------------------------------------------------------

def cross_apply(p: dict, x: jax.Array, memory: jax.Array, cfg: ModelConfig,
                dtype=jnp.bfloat16) -> jax.Array:
    """Decoder cross-attention over encoder memory (no mask, no rope)."""
    B, S, _ = x.shape
    Sm = memory.shape[1]
    H, KH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = dense(x, p["wq"], dtype).reshape(B, S, H, hd)
    k = dense(memory, p["wk"], dtype).reshape(B, Sm, KH, hd)
    v = dense(memory, p["wv"], dtype).reshape(B, Sm, KH, hd)
    o = chunked_attention(q, k, v, causal=False)
    return dense(o.reshape(B, S, -1), p["wo"], dtype)


# ---------------------------------------------------------------------------
# Multi-head Latent Attention (DeepSeek-V3)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, m.q_lora_rank, dtype=dtype),
        "q_a_norm": rmsnorm_init(m.q_lora_rank),
        "wq_b": dense_init(ks[1], m.q_lora_rank, H * qk_dim, dtype=dtype),
        "wkv_a": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim,
                            dtype=dtype),
        "kv_a_norm": rmsnorm_init(m.kv_lora_rank),
        "wkv_b": dense_init(ks[3], m.kv_lora_rank,
                            H * (m.qk_nope_head_dim + m.v_head_dim),
                            dtype=dtype),
        "wo": dense_init(ks[4], H * m.v_head_dim, d,
                         scale=1.0 / math.sqrt(H * m.v_head_dim),
                         dtype=dtype),
    }


def _mla_qkv(p, x, cfg: ModelConfig, positions, dtype):
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    # queries through the low-rank bottleneck
    q_c = rmsnorm(dense(x, p["wq_a"], dtype), p["q_a_norm"], cfg.rmsnorm_eps)
    q = dense(q_c, p["wq_b"], dtype).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    # compressed kv latent + shared rope key
    kv_a = dense(x, p["wkv_a"], dtype)
    c_kv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_a_norm"], cfg.rmsnorm_eps)
    sin, cos = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin, cos)
    k_rope = apply_rope(k_rope[:, :, None, :], sin, cos)  # single shared head
    return q_nope, q_rope, c_kv, k_rope


def _mla_expand_kv(p, c_kv, cfg: ModelConfig, dtype):
    m: MLAConfig = cfg.mla
    B, S, _ = c_kv.shape
    H = cfg.num_heads
    kv = dense(c_kv, p["wkv_b"], dtype).reshape(
        B, S, H, m.qk_nope_head_dim + m.v_head_dim)
    return jnp.split(kv, [m.qk_nope_head_dim], axis=-1)  # k_nope, v


def mla_apply(p: dict, x: jax.Array, cfg: ModelConfig, *, positions,
              q_chunk: int = 1024, dtype=jnp.bfloat16) -> jax.Array:
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions, dtype)
    k_nope, v = _mla_expand_kv(p, c_kv, cfg, dtype)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    o = chunked_attention(q, k, v, causal=True, q_chunk=q_chunk)
    return dense(o.reshape(B, S, -1), p["wo"], dtype)


def mla_prefill(p, x, cfg: ModelConfig, *, positions, q_chunk=1024,
                dtype=jnp.bfloat16):
    """Returns output and the *compressed* cache (c_kv, k_rope) — the
    memory-defining feature of MLA."""
    m: MLAConfig = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, cfg, positions, dtype)
    k_nope, v = _mla_expand_kv(p, c_kv, cfg, dtype)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))],
        axis=-1)
    o = chunked_attention(q, k, v, causal=True, q_chunk=q_chunk)
    return dense(o.reshape(B, S, -1), p["wo"], dtype), \
        (c_kv, k_rope[:, :, 0, :])


def mla_decode(p, x, cfg: ModelConfig, *, cache, length,
               dtype=jnp.bfloat16):
    """Decode with the compressed cache using the **absorbed-matmul** form:
    the up-projections W_uk / W_uv are folded into the query/output sides so
    attention runs directly over the (rank + rope)-dim latents — the K/V
    expansion (B,S,H,·) is never materialized (it would be TBs at 32k).

    cache: (c_kv (B,Smax,rank), k_rope (B,Smax,rope_dim)); length (B,).
    """
    m: MLAConfig = cfg.mla
    c_cache, r_cache = cache
    B = x.shape[0]
    H = cfg.num_heads
    pos = length[:, None].astype(jnp.float32)
    q_nope, q_rope, c_kv_new, k_rope_new = _mla_qkv(p, x, cfg, pos, dtype)
    oh = jax.nn.one_hot(length, c_cache.shape[1], dtype=c_cache.dtype)
    c_cache = c_cache * (1 - oh[..., None]) + oh[..., None] * c_kv_new
    r_cache = r_cache * (1 - oh[..., None]) + oh[..., None] * \
        k_rope_new[:, :, 0, :]
    # absorb W_uk into q, W_uv into the context read-out. This is the ONE
    # decode-path weight materialization left: the absorbed form needs
    # wkv_b reshaped to (rank, H, nope+v), which the 2-D INT8-streaming
    # quantized_dense cannot express; with QVirtual weights the gradient
    # still routes to the virtual-weight shadow.
    w_ukv = layers.materialize(p["wkv_b"], dtype).reshape(
        m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = w_ukv[..., : m.qk_nope_head_dim]      # (rank, H, nope)
    w_uv = w_ukv[..., m.qk_nope_head_dim:]       # (rank, H, v)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)[:, 0]  # (B,H,rank)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s = (jnp.einsum("bhr,bkr->bhk", q_abs.astype(jnp.float32),
                    c_cache.astype(jnp.float32))
         + jnp.einsum("bshr,bkr->bhk", q_rope.astype(jnp.float32),
                      r_cache.astype(jnp.float32))) * scale
    kpos = jnp.arange(c_cache.shape[1])
    s = jnp.where(kpos[None, None] < (length + 1)[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhk,bkr->bhr", w,
                     c_cache.astype(jnp.float32))   # (B,H,rank)
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, H * m.v_head_dim).astype(dtype)
    return dense(o, p["wo"], dtype), (c_cache, r_cache)


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int, dtype):
    m: MLAConfig = cfg.mla
    return (jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), dtype),
            jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), dtype))
