"""Quickstart: train a small LLaMA-family model with Q-GaLore on CPU.

    PYTHONPATH=src python examples/quickstart.py --steps 50

Shows the three moving parts: a model bundle from the zoo, the Q-GaLore
config (INT8 weights + INT4 projections + adaptive lazy SVD), and the
Trainer (fused projected-backward, checkpointing, fault tolerance).
"""
import argparse

import jax.numpy as jnp

from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=5e-3)
    ap.add_argument("--optimizer", default="qgalore",
                    choices=["qgalore", "galore", "full", "adam8bit"])
    ap.add_argument("--checkpoint-dir", default="")
    args = ap.parse_args()

    bundle = model_zoo.build_arch(args.arch, smoke=args.smoke,
                                  dtype=jnp.float32)
    qcfg = preset(args.optimizer, QGaLoreConfig(
        rank=16, min_dim=64, update_interval=20))
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       steps=args.steps, learning_rate=args.lr,
                       warmup_steps=5, log_every=10,
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=25 if args.checkpoint_dir else 0)
    cell = ShapeCell("quickstart", args.seq, args.batch, "train")
    trainer = Trainer(bundle, tcfg, qcfg, cell=cell,
                      param_dtype=jnp.float32)
    trainer.maybe_restore()

    import logging
    logging.basicConfig(level=logging.INFO)
    hist = trainer.run()
    print(f"\nfinal loss: {hist[-1]['loss']:.4f} "
          f"(started {hist[0]['loss']:.4f})")
    print(f"SVD calls used: {trainer.controller.total_svd_count()} "
          f"(fixed-interval GaLore would use "
          f"{trainer.controller.baseline_svd_count(args.steps)})")


if __name__ == "__main__":
    main()
