"""End-to-end driver: pre-train a ~100M-class LLaMA with Q-GaLore for a few
hundred steps, with checkpointing, auto-resume, SVD accounting, and a final
held-out evaluation. The CPU default uses a width-reduced 130M-family
config; pass ``--full`` for the real llama-130m (slow on CPU, sized for a
single TPU host).

    PYTHONPATH=src python examples/pretrain_llama.py --steps 300
"""
import argparse
import logging

import jax.numpy as jnp

from repro.config import ModelConfig, QGaLoreConfig, ShapeCell, TrainConfig
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

# 100M-class geometry, narrowed for CPU wall-clock (layers kept at 12 so the
# adaptive per-layer SVD behavior is non-trivial).
CPU_100M = ModelConfig(name="llama-cpu100m", family="dense", num_layers=12,
                       d_model=256, num_heads=8, num_kv_heads=8, d_ff=688,
                       vocab_size=8192)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--rank", type=int, default=32)
    ap.add_argument("--full", action="store_true",
                    help="use the real llama-130m config")
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_pretrain_ckpt")
    ap.add_argument("--optimizer", default="qgalore")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = model_zoo.get_config("llama-130m") if args.full else CPU_100M
    bundle = model_zoo.build(cfg, dtype=jnp.float32)
    qcfg = preset(args.optimizer, QGaLoreConfig(
        rank=args.rank, min_dim=128, update_interval=50,
        cos_threshold=0.4, adaptive_k=2))
    tcfg = TrainConfig(
        global_batch=args.batch, seq_len=args.seq, steps=args.steps,
        learning_rate=args.lr, warmup_steps=20, log_every=20,
        checkpoint_dir=args.checkpoint_dir, checkpoint_every=100,
        keep_checkpoints=2)
    cell = ShapeCell("pretrain", args.seq, args.batch, "train")
    trainer = Trainer(bundle, tcfg, qcfg, cell=cell,
                      param_dtype=jnp.float32)
    resumed = trainer.maybe_restore()
    if resumed:
        print(f"resumed from step {resumed}")

    hist = trainer.run()
    print(f"\ntrain loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(f"held-out loss: {trainer.eval_loss(4):.3f}")
    used = trainer.controller.total_svd_count()
    base = trainer.controller.baseline_svd_count(args.steps)
    print(f"SVD calls: {used}/{base} "
          f"({100 * (1 - used / max(base, 1)):.0f}% saved by lazy update)")
    print("per-layer intervals:",
          {k.split('/')[-2]: v[:4]
           for k, v in list(trainer.controller.interval_summary().items())[:3]})


if __name__ == "__main__":
    main()
