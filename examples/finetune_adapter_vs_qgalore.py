"""Fine-tuning with the composable optimizer API (paper Tables 3-4 story):
pre-train a small base, then fine-tune it two ways at the SAME rank —

* **Q-GaLore via param-group rules** (`repro.core.rules`): embedding /
  head / early layers frozen (zero optimizer state), late blocks get the
  INT4-projection + INT8-weight + 8-bit-Adam recipe through the optax-style
  transform chain (`repro.core.transform.qgalore_transform`);
* **QLoRA** (`repro.models.lora`): frozen INT8 base + fp32 LoRA adapters
  (now covering the stacked block weights) with fp32 Adam on the adapters.

and report final loss plus weights+optimizer memory for both.

    PYTHONPATH=src python examples/finetune_adapter_vs_qgalore.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
from repro.core import qgalore, quant, transform
from repro.core.optimizers import lr_at, preset
from repro.data.synthetic import batch_for_bundle
from repro.launch.finetune import build_finetune_rules
from repro.models import base as base_lib, lora as lora_lib, model_zoo
from repro.train import stack, step as step_lib
from repro.train.trainer import Trainer

CELL = ShapeCell("finetune", seq_len=32, global_batch=4, kind="train")


def pretrain_base(bundle, steps: int):
    tcfg = TrainConfig(global_batch=4, seq_len=32, steps=steps,
                       learning_rate=3e-3, warmup_steps=2, log_every=0)
    tr = Trainer(bundle, tcfg, preset("full"), cell=CELL,
                 param_dtype=jnp.float32)
    tr.run()
    return tr.state.params


def finetune_qgalore(bundle, base_params, steps: int, rank: int,
                     seed: int = 101):
    """Group-ruled Q-GaLore fine-tune through the transform chain —
    the SAME rule-set the production launcher builds."""
    rules = build_finetune_rules(QGaLoreConfig(rank=rank, min_dim=32),
                                 rank)
    params = step_lib.prepare_params(base_params, rules, jnp.float32)
    specs = qgalore.leaf_specs(params, rules)
    tx = transform.qgalore_transform(rules, specs=specs)
    state = tx.init(params, jax.random.PRNGKey(seed))
    tcfg = TrainConfig(steps=steps, learning_rate=2e-3, warmup_steps=2,
                       seed=seed)
    refresh_every = max(steps // 4, 2)
    masks = {i: jnp.ones((s.nbatch,), bool)
             for i, s in enumerate(specs) if s.galore}

    def make_step(refresh):
        def step(p, st, batch, lr, rng):
            (loss, _), grads = stack.fused_value_and_grad(bundle, p,
                                                          batch, {})
            grads, _ = transform.clip_by_global_norm(grads, 1.0,
                                                     specs=specs)
            p, st, _ = tx.update(grads, st, p, lr=lr, rng=rng,
                                 refresh_masks=masks if refresh else None,
                                 refresh=refresh)
            return p, st, loss
        return jax.jit(step)

    steady, refreshing = make_step(False), make_step(True)
    losses = []
    for s in range(steps):
        batch = batch_for_bundle(bundle, CELL, s, seed)
        fn = refreshing if s % refresh_every == 0 else steady
        params, state, loss = fn(params, state, batch, lr_at(s, tcfg),
                                 jax.random.PRNGKey(1000 + s))
        losses.append(float(loss))
    mem = qgalore.memory_report(params, rules)["total_gb"]
    return {"final_loss": float(np.mean(losses[-5:])), "memory_gb": mem}


def finetune_qlora(bundle, base_params, steps: int, rank: int,
                   seed: int = 101):
    """QLoRA baseline: INT8 frozen base, fp32 adapters, fp32 Adam."""
    params = quant.tree_quantize(
        base_params, bits=8, symmetric=True,
        predicate=lambda p, l: l.ndim >= 2 and l.shape[-1] >= 32)
    adapters = lora_lib.init_adapters(params, rank, jax.random.PRNGKey(7))
    qcfg = preset("full")
    state = qgalore.init(adapters, qcfg)
    specs = qgalore.leaf_specs(adapters, qcfg)
    tcfg = TrainConfig(steps=steps, learning_rate=2e-3, warmup_steps=2,
                       seed=seed)

    def loss_fn(ad, b):
        return base_lib.loss_fn(bundle, lora_lib.merge(params, ad,
                                                       rank=rank), b)

    @jax.jit
    def step(ad, st, b, lr, rng):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(ad, b)
        ad, st, _ = qgalore.apply_updates(ad, g, st, qcfg, lr=lr, rng=rng,
                                          specs=specs)
        return ad, st, loss

    losses = []
    for s in range(steps):
        b = batch_for_bundle(bundle, CELL, s, seed)
        adapters, state, loss = step(adapters, state, b, lr_at(s, tcfg),
                                     jax.random.PRNGKey(2000 + s))
        losses.append(float(loss))
    # BOTH comparison sides share memory_report's convention (fp leaves
    # at the bf16 baseline, fp Adam at fp_state_bytes): base weights via
    # its weights_gb, adapters + their full-Adam state via a report over
    # the adapter tree — mirrors launch/finetune.py
    weights_gb = qgalore.memory_report(params, preset("full"))["weights_gb"]
    mem = weights_gb + \
        qgalore.memory_report(adapters, preset("full"))["total_gb"]
    return {"final_loss": float(np.mean(losses[-5:])), "memory_gb": mem}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--pretrain-steps", type=int, default=20)
    ap.add_argument("--rank", type=int, default=8)
    args = ap.parse_args()

    bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                  dtype=jnp.float32, split_layers=1)
    base_params = pretrain_base(bundle, args.pretrain_steps)
    rows = {
        "qgalore": finetune_qgalore(bundle, base_params, args.steps,
                                    args.rank),
        "qlora": finetune_qlora(bundle, base_params, args.steps,
                                args.rank),
    }
    print("\n=== fine-tune at rank", args.rank, "(lower is better) ===")
    for name, r in rows.items():
        print(f"  {name:8s} loss={r['final_loss']:.3f} "
              f"mem={r['memory_gb'] * 1024:.2f}MiB")
    assert rows["qgalore"]["memory_gb"] <= rows["qlora"]["memory_gb"]
    print("\nQ-GaLore fine-tunes at or below QLoRA's memory "
          "while updating full-rank weights (paper Tables 3-4 claim).")


if __name__ == "__main__":
    main()
