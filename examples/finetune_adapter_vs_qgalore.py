"""Fine-tuning comparison (paper Tables 3-4 workflow): take a pre-trained
base, fine-tune on a shifted synthetic task with Q-GaLore vs QLoRA at the
same memory tier, and report both loss and the weights+optimizer memory.

    PYTHONPATH=src python examples/finetune_adapter_vs_qgalore.py
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import table34_finetune as t34


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()
    rows = t34.main(args.steps)
    print("\n=== summary (lower loss better) ===")
    for name, r in rows.items():
        print(f"  {name:10s} loss={r['final_loss']:.3f} "
              f"mem={r['memory_gb'] * 1024:.1f}MB")
    print("\nQ-GaLore vs QLoRA at the low-memory tier: "
          f"{rows['qgalore']['final_loss']:.3f} vs "
          f"{rows['qlora']['final_loss']:.3f}")


if __name__ == "__main__":
    main()
