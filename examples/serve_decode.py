"""Serve a small model with batched requests: INT8 weights, prefill +
greedy decode with stacked KV caches.

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b

``--continuous``: run the same work through the continuous-batching
scheduler (ragged prompts, mixed output lengths, slot reuse) instead of
one lockstep batch — see docs/serving.md.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import QGaLoreConfig
from repro.models import model_zoo
from repro.serve import engine
from repro.serve.scheduler import Request, Scheduler
from repro.train import step as step_lib


def run_continuous(bundle, params, args):
    rng = np.random.default_rng(42)
    reqs = [Request(rid=r,
                    tokens=rng.integers(
                        1, bundle.cfg.vocab_size,
                        size=int(rng.integers(
                            4, args.prompt_len + 1))).astype(np.int32),
                    max_new_tokens=int(rng.integers(
                        2, max(args.new_tokens, 3))))
            for r in range(args.batch * 3)]
    sched = Scheduler(
        bundle, params, num_slots=args.batch,
        max_len=args.prompt_len + args.new_tokens + 1,
        temperature=args.temperature, dtype=jnp.float32)
    t0 = time.monotonic()
    comps = sched.run(reqs)
    dt = time.monotonic() - t0
    total = sum(len(c.tokens) for c in comps)
    print(f"continuous: {len(reqs)} requests over {args.batch} slots, "
          f"{total} tokens in {dt:.2f}s ({total / dt:.1f} tok/s incl. "
          f"compile), stats={sched.stats}")
    for c in comps[: 2]:
        print(f"  request {c.rid}: {c.tokens[:12]} ... "
              f"latency={c.latency * 1e3:.0f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--int8", action="store_true", default=True)
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler instead of one "
                         "lockstep batch")
    args = ap.parse_args()

    bundle = model_zoo.build_arch(args.arch, smoke=True, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    if args.int8:
        params = step_lib.prepare_params(params, QGaLoreConfig(),
                                         jnp.float32)

    if args.continuous:
        run_continuous(bundle, params, args)
        return

    key = jax.random.PRNGKey(42)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, bundle.cfg.vocab_size)}
    specs = bundle.input_specs(
        type("C", (), {"global_batch": args.batch,
                       "seq_len": args.prompt_len, "kind": "prefill"})())
    for name, spec in specs.items():
        if name not in batch and name != "labels":
            batch[name] = jnp.zeros(spec.shape, spec.dtype)

    t0 = time.monotonic()
    toks, state = engine.generate(
        bundle, params, batch,
        steps=args.new_tokens,
        max_len=args.prompt_len + args.new_tokens + 1,
        temperature=args.temperature)
    dt = time.monotonic() - t0
    print(f"arch={args.arch} int8_weights={args.int8}")
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"  request {b}: {list(map(int, toks[b][:12]))} ...")


if __name__ == "__main__":
    main()
