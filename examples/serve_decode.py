"""Serve a small model with batched requests: INT8 weights, prefill +
greedy decode with stacked KV caches.

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-2.7b
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.config import QGaLoreConfig
from repro.models import model_zoo
from repro.serve import engine
from repro.train import step as step_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama-60m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--int8", action="store_true", default=True)
    args = ap.parse_args()

    bundle = model_zoo.build_arch(args.arch, smoke=True, dtype=jnp.float32)
    params = bundle.init_params(jax.random.PRNGKey(0))
    if args.int8:
        params = step_lib.prepare_params(params, QGaLoreConfig(),
                                         jnp.float32)

    key = jax.random.PRNGKey(42)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, bundle.cfg.vocab_size)}
    specs = bundle.input_specs(
        type("C", (), {"global_batch": args.batch,
                       "seq_len": args.prompt_len, "kind": "prefill"})())
    for name, spec in specs.items():
        if name not in batch and name != "labels":
            batch[name] = jnp.zeros(spec.shape, spec.dtype)

    t0 = time.monotonic()
    toks, state = engine.generate(
        bundle, params, batch,
        steps=args.new_tokens,
        max_len=args.prompt_len + args.new_tokens + 1,
        temperature=args.temperature)
    dt = time.monotonic() - t0
    print(f"arch={args.arch} int8_weights={args.int8}")
    print(f"generated {toks.shape} tokens in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"  request {b}: {list(map(int, toks[b][:12]))} ...")


if __name__ == "__main__":
    main()
