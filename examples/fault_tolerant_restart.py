"""Fault-tolerance demo: a simulated node failure mid-run, automatic restore
from the last atomic checkpoint, and bit-exact trajectory continuation.

    PYTHONPATH=src python examples/fault_tolerant_restart.py
"""
import logging

import jax.numpy as jnp

from repro.config import QGaLoreConfig, ShapeCell, TrainConfig
from repro.core.optimizers import preset
from repro.models import model_zoo
from repro.train.trainer import Trainer

logging.basicConfig(level=logging.INFO)

CKPT = "/tmp/repro_fault_demo"
CELL = ShapeCell("demo", 64, 8, "train")


def make(fault_hook=None):
    bundle = model_zoo.build_arch("llama-60m", smoke=True,
                                  dtype=jnp.float32)
    qcfg = preset("qgalore", QGaLoreConfig(rank=8, min_dim=32,
                                           update_interval=10))
    tcfg = TrainConfig(global_batch=8, seq_len=64, steps=40,
                       learning_rate=5e-3, warmup_steps=5, log_every=10,
                       checkpoint_dir=CKPT, checkpoint_every=10,
                       async_checkpoint=True)
    return Trainer(bundle, tcfg, qcfg, cell=CELL, param_dtype=jnp.float32,
                   fault_hook=fault_hook)


def main():
    import shutil
    shutil.rmtree(CKPT, ignore_errors=True)

    crashed = {"armed": True}

    def failure(step):
        if step == 25 and crashed["armed"]:
            crashed["armed"] = False
            raise RuntimeError("simulated node failure at step 25")

    print("=== run with injected failure at step 25 ===")
    tr = make(failure)
    hist = tr.run()
    print(f"completed {len(hist)} logged steps despite the failure; "
          f"final loss {hist[-1]['loss']:.4f}")

    print("\n=== reference run without failure ===")
    shutil.rmtree(CKPT, ignore_errors=True)
    ref = make().run()
    print(f"reference final loss {ref[-1]['loss']:.4f}")
    drift = abs(ref[-1]["loss"] - hist[-1]["loss"])
    print(f"trajectory drift after recovery: {drift:.5f} "
          f"({'EXACT' if drift < 1e-3 else 'nonzero — expected if the '
              'failure landed between checkpoints'})")


if __name__ == "__main__":
    main()
